# pytest: Pallas kernel vs pure-numpy ref — the CORE L1 correctness signal.
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.cminhash import cminhash_hashes, choose_tile


def _rand_pair(rng, b, d, density):
    bits = (rng.random((b, d)) < density).astype(np.int32)
    pi = rng.permutation(d).astype(np.int32)
    return bits, pi


def _run_kernel(bits, pi, k, **kw):
    pi2 = np.concatenate([pi, pi]).astype(np.int32)
    return np.asarray(cminhash_hashes(jnp.array(bits), jnp.array(pi2), k, **kw))


# ---------------------------------------------------------------------------
# Deterministic unit tests
# ---------------------------------------------------------------------------


def test_matches_ref_basic():
    rng = np.random.default_rng(1)
    bits, pi = _rand_pair(rng, 4, 64, 0.2)
    got = _run_kernel(bits, pi, 32)
    want = ref.cminhash_0pi_ref(bits, pi, 32)
    np.testing.assert_array_equal(got, want)


def test_empty_row_sentinel():
    rng = np.random.default_rng(2)
    bits, pi = _rand_pair(rng, 3, 32, 0.3)
    bits[1] = 0
    got = _run_kernel(bits, pi, 16)
    assert (got[1] == 32).all()
    want = ref.cminhash_0pi_ref(bits, pi, 16)
    np.testing.assert_array_equal(got, want)


def test_full_row_is_global_min_everywhere():
    # A row of all ones sees every pi value under every shift: hash == 0.
    rng = np.random.default_rng(3)
    bits = np.ones((2, 48), dtype=np.int32)
    pi = rng.permutation(48).astype(np.int32)
    got = _run_kernel(bits, pi, 48)
    assert (got == 0).all()


def test_single_nonzero_traces_permutation():
    # One nonzero at position j: h_k = pi[(j - k) mod D], a walk over pi.
    d, k = 40, 40
    rng = np.random.default_rng(4)
    pi = rng.permutation(d).astype(np.int32)
    for j in [0, 7, d - 1]:
        bits = np.zeros((1, d), dtype=np.int32)
        bits[0, j] = 1
        got = _run_kernel(bits, pi, k)[0]
        want = np.array([pi[(j - kk) % d] for kk in range(1, k + 1)])
        np.testing.assert_array_equal(got, want)


def test_k_equals_one_and_k_equals_d():
    rng = np.random.default_rng(5)
    bits, pi = _rand_pair(rng, 2, 32, 0.25)
    for k in (1, 32):
        np.testing.assert_array_equal(
            _run_kernel(bits, pi, k), ref.cminhash_0pi_ref(bits, pi, k)
        )


def test_identity_permutation():
    # pi = identity: h_k = min_{i in S} (i - k) mod D.
    d, k = 24, 24
    pi = np.arange(d, dtype=np.int32)
    bits = np.zeros((1, d), dtype=np.int32)
    bits[0, [3, 10, 17]] = 1
    got = _run_kernel(bits, pi, k)
    want = ref.cminhash_0pi_ref(bits, pi, k)
    np.testing.assert_array_equal(got, want)


def test_tiling_invariance():
    # The same result regardless of block/chunk choices.
    rng = np.random.default_rng(6)
    bits, pi = _rand_pair(rng, 6, 96, 0.15)
    base = _run_kernel(bits, pi, 48)
    for bb, kb, dc in [(1, 1, 1), (2, 3, 8), (6, 48, 96), (3, 16, 32)]:
        got = _run_kernel(bits, pi, 48, block_b=bb, block_k=kb, chunk_d=dc)
        np.testing.assert_array_equal(got, base)


def test_rejects_bad_args():
    bits = jnp.zeros((2, 16), jnp.int32)
    with pytest.raises(ValueError):
        cminhash_hashes(bits, jnp.zeros((16,), jnp.int32), 8)  # pi not doubled
    with pytest.raises(ValueError):
        cminhash_hashes(bits, jnp.zeros((32,), jnp.int32), 17)  # K > D
    with pytest.raises(ValueError):
        cminhash_hashes(bits, jnp.zeros((32,), jnp.int32), 0)  # K < 1


def test_choose_tile():
    assert choose_tile(64, 8) == 8
    assert choose_tile(6, 4) == 3
    assert choose_tile(7, 4) == 1
    assert choose_tile(5, 16) == 5


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, densities, seeds
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 7),
    d=st.integers(2, 80),
    kfrac=st.floats(0.05, 1.0),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_vs_ref_sweep(b, d, kfrac, density, seed):
    k = max(1, int(d * kfrac))
    rng = np.random.default_rng(seed)
    bits, pi = _rand_pair(rng, b, d, density)
    got = _run_kernel(bits, pi, k)
    want = ref.cminhash_0pi_ref(bits, pi, k)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(
    d=st.integers(4, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_hash_values_in_range(d, seed):
    rng = np.random.default_rng(seed)
    bits, pi = _rand_pair(rng, 3, d, 0.5)
    got = _run_kernel(bits, pi, d)
    assert ((got >= 0) & (got <= d)).all()
    nonempty = bits.sum(axis=1) > 0
    assert (got[nonempty] < d).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_shift_consistency(seed):
    # Hash k of bits equals hash k+1 of bits rolled right by one position:
    # rolling the data one step is the same as shifting pi one more unit.
    d, k = 32, 16
    rng = np.random.default_rng(seed)
    bits, pi = _rand_pair(rng, 2, d, 0.3)
    h = _run_kernel(bits, pi, k + 1)
    rolled = np.roll(bits, 1, axis=1)
    h_roll = _run_kernel(rolled, pi, k + 1)
    np.testing.assert_array_equal(h_roll[:, 1:], h[:, :-1])
