# pytest: AOT emission smoke tests — variant table sanity, HLO text
# round-trips through the XLA text parser, manifest consistency.
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_variant_table_well_formed():
    table = aot.variant_table()
    assert len(table) >= 6
    for name, (fn, args, meta) in table.items():
        assert callable(fn)
        assert len(meta["inputs"]) == len(args)
        for spec, inp in zip(args, meta["inputs"]):
            assert list(spec.shape) == inp["shape"], name


def test_lower_small_variant_to_hlo_text():
    table = aot.variant_table()
    name = "cminhash_b8_d1024_k128"
    fn, args, _ = table[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "HloModule" in text
    # Text must contain no 64-bit ids the 0.5.1 parser would choke on —
    # the parser reassigns ids, so presence of ENTRY is the smoke signal.
    assert "ENTRY" in text


def test_emit_and_manifest(tmp_path):
    # Run the real CLI for a single small variant.
    env = dict(os.environ)
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--outdir",
            str(tmp_path),
            "--only",
            "cminhash_b8_d1024_k128,estimate_n8_m8_k128",
        ],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text-v1"
    arts = manifest["artifacts"]
    assert set(arts) == {"cminhash_b8_d1024_k128", "estimate_n8_m8_k128"}
    for meta in arts.values():
        assert (tmp_path / meta["file"]).exists()
        assert meta["inputs"] and meta["outputs"]


def test_lowered_variant_executes_correctly():
    # Execute the jitted (pre-lowering) graph and compare to the oracle —
    # the same computation Rust will run from the artifact.
    b, d, k = 8, 1024, 128
    rng = np.random.default_rng(7)
    bits = (rng.random((b, d)) < 0.05).astype(np.int32)
    sigma = rng.permutation(d).astype(np.int32)
    pi = rng.permutation(d).astype(np.int32)
    pi2 = np.concatenate([pi, pi])
    table = aot.variant_table()
    fn, _, _ = table[f"cminhash_b{b}_d{d}_k{k}"]
    got = np.asarray(jax.jit(fn)(jnp.array(bits), jnp.array(sigma), jnp.array(pi2)))
    want = ref.cminhash_sigma_pi_ref(bits, sigma, pi, k)
    np.testing.assert_array_equal(got, want)
