# pytest: L2 pipelines vs oracles — sigma pipeline, classic baseline,
# estimator graph, fused graph, and statistical sanity (unbiasedness).
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _mk(rng, b, d, density=0.2):
    bits = (rng.random((b, d)) < density).astype(np.int32)
    sigma = rng.permutation(d).astype(np.int32)
    pi = rng.permutation(d).astype(np.int32)
    pi2 = np.concatenate([pi, pi])
    return bits, sigma, pi, pi2


def test_sigma_pi_matches_ref():
    rng = np.random.default_rng(10)
    bits, sigma, pi, pi2 = _mk(rng, 5, 64)
    got = np.asarray(
        model.cminhash_sigma_pi(jnp.array(bits), jnp.array(sigma), jnp.array(pi2), k=32)
    )
    want = ref.cminhash_sigma_pi_ref(bits, sigma, pi, 32)
    np.testing.assert_array_equal(got, want)


def test_zero_pi_matches_ref():
    rng = np.random.default_rng(11)
    bits, _, pi, pi2 = _mk(rng, 5, 64)
    got = np.asarray(model.cminhash_0_pi(jnp.array(bits), jnp.array(pi2), k=32))
    want = ref.cminhash_0pi_ref(bits, pi, 32)
    np.testing.assert_array_equal(got, want)


def test_classic_matches_ref():
    rng = np.random.default_rng(12)
    bits, _, _, _ = _mk(rng, 5, 64)
    perms = np.stack([rng.permutation(64) for _ in range(24)]).astype(np.int32)
    got = np.asarray(model.minhash_classic(jnp.array(bits), jnp.array(perms)))
    want = ref.minhash_ref(bits, perms)
    np.testing.assert_array_equal(got, want)


def test_estimator_matches_ref():
    rng = np.random.default_rng(13)
    h1 = rng.integers(0, 50, size=(6, 40)).astype(np.int32)
    h2 = rng.integers(0, 50, size=(4, 40)).astype(np.int32)
    got = np.asarray(model.estimate_pairwise(jnp.array(h1), jnp.array(h2)))
    np.testing.assert_allclose(got, ref.estimate_ref(h1, h2), atol=1e-6)


def test_estimator_self_is_one():
    rng = np.random.default_rng(14)
    h = rng.integers(0, 100, size=(5, 32)).astype(np.int32)
    got = np.asarray(model.estimate_pairwise(jnp.array(h), jnp.array(h)))
    np.testing.assert_allclose(np.diag(got), 1.0)


def test_fused_graph_consistent():
    rng = np.random.default_rng(15)
    bits1, sigma, pi, pi2 = _mk(rng, 4, 64)
    bits2 = (rng.random((4, 64)) < 0.2).astype(np.int32)
    h1, h2, jh = model.sketch_and_estimate(
        jnp.array(bits1), jnp.array(bits2), jnp.array(sigma), jnp.array(pi2), k=32
    )
    np.testing.assert_array_equal(
        np.asarray(h1), ref.cminhash_sigma_pi_ref(bits1, sigma, pi, 32)
    )
    np.testing.assert_array_equal(
        np.asarray(h2), ref.cminhash_sigma_pi_ref(bits2, sigma, pi, 32)
    )
    np.testing.assert_allclose(
        np.asarray(jh), ref.estimate_ref(np.asarray(h1), np.asarray(h2)), atol=1e-6
    )


@settings(max_examples=8, deadline=None)
@given(
    d=st.integers(8, 64),
    density=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_sigma_pipeline_sweep(d, density, seed):
    rng = np.random.default_rng(seed)
    bits, sigma, pi, pi2 = _mk(rng, 3, d, density)
    k = max(1, d // 2)
    got = np.asarray(
        model.cminhash_sigma_pi(jnp.array(bits), jnp.array(sigma), jnp.array(pi2), k=k)
    )
    want = ref.cminhash_sigma_pi_ref(bits, sigma, pi, k)
    np.testing.assert_array_equal(got, want)


def test_unbiasedness_statistical():
    # E[J_hat] = J (paper section 3): average over many (sigma, pi) draws.
    rng = np.random.default_rng(99)
    d, k, reps = 64, 32, 300
    v = np.zeros(d, dtype=np.int32)
    w = np.zeros(d, dtype=np.int32)
    v[:16] = 1
    w[8:24] = 1  # a=8, f=24, J=1/3
    true_j = ref.jaccard(v, w)
    bits = np.stack([v, w])
    acc = 0.0
    for _ in range(reps):
        sigma = rng.permutation(d).astype(np.int32)
        pi = rng.permutation(d).astype(np.int32)
        h = ref.cminhash_sigma_pi_ref(bits, sigma, pi, k)
        acc += (h[0] == h[1]).mean()
    est = acc / reps
    # sd of the mean-of-means is well under 0.01 here
    assert abs(est - true_j) < 0.03, (est, true_j)
