# pytest: sparse (gather) kernel vs dense kernel vs oracle — the §Perf
# hot path must stay bit-identical to the reference semantics.
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.cminhash import cminhash_sparse_hashes, PAD


def _pack(bits, f_max):
    """Dense 0/1 rows -> padded index matrix."""
    b, d = bits.shape
    idx = np.full((b, f_max), PAD(d), dtype=np.int32)
    for i in range(b):
        nz = np.nonzero(bits[i])[0]
        assert len(nz) <= f_max
        idx[i, : len(nz)] = nz
    return idx


def _mk(rng, b, d, density):
    bits = (rng.random((b, d)) < density).astype(np.int32)
    pi = rng.permutation(d).astype(np.int32)
    pi3 = np.concatenate([pi, pi, np.full(d, d, np.int32)])
    return bits, pi, pi3


def test_sparse_matches_ref_basic():
    rng = np.random.default_rng(1)
    bits, pi, pi3 = _mk(rng, 6, 128, 0.1)
    idx = _pack(bits, 32)
    got = np.asarray(cminhash_sparse_hashes(jnp.array(idx), jnp.array(pi3), 64))
    want = ref.cminhash_0pi_ref(bits, pi, 64)
    np.testing.assert_array_equal(got, want)


def test_all_padding_row_gives_sentinel():
    rng = np.random.default_rng(2)
    _, _, pi3 = _mk(rng, 1, 64, 0.0)
    idx = np.full((2, 16), PAD(64), dtype=np.int32)
    got = np.asarray(cminhash_sparse_hashes(jnp.array(idx), jnp.array(pi3), 32))
    assert (got == 64).all()


def test_unsorted_indices_are_fine():
    # The kernel takes min over contributions; order must not matter.
    rng = np.random.default_rng(3)
    bits, pi, pi3 = _mk(rng, 1, 64, 0.3)
    idx = _pack(bits, 32)
    shuffled = idx.copy()
    rng.shuffle(shuffled[0])
    a = np.asarray(cminhash_sparse_hashes(jnp.array(idx), jnp.array(pi3), 32))
    b = np.asarray(cminhash_sparse_hashes(jnp.array(shuffled), jnp.array(pi3), 32))
    np.testing.assert_array_equal(a, b)


def test_sparse_pipeline_with_sigma_matches_dense():
    rng = np.random.default_rng(4)
    b, d, k, f = 4, 256, 128, 64
    bits, pi, pi3 = _mk(rng, b, d, 0.1)
    sigma = rng.permutation(d).astype(np.int32)
    inv_sigma = np.argsort(sigma).astype(np.int32)
    idx = _pack(bits, f)
    got = np.asarray(
        model.cminhash_sigma_pi_sparse(
            jnp.array(idx), jnp.array(inv_sigma), jnp.array(pi3), k=k
        )
    )
    want = ref.cminhash_sigma_pi_ref(bits, sigma, pi, k)
    np.testing.assert_array_equal(got, want)


def test_rejects_bad_args():
    with pytest.raises(ValueError):
        cminhash_sparse_hashes(
            jnp.zeros((2, 4), jnp.int32), jnp.zeros((64,), jnp.int32), 8
        )  # pi3 not a multiple of 3... 64 not divisible
    with pytest.raises(ValueError):
        cminhash_sparse_hashes(
            jnp.zeros((2, 4), jnp.int32), jnp.zeros((3 * 16,), jnp.int32), 17
        )  # K > D


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 5),
    d=st.integers(4, 96),
    density=st.floats(0.0, 0.6),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_vs_dense_sweep(b, d, density, seed):
    rng = np.random.default_rng(seed)
    bits, pi, pi3 = _mk(rng, b, d, density)
    k = max(1, d // 2)
    f_max = max(1, int(bits.sum(axis=1).max()))
    idx = _pack(bits, f_max)
    got = np.asarray(cminhash_sparse_hashes(jnp.array(idx), jnp.array(pi3), k))
    want = ref.cminhash_0pi_ref(bits, pi, k)
    np.testing.assert_array_equal(got, want)
