"""L1 — Pallas C-MinHash kernel.

Computes, for a batch of dense binary vectors, all K circulant-MinHash
values at once:

    H[b, k] = min_{i : bits[b, i] != 0}  pi_{->(k+1)}(i)
            = min_{i : bits[b, i] != 0}  pi[(i - (k+1)) mod D]

for k = 0..K-1 (the paper's Algorithm 2/3 uses shifts 1..K; we index the
output 0-based but keep the 1-based shift amounts so the k-th hash matches
the paper exactly).  Empty rows hash to the sentinel ``D``.

The kernel receives the *doubled* permutation ``pi2 = concat(pi, pi)`` so
that ``pi[(i - k) mod D] == pi2[i - k + D]`` without any modular
arithmetic in the hot loop.  The circulant structure is the whole point
of the paper's memory story, and it maps directly onto the TPU memory
hierarchy: an output tile of Kb hash slots x a Dc-chunk of input columns
only needs a *contiguous window* of ``Dc + Kb`` permutation entries in
VMEM, so per-tile permutation traffic is O(K + D) instead of classical
MinHash's O(K * D) permutation-matrix stream (see DESIGN.md
section "Hardware adaptation").

Pallas is invoked with ``interpret=True``: this image only has the CPU
PJRT plugin, and real-TPU lowering would emit a Mosaic custom-call the
CPU client cannot execute.  The interpret path lowers to plain HLO, which
is exactly what the Rust runtime loads.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cminhash_hashes", "cminhash_sparse_hashes", "choose_tile", "PAD"]


def PAD(d: int) -> int:
    """Padding index for the sparse kernel: points at the sentinel
    segment of ``pi3`` (see :func:`cminhash_sparse_hashes`)."""
    return 2 * d


def choose_tile(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (>= 1)."""
    t = min(n, cap)
    while n % t != 0:
        t -= 1
    return t


def _kernel(bits_ref, pi2_ref, out_ref, *, kb: int, dc: int, d: int):
    """One (Bb x Kb) output tile.

    bits_ref : (Bb, D)  int32 0/1 mask for this batch tile
    pi2_ref  : (2D,)    int32 doubled permutation
    out_ref  : (Bb, Kb) int32 hash values
    """
    kj = pl.program_id(1)
    k0 = kj * kb  # first (0-based) hash slot of this tile

    bb = bits_ref.shape[0]
    acc0 = jnp.full((bb, kb), d, dtype=jnp.int32)

    # Relative gather offsets inside the pi2 window, shape (Kb, Dc):
    #   off[k_rel, i_rel] = (Kb - 1) + i_rel - k_rel
    i_rel = jax.lax.broadcasted_iota(jnp.int32, (kb, dc), 1)
    k_rel = jax.lax.broadcasted_iota(jnp.int32, (kb, dc), 0)
    offs = (kb - 1) + i_rel - k_rel  # in [0, Dc + Kb - 1)

    def body(c, acc):
        i0 = c * dc
        # Window start in pi2: idx = i - (k0 + 1 + k_rel) + D
        #                          = w0 + (Kb - 1) + i_rel - k_rel
        # with w0 = i0 + D - k0 - Kb.  K <= D guarantees w0 >= 0 and the
        # window end <= 2D (see DESIGN.md).
        w0 = i0 + d - k0 - kb
        window = pi2_ref[pl.dslice(w0, dc + kb)]
        pvals = window[offs]  # (Kb, Dc) permutation values
        bits_c = bits_ref[:, pl.dslice(i0, dc)]
        # masked[b, k, i] = pvals[k, i] where bit set else sentinel D
        masked = jnp.where(
            (bits_c > 0)[:, None, :], pvals[None, :, :], jnp.int32(d)
        )
        return jnp.minimum(acc, masked.min(axis=2))

    out_ref[...] = jax.lax.fori_loop(0, d // dc, body, acc0)


def cminhash_hashes(
    bits: jax.Array,
    pi2: jax.Array,
    k: int,
    *,
    block_b: int = 8,
    block_k: int = 128,
    chunk_d: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """All K C-MinHash values for a batch of dense binary rows.

    Args:
      bits: (B, D) int32 0/1 matrix (rows already permuted by sigma if
        the (sigma, pi) variant is wanted; pass raw rows for (0, pi)).
      pi2: (2D,) int32 doubled permutation ``concat(pi, pi)``.
      k: number of hashes; requires ``k <= D`` (paper's standing
        assumption).
    Returns:
      (B, K) int32; ``H[b, j]`` is the paper's ``h_{j+1}``; empty rows
      yield the sentinel value ``D``.
    """
    b, d = bits.shape
    if pi2.shape != (2 * d,):
        raise ValueError(f"pi2 must have shape {(2 * d,)}, got {pi2.shape}")
    if not 1 <= k <= d:
        raise ValueError(f"need 1 <= K <= D, got K={k}, D={d}")

    bb = choose_tile(b, block_b)
    kb = choose_tile(k, block_k)
    dc = choose_tile(d, chunk_d)

    return pl.pallas_call(
        partial(_kernel, kb=kb, dc=dc, d=d),
        grid=(b // bb, k // kb),
        in_specs=[
            pl.BlockSpec((bb, d), lambda bi, kj: (bi, 0)),
            pl.BlockSpec((2 * d,), lambda bi, kj: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, kb), lambda bi, kj: (bi, kj)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.int32),
        interpret=interpret,
    )(bits.astype(jnp.int32), pi2.astype(jnp.int32))


def _sparse_kernel(idx_ref, pi3_ref, out_ref, *, kb: int, fc: int, d: int):
    """One (Bb x Kb) output tile of the sparse (gather) kernel.

    idx_ref : (Bb, F)  int32 nonzero positions, padded with ``PAD(d)``
    pi3_ref : (3D,)    int32 ``pi ‖ pi ‖ [D]*D`` (sentinel tail)
    out_ref : (Bb, Kb) int32 hash values
    """
    kj = pl.program_id(1)
    k0 = kj * kb
    bb, f = idx_ref.shape
    acc0 = jnp.full((bb, kb), d, dtype=jnp.int32)
    kr = jax.lax.broadcasted_iota(jnp.int32, (1, 1, kb), 2)

    def body(c, acc):
        j0 = c * fc
        ii = idx_ref[:, pl.dslice(j0, fc)]  # (Bb, Fc)
        # value of hash (k0 + kr + 1) contributed by nonzero at ii:
        #   pi[(ii - (k0+kr+1)) mod D] = pi3[ii + D - k0 - 1 - kr];
        # padded entries (ii = 2D) land in the sentinel tail -> D.
        offs = ii[:, :, None] + (d - k0 - 1) - kr  # (Bb, Fc, Kb)
        return jnp.minimum(acc, pi3_ref[offs].min(axis=1))

    out_ref[...] = jax.lax.fori_loop(0, f // fc, body, acc0)


def cminhash_sparse_hashes(
    indices: jax.Array,
    pi3: jax.Array,
    k: int,
    *,
    block_b: int = 8,
    block_k: int = 256,
    chunk_f: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """All K C-MinHash values from *sparse* rows — the optimized hot
    path (§Perf: ~10x over the dense kernel at D/F = 16).

    Work is O(B·F·K) instead of the dense kernel's O(B·D·K): each
    nonzero gathers its K-long reversed window from the tripled
    permutation ``pi3 = pi ‖ pi ‖ [D]*D``; padding indices ``PAD(d)``
    hit the sentinel tail and contribute the empty-hash value ``D``.

    Args:
      indices: (B, F) int32 nonzero positions per row (any order),
        padded with ``PAD(d) = 2*D``.
      pi3: (3D,) int32 tripled permutation with sentinel tail.
      k: number of hashes, 1 ≤ K ≤ D.
    Returns:
      (B, K) int32, identical to :func:`cminhash_hashes` on the
      equivalent dense rows.
    """
    b, f = indices.shape
    if pi3.shape[0] % 3 != 0:
        raise ValueError(f"pi3 must have shape (3*D,), got {pi3.shape}")
    d = pi3.shape[0] // 3
    if not 1 <= k <= d:
        raise ValueError(f"need 1 <= K <= D, got K={k}, D={d}")

    bb = choose_tile(b, block_b)
    kb = choose_tile(k, block_k)
    fc = choose_tile(f, chunk_f)

    return pl.pallas_call(
        partial(_sparse_kernel, kb=kb, fc=fc, d=d),
        grid=(b // bb, k // kb),
        in_specs=[
            pl.BlockSpec((bb, f), lambda bi, kj: (bi, 0)),
            pl.BlockSpec((3 * d,), lambda bi, kj: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, kb), lambda bi, kj: (bi, kj)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.int32),
        interpret=interpret,
    )(indices.astype(jnp.int32), pi3.astype(jnp.int32))
