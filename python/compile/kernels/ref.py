"""Pure-numpy correctness oracles for every hashing variant.

These are deliberately written as literal transcriptions of the paper's
Algorithms 1-3 (loops, no vectorization tricks) so they can serve as the
single source of truth for the Pallas kernel (pytest), the jnp pipelines
(pytest) and the Rust implementations (golden vectors exported by
``python/tests/test_golden_export.py``).

Conventions (shared across the whole repo):
  * permutations are 0-indexed value arrays: ``pi[i]`` is the slot that
    position ``i`` is mapped to, values in ``0..D-1``;
  * the k-th C-MinHash hash (k = 1..K) uses the right-circulant shift by
    k units, i.e. ``pi_{->k}(i) = pi[(i - k) mod D]``;
  * ``sigma`` is applied as a gather: ``v'[i] = v[sigma[i]]``;
  * an all-zero row hashes to the sentinel ``D``.
"""

import numpy as np

__all__ = [
    "minhash_ref",
    "cminhash_0pi_ref",
    "cminhash_sigma_pi_ref",
    "jaccard",
    "estimate_ref",
]


def jaccard(v: np.ndarray, w: np.ndarray) -> float:
    """Exact Jaccard similarity of two 0/1 vectors (eq. 1)."""
    v = np.asarray(v).astype(bool)
    w = np.asarray(w).astype(bool)
    union = np.logical_or(v, w).sum()
    if union == 0:
        return 0.0
    return float(np.logical_and(v, w).sum()) / float(union)


def minhash_ref(bits: np.ndarray, perms: np.ndarray) -> np.ndarray:
    """Classical MinHash (Algorithm 1) with K independent permutations.

    bits: (B, D) 0/1; perms: (K, D) each row a permutation of 0..D-1.
    Returns (B, K) int32.
    """
    bits = np.asarray(bits)
    perms = np.asarray(perms)
    b, d = bits.shape
    k = perms.shape[0]
    out = np.full((b, k), d, dtype=np.int32)
    for bi in range(b):
        nz = np.nonzero(bits[bi])[0]
        if nz.size == 0:
            continue
        for ki in range(k):
            out[bi, ki] = perms[ki, nz].min()
    return out


def cminhash_0pi_ref(bits: np.ndarray, pi: np.ndarray, k: int) -> np.ndarray:
    """C-MinHash-(0, pi) (Algorithm 2): no initial permutation.

    bits: (B, D) 0/1; pi: (D,) permutation of 0..D-1.  Returns (B, K).
    """
    bits = np.asarray(bits)
    pi = np.asarray(pi)
    b, d = bits.shape
    out = np.full((b, k), d, dtype=np.int32)
    for bi in range(b):
        nz = np.nonzero(bits[bi])[0]
        if nz.size == 0:
            continue
        for kk in range(1, k + 1):  # paper shifts by k = 1..K
            out[bi, kk - 1] = pi[(nz - kk) % d].min()
    return out


def cminhash_sigma_pi_ref(
    bits: np.ndarray, sigma: np.ndarray, pi: np.ndarray, k: int
) -> np.ndarray:
    """C-MinHash-(sigma, pi) (Algorithm 3): initial permutation sigma,
    then circulant hashing with pi."""
    bits = np.asarray(bits)
    permuted = bits[:, np.asarray(sigma)]  # v'[i] = v[sigma[i]]
    return cminhash_0pi_ref(permuted, pi, k)


def estimate_ref(h1: np.ndarray, h2: np.ndarray) -> np.ndarray:
    """Pairwise collision estimator J_hat (eqs. 2/4/7).

    h1: (N, K), h2: (M, K) -> (N, M) float32 of mean collision rates.
    """
    h1 = np.asarray(h1)
    h2 = np.asarray(h2)
    eq = h1[:, None, :] == h2[None, :, :]
    return eq.mean(axis=2).astype(np.float32)
