"""L2 — JAX sketch pipelines (build-time only; lowered to HLO by aot.py).

Every function here is a pure jax function of concrete-shaped arrays so it
can be ``jax.jit(...).lower(...)``-ed once and executed forever from the
Rust runtime.  Permutations are *inputs* (int32 arrays), not constants:
the Rust coordinator owns permutation generation (seeded Fisher-Yates in
``rust/src/sketch/perm.rs``), which keeps the artifacts data-independent
and lets one compiled executable serve any (sigma, pi) pair.

Pipelines
  * ``cminhash_sigma_pi``  — Algorithm 3, the paper's recommended method
    (sigma-gather then the Pallas circulant kernel).
  * ``cminhash_0_pi``      — Algorithm 2 ablation (no sigma).
  * ``minhash_classic``    — Algorithm 1 baseline with a K x D
    permutation matrix (the memory-hungry scheme C-MinHash replaces).
  * ``estimate_pairwise``  — collision estimator J_hat over two sketch
    batches (eq. 2/4/7), used by the server's /estimate endpoint.
  * ``sketch_and_estimate``— fused end-to-end graph (sketch two batches,
    return the pairwise estimates), used by the e2e example.
"""

import jax
import jax.numpy as jnp

from compile.kernels.cminhash import cminhash_hashes, cminhash_sparse_hashes

__all__ = [
    "cminhash_sigma_pi",
    "cminhash_sigma_pi_sparse",
    "cminhash_0_pi",
    "minhash_classic",
    "estimate_pairwise",
    "sketch_and_estimate",
]


def cminhash_sigma_pi(bits, sigma, pi2, *, k: int):
    """C-MinHash-(sigma, pi) sketches (Algorithm 3).

    bits: (B, D) int32 0/1; sigma: (D,) int32 permutation;
    pi2: (2D,) int32 doubled permutation.  -> (B, K) int32.
    """
    permuted = jnp.take(bits, sigma, axis=1)  # v'[i] = v[sigma[i]]
    return cminhash_hashes(permuted, pi2, k)


def cminhash_sigma_pi_sparse(indices, inv_sigma, pi3, *, k: int):
    """Sparse-input C-MinHash-(sigma, pi) — the optimized serving path.

    indices: (B, F) int32 nonzero positions padded with 2*D;
    inv_sigma: (D,) int32 inverse of sigma (so sigma-gather on sparse
    rows is a plain lookup: position s of v lands at inv_sigma[s] of
    v' = v[sigma]); pi3: (3D,) tripled permutation with sentinel tail.
    -> (B, K) int32, identical to ``cminhash_sigma_pi`` on the dense
    equivalent.
    """
    d = inv_sigma.shape[0]
    pad = jnp.int32(2 * d)
    mapped = jnp.where(
        indices < d,
        jnp.take(inv_sigma, jnp.clip(indices, 0, d - 1), axis=0),
        pad,
    )
    return cminhash_sparse_hashes(mapped, pi3, k)


def cminhash_0_pi(bits, pi2, *, k: int):
    """C-MinHash-(0, pi) sketches (Algorithm 2): no initial permutation."""
    return cminhash_hashes(bits, pi2, k)


def minhash_classic(bits, perms):
    """Classical MinHash (Algorithm 1) with K independent permutations.

    bits: (B, D) int32 0/1; perms: (K, D) int32.  -> (B, K) int32.

    Kept as a plain-jnp masked min: it is the *baseline*, and XLA already
    emits the optimal reduce for it; the interesting kernel is circulant.
    """
    d = bits.shape[1]
    masked = jnp.where(
        (bits > 0)[:, None, :], perms[None, :, :], jnp.int32(d)
    )  # (B, K, D)
    return masked.min(axis=2)


def estimate_pairwise(h1, h2):
    """Pairwise Jaccard estimates from sketches.

    h1: (N, K) int32; h2: (M, K) int32 -> (N, M) float32, the fraction of
    colliding hash slots (eq. 2).
    """
    k = h1.shape[1]
    eq = (h1[:, None, :] == h2[None, :, :]).astype(jnp.float32)
    return eq.sum(axis=2) * (1.0 / k)


def sketch_and_estimate(bits1, bits2, sigma, pi2, *, k: int):
    """Fused: sketch two batches with C-MinHash-(sigma, pi) and return
    (H1, H2, J_hat) — exercises the full L2 graph in one executable."""
    h1 = cminhash_sigma_pi(bits1, sigma, pi2, k=k)
    h2 = cminhash_sigma_pi(bits2, sigma, pi2, k=k)
    return h1, h2, estimate_pairwise(h1, h2)
