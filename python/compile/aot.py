"""AOT compile path: lower every L2 pipeline variant to HLO text.

Run once at build time (``make artifacts``); Rust loads the results via
``HloModuleProto::from_text_file`` and never touches Python again.

HLO *text* (not ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Outputs (per variant) into --outdir:
  * ``<name>.hlo.txt``   — the HLO module
  * ``manifest.json``    — shapes/dtypes/arg order for every artifact, so
    the Rust runtime can type-check requests against the executable.
"""

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---------------------------------------------------------------------------
# Variant table.  Kept small enough that `make artifacts` stays O(1 min) but
# covering: the serving default, a small test variant, the (0,pi) ablation,
# the classical baseline, the pairwise estimator, and the fused e2e graph.
# The Rust config (`configs/*.toml`) refers to variants by `name`.
# ---------------------------------------------------------------------------


def _spec(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def variant_table():
    """name -> (fn, example_args, metadata) for every artifact."""
    table = {}

    def add(name, fn, args, inputs, outputs):
        table[name] = (fn, args, {"inputs": inputs, "outputs": outputs})

    def sigma_pi(b, d, k):
        add(
            f"cminhash_b{b}_d{d}_k{k}",
            partial(model.cminhash_sigma_pi, k=k),
            (_spec((b, d)), _spec((d,)), _spec((2 * d,))),
            [
                {"name": "bits", "shape": [b, d], "dtype": "s32"},
                {"name": "sigma", "shape": [d], "dtype": "s32"},
                {"name": "pi2", "shape": [2 * d], "dtype": "s32"},
            ],
            [{"name": "hashes", "shape": [b, k], "dtype": "s32"}],
        )

    def sigma_pi_sparse(b, d, f, k):
        add(
            f"cminhashs_b{b}_d{d}_f{f}_k{k}",
            partial(model.cminhash_sigma_pi_sparse, k=k),
            (_spec((b, f)), _spec((d,)), _spec((3 * d,))),
            [
                {"name": "indices", "shape": [b, f], "dtype": "s32"},
                {"name": "inv_sigma", "shape": [d], "dtype": "s32"},
                {"name": "pi3", "shape": [3 * d], "dtype": "s32"},
            ],
            [{"name": "hashes", "shape": [b, k], "dtype": "s32"}],
        )

    def zero_pi(b, d, k):
        add(
            f"cminhash0_b{b}_d{d}_k{k}",
            partial(model.cminhash_0_pi, k=k),
            (_spec((b, d)), _spec((2 * d,))),
            [
                {"name": "bits", "shape": [b, d], "dtype": "s32"},
                {"name": "pi2", "shape": [2 * d], "dtype": "s32"},
            ],
            [{"name": "hashes", "shape": [b, k], "dtype": "s32"}],
        )

    def classic(b, d, k):
        add(
            f"minhash_b{b}_d{d}_k{k}",
            model.minhash_classic,
            (_spec((b, d)), _spec((k, d))),
            [
                {"name": "bits", "shape": [b, d], "dtype": "s32"},
                {"name": "perms", "shape": [k, d], "dtype": "s32"},
            ],
            [{"name": "hashes", "shape": [b, k], "dtype": "s32"}],
        )

    def estimator(n, m, k):
        add(
            f"estimate_n{n}_m{m}_k{k}",
            model.estimate_pairwise,
            (_spec((n, k)), _spec((m, k))),
            [
                {"name": "h1", "shape": [n, k], "dtype": "s32"},
                {"name": "h2", "shape": [m, k], "dtype": "s32"},
            ],
            [{"name": "jhat", "shape": [n, m], "dtype": "f32"}],
        )

    def fused(b, d, k):
        add(
            f"sketchest_b{b}_d{d}_k{k}",
            partial(model.sketch_and_estimate, k=k),
            (_spec((b, d)), _spec((b, d)), _spec((d,)), _spec((2 * d,))),
            [
                {"name": "bits1", "shape": [b, d], "dtype": "s32"},
                {"name": "bits2", "shape": [b, d], "dtype": "s32"},
                {"name": "sigma", "shape": [d], "dtype": "s32"},
                {"name": "pi2", "shape": [2 * d], "dtype": "s32"},
            ],
            [
                {"name": "h1", "shape": [b, k], "dtype": "s32"},
                {"name": "h2", "shape": [b, k], "dtype": "s32"},
                {"name": "jhat", "shape": [b, b], "dtype": "f32"},
            ],
        )

    # Serving defaults (used by `configs/serve.json` and the e2e example).
    # The sparse (gather) variants are the optimized hot path (§Perf:
    # ~10x over dense); a ladder of batch sizes lets the coordinator
    # route partial batches to the smallest fitting executable instead
    # of padding to 64.  The dense variant stays as the fallback for
    # rows with more than F nonzeros.
    for b in (8, 16, 32, 64):
        sigma_pi_sparse(b, 4096, 512, 256)
    sigma_pi(64, 4096, 256)
    # Small variants for tests / quickstart.
    sigma_pi_sparse(8, 1024, 128, 128)
    sigma_pi(8, 1024, 128)
    # Ablation and baseline at the small shape (Fig 6/7 cross-checks run in
    # Rust; these artifacts let the server expose all three methods).
    zero_pi(8, 1024, 128)
    classic(8, 1024, 128)
    # Pairwise estimator for the /estimate endpoint.
    estimator(64, 64, 256)
    estimator(8, 8, 128)
    # Fused end-to-end graph.
    fused(32, 2048, 256)
    return table


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated variant names"
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text-v1", "artifacts": {}}
    for name, (fn, example_args, meta) in variant_table().items():
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            **meta,
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
