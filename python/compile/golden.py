"""Export golden hash vectors for the Rust test-suite.

The pure-Rust hashers (`rust/src/sketch/`) must agree bit-for-bit with the
Python oracles in `kernels/ref.py` (which the Pallas kernel is itself
verified against).  This script materializes a few deterministic cases —
explicit bits, sigma, pi, K — together with the oracle outputs, into a
JSON file the Rust integration test `rust/tests/golden.rs` replays.

Run via ``make artifacts`` (output: artifacts/golden.json).
"""

import argparse
import json

import numpy as np

from compile.kernels import ref


def cases():
    out = []
    rng = np.random.default_rng(20240717)
    for (b, d, k, density) in [
        (3, 16, 8, 0.4),
        (4, 64, 64, 0.1),
        (2, 128, 96, 0.03),
        (5, 33, 17, 0.5),  # awkward non-power-of-two shapes
    ]:
        bits = (rng.random((b, d)) < density).astype(np.int32)
        bits[0] = 0  # always include an empty row
        sigma = rng.permutation(d).astype(np.int32)
        pi = rng.permutation(d).astype(np.int32)
        perms = np.stack([rng.permutation(d) for _ in range(k)]).astype(np.int32)
        out.append(
            {
                "b": b,
                "d": d,
                "k": k,
                "bits": bits.tolist(),
                "sigma": sigma.tolist(),
                "pi": pi.tolist(),
                "perms": perms.tolist(),
                "minhash": ref.minhash_ref(bits, perms).tolist(),
                "cminhash_0pi": ref.cminhash_0pi_ref(bits, pi, k).tolist(),
                "cminhash_sigma_pi": ref.cminhash_sigma_pi_ref(
                    bits, sigma, pi, k
                ).tolist(),
            }
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/golden.json")
    args = ap.parse_args()
    with open(args.out, "w") as f:
        json.dump({"cases": cases()}, f)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
