# cminhash — build/test/bench/doc entry points.
#
# `make verify` is the tier-1 gate CI runs on every push.
# `make artifacts` is the only target that needs Python (JAX); everything
# else is pure cargo.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all build test bench doc clippy staticlint lint linkcheck checkbench verify artifacts figures clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Compile every bench target, then run them (fast mode keeps CI cheap).
# Results land in results/bench/*.csv.
bench:
	$(CARGO) build --release --benches
	CMINHASH_BENCH_FAST=1 $(CARGO) bench

doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Cross-layer static analysis (docs/LINTS.md): wire-registry parity,
# persistence-format audit, lock discipline, metrics-surface parity,
# config-knob drift.  Zero dependencies, no cargo needed.
staticlint:
	$(PYTHON) tools/staticlint.py .
	$(PYTHON) tools/tests/test_staticlint.py

# The pure-Python lint gate CI runs before any Rust job: staticlint and
# its self-tests, the markdown link check, and the bench-gate check
# (which skips cleanly when BENCH_*.json haven't been produced yet).
lint: staticlint linkcheck checkbench

# Offline markdown link check over README/DESIGN/docs/… so the docs
# can't rot silently (local targets only; external URLs not fetched).
linkcheck:
	$(PYTHON) tools/linkcheck.py .

# Offline gate over emitted BENCH_*.json: the packed b-bit plane must
# beat unpacked query throughput at b <= 8 and shrink memory ~32/b x,
# the bucket-at-a-time scoring kernel must beat the per-candidate
# scalar loop by >= 1.2x at b <= 8 (bbit_query's batch_score_speedup),
# pre-packed bin1 ingest must beat JSON-lines ingest by >= 1.3x, the
# tracing-enabled hot path must hold >= 0.97x of the tracing-off
# throughput (obs_overhead), 2-node cluster ingest must hold
# >= 1.6x the single-node rate (cluster_scale), the O(1)-memory iuh
# hasher must stay within 1.5x of cmh ns/sketch (scheme_sweep), and
# the shard-parallel snapshot loader must open >= 1.5x faster than the
# serial replay (snapshot_load).  An absent bench file
# skips cleanly (run `make bench` first to arm the gates); a present
# but malformed one hard-fails — its own self-tests pin that split.
# CI always runs the benches before this gate.
checkbench:
	$(PYTHON) tools/tests/test_check_bench.py
	$(PYTHON) tools/check_bench.py .

verify: lint build test clippy

# AOT-lower the L1/L2 pipelines to artifacts/ (HLO text + manifest) and
# export the golden vectors for rust/tests/golden.rs.  Optional: the
# pure-Rust engine serves identical sketches without it.
artifacts:
	cd python && $(PYTHON) -m compile.aot --outdir ../artifacts
	cd python && $(PYTHON) -m compile.golden --out ../artifacts/golden.json

figures:
	$(CARGO) run --release -- figures --all --out results

clean:
	$(CARGO) clean
	rm -rf results
