#!/usr/bin/env python3
"""Self-tests for tools/staticlint: every analyzer must catch a
deliberately seeded violation (red test) and pass its clean fixture
(green test), so the gate itself is gated.

Run: python3 tools/tests/test_staticlint.py
"""

import os
import sys
import unittest

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
)

import staticlint  # noqa: E402
from staticlint import (  # noqa: E402
    config_knobs,
    locks,
    metrics_surface,
    persistence,
    wire,
)

REPO_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")


def codes(findings):
    return {f.code for f in findings}


# ---------------------------------------------------------------------------
# wire fixtures
# ---------------------------------------------------------------------------

WIRE_PROTOCOL = """
impl Request {
    pub fn from_json(j: &Json) -> crate::Result<Request> {
        let r = match op {
            "ping" => Request::Ping,
            "delete" => Request::Delete { id },
            _ => return Err(bad_op),
        };
        Ok(r)
    }
}
"""

WIRE_OBS = """
impl OpKind {
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Ping => "ping",
            OpKind::Delete => "delete",
        }
    }
}
"""

WIRE_FRAME = """
pub mod op {
    pub const PING: u8 = 0x01;
    pub const DELETE: u8 = 0x02;
    pub const R_ERR: u8 = 0x80;
    pub const R_PONG: u8 = 0x81;
    pub const R_DELETED: u8 = 0x82;
}
"""

WIRE_SERVER = """
fn bin_op_kind(req: &frame::BinRequest) -> OpKind {
    use frame::BinRequest as B;
    match req {
        B::Ping => OpKind::Ping,
        B::Delete(_) => OpKind::Delete,
    }
}
impl BlockingClient {
    pub fn ping(&mut self) -> crate::Result<()> { todo() }
    pub fn delete(&mut self, id: u64) -> crate::Result<()> { todo() }
}
"""

WIRE_DOC = """
### `ping` — liveness
### `delete` — remove a stored id

| op | request | payload |
|---|---|---|
| `0x01` | `ping` | empty |
| `0x02` | `delete` | `id:u64` |

| op | response | payload |
|---|---|---|
| `0x80` | error | UTF-8 message |
| `0x81` | pong | empty |
| `0x82` | deleted | `id:u64` |
"""


def wire_tree(**overrides):
    tree = {
        "rust/src/server/protocol.rs": WIRE_PROTOCOL,
        "rust/src/obs/mod.rs": WIRE_OBS,
        "rust/src/server/frame.rs": WIRE_FRAME,
        "rust/src/server/mod.rs": WIRE_SERVER,
        "docs/PROTOCOL.md": WIRE_DOC,
    }
    tree.update(overrides)
    return tree


class WireTests(unittest.TestCase):
    def test_clean_fixture(self):
        self.assertEqual(wire.analyze(wire_tree()), [])

    def test_doc_table_code_mismatch_is_caught(self):
        doc = WIRE_DOC.replace("| `0x02` | `delete` |", "| `0x03` | `delete` |")
        found = wire.analyze(wire_tree(**{"docs/PROTOCOL.md": doc}))
        self.assertIn("doc-table", codes(found))

    def test_missing_client_method_is_caught(self):
        server = WIRE_SERVER.replace(
            "pub fn delete(&mut self, id: u64) -> crate::Result<()> { todo() }", ""
        )
        found = wire.analyze(wire_tree(**{"rust/src/server/mod.rs": server}))
        self.assertIn("client-gap", codes(found))

    def test_missing_dispatch_arm_is_caught(self):
        server = WIRE_SERVER.replace("B::Delete(_) => OpKind::Delete,", "")
        found = wire.analyze(wire_tree(**{"rust/src/server/mod.rs": server}))
        self.assertIn("missing-dispatch", codes(found))

    def test_jsonl_op_without_opkind_is_caught(self):
        proto = WIRE_PROTOCOL.replace(
            '"delete" => Request::Delete { id },',
            '"delete" => Request::Delete { id },\n'
            '            "save" => Request::Save,',
        )
        found = wire.analyze(wire_tree(**{"rust/src/server/protocol.rs": proto}))
        self.assertIn("missing-opkind", codes(found))

    def test_unpaired_opcode_is_caught(self):
        frame = WIRE_FRAME.replace("    pub const R_DELETED: u8 = 0x82;\n", "")
        found = wire.analyze(wire_tree(**{"rust/src/server/frame.rs": frame}))
        self.assertIn("unpaired-opcode", codes(found))

    def test_undocumented_op_is_caught(self):
        doc = WIRE_DOC.replace("### `ping` — liveness\n", "")
        found = wire.analyze(wire_tree(**{"docs/PROTOCOL.md": doc}))
        self.assertIn("undocumented-op", codes(found))


# ---------------------------------------------------------------------------
# cluster/replicate wire fixtures — the same registry grown by the
# replicate op, with BlockingClient living in client.rs (the real
# tree's layout since the cluster plane landed).
# ---------------------------------------------------------------------------

CLUSTER_PROTOCOL = WIRE_PROTOCOL.replace(
    '"delete" => Request::Delete { id },',
    '"delete" => Request::Delete { id },\n'
    '            "replicate" => Request::Replicate,',
)

CLUSTER_OBS = WIRE_OBS.replace(
    'OpKind::Delete => "delete",',
    'OpKind::Delete => "delete",\n'
    '            OpKind::Replicate => "replicate",',
)

CLUSTER_FRAME = WIRE_FRAME.replace(
    "    pub const R_ERR: u8 = 0x80;",
    "    pub const REPLICATE: u8 = 0x03;\n"
    "    pub const R_ERR: u8 = 0x80;",
).replace(
    "    pub const R_DELETED: u8 = 0x82;",
    "    pub const R_DELETED: u8 = 0x82;\n"
    "    pub const R_REPLICATE: u8 = 0x83;",
)

CLUSTER_SERVER = """
fn bin_op_kind(req: &frame::BinRequest) -> OpKind {
    use frame::BinRequest as B;
    match req {
        B::Ping => OpKind::Ping,
        B::Delete(_) => OpKind::Delete,
        B::Replicate => OpKind::Replicate,
    }
}
"""

CLUSTER_CLIENT = """
impl BlockingClient {
    pub fn ping(&mut self) -> crate::Result<()> { todo() }
    pub fn delete(&mut self, id: u64) -> crate::Result<()> { todo() }
    pub fn replicate(&mut self) -> crate::Result<(Vec<u8>, Vec<u8>)> { todo() }
}
impl ClusterClient {
    pub fn replicate_from(&mut self, i: usize) -> crate::Result<(Vec<u8>, Vec<u8>)> { todo() }
}
"""

CLUSTER_DOC = """
### `ping` — liveness
### `delete` — remove a stored id
### `replicate` — export the durable image

| op | request | payload |
|---|---|---|
| `0x01` | `ping` | empty |
| `0x02` | `delete` | `id:u64` |
| `0x03` | `replicate` | empty |

| op | response | payload |
|---|---|---|
| `0x80` | error | UTF-8 message |
| `0x81` | pong | empty |
| `0x82` | deleted | `id:u64` |
| `0x83` | replicate image | `snap_len:u64`, snapshot bytes, WAL bytes |
"""


def cluster_tree(**overrides):
    tree = {
        "rust/src/server/protocol.rs": CLUSTER_PROTOCOL,
        "rust/src/obs/mod.rs": CLUSTER_OBS,
        "rust/src/server/frame.rs": CLUSTER_FRAME,
        "rust/src/server/mod.rs": CLUSTER_SERVER,
        "rust/src/server/client.rs": CLUSTER_CLIENT,
        "docs/PROTOCOL.md": CLUSTER_DOC,
    }
    tree.update(overrides)
    return tree


class ClusterWireTests(unittest.TestCase):
    def test_clean_cluster_fixture(self):
        # BlockingClient lives in client.rs, not mod.rs — the analyzer
        # must find it there without a client-gap.
        self.assertEqual(wire.analyze(cluster_tree()), [])

    def test_missing_replicate_client_method_is_caught(self):
        client = CLUSTER_CLIENT.replace(
            "    pub fn replicate(&mut self) -> "
            "crate::Result<(Vec<u8>, Vec<u8>)> { todo() }\n",
            "",
        )
        found = wire.analyze(
            cluster_tree(**{"rust/src/server/client.rs": client})
        )
        self.assertIn("client-gap", codes(found))
        # ... and the finding points at client.rs, where the fix goes.
        paths = {f.path for f in found if f.code == "client-gap"}
        self.assertIn("rust/src/server/client.rs", paths)

    def test_replicate_without_opkind_is_caught(self):
        found = wire.analyze(cluster_tree(**{"rust/src/obs/mod.rs": WIRE_OBS}))
        self.assertIn("missing-opkind", codes(found))

    def test_opkind_without_jsonl_arm_is_caught(self):
        # replicate is NOT in the audited binary-only set: an OpKind
        # entry without a jsonl from_json arm is drift.
        found = wire.analyze(
            cluster_tree(**{"rust/src/server/protocol.rs": WIRE_PROTOCOL})
        )
        self.assertIn("missing-jsonl-op", codes(found))

    def test_missing_replicate_dispatch_arm_is_caught(self):
        server = CLUSTER_SERVER.replace(
            "        B::Replicate => OpKind::Replicate,\n", ""
        )
        found = wire.analyze(cluster_tree(**{"rust/src/server/mod.rs": server}))
        self.assertIn("missing-dispatch", codes(found))

    def test_unpaired_replicate_opcode_is_caught(self):
        frame = CLUSTER_FRAME.replace(
            "    pub const R_REPLICATE: u8 = 0x83;\n", ""
        )
        found = wire.analyze(cluster_tree(**{"rust/src/server/frame.rs": frame}))
        self.assertIn("unpaired-opcode", codes(found))

    def test_missing_replicate_doc_rows_are_caught(self):
        doc = CLUSTER_DOC.replace(
            "| `0x03` | `replicate` | empty |\n", ""
        ).replace(
            "| `0x83` | replicate image | `snap_len:u64`, snapshot bytes, "
            "WAL bytes |\n",
            "",
        )
        found = wire.analyze(cluster_tree(**{"docs/PROTOCOL.md": doc}))
        self.assertIn("doc-table", codes(found))
        msgs = " ".join(f.message for f in found if f.code == "doc-table")
        self.assertIn("replicate", msgs)
        self.assertIn("0x83", msgs)


# ---------------------------------------------------------------------------
# persistence fixtures
# ---------------------------------------------------------------------------

PERSIST_WAL = """
const TAG_INSERT: u8 = 1;
const TAG_DELETE: u8 = 2;

pub enum WalRecord {
    Insert { id: u64 },
    Delete { id: u64 },
}

fn encode(rec: &WalRecord) -> Vec<u8> {
    match rec {
        WalRecord::Insert { id } => out.push(TAG_INSERT),
        WalRecord::Delete { id } => out.push(TAG_DELETE),
    }
}

fn decode_payload(p: &[u8]) -> crate::Result<WalRecord> {
    match p[0] {
        TAG_INSERT => Ok(WalRecord::Insert { id: 0 }),
        TAG_DELETE => Ok(WalRecord::Delete { id: 0 }),
        _ => Err(bad("unknown record tag")),
    }
}
"""

PERSIST_SNAP = """
const MAGIC_V2: &[u8; 8] = b"TESTSNP2";
const MAGIC_V1: &[u8; 8] = b"TESTSNP1";

fn header(k: u32) -> Vec<u8> {
    out.extend_from_slice(MAGIC_V2);
}

fn load(path: &Path) -> crate::Result<Snapshot> {
    match magic {
        m if m == *MAGIC_V2 => version = 2,
        m if m == *MAGIC_V1 => version = 1,
        _ => return Err(bad("bad magic")),
    }
}
"""

PERSIST_TESTS = """
#[test]
fn formats_are_pinned() {
    let _ = WalRecord::Insert { id: 1 };
    let _ = WalRecord::Delete { id: 1 };
    assert_eq!(&head[..8], b"TESTSNP2");
    assert_eq!(&legacy[..8], b"TESTSNP1");
}
"""


def persist_tree(**overrides):
    tree = {
        "rust/src/store/wal.rs": PERSIST_WAL,
        "rust/src/store/snapshot.rs": PERSIST_SNAP,
        "rust/tests/store_persistence.rs": PERSIST_TESTS,
    }
    tree.update(overrides)
    return tree


class PersistenceTests(unittest.TestCase):
    def test_clean_fixture(self):
        self.assertEqual(persistence.analyze(persist_tree()), [])

    def test_missing_encoder_is_caught(self):
        wal = PERSIST_WAL.replace(
            "WalRecord::Delete { id } => out.push(TAG_DELETE),", ""
        )
        found = persistence.analyze(persist_tree(**{"rust/src/store/wal.rs": wal}))
        self.assertIn("no-encoder", codes(found))

    def test_missing_refusal_is_caught(self):
        wal = PERSIST_WAL.replace(
            '_ => Err(bad("unknown record tag")),', ""
        )
        found = persistence.analyze(persist_tree(**{"rust/src/store/wal.rs": wal}))
        self.assertIn("no-refusal", codes(found))

    def test_unreadable_magic_is_caught(self):
        snap = PERSIST_SNAP.replace("m if m == *MAGIC_V1 => version = 1,", "")
        found = persistence.analyze(
            persist_tree(**{"rust/src/store/snapshot.rs": snap})
        )
        self.assertIn("no-decoder", codes(found))

    def test_unpinned_format_is_caught(self):
        tests = PERSIST_TESTS.replace(
            'assert_eq!(&legacy[..8], b"TESTSNP1");', ""
        )
        found = persistence.analyze(
            persist_tree(**{"rust/tests/store_persistence.rs": tests})
        )
        self.assertIn("untested-format", codes(found))

    def test_tag_collision_is_caught(self):
        wal = PERSIST_WAL.replace(
            "const TAG_DELETE: u8 = 2;", "const TAG_DELETE: u8 = 1;"
        )
        found = persistence.analyze(persist_tree(**{"rust/src/store/wal.rs": wal}))
        self.assertIn("tag-collision", codes(found))


# ---------------------------------------------------------------------------
# locks fixtures
# ---------------------------------------------------------------------------

LOCKS_CLEAN = """
impl Registry {
    fn get(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.value
    }
    fn put(&self, v: u64) {
        self.inner.lock().unwrap().value = v;
    }
}
"""

LOCKS_DOUBLE = """
impl Registry {
    fn broken(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        let h = self.inner.lock().unwrap();
        g.value + h.value
    }
}
"""

LOCKS_CYCLE = """
impl Registry {
    fn ab(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
    }
    fn ba(&self) {
        let g = self.b.lock().unwrap();
        let h = self.a.lock().unwrap();
    }
}
"""

LOCKS_IO = """
impl Registry {
    fn persist(&self) {
        let g = self.file.lock().unwrap();
        g.sync_all().unwrap();
    }
}
"""


class LocksTests(unittest.TestCase):
    def test_clean_fixture(self):
        self.assertEqual(
            locks.analyze({"rust/src/registry.rs": LOCKS_CLEAN}), []
        )

    def test_double_acquire_is_caught(self):
        found = locks.analyze({"rust/src/registry.rs": LOCKS_DOUBLE})
        self.assertIn("double-acquire", codes(found))

    def test_lock_cycle_is_caught(self):
        found = locks.analyze({"rust/src/registry.rs": LOCKS_CYCLE})
        self.assertIn("lock-cycle", codes(found))

    def test_io_under_lock_is_caught(self):
        found = locks.analyze({"rust/src/registry.rs": LOCKS_IO})
        self.assertIn("io-under-lock", codes(found))
        self.assertEqual(found[0].function, "persist")

    def test_guard_scope_ends_at_block(self):
        # The same two classes in *separate* blocks must not edge.
        src = """
impl Registry {
    fn sequential(&self) {
        {
            let g = self.a.lock().unwrap();
            g.touch();
        }
        let h = self.b.lock().unwrap();
    }
    fn reverse(&self) {
        {
            let g = self.b.lock().unwrap();
            g.touch();
        }
        let h = self.a.lock().unwrap();
    }
}
"""
        self.assertEqual(locks.analyze({"rust/src/registry.rs": src}), [])

    def test_test_code_is_exempt(self):
        src = LOCKS_CLEAN + "\n#[cfg(test)]\nmod tests {\n" + LOCKS_IO + "\n}\n"
        self.assertEqual(locks.analyze({"rust/src/registry.rs": src}), [])


# ---------------------------------------------------------------------------
# metrics fixtures
# ---------------------------------------------------------------------------

MET_OBS = """
pub const NUM_OPS: usize = 2;
pub const NUM_STAGES: usize = 1;
impl OpKind {
    pub const ALL: [OpKind; NUM_OPS] = [OpKind::Ping, OpKind::Query];
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Ping => "ping",
            OpKind::Query => "query",
        }
    }
}
impl Stage {
    pub const ALL: [Stage; NUM_STAGES] = [Stage::Decode];
    pub fn name(self) -> &'static str {
        match self {
            Stage::Decode => "decode",
        }
    }
}
"""

MET_METRICS = """
pub struct Metrics {
    pub query_latency: LatencyHistogram,
    pub queries: AtomicU64,
    pub errors: AtomicU64,
}
pub struct MetricsSnapshot {
    pub query_latency: LatencySnapshot,
    pub queries: u64,
    pub errors: u64,
}
impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("query_latency", self.query_latency.to_json()),
            ("queries", Json::Num(self.queries as f64)),
            ("errors", Json::Num(self.errors as f64)),
        ])
    }
}
pub struct LatencySnapshot {
    pub count: u64,
}
impl LatencySnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("count", Json::Num(self.count as f64))])
    }
}
"""

MET_PROM = """
pub fn render(out: &mut String) {
    series(out, "cminhash_queries_total");
    series(out, "cminhash_errors_total");
    series(out, "cminhash_query_latency_us");
    series(out, "cminhash_requests_total");
}
"""

MET_DOC = """
| stage | covers |
|---|---|
| `decode` | wire read |

| series | kind | meaning |
|---|---|---|
| `cminhash_queries_total` | counter | queries |
| `cminhash_errors_total` | counter | errors |
| `cminhash_query_latency_us` | histogram | query latency |
| `cminhash_requests_total` | counter | per-op requests |
"""


def met_tree(**overrides):
    tree = {
        "rust/src/obs/mod.rs": MET_OBS,
        "rust/src/metrics.rs": MET_METRICS,
        "rust/src/obs/prom.rs": MET_PROM,
        "docs/OBSERVABILITY.md": MET_DOC,
    }
    tree.update(overrides)
    return tree


class MetricsTests(unittest.TestCase):
    def test_clean_fixture(self):
        self.assertEqual(metrics_surface.analyze(met_tree()), [])

    def test_counter_missing_from_json_is_caught(self):
        met = MET_METRICS.replace(
            '("errors", Json::Num(self.errors as f64)),', ""
        )
        found = metrics_surface.analyze(met_tree(**{"rust/src/metrics.rs": met}))
        self.assertIn("json-gap", codes(found))

    def test_counter_missing_from_prom_is_caught(self):
        prom = MET_PROM.replace('series(out, "cminhash_errors_total");', "")
        found = metrics_surface.analyze(met_tree(**{"rust/src/obs/prom.rs": prom}))
        self.assertIn("prom-gap", codes(found))

    def test_num_ops_drift_is_caught(self):
        obs = MET_OBS.replace(
            "pub const NUM_OPS: usize = 2;", "pub const NUM_OPS: usize = 3;"
        )
        found = metrics_surface.analyze(met_tree(**{"rust/src/obs/mod.rs": obs}))
        self.assertIn("registry-drift", codes(found))

    def test_all_array_drift_is_caught(self):
        obs = MET_OBS.replace("[OpKind::Ping, OpKind::Query]", "[OpKind::Ping]")
        found = metrics_surface.analyze(met_tree(**{"rust/src/obs/mod.rs": obs}))
        self.assertIn("registry-drift", codes(found))

    def test_series_missing_from_docs_is_caught(self):
        doc = MET_DOC.replace(
            "| `cminhash_errors_total` | counter | errors |", ""
        )
        found = metrics_surface.analyze(met_tree(**{"docs/OBSERVABILITY.md": doc}))
        self.assertIn("doc-gap", codes(found))

    def test_stage_missing_from_docs_is_caught(self):
        doc = MET_DOC.replace("| `decode` | wire read |", "")
        found = metrics_surface.analyze(met_tree(**{"docs/OBSERVABILITY.md": doc}))
        self.assertIn("doc-gap", codes(found))


# ---------------------------------------------------------------------------
# config fixtures — the full 19-knob registry, because the analyzer
# also prunes knobs that vanish (registry - knobs), so a partial
# fixture is itself a violation.
# ---------------------------------------------------------------------------

CFG_SERVE_JSON = """{
  "_doc": "fixture",
  "addr": "127.0.0.1:7878",
  "artifacts_dir": "artifacts",
  "engine": "rust",
  "dim": 4096,
  "num_hashes": 256,
  "seed": 42,
  "sketch": { "_doc_scheme": "x", "scheme": "cmh", "bits": 32 },
  "batch": { "max_batch": 64, "max_delay_us": 2000, "policy": "eager" },
  "index": { "bands": 32, "rows_per_band": 4 },
  "store": { "shards": 0, "persist_dir": "data" },
  "server": { "max_connections": 256 },
  "obs": { "trace_ring": 256, "slow_threshold_us": 10000, "pinned": 32 }
}
"""

CFG_CONFIG_RS = """
pub struct SketchSettings { pub scheme: SketchScheme, pub bits: u8 }
pub struct BatchConfig { pub max_batch: usize, pub max_delay_us: u64, pub policy: BatchPolicy }
pub struct IndexSettings { pub bands: usize, pub rows_per_band: usize }
pub struct StoreSettings { pub shards: usize, pub persist_dir: Option<PathBuf> }
pub struct ServerSettings { pub max_connections: usize }
pub struct ObsSettings { pub trace_ring: usize, pub slow_threshold_us: u64, pub pinned: usize }
pub struct ServeConfig {
    pub addr: String,
    pub artifacts_dir: PathBuf,
    pub engine: EngineKind,
    pub dim: usize,
    pub num_hashes: usize,
    pub seed: u64,
    pub sketch: SketchSettings,
    pub batch: BatchConfig,
    pub index: IndexSettings,
    pub store: StoreSettings,
    pub server: ServerSettings,
    pub obs: ObsSettings,
}
impl ServeConfig {
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        if let Some(v) = j.get_opt("addr") { cfg.addr = s(v); }
        if let Some(v) = j.get_opt("artifacts_dir") { cfg.artifacts_dir = p(v); }
        if let Some(v) = j.get_opt("engine") { cfg.engine = e(v); }
        if let Some(v) = j.get_opt("dim") { cfg.dim = n(v); }
        if let Some(v) = j.get_opt("num_hashes") { cfg.num_hashes = n(v); }
        if let Some(v) = j.get_opt("seed") { cfg.seed = n(v); }
        if let Some(sk) = j.get_opt("sketch") {
            if let Some(v) = sk.get_opt("scheme") { cfg.sketch.scheme = sc(v); }
            if let Some(v) = sk.get_opt("bits") { cfg.sketch.bits = n(v); }
        }
        if let Some(b) = j.get_opt("batch") {
            if let Some(v) = b.get_opt("max_batch") { cfg.batch.max_batch = n(v); }
            if let Some(v) = b.get_opt("max_delay_us") { cfg.batch.max_delay_us = n(v); }
            if let Some(v) = b.get_opt("policy") { cfg.batch.policy = bp(v); }
        }
        if let Some(ix) = j.get_opt("index") {
            if let Some(v) = ix.get_opt("bands") { cfg.index.bands = n(v); }
            if let Some(v) = ix.get_opt("rows_per_band") { cfg.index.rows_per_band = n(v); }
        }
        if let Some(st) = j.get_opt("store") {
            if let Some(v) = st.get_opt("shards") { cfg.store.shards = n(v); }
            if let Some(v) = st.get_opt("persist_dir") { cfg.store.persist_dir = Some(p(v)); }
        }
        if let Some(sv) = j.get_opt("server") {
            if let Some(v) = sv.get_opt("max_connections") { cfg.server.max_connections = n(v); }
        }
        if let Some(ob) = j.get_opt("obs") {
            if let Some(v) = ob.get_opt("trace_ring") { cfg.obs.trace_ring = n(v); }
            if let Some(v) = ob.get_opt("slow_threshold_us") { cfg.obs.slow_threshold_us = n(v); }
            if let Some(v) = ob.get_opt("pinned") { cfg.obs.pinned = n(v); }
        }
        Ok(cfg)
    }
}
"""

CFG_MAIN_RS = """
const USAGE: &str = "\\
  serve [--config F] [--addr A] [--engine E] [--scheme S] [--bits B] \\
        [--dim D] [--num-hashes K] [--artifacts DIR] [--seed S] \\
        [--shards N] [--persist DIR] [--max-conns N]";

fn cmd_serve(args: &Args) -> crate::Result<()> {
    let cfg = args.get("config");
    if let Some(a) = args.get("addr") {}
    if let Some(e) = args.get("engine") {}
    if let Some(s) = args.get("scheme") {}
    if let Some(b) = args.get_parsed::<u8>("bits")? {}
    if let Some(d) = args.get_parsed::<usize>("dim")? {}
    if let Some(k) = args.get_parsed::<usize>("num-hashes")? {}
    if let Some(p) = args.get("artifacts") {}
    if let Some(s) = args.get_parsed::<u64>("seed")? {}
    if let Some(s) = args.get_parsed::<usize>("shards")? {}
    if let Some(p) = args.get("persist") {}
    if let Some(c) = args.get_parsed::<usize>("max-conns")? {}
    Ok(())
}
"""

CFG_README = """
## Configuration

| knob | serve flag | default | meaning |
|---|---|---|---|
| `addr` | `--addr` | `127.0.0.1:7878` | listen address |
| `artifacts_dir` | `--artifacts` | `artifacts` | artifact dir |
| `engine` | `--engine` | `rust` | engine kind |
| `dim` | `--dim` | `4096` | dimensionality |
| `num_hashes` | `--num-hashes` | `256` | K |
| `seed` | `--seed` | `42` | permutation seed |
| `sketch.scheme` | `--scheme` | `cmh` | hashing scheme |
| `sketch.bits` | `--bits` | `32` | stored bits per hash |
| `batch.max_batch` | — | `64` | rows per batch |
| `batch.max_delay_us` | — | `2000` | batch linger |
| `batch.policy` | — | `eager` | partial-batch policy |
| `index.bands` | — | `32` | LSH bands |
| `index.rows_per_band` | — | `4` | rows per band |
| `store.shards` | `--shards` | `0` | index shards |
| `store.persist_dir` | `--persist` | none | WAL + snapshot dir |
| `server.max_connections` | `--max-conns` | `256` | pool bound |
| `obs.trace_ring` | — | `256` | trace ring size |
| `obs.slow_threshold_us` | — | `10000` | slow pin threshold |
| `obs.pinned` | — | `32` | pinned FIFO size |

## Next section
"""


def cfg_tree(**overrides):
    tree = {
        "configs/serve.json": CFG_SERVE_JSON,
        "rust/src/config.rs": CFG_CONFIG_RS,
        "rust/src/main.rs": CFG_MAIN_RS,
        "README.md": CFG_README,
    }
    tree.update(overrides)
    return tree


class ConfigTests(unittest.TestCase):
    def test_clean_fixture(self):
        self.assertEqual(config_knobs.analyze(cfg_tree()), [])

    def test_missing_flag_is_caught(self):
        main = CFG_MAIN_RS.replace(
            'if let Some(s) = args.get_parsed::<usize>("shards")? {}', ""
        )
        found = config_knobs.analyze(cfg_tree(**{"rust/src/main.rs": main}))
        self.assertIn("flag-drift", codes(found))

    def test_stale_exemplar_key_is_caught(self):
        sj = CFG_SERVE_JSON.replace('"dim": 4096,', '"dim": 4096, "dims": 2,')
        found = config_knobs.analyze(cfg_tree(**{"configs/serve.json": sj}))
        self.assertIn("knob-drift", codes(found))

    def test_unparsed_struct_field_is_caught(self):
        cfg = CFG_CONFIG_RS.replace(
            'if let Some(v) = j.get_opt("seed") { cfg.seed = n(v); }', ""
        )
        found = config_knobs.analyze(cfg_tree(**{"rust/src/config.rs": cfg}))
        self.assertIn("knob-drift", codes(found))

    def test_wrong_readme_flag_is_caught(self):
        doc = CFG_README.replace(
            "| `store.shards` | `--shards` |", "| `store.shards` | `--shard-count` |"
        )
        found = config_knobs.analyze(cfg_tree(**{"README.md": doc}))
        self.assertIn("doc-gap", codes(found))

    def test_missing_readme_row_is_caught(self):
        doc = CFG_README.replace(
            "| `obs.pinned` | — | `32` | pinned FIFO size |", ""
        )
        found = config_knobs.analyze(cfg_tree(**{"README.md": doc}))
        self.assertIn("doc-gap", codes(found))

    def test_unclassified_knob_is_caught(self):
        cfg = CFG_CONFIG_RS.replace(
            "pub struct ServerSettings { pub max_connections: usize }",
            "pub struct ServerSettings { pub max_connections: usize, "
            "pub backlog: usize }",
        ).replace(
            'if let Some(v) = sv.get_opt("max_connections") '
            "{ cfg.server.max_connections = n(v); }",
            'if let Some(v) = sv.get_opt("max_connections") '
            "{ cfg.server.max_connections = n(v); }\n"
            '            if let Some(v) = sv.get_opt("backlog") '
            "{ cfg.server.backlog = n(v); }",
        )
        found = config_knobs.analyze(cfg_tree(**{"rust/src/config.rs": cfg}))
        self.assertIn("unclassified-knob", codes(found))


# ---------------------------------------------------------------------------
# allowlist + whole-tree baseline
# ---------------------------------------------------------------------------

class AllowlistTests(unittest.TestCase):
    def test_allowlisted_finding_is_suppressed(self):
        tree = {"rust/src/registry.rs": LOCKS_IO}
        entry = {
            "analyzer": "locks",
            "code": "io-under-lock",
            "path": "rust/src/registry.rs",
            "match": "persist",
            "reason": "fixture",
        }
        findings, allowed, stale = staticlint.run(tree, [entry])
        self.assertEqual([f.code for f in findings], [])
        self.assertEqual([f.code for f in allowed], ["io-under-lock"])
        self.assertEqual(stale, [])

    def test_stale_entry_is_reported(self):
        entry = {
            "analyzer": "locks",
            "code": "io-under-lock",
            "path": "rust/src/registry.rs",
            "match": "no_such_function",
            "reason": "fixture",
        }
        findings, allowed, stale = staticlint.run(
            {"rust/src/registry.rs": LOCKS_CLEAN}, [entry]
        )
        self.assertEqual(findings, [])
        self.assertEqual(stale, [entry])

    def test_finding_dict_shape(self):
        found = locks.analyze({"rust/src/registry.rs": LOCKS_IO})
        d = found[0].to_dict()
        for key in ("analyzer", "code", "path", "line", "message"):
            self.assertIn(key, d)


class RealTreeBaseline(unittest.TestCase):
    def test_repo_is_clean_under_the_committed_allowlist(self):
        tree = staticlint.load_tree(REPO_ROOT)
        allowlist = staticlint.load_allowlist(
            os.path.join(REPO_ROOT, "tools", "staticlint", "allowlist.json")
        )
        findings, allowed, stale = staticlint.run(tree, allowlist)
        self.assertEqual(
            [f.text() for f in findings], [], "tree has unallowed findings"
        )
        self.assertEqual(stale, [], "allowlist has stale entries")
        # The audited WAL-under-lock family must stay visible, not
        # silently vanish (if it does, the allowlist should shrink).
        self.assertGreaterEqual(len(allowed), 1)


if __name__ == "__main__":
    unittest.main()
