#!/usr/bin/env python3
"""Self-tests for tools/check_bench: the absent-vs-malformed split and
the cluster scaling gate.

The gate's contract is asymmetric on purpose — an *absent* bench file
means "bench not run" and skips with exit 0, while a *present but
malformed* file means "broken emitter" and hard-fails with a clean
``check_bench: FAIL:`` line (never a traceback).  These tests drive
the script as a subprocess against throwaway directories so the whole
surface — parsing, gating, exit codes, output discipline — is pinned,
not just the helper functions.

Run: python3 tools/tests/test_check_bench.py
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
)

import check_bench  # noqa: E402

SCRIPT = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "check_bench.py"
)


def run_gate(root):
    """Run check_bench.py against ``root``; return (exit, stdout, stderr)."""
    proc = subprocess.run(
        [sys.executable, SCRIPT, root],
        capture_output=True,
        text=True,
        check=False,
    )
    return proc.returncode, proc.stdout, proc.stderr


def cluster_record(single=10_000.0, two=18_000.0, four=30_000.0):
    return {
        "bench": "cluster_scale",
        "dim": 4096,
        "k": 256,
        "rows": 16384,
        "conns": 4,
        "nodes": [
            {
                "nodes": n,
                "ingest_rows_per_s": rps,
                "query_rows_per_s": rps / 2.0,
                "speedup_vs_single": rps / single if single else 0.0,
            }
            for n, rps in ((1, single), (2, two), (4, four))
        ],
    }


class LoadBenchTests(unittest.TestCase):
    """The helper itself: (data, error) tri-state."""

    def test_absent_file_is_a_skip_not_an_error(self):
        with tempfile.TemporaryDirectory() as d:
            data, err = check_bench.load_bench(os.path.join(d, "nope.json"))
        self.assertIsNone(data)
        self.assertIsNone(err)

    def test_malformed_json_is_an_error_not_a_skip(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "BENCH_x.json")
            with open(path, "w") as f:
                f.write('{"bench": "x", truncated')
            data, err = check_bench.load_bench(path)
        self.assertIsNone(data)
        self.assertIsNotNone(err)
        self.assertIn("malformed bench JSON", err)

    def test_non_object_top_level_is_an_error(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "BENCH_x.json")
            with open(path, "w") as f:
                json.dump([1, 2, 3], f)
            data, err = check_bench.load_bench(path)
        self.assertIsNone(data)
        self.assertIn("not a JSON object", err)

    def test_valid_object_loads(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "BENCH_x.json")
            with open(path, "w") as f:
                json.dump({"bench": "x"}, f)
            data, err = check_bench.load_bench(path)
        self.assertIsNone(err)
        self.assertEqual(data, {"bench": "x"})


class GateProcessTests(unittest.TestCase):
    """End-to-end runs of the script against seeded directories."""

    def test_empty_root_skips_with_exit_zero(self):
        with tempfile.TemporaryDirectory() as d:
            code, out, err = run_gate(d)
        self.assertEqual(code, 0, out + err)
        self.assertIn("skipping the perf gates", out)

    def test_malformed_gated_file_hard_fails_without_traceback(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_cluster_scale.json"), "w") as f:
                f.write("{not json at all")
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("check_bench: FAIL:", out)
        self.assertIn("malformed bench JSON", out)
        self.assertNotIn("Traceback", err)

    def test_malformed_ungated_bench_file_also_hard_fails(self):
        # A BENCH_*.json outside the gated set still must parse: a
        # truncated emission is a broken emitter wherever it came from.
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_future_thing.json"), "w") as f:
                f.write("[[[")
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("malformed bench JSON", out)
        self.assertNotIn("Traceback", err)

    def test_missing_bench_tag_fails(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_future_thing.json"), "w") as f:
                json.dump({"results": []}, f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("missing 'bench' tag", out)

    def test_wrong_shape_in_gated_record_fails_cleanly(self):
        # Valid JSON, tagged, but the gate's fields are missing: must be
        # a clean FAIL (broken emitter), not a traceback and not a pass.
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_cluster_scale.json"), "w") as f:
                json.dump({"bench": "cluster_scale", "nodes": "oops"}, f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("check_bench: FAIL:", out)
        self.assertIn("malformed cluster_scale record", out)
        self.assertNotIn("Traceback", err)

    def test_cluster_gate_passes_at_healthy_scaling(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_cluster_scale.json"), "w") as f:
                json.dump(cluster_record(single=10_000, two=18_000), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 0, out + err)
        self.assertIn("all bench gates passed", out)
        self.assertIn("1.80x", out)

    def test_cluster_gate_fails_below_the_floor(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_cluster_scale.json"), "w") as f:
                json.dump(cluster_record(single=10_000, two=14_000), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("check_bench: FAIL:", out)
        self.assertIn("cluster scaling", out)
        self.assertIn("1.40x", out)

    def test_cluster_gate_requires_the_compared_rows(self):
        rec = cluster_record()
        rec["nodes"] = [r for r in rec["nodes"] if r["nodes"] != 2]
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_cluster_scale.json"), "w") as f:
                json.dump(rec, f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("lacks the 1-node and 2-node rows", out)

    def test_one_malformed_file_does_not_mask_a_failing_gate(self):
        # Both problems must be reported in one run: the malformed
        # stray file AND the failing cluster ratio.
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_broken.json"), "w") as f:
                f.write("><")
            with open(os.path.join(d, "BENCH_cluster_scale.json"), "w") as f:
                json.dump(cluster_record(single=10_000, two=12_000), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("malformed bench JSON", out)
        self.assertIn("cluster scaling", out)


def bbit_record(kernel8=2.0, kernel1=3.0):
    def row(bits, qps, bpi, kernel):
        return {
            "bits": bits,
            "k": 128,
            "insert_per_s": 500_000.0,
            "query_per_s": qps,
            "bytes_per_item": bpi,
            "batch_score_speedup": kernel,
        }

    return {
        "bench": "bbit_query",
        "items": 20_000,
        "queries": 2_000,
        "results": [
            row(32, 1_000.0, 512.0, 1.0),
            row(8, 1_500.0, 128.0, kernel8),
            row(1, 2_000.0, 16.0, kernel1),
        ],
    }


def scheme_record(iuh_ns=900.0, cmh_ns=800.0, drop_iuh=False):
    rows = []
    for k in (16, 256):
        for scheme, ns in (("cmh", cmh_ns), ("iuh", iuh_ns), ("oph", 500.0)):
            if drop_iuh and scheme == "iuh":
                continue
            rows.append(
                {
                    "scheme": scheme,
                    "k": k,
                    "ns_per_sketch": ns,
                    "estimate_mse": 0.01,
                }
            )
    return {
        "bench": "scheme_sweep",
        "dim": 4096,
        "nnz": 250,
        "jaccard": 1 / 3,
        "seeds": 8,
        "results": rows,
    }


def snapshot_record(speedup=2.1):
    serial = 400_000.0
    return {
        "bench": "snapshot_load",
        "items": 20_000,
        "shards": 4,
        "k": 64,
        "results": [
            {
                "serial_items_per_s": serial,
                "parallel_items_per_s": serial * speedup,
                "speedup": speedup,
            }
        ],
    }


class BatchKernelGateTests(unittest.TestCase):
    """The batch_score_speedup column of the bbit_query gate."""

    def test_healthy_kernel_passes(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_bbit_query.json"), "w") as f:
                json.dump(bbit_record(), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 0, out + err)
        self.assertIn("all bench gates passed", out)
        self.assertIn("batch kernel 2.00x", out)

    def test_kernel_below_floor_fails(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_bbit_query.json"), "w") as f:
                json.dump(bbit_record(kernel8=1.05), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("batch scoring kernel is only 1.05x", out)
        self.assertNotIn("Traceback", err)

    def test_missing_kernel_field_is_a_malformed_row(self):
        # An emitter that stops reporting the kernel measurement is a
        # broken emitter, not a silent pass.
        rec = bbit_record()
        del rec["results"][1]["batch_score_speedup"]
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_bbit_query.json"), "w") as f:
                json.dump(rec, f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("malformed row", out)

    def test_floor_is_pinned(self):
        self.assertEqual(check_bench.BATCH_SCORE_SPEEDUP, 1.2)


class SchemeSweepGateTests(unittest.TestCase):
    """The iuh-vs-cmh ns/sketch ceiling."""

    def test_parity_passes(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_scheme_sweep.json"), "w") as f:
                json.dump(scheme_record(iuh_ns=900, cmh_ns=800), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 0, out + err)
        self.assertIn("all bench gates passed", out)
        self.assertIn("1.12x", out)

    def test_slow_iuh_fails(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_scheme_sweep.json"), "w") as f:
                json.dump(scheme_record(iuh_ns=1600, cmh_ns=800), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("check_bench: FAIL:", out)
        self.assertIn("iuh sketching", out)
        self.assertIn("2.00x", out)

    def test_missing_iuh_rows_fail(self):
        # A sweep that silently dropped the scheme under test must not
        # let the gate pass vacuously.
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_scheme_sweep.json"), "w") as f:
                json.dump(scheme_record(drop_iuh=True), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("lacks scheme rows", out)
        self.assertIn("iuh", out)

    def test_unit_exactly_at_ceiling_passes(self):
        rec = scheme_record(iuh_ns=1200, cmh_ns=800)
        self.assertEqual(check_bench.check_scheme_sweep("p", rec), [])

    def test_ceiling_is_pinned(self):
        self.assertEqual(check_bench.IUH_VS_CMH, 1.5)


class SnapshotLoadGateTests(unittest.TestCase):
    """The parallel-vs-serial snapshot open floor."""

    def test_healthy_speedup_passes(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_snapshot_load.json"), "w") as f:
                json.dump(snapshot_record(speedup=2.1), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 0, out + err)
        self.assertIn("all bench gates passed", out)
        self.assertIn("2.10x", out)

    def test_below_floor_fails(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_snapshot_load.json"), "w") as f:
                json.dump(snapshot_record(speedup=1.2), f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("check_bench: FAIL:", out)
        self.assertIn("snapshot load", out)
        self.assertIn("1.20x", out)

    def test_wrong_shape_fails_cleanly(self):
        with tempfile.TemporaryDirectory() as d:
            with open(os.path.join(d, "BENCH_snapshot_load.json"), "w") as f:
                json.dump({"bench": "snapshot_load", "results": [{}]}, f)
            code, out, err = run_gate(d)
        self.assertEqual(code, 1, out + err)
        self.assertIn("malformed snapshot_load results row", out)
        self.assertNotIn("Traceback", err)

    def test_unit_exactly_at_the_floor_passes(self):
        rec = snapshot_record(speedup=1.5)
        self.assertEqual(check_bench.check_snapshot_load("p", rec), [])

    def test_floor_is_pinned(self):
        self.assertEqual(check_bench.SNAPSHOT_LOAD_SPEEDUP, 1.5)


class ClusterGateUnitTests(unittest.TestCase):
    """Direct calls into check_cluster_scale for the ratio arithmetic."""

    def test_exactly_at_the_floor_passes(self):
        rec = cluster_record(single=10_000, two=16_000)
        self.assertEqual(
            check_bench.check_cluster_scale("p", rec), []
        )

    def test_zero_single_node_rate_fails(self):
        rec = cluster_record(single=0.0, two=16_000)
        failures = check_bench.check_cluster_scale("p", rec)
        self.assertEqual(len(failures), 1)
        self.assertIn("cluster scaling", failures[0])

    def test_floor_matches_the_bench_docstring(self):
        # The 1.6x figure is quoted in rust/benches/cluster_scale.rs and
        # docs; pin the constant so a silent relaxation shows up here.
        self.assertEqual(check_bench.CLUSTER_SPEEDUP, 1.6)


if __name__ == "__main__":
    unittest.main()
