#!/usr/bin/env python3
"""Cross-layer static analysis gate (stdlib only, offline).

Runs the five analyzers in ``tools/staticlint/`` over the repo:

  wire         jsonl ops <-> bin1 opcodes <-> client <-> PROTOCOL.md
  persistence  WAL tags / snapshot magics: encoder, decoder, refusal,
               pinning test
  locks        lock nesting graph: cycles, double-acquisition, I/O
               under a guard (allowlisted where audited)
  metrics      OpKind/counter/histogram parity across stats JSON,
               prom, OBSERVABILITY.md
  config       serve.json <-> ServeConfig <-> CLI flags <-> README

Audited exceptions live in ``tools/staticlint/allowlist.json``; a
stale entry (matching nothing) fails the gate so the allowlist cannot
rot.  See ``docs/LINTS.md`` for the contract and how to extend the
registries.

Usage: python3 tools/staticlint.py [ROOT] [--json]

Exit status: 0 = clean (allowlisted findings only), 1 = violations.
``--json`` emits the machine-readable findings instead of text.
"""

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import staticlint  # noqa: E402  (the tools/staticlint/ package)


def main():
    args = [a for a in sys.argv[1:] if a != "--json"]
    as_json = "--json" in sys.argv[1:]
    root = args[0] if args else "."

    tree = staticlint.load_tree(root)
    if not tree:
        print(f"staticlint: FAIL: no analyzable files under {root!r}")
        return 1
    allow_path = os.path.join(
        root, "tools", "staticlint", "allowlist.json"
    )
    try:
        allowlist = staticlint.load_allowlist(allow_path)
    except ValueError as e:
        print(f"staticlint: FAIL: {e}")
        return 1

    findings, allowed, stale = staticlint.run(tree, allowlist)

    if as_json:
        print(json.dumps(
            {
                "findings": [f.to_dict() for f in findings],
                "allowed": [f.to_dict() for f in allowed],
                "stale_allowlist": stale,
            },
            indent=2,
        ))
        return 1 if findings or stale else 0

    for f in findings:
        print(f"staticlint: FAIL: {f.text()}")
    for entry in stale:
        print(
            "staticlint: FAIL: stale allowlist entry matches nothing: "
            f"{entry['analyzer']}/{entry['code']} at {entry['path']} "
            f"(match: {entry['match']!r}) — remove it or fix the drift "
            f"it was written for"
        )
    for f in allowed:
        print(f"staticlint: allowed: {f.text()}")
    if findings or stale:
        print(
            f"staticlint: {len(findings)} violation(s), "
            f"{len(stale)} stale allowlist entr(y/ies), "
            f"{len(allowed)} allowlisted"
        )
        return 1
    print(
        f"staticlint: clean ({len(tree)} files, "
        f"{len(allowed)} allowlisted exception(s))"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
