#!/usr/bin/env python3
"""Markdown link checker (stdlib only, offline).

Scans the repo's Markdown files for inline links/images
(``[text](target)``) and verifies that every *local* target exists
relative to the file containing it.  External schemes (http, https,
mailto) are recorded but not fetched — this build is offline — and
pure in-page anchors (``#section``) are skipped.  Anchored local links
(``FILE.md#section``) are checked for file existence only.

Exit status: 0 when every local target resolves, 1 otherwise (broken
links are listed one per line as ``file:line: target``).

Usage: python3 tools/linkcheck.py [ROOT]
"""
import os
import re
import sys

# Inline markdown link or image: [text](target) / ![alt](target).
# Targets may carry an optional title: (target "title").
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")

# Directories never worth scanning (build output, VCS internals).
SKIP_DIRS = {".git", "target", "results", "artifacts", "__pycache__", ".claude"}

EXTERNAL_SCHEMES = ("http://", "https://", "mailto:")


def markdown_files(root):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d not in SKIP_DIRS)
        for name in sorted(filenames):
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def check_file(path, root):
    broken = []
    external = 0
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            # Links inside fenced code blocks are examples, not links.
            if CODE_FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(EXTERNAL_SCHEMES):
                    external += 1
                    continue
                if target.startswith("#"):
                    continue  # in-page anchor
                local = target.split("#", 1)[0]
                if not local:
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), local)
                )
                if not os.path.exists(resolved):
                    rel = os.path.relpath(path, root)
                    broken.append(f"{rel}:{lineno}: {target}")
    return broken, external


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    files = list(markdown_files(root))
    if not files:
        print(f"linkcheck: no markdown files under {root!r}", file=sys.stderr)
        return 1
    broken = []
    checked = external = 0
    for path in files:
        b, e = check_file(path, root)
        broken.extend(b)
        external += e
        checked += 1
    if broken:
        print(f"linkcheck: {len(broken)} broken local link(s):", file=sys.stderr)
        for line in broken:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(
        f"linkcheck: OK — {checked} markdown files, all local links resolve "
        f"({external} external links not fetched: offline)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
