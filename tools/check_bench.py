#!/usr/bin/env python3
"""Offline bench-output gate (stdlib only).

Parses the machine-readable ``BENCH_*.json`` files the bench harnesses
emit and enforces the packed b-bit plane's perf contract from
``BENCH_bbit_query.json``:

* at every K, packed query throughput must not regress below the
  unpacked (bits = 32) baseline for b <= 8 — the popcount kernel must
  actually win where it claims to;
* memory per item must shrink by at least (32/b) * 0.9 — packing that
  doesn't pack is a bug;
* at b <= 8, the bucket-at-a-time scoring kernel must beat the
  per-candidate scalar loop by at least 1.2x (the bench's
  ``batch_score_speedup`` field) — a batch kernel that doesn't batch
  is dead weight.

It also enforces the scheme registry's hot-loop contract from
``BENCH_scheme_sweep.json`` (emitted by the hasher_hotpath bench): the
O(1)-state ``iuh`` hasher must stay within 1.5x of ``cmh`` ns/sketch
at every K — the point of iterative universal hashing is trading the
O(D) permutation tables for *comparable* speed, so a slow ``iuh`` is a
regression, and a sweep missing either scheme's rows is an emitter
bug.

And the recovery plane's contract from ``BENCH_snapshot_load.json``
(emitted by the snapshot_load bench): the shard-parallel
``load_items`` bulk loader must rebuild the index at >= 1.5x the
serial ``insert_with_id`` replay rate — no measured win, no merge.

It also enforces the binary wire format's contract from
``BENCH_wire_format.json`` (emitted by the serving_throughput bench):
at b <= 8, pre-packed ``bin1`` ingest must beat JSON-lines ingest by
at least 1.3x rows/s — if shipping ready-made bytes is not clearly
faster than parse-and-sketch, the zero-copy path has regressed.

And the observability plane's always-on-cheap contract from
``BENCH_obs_overhead.json`` (emitted by the obs_overhead bench): query
throughput with tracing enabled must stay >= 0.97x of the same stack
with tracing disabled — instrumentation that taxes the hot path more
than 3% is a regression, not a feature.

And the cluster plane's scaling contract from
``BENCH_cluster_scale.json`` (emitted by the cluster_scale bench): a
2-node cluster must ingest at >= 1.6x the single-node rate on the same
machine — each node is a full stack with its own batch pump, so if
fan-out doesn't buy most of a second node's compute, the routing or
merge path is eating it.

Any other ``BENCH_*.json`` present is checked for being valid JSON
with a ``bench`` tag (schema drift in an emitter fails fast here
rather than in a downstream dashboard).

An **absent** bench file means the bench was not run (e.g. a plain
``make verify`` before ``make bench``) and its gate SKIPS so verify
stays runnable from a fresh clone; CI runs the benches first and then
this gate, making the skip path impossible there.  A **present but
malformed** file is never a skip: a truncated or mis-typed emission is
a broken emitter, and conflating it with "not run" would let a
regressed bench vanish from the gate, so it is a hard FAIL.  The
absent/malformed split lives in :func:`load_bench`; every gate takes
the pre-parsed record and never touches the filesystem itself.

Exit status: 0 = pass or skip, 1 = regression or malformed bench file
(one ``check_bench: FAIL:`` line per failure, never a traceback).

Usage: python3 tools/check_bench.py [ROOT]
"""
import glob
import json
import os
import sys

# b <= 8 widths must beat (or match) the unpacked baseline.
PACKED_WIN_BITS = (1, 2, 4, 8)
# Noise floor for the throughput comparison: single-run wall-clock
# numbers on shared CI runners jitter a few percent, and a gate that
# fails on scheduler noise trains people to ignore it.  A genuine
# kernel regression shows up far below this.
QPS_MARGIN = 0.95
# Required memory shrink: 90% of the ideal 32/b ratio (word-rounding
# at small K legitimately eats a little).
MEM_MARGIN = 0.9
# Pre-packed bin1 ingest must beat JSON-lines ingest by this factor at
# b <= 8.  The binary side skips JSON parsing AND the server-side
# sketch, so a healthy implementation clears this with a wide margin;
# 1.3x is the regression floor, not the target.
WIRE_SPEEDUP = 1.3
# Tracing-enabled throughput must stay at least this fraction of the
# tracing-disabled run.  The instrumented path adds two Instant reads
# per stage plus one ring-slot write per request — well under 1% on a
# healthy build; 0.97 leaves room for run-to-run jitter while still
# catching an accidentally hot lock or allocation in the trace path.
OBS_MARGIN = 0.97
# Two nodes must ingest at least this multiple of the single-node
# rate.  Perfect scaling is 2.0; rendezvous routing + per-node
# batching leave the fan-out path with no shared bottleneck, so a
# healthy build lands well above 1.6 — the floor catches a merge or
# routing path that serializes what should be parallel.
CLUSTER_SPEEDUP = 1.6
# The bucket-at-a-time scoring kernel must beat the per-candidate
# scalar collision_count loop by this factor at b <= 8.  The kernel
# hoists the width asserts out of the candidate loop, streams the
# arena sequentially, and unrolls 4-wide, so a healthy build clears
# this easily; 1.2x is the floor that catches the kernel degrading
# into a dressed-up scalar loop.
BATCH_SCORE_SPEEDUP = 1.2
# iuh ns/sketch must stay within this factor of cmh at every K.  The
# iterative hasher trades cmh's O(D) permutation tables for O(1) state
# and pays a few multiplies per slot for it — a healthy build sits
# near parity, and drifting past 1.5x means the O(1)-memory story
# costs more time than it saves space.
IUH_VS_CMH = 1.5
# The shard-parallel bulk loader must rebuild an index at >= this
# multiple of the serial insert_with_id replay rate.  Shards rebuild
# independently (one writer per shard, no shared state), so even two
# cores clear 1.5x; below it the "parallel" loader is serializing.
SNAPSHOT_LOAD_SPEEDUP = 1.5


def fail(msgs):
    for m in msgs:
        print(f"check_bench: FAIL: {m}")
    return 1


def load_bench(path):
    """Load one bench JSON file, separating absent from malformed.

    Returns ``(data, error)``: an absent file is ``(None, None)`` —
    the caller skips its gate; a present-but-unparsable or non-object
    file is ``(None, message)`` — the caller hard-fails.  A parsed
    dict is ``(data, None)``.
    """
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return None, None
    except (OSError, ValueError) as e:
        return None, f"{path}: malformed bench JSON ({e})"
    if not isinstance(data, dict):
        return None, f"{path}: bench record is not a JSON object"
    return data, None


def check_bbit_query(path, data):
    rows = data.get("results", [])
    failures = []
    by_k = {}
    try:
        for row in rows:
            by_k.setdefault(int(row["k"]), []).append(row)
    except (KeyError, TypeError, ValueError) as e:
        return [f"{path}: malformed bbit_query results row ({e})"]
    if not by_k:
        return [f"{path}: no results rows"]
    for k, krows in sorted(by_k.items()):
        base = [r for r in krows if int(r.get("bits", 0)) == 32]
        if not base:
            failures.append(f"{path}: K={k} has no bits=32 baseline row")
            continue
        base = base[0]
        try:
            base_qps = float(base["query_per_s"])
            base_bytes = float(base["bytes_per_item"])
        except (KeyError, TypeError, ValueError) as e:
            failures.append(f"{path}: K={k} malformed baseline row ({e})")
            continue
        for row in krows:
            try:
                bits = int(row["bits"])
                if bits == 32:
                    continue
                qps = float(row["query_per_s"])
                bpi = float(row["bytes_per_item"])
                kernel = float(row["batch_score_speedup"])
            except (KeyError, TypeError, ValueError) as e:
                failures.append(f"{path}: K={k} malformed row ({e})")
                continue
            if bits in PACKED_WIN_BITS and qps < QPS_MARGIN * base_qps:
                failures.append(
                    f"K={k} bits={bits}: packed query throughput "
                    f"{qps:.0f}/s regresses below unpacked "
                    f"{base_qps:.0f}/s (margin {QPS_MARGIN})"
                )
            want_ratio = (32.0 / bits) * MEM_MARGIN
            got_ratio = base_bytes / bpi if bpi else 0.0
            if got_ratio < want_ratio:
                failures.append(
                    f"K={k} bits={bits}: memory/item shrank only "
                    f"{got_ratio:.2f}x (need >= {want_ratio:.2f}x: "
                    f"{base_bytes:.0f} B -> {bpi:.0f} B)"
                )
            if bits in PACKED_WIN_BITS and kernel < BATCH_SCORE_SPEEDUP:
                failures.append(
                    f"K={k} bits={bits}: batch scoring kernel is only "
                    f"{kernel:.2f}x the scalar loop "
                    f"(need >= {BATCH_SCORE_SPEEDUP}x)"
                )
            print(
                f"check_bench: K={k} bits={bits}: {qps:.0f} q/s "
                f"(unpacked {base_qps:.0f}), {bpi:.0f} B/item "
                f"({got_ratio:.1f}x smaller), batch kernel {kernel:.2f}x"
            )
    return failures


def check_scheme_sweep(path, data):
    by_k = {}
    try:
        for row in data.get("results", []):
            by_k.setdefault(int(row["k"]), {})[str(row["scheme"])] = float(
                row["ns_per_sketch"]
            )
    except (KeyError, TypeError, ValueError) as e:
        return [f"{path}: malformed scheme_sweep results row ({e})"]
    if not by_k:
        return [f"{path}: no results rows"]
    failures = []
    for k, schemes in sorted(by_k.items()):
        missing = [s for s in ("cmh", "iuh") if s not in schemes]
        if missing:
            failures.append(
                f"{path}: K={k} sweep lacks scheme rows {missing} — the "
                f"iuh-vs-cmh gate cannot run"
            )
            continue
        cmh_ns, iuh_ns = schemes["cmh"], schemes["iuh"]
        ratio = iuh_ns / cmh_ns if cmh_ns else float("inf")
        print(
            f"check_bench: scheme K={k}: iuh {iuh_ns:.0f} ns/sketch vs "
            f"cmh {cmh_ns:.0f} ns/sketch ({ratio:.2f}x, ceiling "
            f"{IUH_VS_CMH}x)"
        )
        if ratio > IUH_VS_CMH:
            failures.append(
                f"K={k}: iuh sketching {iuh_ns:.0f} ns is {ratio:.2f}x "
                f"cmh's {cmh_ns:.0f} ns (need <= {IUH_VS_CMH}x)"
            )
    return failures


def check_snapshot_load(path, data):
    rows = data.get("results", [])
    if not rows:
        return [f"{path}: no results rows"]
    try:
        serial = float(rows[0]["serial_items_per_s"])
        parallel = float(rows[0]["parallel_items_per_s"])
        speedup = float(rows[0]["speedup"])
    except (KeyError, TypeError, ValueError) as e:
        return [f"{path}: malformed snapshot_load results row ({e})"]
    print(
        f"check_bench: snapshot load: serial {serial:.0f} items/s, "
        f"parallel {parallel:.0f} items/s ({speedup:.2f}x, floor "
        f"{SNAPSHOT_LOAD_SPEEDUP}x)"
    )
    if speedup < SNAPSHOT_LOAD_SPEEDUP:
        return [
            f"snapshot load: parallel open {parallel:.0f} items/s is only "
            f"{speedup:.2f}x the serial replay {serial:.0f} items/s "
            f"(need >= {SNAPSHOT_LOAD_SPEEDUP}x)"
        ]
    return []


def check_wire_format(path, data):
    try:
        bits = int(data["bits"])
        json_ins = float(data["json_insert_rows_per_s"])
        bin_ins = float(data["bin_insert_rows_per_s"])
        json_q = float(data["json_query_rows_per_s"])
        bin_q = float(data["bin_query_rows_per_s"])
    except (KeyError, TypeError, ValueError) as e:
        return [f"{path}: malformed wire_format record ({e})"]
    ratio = bin_ins / json_ins if json_ins else 0.0
    print(
        f"check_bench: wire b={bits}: ingest jsonl {json_ins:.0f} rows/s, "
        f"bin1 {bin_ins:.0f} rows/s ({ratio:.2f}x); query jsonl "
        f"{json_q:.0f}, bin1 {bin_q:.0f} rows/s"
    )
    if bits <= 8 and ratio < WIRE_SPEEDUP:
        return [
            f"bits={bits}: bin1 ingest {bin_ins:.0f} rows/s is only "
            f"{ratio:.2f}x the jsonl {json_ins:.0f} rows/s "
            f"(need >= {WIRE_SPEEDUP}x)"
        ]
    return []


def check_obs_overhead(path, data):
    try:
        qps_on = float(data["qps_on"])
        qps_off = float(data["qps_off"])
        ratio = float(data["ratio"])
    except (KeyError, TypeError, ValueError) as e:
        return [f"{path}: malformed obs_overhead record ({e})"]
    print(
        f"check_bench: obs: query tracing-on {qps_on:.0f} q/s vs "
        f"tracing-off {qps_off:.0f} q/s ({ratio:.4f}x, floor {OBS_MARGIN})"
    )
    if ratio < OBS_MARGIN:
        return [
            f"observability overhead: tracing-on query throughput "
            f"{qps_on:.0f} q/s is {ratio:.4f}x the tracing-off "
            f"{qps_off:.0f} q/s (need >= {OBS_MARGIN}x)"
        ]
    return []


def check_cluster_scale(path, data):
    by_nodes = {}
    try:
        for row in data["nodes"]:
            by_nodes[int(row["nodes"])] = float(row["ingest_rows_per_s"])
    except (KeyError, TypeError, ValueError) as e:
        return [f"{path}: malformed cluster_scale record ({e})"]
    if 1 not in by_nodes or 2 not in by_nodes:
        return [
            f"{path}: cluster_scale record lacks the 1-node and 2-node "
            f"rows the scaling gate compares (got {sorted(by_nodes)})"
        ]
    single, two = by_nodes[1], by_nodes[2]
    ratio = two / single if single else 0.0
    print(
        f"check_bench: cluster: ingest 1 node {single:.0f} rows/s, "
        f"2 nodes {two:.0f} rows/s ({ratio:.2f}x, floor {CLUSTER_SPEEDUP})"
    )
    for n in sorted(by_nodes):
        if n > 2:
            wider = by_nodes[n] / single if single else 0.0
            print(
                f"check_bench: cluster: {n} nodes {by_nodes[n]:.0f} rows/s "
                f"({wider:.2f}x single, informational)"
            )
    if ratio < CLUSTER_SPEEDUP:
        return [
            f"cluster scaling: 2-node ingest {two:.0f} rows/s is only "
            f"{ratio:.2f}x the single-node {single:.0f} rows/s "
            f"(need >= {CLUSTER_SPEEDUP}x)"
        ]
    return []


# Gated files by basename; anything else matching BENCH_*.json gets
# only the generic well-formed + 'bench'-tag check.
GATES = {
    "BENCH_bbit_query.json": check_bbit_query,
    "BENCH_wire_format.json": check_wire_format,
    "BENCH_obs_overhead.json": check_obs_overhead,
    "BENCH_cluster_scale.json": check_cluster_scale,
    "BENCH_scheme_sweep.json": check_scheme_sweep,
    "BENCH_snapshot_load.json": check_snapshot_load,
}


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    failures = []
    ran_gate = False
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        data, err = load_bench(path)
        if err is not None:
            failures.append(err)
            continue
        if data is None:
            # Deleted between glob and open: same as never emitted.
            continue
        if "bench" not in data:
            failures.append(f"{path}: missing 'bench' tag")
            continue
        gate = GATES.get(os.path.basename(path))
        if gate is not None:
            failures.extend(gate(path, data))
            ran_gate = True

    if not ran_gate and not failures:
        print(
            "check_bench: no gated BENCH_*.json found (benches not "
            "run); skipping the perf gates"
        )
        return 0

    if failures:
        return fail(failures)
    print("check_bench: all bench gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
