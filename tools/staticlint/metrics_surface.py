"""Metrics-surface parity: every OpKind, Stage, counter, and
histogram must appear in the stats JSON serializer, the prom
renderer, and docs/OBSERVABILITY.md.

The observability plane (PR 7) has four coupled surfaces:

* the ``OpKind``/``Stage`` registries in ``rust/src/obs/mod.rs``
  (enum variants, ``ALL`` arrays, ``name()`` strings, ``NUM_*``
  constants — all hand-synchronized);
* the ``Metrics``/``MetricsSnapshot`` structs and their
  ``to_json`` keys in ``rust/src/metrics.rs``;
* the Prometheus renderer in ``rust/src/obs/prom.rs`` (every counter
  as ``cminhash_<name>_total``, every histogram as
  ``cminhash_<name>_us``, plus the store-stats series);
* the human registry: the stage table and the metrics reference table
  in ``docs/OBSERVABILITY.md``.

A counter added to ``Metrics`` but absent from ``to_json``, prom, or
the docs is a silent observability gap; this analyzer makes it a CI
failure instead.
"""

import re

from . import Finding, fn_body, impl_body, strip_comments, struct_body

OBS_RS = "rust/src/obs/mod.rs"
METRICS_RS = "rust/src/metrics.rs"
PROM_RS = "rust/src/obs/prom.rs"
PROTOCOL_RS = "rust/src/server/protocol.rs"
STORE_RS = "rust/src/store/mod.rs"
OBSERVABILITY_MD = "docs/OBSERVABILITY.md"

# StoreStats field -> the prom series that must carry it.  `bits`
# rides in the build_info labels rather than its own series.
STORE_PROM = {
    "stored": "cminhash_stored_items",
    "shards": "cminhash_shard_items",
    "persisted_bytes": "cminhash_persisted_bytes",
    "sketch_bytes": "cminhash_sketch_bytes",
    "wal_appended_bytes": "cminhash_wal_appended_bytes_total",
    "fsync": "cminhash_fsync_latency_us",
    "shard_ops": "cminhash_shard_ops_total",
    "band_buckets": "cminhash_band_buckets",
    "band_max_bucket": "cminhash_band_max_bucket",
    "candidates": "cminhash_candidates_scored_total",
    "bits": "cminhash_build_info",
}

# StoreStats field -> its stats-JSON key when the names differ.
STORE_JSON_ALIAS = {"fsync": "fsync_latency"}


def check_enum(findings, text, enum, num_const):
    """ALL array, name() arms, and NUM_* must agree for one enum."""
    imp = impl_body(text, enum)
    if imp is None:
        findings.append(Finding(
            "metrics", "registry-shape", OBS_RS, 0,
            f"impl {enum} not found; registry unchecked",
        ))
        return None
    name_arms = dict(re.findall(enum + r'::(\w+)\s*=>\s*"([a-z_]+)"', imp))
    all_m = re.search(r"const ALL\s*:\s*\[[^\]]*\]\s*=\s*\[(.*?)\]", imp, re.S)
    all_variants = (
        re.findall(enum + r"::(\w+)", all_m.group(1)) if all_m else []
    )
    if not name_arms or not all_variants:
        findings.append(Finding(
            "metrics", "registry-shape", OBS_RS, 0,
            f"{enum}: could not extract name() arms or the ALL array",
        ))
        return None
    for v in sorted(set(all_variants) - set(name_arms)):
        findings.append(Finding(
            "metrics", "registry-drift", OBS_RS, 0,
            f"{enum}::{v} is in ALL but has no name() arm",
        ))
    for v in sorted(set(name_arms) - set(all_variants)):
        findings.append(Finding(
            "metrics", "registry-drift", OBS_RS, 0,
            f"{enum}::{v} has a name() arm but is missing from ALL",
        ))
    if len(all_variants) != len(set(all_variants)):
        findings.append(Finding(
            "metrics", "registry-drift", OBS_RS, 0,
            f"{enum}::ALL lists a variant twice",
        ))
    num = re.search(r"const " + num_const + r"\s*:\s*usize\s*=\s*(\d+)", text)
    if num and int(num.group(1)) != len(all_variants):
        findings.append(Finding(
            "metrics", "registry-drift", OBS_RS, 0,
            f"{num_const} = {num.group(1)} but {enum}::ALL has "
            f"{len(all_variants)} variants",
        ))
    return name_arms


def analyze(tree):
    findings = []

    obs = tree.get(OBS_RS)
    stage_names = opkind_names = None
    if obs is not None:
        clean = strip_comments(obs)
        opkind_arms = check_enum(findings, clean, "OpKind", "NUM_OPS")
        stage_arms = check_enum(findings, clean, "Stage", "NUM_STAGES")
        opkind_names = set(opkind_arms.values()) if opkind_arms else None
        stage_names = set(stage_arms.values()) if stage_arms else None

    # -- Metrics struct vs snapshot JSON vs prom ---------------------------
    met = tree.get(METRICS_RS)
    prom = tree.get(PROM_RS)
    counters = histograms = None
    if met is not None:
        clean = strip_comments(met)
        body = struct_body(clean, "Metrics")
        if body is None:
            findings.append(Finding(
                "metrics", "registry-shape", METRICS_RS, 0,
                "struct Metrics not found",
            ))
        else:
            counters = re.findall(r"pub (\w+): AtomicU64", body)
            histograms = re.findall(r"pub (\w+): LatencyHistogram", body)
            snap_impl = impl_body(clean, "MetricsSnapshot")
            keys = set()
            if snap_impl is not None:
                tj = fn_body(snap_impl, "to_json")
                if tj is not None:
                    keys = set(re.findall(r'"(\w+)"', tj))
            if not keys:
                findings.append(Finding(
                    "metrics", "registry-shape", METRICS_RS, 0,
                    "MetricsSnapshot::to_json not found or empty",
                ))
            for name in counters + histograms:
                if keys and name not in keys:
                    findings.append(Finding(
                        "metrics", "json-gap", METRICS_RS, 0,
                        f"Metrics field '{name}' is missing from "
                        f"MetricsSnapshot::to_json: invisible to the "
                        f"stats op",
                    ))
        # LatencySnapshot fields must all serialize too.
        lat = struct_body(clean, "LatencySnapshot")
        lat_impl = impl_body(clean, "LatencySnapshot")
        if lat is not None and lat_impl is not None:
            tj = fn_body(lat_impl, "to_json") or ""
            lkeys = set(re.findall(r'"(\w+)"', tj))
            for name in re.findall(r"pub (\w+):", lat):
                if name not in lkeys:
                    findings.append(Finding(
                        "metrics", "json-gap", METRICS_RS, 0,
                        f"LatencySnapshot field '{name}' is missing "
                        f"from its to_json",
                    ))

    if prom is not None and counters is not None:
        for name in counters:
            series = f"cminhash_{name}_total"
            if series not in prom:
                findings.append(Finding(
                    "metrics", "prom-gap", PROM_RS, 0,
                    f"counter '{name}' has no '{series}' series in the "
                    f"prom renderer",
                ))
        for name in histograms:
            series = f"cminhash_{name}_us"
            if series not in prom:
                findings.append(Finding(
                    "metrics", "prom-gap", PROM_RS, 0,
                    f"histogram '{name}' has no '{series}' series in "
                    f"the prom renderer",
                ))
        if opkind_names is not None and "cminhash_requests_total" not in prom:
            findings.append(Finding(
                "metrics", "prom-gap", PROM_RS, 0,
                "no per-op cminhash_requests_total series in the prom "
                "renderer",
            ))

    # -- StoreStats vs stats JSON vs prom ----------------------------------
    store = tree.get(STORE_RS)
    proto = tree.get(PROTOCOL_RS)
    if store is not None:
        body = struct_body(strip_comments(store), "StoreStats")
        if body is None:
            findings.append(Finding(
                "metrics", "registry-shape", STORE_RS, 0,
                "struct StoreStats not found",
            ))
        else:
            fields = re.findall(r"pub (\w+):", body)
            if proto is not None:
                seg = None
                m = re.search(r"Response::Stats\b", strip_comments(proto))
                if m:
                    nxt = re.search(
                        r"Response::\w+", strip_comments(proto)[m.end():]
                    )
                    end = m.end() + (nxt.start() if nxt else 0)
                    seg = strip_comments(proto)[m.start():end]
                keys = set(re.findall(r'"(\w+)"', seg)) if seg else set()
                if not keys:
                    findings.append(Finding(
                        "metrics", "registry-shape", PROTOCOL_RS, 0,
                        "Response::Stats serializer arm not found",
                    ))
                for f in fields:
                    key = STORE_JSON_ALIAS.get(f, f)
                    if keys and key not in keys:
                        findings.append(Finding(
                            "metrics", "json-gap", PROTOCOL_RS, 0,
                            f"StoreStats field '{f}' (key '{key}') is "
                            f"missing from the Response::Stats "
                            f"serializer",
                        ))
            if prom is not None:
                for f in fields:
                    series = STORE_PROM.get(f)
                    if series is None:
                        findings.append(Finding(
                            "metrics", "prom-gap", PROM_RS, 0,
                            f"StoreStats field '{f}' has no entry in the "
                            f"analyzer's STORE_PROM map — extend "
                            f"tools/staticlint/metrics_surface.py when "
                            f"adding store stats",
                        ))
                    elif series not in prom:
                        findings.append(Finding(
                            "metrics", "prom-gap", PROM_RS, 0,
                            f"StoreStats field '{f}' has no '{series}' "
                            f"series in the prom renderer",
                        ))

    # -- docs/OBSERVABILITY.md ---------------------------------------------
    doc = tree.get(OBSERVABILITY_MD)
    if doc is not None:
        doc_cells = set(re.findall(r"`([\w.]+)`", doc))
        if stage_names:
            for s in sorted(stage_names - doc_cells):
                findings.append(Finding(
                    "metrics", "doc-gap", OBSERVABILITY_MD, 0,
                    f"pipeline stage '{s}' is missing from the "
                    f"OBSERVABILITY.md stage table",
                ))
        if prom is not None:
            for series in sorted(set(re.findall(r'"(cminhash_\w+)"', prom))):
                if series not in doc_cells:
                    findings.append(Finding(
                        "metrics", "doc-gap", OBSERVABILITY_MD, 0,
                        f"prom series '{series}' is missing from the "
                        f"OBSERVABILITY.md metrics reference",
                    ))

    return findings
