"""Wire-registry parity: jsonl ops <-> OpKind <-> bin1 opcodes <->
BlockingClient methods <-> docs/PROTOCOL.md tables.

The wire surface lives in four places that drift independently:

* ``rust/src/server/protocol.rs`` — the jsonl op strings accepted by
  ``Request::from_json``;
* ``rust/src/obs/mod.rs`` — ``OpKind``, the canonical op registry the
  observability plane indexes by;
* ``rust/src/server/frame.rs`` — the ``bin1`` opcode constants, plus
  the ``bin_op_kind`` dispatch in ``rust/src/server/mod.rs`` and the
  ``BlockingClient`` conveniences in ``rust/src/server/client.rs``
  (the client moved there when the cluster plane landed; trees that
  still keep it in ``mod.rs`` are accepted as a fallback);
* ``docs/PROTOCOL.md`` — the human registry: per-op headings and the
  two opcode tables.

Every one of these must agree on names, codes, and dialect coverage.
"""

import re

from . import Finding, camel_to_snake, fn_body, impl_body, strip_comments

PROTOCOL_RS = "rust/src/server/protocol.rs"
FRAME_RS = "rust/src/server/frame.rs"
SERVER_RS = "rust/src/server/mod.rs"
CLIENT_RS = "rust/src/server/client.rs"
OBS_RS = "rust/src/obs/mod.rs"
PROTOCOL_MD = "docs/PROTOCOL.md"

# Ops that exist only on the binary dialect by design: packed ingest
# ships raw sketch words, which jsonl (a parse-and-sketch dialect)
# cannot express.  Extending this set is an audited decision.
BINARY_ONLY = {"insert_packed"}

# The typed BlockingClient convenience expected for each bin1 op.
# `metrics` returns the raw exposition string, hence the _text name.
CLIENT_METHOD = {
    "ping": "ping",
    "sketch": "sketch",
    "sketch_batch": "sketch_batch",
    "insert_packed": "insert_packed",
    "query_batch": "query_batch",
    "delete": "delete",
    "estimate": "estimate",
    "trace": "trace",
    "metrics": "metrics_text",
    "replicate": "replicate",
}


def jsonl_ops(tree):
    """Op strings accepted by Request::from_json, with line numbers."""
    text = tree.get(PROTOCOL_RS)
    if text is None:
        return None
    body = fn_body(strip_comments(text), "from_json")
    if body is None:
        return None
    return set(re.findall(r'"([a-z_]+)"\s*=>', body))


def opkind_names(tree):
    text = tree.get(OBS_RS)
    if text is None:
        return None
    return set(re.findall(r'OpKind::\w+\s*=>\s*"([a-z_]+)"', strip_comments(text)))


def frame_consts(tree):
    """(requests, responses) as {lower_name: code} dicts, or None."""
    text = tree.get(FRAME_RS)
    if text is None:
        return None
    pairs = re.findall(
        r"pub const (\w+): u8 = (0x[0-9A-Fa-f]{2})", strip_comments(text)
    )
    requests, responses = {}, {}
    for name, code in pairs:
        if name.startswith("R_"):
            responses[name[2:].lower()] = int(code, 16)
        else:
            requests[name.lower()] = int(code, 16)
    return requests, responses


def analyze(tree):
    findings = []

    jsonl = jsonl_ops(tree)
    opkinds = opkind_names(tree)
    consts = frame_consts(tree)

    # -- jsonl <-> OpKind ---------------------------------------------------
    if jsonl is not None and opkinds is not None:
        for op in sorted(opkinds - jsonl - BINARY_ONLY):
            findings.append(Finding(
                "wire", "missing-jsonl-op", PROTOCOL_RS, 0,
                f"OpKind '{op}' has no jsonl from_json arm (and is not "
                f"in the audited binary-only set)",
            ))
        for op in sorted(jsonl - opkinds):
            findings.append(Finding(
                "wire", "missing-opkind", OBS_RS, 0,
                f"jsonl op '{op}' has no OpKind registry entry",
            ))

    # -- bin1 opcode block integrity ---------------------------------------
    if consts is not None:
        requests, responses = consts
        codes = sorted(requests.values())
        if codes != list(range(1, len(codes) + 1)):
            findings.append(Finding(
                "wire", "opcode-gap", FRAME_RS, 0,
                f"bin1 request opcodes are not contiguous from 0x01: "
                f"{[hex(c) for c in codes]}",
            ))
        rcodes = sorted(responses.values())
        if rcodes != list(range(0x80, 0x80 + len(rcodes))):
            findings.append(Finding(
                "wire", "opcode-gap", FRAME_RS, 0,
                f"bin1 response opcodes are not contiguous from 0x80: "
                f"{[hex(c) for c in rcodes]}",
            ))
        # Every request op pairs with a success response, plus the one
        # shared error frame — so the response block is requests + 1.
        if len(responses) != len(requests) + 1:
            findings.append(Finding(
                "wire", "unpaired-opcode", FRAME_RS, 0,
                f"{len(requests)} request opcodes but {len(responses)} "
                f"response opcodes (want requests + 1 for R_ERR): a "
                f"request op is missing its response frame or vice versa",
            ))
        if opkinds is not None:
            for op in sorted(set(requests) - opkinds):
                findings.append(Finding(
                    "wire", "missing-opkind", OBS_RS, 0,
                    f"bin1 op '{op}' has no OpKind registry entry",
                ))

    # -- bin1 dispatch coverage in the server ------------------------------
    server = tree.get(SERVER_RS)
    if server is not None and consts is not None:
        requests, _ = consts
        body = fn_body(strip_comments(server), "bin_op_kind")
        if body is None:
            findings.append(Finding(
                "wire", "missing-dispatch", SERVER_RS, 0,
                "fn bin_op_kind not found: bin1 requests cannot be "
                "attributed to an OpKind",
            ))
        else:
            names = ["BinRequest"]
            alias = re.search(r"\bBinRequest as (\w+)\s*;", body)
            if alias:
                names.append(alias.group(1))
            arms = {
                camel_to_snake(v)
                for v in re.findall(
                    r"\b(?:" + "|".join(names) + r")::(\w+)", body
                )
            }
            for op in sorted(set(requests) - arms):
                findings.append(Finding(
                    "wire", "missing-dispatch", SERVER_RS, 0,
                    f"bin1 op '{op}' has no bin_op_kind arm",
                    function="bin_op_kind",
                ))
            for op in sorted(arms - set(requests)):
                findings.append(Finding(
                    "wire", "missing-dispatch", FRAME_RS, 0,
                    f"bin_op_kind handles '{op}' but frame.rs defines "
                    f"no such request opcode",
                    function="bin_op_kind",
                ))

    # -- BlockingClient dialect coverage -----------------------------------
    # The client lives in client.rs; older trees (and the minimal test
    # fixtures) keep it in mod.rs, so fall back there.
    client_text = tree.get(CLIENT_RS)
    client_file = CLIENT_RS if client_text is not None else SERVER_RS
    if client_text is None:
        client_text = server
    if client_text is not None and consts is not None:
        requests, _ = consts
        client = impl_body(strip_comments(client_text), "BlockingClient")
        if client is None:
            findings.append(Finding(
                "wire", "client-gap", client_file, 0,
                "impl BlockingClient not found",
            ))
        else:
            methods = set(re.findall(r"pub fn (\w+)", client))
            for op in sorted(requests):
                want = CLIENT_METHOD.get(op)
                if want is None:
                    findings.append(Finding(
                        "wire", "client-gap", client_file, 0,
                        f"bin1 op '{op}' has no entry in the analyzer's "
                        f"CLIENT_METHOD map — extend "
                        f"tools/staticlint/wire.py when adding ops",
                    ))
                elif want not in methods:
                    findings.append(Finding(
                        "wire", "client-gap", client_file, 0,
                        f"bin1 op '{op}' has no BlockingClient::{want} "
                        f"convenience: the op is unreachable from typed "
                        f"client code",
                    ))

    # -- docs/PROTOCOL.md tables and headings ------------------------------
    doc = tree.get(PROTOCOL_MD)
    if doc is not None and consts is not None:
        requests, responses = consts
        doc_rows = re.findall(r"\|\s*`0x([0-9A-Fa-f]{2})`\s*\|\s*`?([a-z_ ]+?)`?\s*\|", doc)
        doc_req = {}
        doc_resp_codes = set()
        for code_hex, name in doc_rows:
            code = int(code_hex, 16)
            if code < 0x80:
                doc_req[name] = code
            else:
                doc_resp_codes.add(code)
        for op, code in sorted(requests.items()):
            if op not in doc_req:
                findings.append(Finding(
                    "wire", "doc-table", PROTOCOL_MD, 0,
                    f"bin1 request op '{op}' (0x{code:02x}) missing from "
                    f"the PROTOCOL.md request opcode table",
                ))
            elif doc_req[op] != code:
                findings.append(Finding(
                    "wire", "doc-table", PROTOCOL_MD, 0,
                    f"PROTOCOL.md lists '{op}' as 0x{doc_req[op]:02x} but "
                    f"frame.rs defines 0x{code:02x}",
                ))
        for op in sorted(set(doc_req) - set(requests)):
            findings.append(Finding(
                "wire", "doc-table", PROTOCOL_MD, 0,
                f"PROTOCOL.md documents request op '{op}' "
                f"(0x{doc_req[op]:02x}) that frame.rs does not define",
            ))
        for code in sorted(set(responses.values()) - doc_resp_codes):
            findings.append(Finding(
                "wire", "doc-table", PROTOCOL_MD, 0,
                f"bin1 response opcode 0x{code:02x} missing from the "
                f"PROTOCOL.md response table",
            ))
        for code in sorted(doc_resp_codes - set(responses.values())):
            findings.append(Finding(
                "wire", "doc-table", PROTOCOL_MD, 0,
                f"PROTOCOL.md documents response opcode 0x{code:02x} "
                f"that frame.rs does not define",
            ))

    if doc is not None and jsonl is not None:
        # An op is documented if it has a `### \`op\`` heading or
        # appears in a fenced request example (the batch ops share one
        # section of worked examples rather than per-op headings).
        documented = set(re.findall(r"^###\s+`(\w+)`", doc, re.M))
        documented |= set(re.findall(r'"op"\s*:\s*"(\w+)"', doc))
        for op in sorted(jsonl - documented):
            findings.append(Finding(
                "wire", "undocumented-op", PROTOCOL_MD, 0,
                f"jsonl op '{op}' has neither a heading nor a worked "
                f"example in PROTOCOL.md",
            ))

    return findings
