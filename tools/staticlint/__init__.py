"""Cross-layer static analysis for the C-MinHash serving stack.

Zero-dependency (stdlib only), offline, in the same spirit as
``tools/check_bench.py`` and ``tools/linkcheck.py``: the container has
no cargo, so these analyzers parse the Rust sources and docs as text
and enforce the invariants that keep the five hand-synchronized
registries aligned:

* wire      — jsonl op strings <-> bin1 opcodes <-> BlockingClient
              methods <-> docs/PROTOCOL.md tables
* persistence — WAL record tags and snapshot magics each have exactly
              one encoder, one decoder, a mismatch-refusal path, and a
              test referencing them
* locks     — lock acquisition sites, nesting graph, lock-order
              cycles, double-acquisition, guards held across I/O
              (allowlisted where deliberate)
* metrics   — OpKind/Stage/counter/histogram surface parity across
              stats JSON, the prom renderer, and docs/OBSERVABILITY.md
* config    — serve.json keys <-> ServeConfig fields <-> CLI flags <->
              README configuration table

Every analyzer takes a *virtual tree* (``dict`` of repo-relative path
-> file text) so the self-tests in ``tools/tests/test_staticlint.py``
can seed deliberate violations into fixture snippets; the driver
``tools/staticlint.py`` loads the real files.

Findings are machine-readable (``Finding.to_dict``) and suppressible
via ``tools/staticlint/allowlist.json`` for audited exceptions; a
stale allowlist entry (matching nothing) is itself a failure so the
allowlist cannot rot.
"""

import json
import os
import re

ANALYZERS = ("wire", "persistence", "locks", "metrics", "config")

# Mirrors tools/linkcheck.py: never descend into build output or VCS
# internals when loading the real tree.
SKIP_DIRS = {".git", "target", "results", "artifacts", "__pycache__", ".claude"}

# File suffixes the analyzers can consume.  Everything else (binaries,
# data files) is irrelevant to registry parity.
LOAD_SUFFIXES = (".rs", ".md", ".json", ".toml")


class Finding:
    """One violation: where it is, which invariant, and why."""

    def __init__(self, analyzer, code, path, line, message, function=""):
        self.analyzer = analyzer
        self.code = code
        self.path = path
        self.line = line
        self.message = message
        self.function = function

    def to_dict(self):
        d = {
            "analyzer": self.analyzer,
            "code": self.code,
            "path": self.path,
            "line": self.line,
            "message": self.message,
        }
        if self.function:
            d["function"] = self.function
        return d

    def text(self):
        where = f"{self.path}:{self.line}" if self.line else self.path
        fn = f" (fn {self.function})" if self.function else ""
        return f"{where}: [{self.analyzer}/{self.code}] {self.message}{fn}"


def load_tree(root):
    """Load the repo's analyzable files as {relative path: text}."""
    tree = {}
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if not name.endswith(LOAD_SUFFIXES):
                continue
            full = os.path.join(dirpath, name)
            rel = os.path.relpath(full, root).replace(os.sep, "/")
            try:
                with open(full, encoding="utf-8") as f:
                    tree[rel] = f.read()
            except (OSError, UnicodeDecodeError):
                # Unreadable files are not silently skippable: a
                # registry we cannot read is a registry we cannot check.
                tree[rel] = ""
    return tree


# ---------------------------------------------------------------------------
# Rust-source text helpers shared by the analyzers.  These are
# deliberately lexical (regex + brace counting) — good enough for this
# codebase's rustfmt'd style, and they fail loudly (None) rather than
# guessing when a shape is not found.
# ---------------------------------------------------------------------------

# Strip `// ...` line comments so commented-out code and doc examples
# (which quote op names and JSON keys) never feed the extractors.  The
# lookbehind keeps `https://` inside string literals intact.
_COMMENT_RE = re.compile(r'(?<!:)//.*$', re.M)


def strip_comments(text):
    return _COMMENT_RE.sub("", text)


def line_of(text, offset):
    """1-based line number of a character offset."""
    return text.count("\n", 0, offset) + 1


def block_span(text, open_idx):
    """(start, end) offsets of the ``{...}`` block whose opening brace
    is at ``open_idx``; ``end`` points just past the closing brace.
    Returns None when braces never balance."""
    depth = 0
    for i in range(open_idx, len(text)):
        c = text[i]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                return (open_idx, i + 1)
    return None


def fn_body(text, name):
    """Body text of ``fn <name>`` (first match), or None."""
    m = re.search(r"\bfn\s+" + re.escape(name) + r"\b", text)
    if not m:
        return None
    open_idx = text.find("{", m.end())
    if open_idx < 0:
        return None
    span = block_span(text, open_idx)
    return text[span[0] + 1 : span[1] - 1] if span else None


def impl_body(text, type_name):
    """Body text of the first ``impl <TypeName>`` block, or None."""
    m = re.search(r"\bimpl\s+" + re.escape(type_name) + r"\b", text)
    if not m:
        return None
    open_idx = text.find("{", m.end())
    if open_idx < 0:
        return None
    span = block_span(text, open_idx)
    return text[span[0] + 1 : span[1] - 1] if span else None


def struct_body(text, name):
    """Body text of ``struct <name> {...}``, or None."""
    m = re.search(r"\bstruct\s+" + re.escape(name) + r"\b", text)
    if not m:
        return None
    open_idx = text.find("{", m.end())
    if open_idx < 0:
        return None
    span = block_span(text, open_idx)
    return text[span[0] + 1 : span[1] - 1] if span else None


def camel_to_snake(name):
    return re.sub(r"(?<!^)(?=[A-Z])", "_", name).lower()


# ---------------------------------------------------------------------------
# Allowlist: audited exceptions, one JSON object per entry.
# ---------------------------------------------------------------------------

ALLOWLIST_FIELDS = ("analyzer", "code", "path", "match", "reason")


def load_allowlist(path):
    """Load and validate the allowlist; raises ValueError on a
    malformed file (a broken allowlist must not silently allow)."""
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"{path}: allowlist must be a JSON array")
    for i, entry in enumerate(data):
        if not isinstance(entry, dict):
            raise ValueError(f"{path}: entry {i} is not an object")
        for field in ALLOWLIST_FIELDS:
            if field not in entry or not isinstance(entry[field], str):
                raise ValueError(
                    f"{path}: entry {i} missing string field '{field}'"
                )
    return data


def entry_matches(entry, finding):
    return (
        entry["analyzer"] == finding.analyzer
        and entry["code"] == finding.code
        and entry["path"] == finding.path
        and (
            entry["match"] in finding.message
            or (finding.function and entry["match"] == finding.function)
        )
    )


def run(tree, allowlist=()):
    """Run every analyzer over the virtual tree.

    Returns ``(findings, allowed, stale)``: unallowed findings, the
    findings an allowlist entry suppressed, and allowlist entries that
    matched nothing (stale — a failure in their own right).
    """
    from . import config_knobs, locks, metrics_surface, persistence, wire

    raw = []
    raw.extend(wire.analyze(tree))
    raw.extend(persistence.analyze(tree))
    raw.extend(locks.analyze(tree))
    raw.extend(metrics_surface.analyze(tree))
    raw.extend(config_knobs.analyze(tree))

    findings, allowed = [], []
    used = [False] * len(allowlist)
    for f in raw:
        hit = None
        for i, entry in enumerate(allowlist):
            if entry_matches(entry, f):
                hit = entry
                used[i] = True
                break
        (allowed if hit else findings).append(f)
    stale = [e for e, u in zip(allowlist, used) if not u]
    return findings, allowed, stale
