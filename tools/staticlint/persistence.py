"""Persistence-format audit: WAL record tags and snapshot magics.

A byte format drifts in one of three ways: an encoder without a
decoder (unreadable data), a decoder without the corresponding format
actually being written (dead compatibility code that silently rots),
or a tag nobody's tests pin (a format change ships without tripping
anything).  This analyzer demands, for every WAL record tag and every
snapshot magic:

* exactly one encoder site and exactly one decoder site (the WAL), or
  a writer/reader classification (snapshot: the newest magics are
  written, legacy magics are load-only);
* a mismatch-refusal path — unknown tags and unknown magics must be
  rejected, not skipped;
* at least one test referencing the tag/magic (rust/tests/ or a
  ``#[cfg(test)]`` module), so the byte layout is pinned.
"""

import re

from . import Finding, fn_body, strip_comments

WAL_RS = "rust/src/store/wal.rs"
SNAPSHOT_RS = "rust/src/store/snapshot.rs"


def test_text(tree):
    """All test code in the tree: integration tests plus everything
    after a ``#[cfg(test)]`` marker in library files."""
    chunks = []
    for path, text in tree.items():
        if path.startswith("rust/tests/"):
            chunks.append(text)
        elif path.endswith(".rs"):
            idx = text.find("#[cfg(test)]")
            if idx >= 0:
                chunks.append(text[idx:])
    return "\n".join(chunks)


def analyze(tree):
    findings = []
    tests = test_text(tree)

    # -- WAL record tags ----------------------------------------------------
    wal = tree.get(WAL_RS)
    if wal is not None:
        clean = strip_comments(wal)
        tags = re.findall(r"const (TAG_\w+): u8 = (\d+)", clean)
        by_value = {}
        for name, value in tags:
            by_value.setdefault(value, []).append(name)
        for value, names in sorted(by_value.items()):
            if len(names) > 1:
                findings.append(Finding(
                    "persistence", "tag-collision", WAL_RS, 0,
                    f"WAL tags {names} share byte value {value}",
                ))
        if not tags:
            findings.append(Finding(
                "persistence", "no-tags", WAL_RS, 0,
                "no TAG_* constants found; the WAL analyzer has nothing "
                "to audit (extraction regression?)",
            ))
        encode = fn_body(clean, "encode")
        decode = fn_body(clean, "decode_payload")
        for name, _ in tags:
            for label, body, fname in (
                ("encoder", encode, "encode"),
                ("decoder", decode, "decode_payload"),
            ):
                if body is None:
                    findings.append(Finding(
                        "persistence", f"no-{label}", WAL_RS, 0,
                        f"fn {fname} not found; cannot audit {name}",
                    ))
                    continue
                n = len(re.findall(r"\b" + name + r"\b", body))
                if n == 0:
                    findings.append(Finding(
                        "persistence", f"no-{label}", WAL_RS, 0,
                        f"WAL tag {name} has no {label} site in {fname}",
                    ))
                elif n > 1:
                    findings.append(Finding(
                        "persistence", f"dup-{label}", WAL_RS, 0,
                        f"WAL tag {name} appears {n} times in {fname}; "
                        f"exactly one {label} site expected",
                    ))
        if decode is not None and not re.search(r"_\s*=>", decode):
            findings.append(Finding(
                "persistence", "no-refusal", WAL_RS, 0,
                "decode_payload has no catch-all arm: an unknown WAL "
                "tag must be refused, not fall through",
            ))
        # Every record variant must be pinned by a test (roundtrip or
        # golden) referencing it by name.
        for variant in set(re.findall(r"enum WalRecord.*?\{(.*?)\n\}", clean, re.S)):
            for vname in re.findall(r"^\s{4}(\w+)\s*[{(]", variant, re.M):
                if not re.search(r"\bWalRecord::" + vname + r"\b", tests):
                    findings.append(Finding(
                        "persistence", "untested-format", WAL_RS, 0,
                        f"WalRecord::{vname} is referenced by no test: "
                        f"its byte layout is unpinned",
                    ))

    # -- snapshot magics ----------------------------------------------------
    snap = tree.get(SNAPSHOT_RS)
    if snap is not None:
        clean = strip_comments(snap)
        magics = re.findall(r'const (MAGIC_\w+): &\[u8; \d+\] = b"(\w+)"', clean)
        if not magics:
            findings.append(Finding(
                "persistence", "no-tags", SNAPSHOT_RS, 0,
                "no MAGIC_* constants found; the snapshot analyzer has "
                "nothing to audit (extraction regression?)",
            ))
        header = fn_body(clean, "header")
        load = fn_body(clean, "load")
        writers = set()
        if header is not None:
            writers = {
                name for name, _ in magics
                if re.search(r"\b" + name + r"\b", header)
            }
        if not writers:
            findings.append(Finding(
                "persistence", "no-encoder", SNAPSHOT_RS, 0,
                "no snapshot magic is referenced by fn header: nothing "
                "can be written",
            ))
        if load is None:
            findings.append(Finding(
                "persistence", "no-decoder", SNAPSHOT_RS, 0,
                "fn load not found; cannot audit snapshot magics",
            ))
        else:
            for name, literal in magics:
                if not re.search(r"\b" + name + r"\b", load):
                    findings.append(Finding(
                        "persistence", "no-decoder", SNAPSHOT_RS, 0,
                        f"snapshot magic {name} (b\"{literal}\") is not "
                        f"accepted by fn load: "
                        + ("files written with it are unreadable"
                           if name in writers
                           else "dead legacy constant"),
                    ))
            if not re.search(r"(?i)(bad|invalid|unknown)[^;]{0,40}magic", load):
                findings.append(Finding(
                    "persistence", "no-refusal", SNAPSHOT_RS, 0,
                    "fn load has no unknown-magic refusal path: a "
                    "foreign or torn header must error, not parse",
                ))
        for name, literal in magics:
            if literal not in tests and not re.search(r"\b" + name + r"\b", tests):
                findings.append(Finding(
                    "persistence", "untested-format", SNAPSHOT_RS, 0,
                    f"snapshot magic {name} (b\"{literal}\") is "
                    f"referenced by no test: the header bytes are "
                    f"unpinned",
                ))

    return findings
