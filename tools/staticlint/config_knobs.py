"""Config-knob drift: serve.json <-> ServeConfig <-> CLI flags <->
the README configuration table.

A serving knob exists in four places: the exemplar config
(``configs/serve.json``), the parser (``ServeConfig::from_json`` in
``rust/src/config.rs``), the struct fields themselves, and — for the
operationally interesting subset — a ``serve`` CLI override flag in
``rust/src/main.rs`` plus a row in the README's Configuration table.
Knobs are named by dotted path (``sketch.bits``, ``store.shards``).

FLAG_MAP / CONFIG_ONLY below are the audited registry of which knobs
carry CLI flags; a knob in neither set fails the gate, which is the
point — adding a knob forces a deliberate decision (flag + README row
+ exemplar entry) instead of a silent half-wiring.
"""

import json
import re

from . import Finding, fn_body, strip_comments, struct_body

SERVE_JSON = "configs/serve.json"
CONFIG_RS = "rust/src/config.rs"
MAIN_RS = "rust/src/main.rs"
README = "README.md"

# knob -> serve CLI flag (without the leading --).
FLAG_MAP = {
    "addr": "addr",
    "artifacts_dir": "artifacts",
    "engine": "engine",
    "dim": "dim",
    "num_hashes": "num-hashes",
    "seed": "seed",
    "sketch.scheme": "scheme",
    "sketch.bits": "bits",
    "store.shards": "shards",
    "store.persist_dir": "persist",
    "server.max_connections": "max-conns",
}

# Knobs deliberately reachable only through a config file: batching and
# banding geometry are artifact-coupled, the obs plane is a tuning
# surface — none are one-off overrides an operator flips per run.
CONFIG_ONLY = {
    "batch.max_batch",
    "batch.max_delay_us",
    "batch.policy",
    "index.bands",
    "index.rows_per_band",
    "obs.trace_ring",
    "obs.slow_threshold_us",
    "obs.pinned",
}

# serve-command flags that are not knob overrides.
NON_KNOB_FLAGS = {"config"}


def serve_json_knobs(tree, findings):
    text = tree.get(SERVE_JSON)
    if text is None:
        return None
    try:
        data = json.loads(text)
    except ValueError as e:
        findings.append(Finding(
            "config", "bad-exemplar", SERVE_JSON, 0,
            f"configs/serve.json is not valid JSON: {e}",
        ))
        return None
    knobs = set()
    for key, value in data.items():
        if key.startswith("_doc"):
            continue
        if isinstance(value, dict):
            for sub in value:
                if not sub.startswith("_doc"):
                    knobs.add(f"{key}.{sub}")
        else:
            knobs.add(key)
    return knobs


def from_json_knobs(tree, findings):
    text = tree.get(CONFIG_RS)
    if text is None:
        return None
    clean = strip_comments(text)
    body = fn_body(clean, "from_json")
    if body is None:
        findings.append(Finding(
            "config", "registry-shape", CONFIG_RS, 0,
            "ServeConfig::from_json not found",
        ))
        return None
    matches = re.findall(
        r"let Some\((\w+)\)\s*=\s*(\w+)\.get_opt\(\"(\w+)\"\)", body
    )
    receivers = {recv for _, recv, _ in matches}
    # The root receiver is the fn's Json parameter.
    root_m = re.search(r"fn from_json\((\w+)\s*:", clean)
    root = root_m.group(1) if root_m else "j"
    section_of = {}
    knobs = set()
    for var, recv, key in matches:
        if recv == root and var in receivers:
            section_of[var] = key  # a nested section binding
    for var, recv, key in matches:
        if recv == root:
            if var not in section_of:
                knobs.add(key)
        elif recv in section_of:
            knobs.add(f"{section_of[recv]}.{key}")
        else:
            findings.append(Finding(
                "config", "registry-shape", CONFIG_RS, 0,
                f"from_json reads '{key}' through unknown receiver "
                f"'{recv}' — analyzer cannot attribute it to a section",
            ))
    return knobs


def struct_knobs(tree, findings):
    text = tree.get(CONFIG_RS)
    if text is None:
        return None
    clean = strip_comments(text)
    structs = {}
    for name in re.findall(r"pub struct (\w+)", clean):
        body = struct_body(clean, name)
        if body is not None:
            structs[name] = re.findall(r"pub (\w+)\s*:\s*([\w:<>]+)", body)
    serve = structs.get("ServeConfig")
    if serve is None:
        findings.append(Finding(
            "config", "registry-shape", CONFIG_RS, 0,
            "struct ServeConfig not found",
        ))
        return None
    knobs = set()
    for field, ty in serve:
        if ty in structs:
            for sub, _ in structs[ty]:
                knobs.add(f"{field}.{sub}")
        else:
            knobs.add(field)
    return knobs


def serve_flags(tree, findings):
    text = tree.get(MAIN_RS)
    if text is None:
        return None
    body = fn_body(strip_comments(text), "cmd_serve")
    if body is None:
        findings.append(Finding(
            "config", "registry-shape", MAIN_RS, 0,
            "fn cmd_serve not found",
        ))
        return None
    flags = set(re.findall(r'args\s*\.\s*get\w*(?:::<[\w:<> ]+>)?\(\s*"([\w-]+)"', body))
    return flags - NON_KNOB_FLAGS


def readme_rows(tree):
    """{knob: flag-or-None} from the README Configuration table."""
    text = tree.get(README)
    if text is None:
        return None
    m = re.search(r"^## Configuration$(.*?)(?=^## |\Z)", text, re.M | re.S)
    if m is None:
        return None
    rows = {}
    for line in m.group(1).splitlines():
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) < 2 or not cells[0].startswith("`"):
            continue
        knob = cells[0].strip("`")
        flag_m = re.match(r"`--([\w-]+)`", cells[1])
        rows[knob] = flag_m.group(1) if flag_m else None
    return rows


def analyze(tree):
    findings = []

    exemplar = serve_json_knobs(tree, findings)
    parsed = from_json_knobs(tree, findings)
    fields = struct_knobs(tree, findings)
    flags = serve_flags(tree, findings)
    table = readme_rows(tree)
    registry = set(FLAG_MAP) | CONFIG_ONLY

    if parsed is not None and fields is not None:
        for k in sorted(fields - parsed):
            findings.append(Finding(
                "config", "knob-drift", CONFIG_RS, 0,
                f"ServeConfig field '{k}' is never read by from_json: "
                f"config files cannot set it",
            ))
        for k in sorted(parsed - fields):
            findings.append(Finding(
                "config", "knob-drift", CONFIG_RS, 0,
                f"from_json reads '{k}' but ServeConfig has no such "
                f"field",
            ))

    knobs = parsed if parsed is not None else fields
    if knobs is None:
        return findings

    if exemplar is not None:
        for k in sorted(knobs - exemplar):
            findings.append(Finding(
                "config", "knob-drift", SERVE_JSON, 0,
                f"knob '{k}' is missing from the exemplar "
                f"configs/serve.json",
            ))
        for k in sorted(exemplar - knobs):
            findings.append(Finding(
                "config", "knob-drift", SERVE_JSON, 0,
                f"configs/serve.json sets '{k}' which no ServeConfig "
                f"parser reads (typo or removed knob)",
            ))

    for k in sorted(knobs - registry):
        findings.append(Finding(
            "config", "unclassified-knob", CONFIG_RS, 0,
            f"knob '{k}' is in neither FLAG_MAP nor CONFIG_ONLY — "
            f"decide its CLI/README story and extend "
            f"tools/staticlint/config_knobs.py",
        ))
    for k in sorted(registry - knobs):
        findings.append(Finding(
            "config", "unclassified-knob", CONFIG_RS, 0,
            f"analyzer registry lists knob '{k}' that ServeConfig no "
            f"longer has — prune tools/staticlint/config_knobs.py",
        ))

    if flags is not None:
        want_flags = {FLAG_MAP[k] for k in knobs & set(FLAG_MAP)}
        for k in sorted(knobs & set(FLAG_MAP)):
            if FLAG_MAP[k] not in flags:
                findings.append(Finding(
                    "config", "flag-drift", MAIN_RS, 0,
                    f"knob '{k}' should have serve flag "
                    f"'--{FLAG_MAP[k]}' but cmd_serve does not read it",
                ))
        for f in sorted(flags - want_flags):
            findings.append(Finding(
                "config", "flag-drift", MAIN_RS, 0,
                f"cmd_serve reads flag '--{f}' that maps to no knob in "
                f"FLAG_MAP",
            ))
        # Every knob flag must be advertised in the usage text.
        main_text = tree.get(MAIN_RS, "")
        for f in sorted(want_flags & flags):
            if f"--{f}" not in main_text.replace(f'"{f}"', ""):
                findings.append(Finding(
                    "config", "flag-drift", MAIN_RS, 0,
                    f"serve flag '--{f}' is not mentioned in the usage "
                    f"text",
                ))

    if table is None:
        findings.append(Finding(
            "config", "doc-gap", README, 0,
            "README has no '## Configuration' table",
        ))
    else:
        for k in sorted(knobs - set(table)):
            findings.append(Finding(
                "config", "doc-gap", README, 0,
                f"knob '{k}' has no row in the README Configuration "
                f"table",
            ))
        for k in sorted(set(table) - knobs):
            findings.append(Finding(
                "config", "doc-gap", README, 0,
                f"README Configuration table documents unknown knob "
                f"'{k}'",
            ))
        for k in sorted(knobs & set(table)):
            want = FLAG_MAP.get(k)
            got = table[k]
            if want != got:
                findings.append(Finding(
                    "config", "doc-gap", README, 0,
                    f"README row for '{k}' lists flag "
                    f"{'`--' + got + '`' if got else 'none'} but the "
                    f"registry says "
                    f"{'`--' + want + '`' if want else 'config-only'}",
                ))

    return findings
