"""Lock-discipline analyzer: acquisition sites, nesting graph,
cycles, double-acquisition, and guards held across I/O.

Extraction is lexical but precise for this codebase's idiom: a *guard
acquisition* is ``.lock()`` / ``.read()`` / ``.write()`` with **empty
parens** — ``io::Read::read`` and ``io::Write::write`` always take a
buffer argument, so the empty-paren form is exactly the
``Mutex``/``RwLock`` surface.  Sites are grouped into *lock classes*
(CLASS_RULES below); a guard bound with ``let`` is live to the end of
its enclosing block, a temporary guard to the end of its statement.

Three properties are enforced over the class graph:

* no lock-order cycles (class A held while taking B, elsewhere B held
  while taking A);
* no same-class nesting (double-acquisition: self-deadlock for a
  Mutex, writer-starvation deadlock bait for an RwLock);
* no I/O (fsync, WAL append, snapshot write, socket writes) under a
  guard — except sites listed in ``allowlist.json`` with an audit
  reason.  The WAL append-under-persist-lock family is the known
  deliberate case: the store's memory/log coherence contract (rollback
  on append failure) requires the ordering, and
  ``rust/tests/lock_discipline.rs`` pins that it is safe under
  contention, not just tolerated.

Calls that *transitively* acquire locks are not name-resolved (too
many false positives); instead IMPLIED_ACQUISITIONS curates the one
cross-module pattern that matters: ``self.index.*`` calls inside
``store/mod.rs`` take shard locks, giving the persist -> shard nesting
edge.  Extend that table when adding a new cross-module lock path.
"""

import re

from . import Finding, line_of, strip_comments

LOCK_RE = re.compile(r"([\w\.\[\]]*)\.(?:lock|read|write)\(\)")

# (path suffix, receiver regex or None (any), class name).  First match
# wins; files with no rule fall back to a per-receiver class so new
# locks are still tracked without editing this table.
CLASS_RULES = [
    ("rust/src/store/mod.rs", None, "store.persist"),
    ("rust/src/store/sharded.rs", None, "store.shard"),
    ("rust/src/obs/mod.rs", re.compile(r"pinned"), "obs.pinned"),
    ("rust/src/obs/mod.rs", None, "obs.ring"),
    ("rust/src/server/mod.rs", re.compile(r"rx"), "server.connrx"),
]

# (path suffix, pattern, class acquired transitively).
IMPLIED_ACQUISITIONS = [
    ("rust/src/store/mod.rs", re.compile(r"self\.index\.\w+\("), "store.shard"),
]

# I/O reachable while a guard is live.  Patterns are call-shaped so
# identifiers alone (e.g. a field named `flush`) cannot match.
IO_PATTERNS = [
    (re.compile(r"\bwal_append\("), "WAL append"),
    (re.compile(r"\.wal\.append\("), "WAL append"),
    (re.compile(r"\.wal\.reset\("), "WAL truncate"),
    (re.compile(r"\.wal\.sync\("), "WAL fsync"),
    (re.compile(r"Snapshot::write"), "snapshot write"),
    (re.compile(r"\bsync_all\("), "fsync"),
    (re.compile(r"\bsync_data\("), "fsync"),
    (re.compile(r"\.write_all\("), "stream write"),
    (re.compile(r"\.flush\("), "stream flush"),
    (re.compile(r"\bTcpStream\b"), "socket"),
]


def lock_class(path, receiver):
    for suffix, recv_re, cls in CLASS_RULES:
        if path.endswith(suffix) and (recv_re is None or recv_re.search(receiver)):
            return cls
    return f"{path}:{receiver or '<chain>'}"


def fn_spans(text):
    """[(name, body_start, body_end)] for every fn with a body."""
    spans = []
    for m in re.finditer(r"\bfn\s+(\w+)", text):
        open_idx = text.find("{", m.end())
        if open_idx < 0:
            continue
        semi = text.find(";", m.end())
        if 0 <= semi < open_idx:
            continue  # bodyless trait/extern declaration
        depth = 0
        end = None
        for i in range(open_idx, len(text)):
            c = text[i]
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        if end is not None:
            spans.append((m.group(1), open_idx, end))
    return spans


def enclosing_fn(spans, offset):
    best = None
    for name, s, e in spans:
        if s <= offset < e and (best is None or s > best[1]):
            best = (name, s, e)
    return best


def block_end_from(text, offset):
    """Offset just past the ``}`` closing the innermost block
    containing ``offset``; end of text if unbalanced."""
    depth = 0
    for i in range(offset, len(text)):
        c = text[i]
        if c == "{":
            depth += 1
        elif c == "}":
            if depth == 0:
                return i
            depth -= 1
    return len(text)


def is_let_bound(text, offset):
    """True when the statement containing ``offset`` binds with let."""
    start = max(
        text.rfind(";", 0, offset),
        text.rfind("{", 0, offset),
        text.rfind("}", 0, offset),
    )
    return re.search(r"\blet\b", text[start + 1 : offset]) is not None


class Site:
    def __init__(self, path, offset, line, receiver, cls, fn, end):
        self.path = path
        self.offset = offset
        self.line = line
        self.receiver = receiver
        self.cls = cls
        self.fn = fn
        self.end = end  # guard live until this offset


def extract_sites(path, text):
    """Guard acquisition sites with live intervals, test code excluded."""
    clean = strip_comments(text)
    cut = clean.find("#[cfg(test)]")
    if cut >= 0:
        clean = clean[:cut]
    spans = fn_spans(clean)
    sites = []
    for m in LOCK_RE.finditer(clean):
        fn = enclosing_fn(spans, m.start())
        if is_let_bound(clean, m.start()):
            end = block_end_from(clean, m.end())
        else:
            semi = clean.find(";", m.end())
            end = semi if semi >= 0 else block_end_from(clean, m.end())
        sites.append(Site(
            path, m.start(), line_of(clean, m.start()), m.group(1),
            lock_class(path, m.group(1)), fn[0] if fn else "<top>", end,
        ))
    return clean, sites


def analyze(tree):
    findings = []
    edges = {}  # (outer class, inner class) -> example Finding location

    for path in sorted(tree):
        if not (path.startswith("rust/src/") and path.endswith(".rs")):
            continue
        clean, sites = extract_sites(path, tree[path])
        for g in sites:
            # Direct nested acquisitions while g is live.
            inner = [
                s for s in sites
                if g.offset < s.offset < g.end and s.fn == g.fn
            ]
            held = clean[g.offset : g.end]
            # Curated transitive acquisitions.
            implied = [
                (m.start() + g.offset, cls)
                for suffix, pat, cls in IMPLIED_ACQUISITIONS
                if path.endswith(suffix)
                for m in pat.finditer(held)
            ]
            for s in inner:
                if s.cls == g.cls:
                    findings.append(Finding(
                        "locks", "double-acquire", path, s.line,
                        f"lock class '{g.cls}' acquired again while a "
                        f"guard from line {g.line} is still live",
                        function=g.fn,
                    ))
                else:
                    edges.setdefault((g.cls, s.cls), (path, s.line, g.fn))
            for off, cls in implied:
                if cls == g.cls:
                    findings.append(Finding(
                        "locks", "double-acquire", path, line_of(clean, off),
                        f"lock class '{g.cls}' transitively re-acquired "
                        f"while a guard from line {g.line} is still live",
                        function=g.fn,
                    ))
                else:
                    edges.setdefault((g.cls, cls), (path, line_of(clean, off), g.fn))
            # I/O while the guard is live.
            labels = sorted({
                label for pat, label in IO_PATTERNS if pat.search(held)
            })
            if labels:
                findings.append(Finding(
                    "locks", "io-under-lock", path, g.line,
                    f"guard of lock class '{g.cls}' held across I/O: "
                    + ", ".join(labels),
                    function=g.fn,
                ))

    # Lock-order cycles over the class graph.
    adj = {}
    for (a, b), _ in edges.items():
        adj.setdefault(a, set()).add(b)
    state = {}  # 0 visiting, 1 done
    reported = set()

    def dfs(node, stack):
        state[node] = 0
        for nxt in sorted(adj.get(node, ())):
            if state.get(nxt) == 0:
                cycle = tuple(stack[stack.index(nxt):] + [nxt])
                if frozenset(cycle) not in reported:
                    reported.add(frozenset(cycle))
                    path, line, fn = edges[(node, nxt)]
                    findings.append(Finding(
                        "locks", "lock-cycle", path, line,
                        "lock-order cycle: " + " -> ".join(cycle),
                        function=fn,
                    ))
            elif nxt not in state:
                dfs(nxt, stack + [nxt])
        state[node] = 1

    for node in sorted(adj):
        if node not in state:
            dfs(node, [node])

    return findings
