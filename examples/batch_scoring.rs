//! Offline batch similarity scoring through the **XLA estimator
//! artifact** — the bulk analytics use-case (e.g. computing an n×n
//! similarity matrix for clustering, Li et al. 2011's large-scale
//! learning kernels).
//!
//! Sketches a corpus with the sparse AOT artifact, then scores all
//! pairs blockwise through `estimate_n64_m64_k256` (also AOT), and
//! validates the result against exact Jaccard and against the b-bit
//! compressed path.  Self-skips to the Rust path without artifacts.
//!
//! Run: `make artifacts && cargo run --release --example batch_scoring`

use cminhash::data::zipf_corpus;
use cminhash::runtime::{HostTensor, XlaEngine};
use cminhash::sketch::{BBitSketch, CMinHasher, Sketcher};
use std::path::Path;
use std::time::Instant;

fn main() -> cminhash::Result<()> {
    let (d, k, n) = (4096usize, 256usize, 64usize);
    let corpus = zipf_corpus("scoring", n, d as u32, 60, 150, 1.1, 13);
    let hasher = CMinHasher::new(d, k, 42);

    // Sketch everything (Rust hot path).
    let t = Instant::now();
    let sketches: Vec<Vec<u32>> = corpus
        .rows()
        .iter()
        .map(|r| hasher.sketch_sparse(r.indices()))
        .collect();
    println!(
        "sketched {n} docs in {:.2}ms",
        t.elapsed().as_secs_f64() * 1e3
    );

    // Exact ground truth for validation.
    let rows = corpus.rows();

    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let engine = XlaEngine::load(artifacts)?;
        // Pack both sketch banks as (64, 256) i32 and score on the AOT
        // pairwise-estimator graph.
        let flat: Vec<i32> = sketches
            .iter()
            .flat_map(|s| s.iter().map(|&v| v as i32))
            .collect();
        let t = Instant::now();
        let out = engine.execute(
            "estimate_n64_m64_k256",
            &[HostTensor::I32(flat.clone()), HostTensor::I32(flat)],
        )?;
        let dt = t.elapsed();
        let jhat = out[0].as_f32()?;
        println!(
            "scored {}x{} pairs on the XLA estimator artifact in {:.2}ms \
             ({:.0} pairs/ms)",
            n,
            n,
            dt.as_secs_f64() * 1e3,
            (n * n) as f64 / (dt.as_secs_f64() * 1e3)
        );
        // Validate: diagonal exactly 1, off-diagonal tracks exact J.
        let mut mae = 0.0;
        let mut pairs = 0usize;
        for i in 0..n {
            assert!((jhat[i * n + i] - 1.0).abs() < 1e-6, "diagonal must be 1");
            for j in (i + 1)..n {
                mae += (f64::from(jhat[i * n + j]) - rows[i].jaccard(&rows[j])).abs();
                pairs += 1;
            }
        }
        mae /= pairs as f64;
        println!("XLA-scored MAE vs exact Jaccard: {mae:.4} (K={k})");
        assert!(mae < 0.05, "MAE too high: {mae}");
    } else {
        println!("(artifacts missing; skipping the XLA estimator path)");
    }

    // b-bit compressed path: 32x/8x smaller sketches, corrected estimate.
    for b in [1u8, 4] {
        let compressed: Vec<BBitSketch> = sketches
            .iter()
            .map(|s| BBitSketch::compress(s, b))
            .collect();
        let t = Instant::now();
        let mut mae = 0.0;
        let mut pairs = 0usize;
        for i in 0..n {
            for j in (i + 1)..n {
                mae += (compressed[i].estimate(&compressed[j])
                    - rows[i].jaccard(&rows[j]))
                .abs();
                pairs += 1;
            }
        }
        mae /= pairs as f64;
        println!(
            "b={b}-bit path: {} B/sketch ({}x smaller), all-pairs MAE {mae:.4}, \
             {:.2}ms",
            compressed[0].size_bytes(),
            4 * k / compressed[0].size_bytes(),
            t.elapsed().as_secs_f64() * 1e3
        );
        assert!(mae < 0.12, "b-bit MAE too high: {mae}");
    }

    println!("batch_scoring OK");
    Ok(())
}
