//! Quickstart: sketch two documents, estimate their Jaccard similarity,
//! and compare against the exact value and the paper's variance theory.
//!
//! Run: `cargo run --release --example quickstart`

use cminhash::sketch::{estimate, CMinHasher, Sketcher, SparseVec};
use cminhash::theory::{var_minhash, var_sigma_pi};

fn main() -> cminhash::Result<()> {
    // Two sparse binary vectors in a D = 4096 space (e.g. bag-of-words).
    let d = 4096u32;
    let doc_a = SparseVec::new(d, (0..300).map(|i| i * 10).collect())?;
    let doc_b = SparseVec::new(d, (0..300).map(|i| i * 10 + (i % 5 == 0) as u32).collect())?;

    let exact = doc_a.jaccard(&doc_b);
    println!("exact Jaccard:      {exact:.4}");

    // C-MinHash-(σ, π): TWO permutations total, any K.
    for k in [64usize, 256, 1024] {
        let hasher = CMinHasher::new(d as usize, k, /*seed=*/ 42);
        let ha = hasher.sketch_sparse(doc_a.indices());
        let hb = hasher.sketch_sparse(doc_b.indices());
        let j_hat = estimate(&ha, &hb);

        // The paper's theory: Var[Ĵ_{σ,π}] < Var[Ĵ_MH] = J(1−J)/K,
        // uniformly (Theorem 3.4).
        let (a, f) = doc_a.overlap(&doc_b);
        let v_c = var_sigma_pi(d as usize, f, a, k);
        let v_mh = var_minhash(exact, k);
        println!(
            "K={k:<5} Ĵ={j_hat:.4}  |Ĵ−J|={:.4}   sd_C={:.4} < sd_MH={:.4}  (ratio {:.3}x)",
            (j_hat - exact).abs(),
            v_c.sqrt(),
            v_mh.sqrt(),
            v_mh / v_c,
        );
        assert!(v_c < v_mh, "Theorem 3.4");
    }

    println!(
        "\nMemory: C-MinHash stores 2 permutations (σ, π) = {} bytes at D={d};",
        2 * 4 * d
    );
    println!(
        "classical MinHash at K=1024 would store {} bytes of permutations.",
        1024usize * 4 * d as usize
    );
    Ok(())
}
