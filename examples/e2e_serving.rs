//! End-to-end serving driver — the full three-layer stack on a real
//! workload.
//!
//! Spins up the coordinator with the **XLA engine** (AOT Pallas/JAX
//! artifacts via PJRT; falls back to the pure-Rust engine with a warning
//! if `artifacts/` is missing), serves a Poisson trace of sketch +
//! near-neighbor-query requests over real TCP, and reports throughput,
//! latency percentiles, batching efficiency, and estimation accuracy
//! against exact Jaccard.  Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::data::{zipf_corpus, Workload, WorkloadSpec};
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::estimate;
use std::path::Path;
use std::time::Instant;

fn main() -> cminhash::Result<()> {
    let (dim, k) = (4096usize, 256usize);
    let artifacts = Path::new("artifacts");
    let engine = if artifacts.join("manifest.json").exists() {
        EngineKind::Xla
    } else {
        eprintln!("WARNING: artifacts/ missing, using the pure-Rust engine");
        EngineKind::Rust
    };
    let cfg = ServeConfig {
        engine,
        artifacts_dir: artifacts.to_path_buf(),
        dim,
        num_hashes: k,
        seed: 42,
        batch: BatchConfig {
            max_batch: 64,
            max_delay_us: 2_000,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 32,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    println!("== e2e serving driver (engine={engine:?}, D={dim}, K={k}) ==");
    let svc = Coordinator::start(cfg)?;
    let server = Server::spawn(svc.clone(), "127.0.0.1:0")?;
    let addr = server.addr().to_string();
    println!("server on {addr}");

    // Workload: a zipf "documents" corpus, 80% sketch-and-insert / 20%
    // similarity queries, Poisson arrivals.
    let corpus = zipf_corpus("e2e", 512, dim as u32, 40, 120, 1.1, 7);
    let trace = Workload::generate(
        &corpus,
        WorkloadSpec {
            n_requests: 1500,
            rate_per_sec: 100_000.0, // effectively closed-loop
            query_fraction: 0.2,
            seed: 3,
        },
    );

    // Drive with 8 closed-loop connections partitioned over the trace.
    let conns = 8usize;
    let items = trace.items().to_vec();
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..conns {
        let addr = addr.clone();
        let my_items: Vec<_> = items
            .iter()
            .enumerate()
            .filter(|(i, _)| i % conns == c)
            .map(|(_, it)| it.clone())
            .collect();
        joins.push(std::thread::spawn(move || -> cminhash::Result<Vec<f64>> {
            let mut client = BlockingClient::connect(&addr)?;
            let mut lats = Vec::with_capacity(my_items.len());
            for item in my_items {
                let t = Instant::now();
                if item.is_query {
                    let _ = client.query(item.vec.dim(), item.vec.indices().to_vec(), 5)?;
                } else {
                    let _ = client.insert(item.vec.dim(), item.vec.indices().to_vec())?;
                }
                lats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            Ok(lats)
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for j in joins {
        lats.extend(j.join().expect("client thread")?);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|x, y| x.total_cmp(y));
    let q = |p: f64| lats[((lats.len() as f64 * p) as usize).min(lats.len() - 1)];
    println!(
        "\n{} requests in {wall:.2}s  ->  {:.0} req/s",
        lats.len(),
        lats.len() as f64 / wall
    );
    println!(
        "latency ms: p50={:.2}  p90={:.2}  p99={:.2}  max={:.2}",
        q(0.5),
        q(0.9),
        q(0.99),
        lats[lats.len() - 1]
    );

    let (snap, store) = svc.stats();
    println!(
        "batches={}  mean fill={:.1}/{}  pad rows={}  stored sketches={} across {} shards",
        snap.batches,
        snap.mean_batch_fill,
        64,
        snap.pad_rows,
        store.stored,
        store.shards.len()
    );
    println!(
        "batch exec latency: mean={:.2}ms p99<={:.2}ms",
        snap.batch_latency.mean_us as f64 / 1e3,
        snap.batch_latency.p99_us as f64 / 1e3
    );

    // Accuracy check through the served sketches: estimate J for 200
    // random pairs via one connection and compare with exact values.
    // The 200 probe sketches travel as two `sketch_batch` round-trips
    // instead of 200 per-item calls — the batch wire path end to end.
    let mut client = BlockingClient::connect(&addr)?;
    let rows = corpus.rows();
    let probes: Vec<&cminhash::sketch::SparseVec> =
        (0..200).map(|i| &rows[i % rows.len()]).collect();
    let t_batch = Instant::now();
    let mut sketches = Vec::with_capacity(probes.len());
    for chunk in probes.chunks(100) {
        let batch: Vec<Vec<u32>> = chunk.iter().map(|v| v.indices().to_vec()).collect();
        sketches.extend(client.sketch_batch(dim as u32, batch)?);
    }
    println!(
        "\nsketched {} probes over {} batched round-trips in {:.1}ms",
        probes.len(),
        probes.len() / 100,
        t_batch.elapsed().as_secs_f64() * 1e3
    );
    let mut err_sum = 0.0f64;
    let mut n_pairs = 0usize;
    for i in (0..200).step_by(2) {
        let (a, b) = (probes[i], probes[i + 1]);
        let j_hat = estimate(&sketches[i], &sketches[i + 1]);
        err_sum += (j_hat - a.jaccard(b)).abs();
        n_pairs += 1;
    }
    let mae = err_sum / n_pairs as f64;
    println!("\nserved-sketch MAE over {n_pairs} pairs: {mae:.4} (K={k})");
    // Loose sanity bound: sd ~ sqrt(J(1-J)/K) ~ 0.03 at J~0.2.
    assert!(mae < 0.06, "MAE unexpectedly high: {mae}");
    println!("e2e OK");
    Ok(())
}
