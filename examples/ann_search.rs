//! Approximate near-neighbor search — the application the paper's intro
//! motivates (K often ≫ 1024 there, which is exactly where storing two
//! permutations instead of K matters).
//!
//! Builds an LSH banding index over C-MinHash sketches of a
//! near-duplicate corpus, queries every document, and reports
//! recall/precision against exact Jaccard ground truth, plus the
//! S-curve the band configuration implies.
//!
//! Run: `cargo run --release --example ann_search`

use cminhash::data::near_duplicate_corpus;
use cminhash::index::{BandingIndex, IndexConfig};
use cminhash::sketch::{CMinHasher, Sketcher};
use std::time::Instant;

fn main() -> cminhash::Result<()> {
    let (dim, k) = (65_536u32, 512usize);
    let families = 200usize;
    let copies = 5usize;
    let corpus = near_duplicate_corpus(families, copies, dim, 400, 30, 11);
    println!(
        "corpus: {} docs ({} families x {} near-duplicates), D={dim}",
        corpus.len(),
        families,
        copies
    );

    let hasher = CMinHasher::new(dim as usize, k, 99);
    let cfg = IndexConfig {
        bands: 64,
        rows_per_band: 8,
    };
    println!(
        "index: {} bands x {} rows, S-curve threshold ≈ {:.2}",
        cfg.bands,
        cfg.rows_per_band,
        cfg.threshold()
    );
    for j in [0.2, 0.4, 0.6, 0.8, 0.95] {
        println!("  P(candidate | J={j:.2}) = {:.4}", cfg.candidate_probability(j));
    }

    // Sketch + index.
    let t = Instant::now();
    let sketches: Vec<Vec<u32>> = corpus
        .rows()
        .iter()
        .map(|r| hasher.sketch_sparse(r.indices()))
        .collect();
    let sketch_dt = t.elapsed();
    let mut index = BandingIndex::new(k, cfg)?;
    let t = Instant::now();
    for (i, sk) in sketches.iter().enumerate() {
        index.insert(i as u64, sk)?;
    }
    let index_dt = t.elapsed();
    println!(
        "\nsketched {} docs in {:.1}ms ({:.0}/s), indexed in {:.1}ms",
        corpus.len(),
        sketch_dt.as_secs_f64() * 1e3,
        corpus.len() as f64 / sketch_dt.as_secs_f64(),
        index_dt.as_secs_f64() * 1e3
    );

    // Query every doc for neighbors above J >= 0.5; ground truth is its
    // family (mutation keeps within-family J ~ 0.85).
    let threshold = 0.5;
    let (mut tp, mut fp, mut fn_) = (0usize, 0usize, 0usize);
    let t = Instant::now();
    for (i, sk) in sketches.iter().enumerate() {
        let hits = index.query_above(sk, threshold);
        let fam = i / copies;
        let truth: Vec<u64> = (fam * copies..(fam + 1) * copies)
            .filter(|&x| x != i)
            .map(|x| x as u64)
            .filter(|&x| {
                corpus.rows()[i].jaccard(&corpus.rows()[x as usize]) >= threshold
            })
            .collect();
        let found: Vec<u64> = hits.iter().map(|h| h.id).filter(|&id| id != i as u64).collect();
        for t in &truth {
            if found.contains(t) {
                tp += 1;
            } else {
                fn_ += 1;
            }
        }
        for f in &found {
            let exact = corpus.rows()[i].jaccard(&corpus.rows()[*f as usize]);
            if exact < threshold {
                fp += 1;
            }
        }
    }
    let query_dt = t.elapsed();
    let recall = tp as f64 / (tp + fn_).max(1) as f64;
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    println!(
        "\n{} queries in {:.1}ms ({:.0}/s)",
        corpus.len(),
        query_dt.as_secs_f64() * 1e3,
        corpus.len() as f64 / query_dt.as_secs_f64()
    );
    println!("near-neighbor retrieval @ J>={threshold}: recall={recall:.3} precision={precision:.3}");
    assert!(recall > 0.95, "recall too low: {recall}");
    assert!(precision > 0.8, "precision too low: {precision}");

    println!(
        "\npermutation memory: C-MinHash 2x{}B vs classical MinHash {}x{}B ({}x saving)",
        4 * dim,
        k,
        4 * dim,
        k / 2
    );
    println!("ann_search OK");
    Ok(())
}
