//! Offline near-duplicate detection pipeline — MinHash's original
//! application (Broder 1997, web-page dedup), run with C-MinHash.
//!
//! Generates a text-like corpus with planted duplicate pairs, sketches
//! every document, finds candidate pairs via banding, verifies
//! candidates by sketch estimate, and reports precision/recall against
//! exact Jaccard plus the ablation: the same pipeline with
//! C-MinHash-(0, π) and classical MinHash.
//!
//! Run: `cargo run --release --example dedup_pipeline`

use cminhash::data::zipf_corpus;
use cminhash::index::{BandingIndex, IndexConfig};
use cminhash::sketch::{
    estimate, CMinHasher, ClassicMinHasher, Sketcher, SparseVec, ZeroPiHasher,
};
use cminhash::util::rng::Rng;
use std::collections::HashSet;
use std::time::Instant;

/// Plant near-duplicates: every 10th document is a lightly mutated copy
/// of its predecessor.
fn plant_duplicates(rows: &mut Vec<SparseVec>, dim: u32, seed: u64) -> HashSet<(usize, usize)> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut truth = HashSet::new();
    for i in (10..rows.len()).step_by(10) {
        let mut idx = rows[i - 1].indices().to_vec();
        // mutate ~5% of tokens
        let muts = (idx.len() / 20).max(1);
        for _ in 0..muts {
            let p = rng.range_usize(0, idx.len());
            idx[p] = rng.range_u32(0, dim);
        }
        rows[i] = SparseVec::new(dim, idx).unwrap();
        truth.insert((i - 1, i));
    }
    truth
}

fn run_pipeline(
    name: &str,
    sketcher: &dyn Sketcher,
    rows: &[SparseVec],
    threshold: f64,
    truth: &HashSet<(usize, usize)>,
) {
    let t = Instant::now();
    let sketches: Vec<Vec<u32>> = rows
        .iter()
        .map(|r| sketcher.sketch_sparse(r.indices()))
        .collect();
    let sketch_dt = t.elapsed();

    let k = sketcher.num_hashes();
    let cfg = IndexConfig {
        bands: 32,
        rows_per_band: k / 32,
    };
    let mut index = BandingIndex::new(k, cfg).unwrap();
    let mut found: HashSet<(usize, usize)> = HashSet::new();
    let t = Instant::now();
    for (i, sk) in sketches.iter().enumerate() {
        // candidates among already-inserted docs (streaming dedup)
        for cand in index.candidates(sk) {
            let est = estimate(sk, &sketches[cand as usize]);
            if est >= threshold {
                found.insert((cand as usize, i));
            }
        }
        index.insert(i as u64, sk).unwrap();
    }
    let pipe_dt = t.elapsed();

    // score against exact Jaccard
    let mut tp = 0usize;
    let mut fp = 0usize;
    for &(a, b) in &found {
        if rows[a].jaccard(&rows[b]) >= threshold {
            tp += 1;
        } else {
            fp += 1;
        }
    }
    let mut fn_ = 0usize;
    for &(a, b) in truth {
        if rows[a].jaccard(&rows[b]) >= threshold && !found.contains(&(a, b)) {
            fn_ += 1;
        }
    }
    let recall = tp as f64 / (tp + fn_).max(1) as f64;
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    println!(
        "{name:<22} sketch {:>7.1}ms  dedup {:>7.1}ms  pairs={:<4} precision={precision:.3} recall={recall:.3}",
        sketch_dt.as_secs_f64() * 1e3,
        pipe_dt.as_secs_f64() * 1e3,
        found.len(),
    );
}

fn main() -> cminhash::Result<()> {
    let dim = 16_384u32;
    let n_docs = 1000usize;
    let k = 256usize;
    let threshold = 0.8;

    let corpus = zipf_corpus("dedup", n_docs, dim, 80, 200, 1.05, 21);
    let mut rows = corpus.rows().to_vec();
    let truth = plant_duplicates(&mut rows, dim, 5);
    println!(
        "corpus: {n_docs} docs, D={dim}, {} planted near-duplicate pairs, K={k}, J>={threshold}",
        truth.len()
    );
    println!();

    run_pipeline(
        "cminhash-(sigma,pi)",
        &CMinHasher::new(dim as usize, k, 1),
        &rows,
        threshold,
        &truth,
    );
    run_pipeline(
        "cminhash-(0,pi)",
        &ZeroPiHasher::new(dim as usize, k, 1),
        &rows,
        threshold,
        &truth,
    );
    run_pipeline(
        "classic minhash",
        &ClassicMinHasher::new(dim as usize, k, 1),
        &rows,
        threshold,
        &truth,
    );

    println!(
        "\npermutation memory: 2x{}B (C-MinHash) vs {}x{}B (classic) — {}x less",
        4 * dim,
        k,
        4 * dim,
        k / 2
    );
    println!("dedup_pipeline OK");
    Ok(())
}
