//! The packed b-bit query-plane sweep: b × K query throughput and
//! memory per item, packed popcount scoring vs the unpacked (bits=32)
//! baseline, through the real `ShardedIndex` store layer.  Emits
//! `BENCH_bbit_query.json`, which `tools/check_bench.py` gates in
//! `make verify` / CI: packed throughput must not regress below
//! unpacked at b ≤ 8, and memory/item must shrink ≈ 32/b×.
//!
//! The corpus is families of near-duplicate sketches (like
//! `index_scale`), so band postings collide and queries do real
//! scoring work; the band shape (8 bands × 16 rows) keeps the packed
//! signature space large even at b = 1 (16-bit band signatures), so
//! the candidate sets stay comparable across widths and the sweep
//! isolates the scoring kernel.

use cminhash::bench::{black_box, Harness};
use cminhash::index::IndexConfig;
use cminhash::sketch::{
    bucket_collision_counts, collision_count, pack_row, packed_words, SUPPORTED_BITS,
};
use cminhash::store::ShardedIndex;
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use std::time::Instant;

const QUERIES: usize = 2_000;

fn corpus(n: usize, k: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::seed_from_u64(7);
    let bases: Vec<Vec<u32>> = (0..1024)
        .map(|_| (0..k).map(|_| rng.range_u32(0, 1 << 20)).collect())
        .collect();
    (0..n)
        .map(|i| {
            let mut sk = bases[i % bases.len()].clone();
            for _ in 0..rng.range_usize(1, k / 4) {
                let pos = rng.range_usize(0, k);
                sk[pos] = rng.range_u32(0, 1 << 20);
            }
            sk
        })
        .collect()
}

/// Build a single-shard index at `bits`, bulk-insert the corpus, run
/// the query sweep.  Returns (insert/s, query/s, bytes/item).
fn run(
    h: &mut Harness,
    bits: u8,
    k: usize,
    items: &[Vec<u32>],
) -> (f64, f64, usize) {
    let cfg = IndexConfig {
        bands: 8,
        rows_per_band: 16,
    };
    let idx = ShardedIndex::with_bits(k, cfg, bits, 1).unwrap();

    let t0 = Instant::now();
    for chunk in items.chunks(4096) {
        idx.insert_many(chunk).unwrap();
    }
    let insert_wall = t0.elapsed();
    h.report(
        &format!("insert {} items, K={k}, bits={bits}", items.len()),
        insert_wall,
        items.len() as u64,
    );
    assert_eq!(idx.len(), items.len());

    // sanity: a stored item probed with itself is an exact hit at
    // every width (all lanes collide → corrected Ĵ = 1)
    let self_hit = idx.query(&items[0], 1).unwrap();
    assert_eq!(self_hit[0].score, 1.0, "bits={bits}");

    // Warmup, then best-of-3 timed sweeps: the offline gate compares
    // this number against the bits=32 baseline run minutes earlier, so
    // each width reports its least-noisy pass rather than whatever one
    // scheduler hiccup produced.
    for q in 0..100 {
        idx.query(&items[q * items.len() / 100], 10).unwrap();
    }
    let mut query_wall = std::time::Duration::MAX;
    for _ in 0..3 {
        let t0 = Instant::now();
        for q in 0..QUERIES {
            let probe = &items[q * items.len() / QUERIES];
            let hits = idx.query(probe, 10).unwrap();
            assert!(!hits.is_empty());
        }
        query_wall = query_wall.min(t0.elapsed());
    }
    h.report(
        &format!("query {QUERIES} probes (best of 3), K={k}, bits={bits}"),
        query_wall,
        QUERIES as u64,
    );

    (
        items.len() as f64 / insert_wall.as_secs_f64(),
        QUERIES as f64 / query_wall.as_secs_f64(),
        idx.sketch_bytes_per_item(),
    )
}

/// Kernel-level scalar-vs-batch comparison: one synthetic posting
/// bucket scored by per-candidate [`collision_count`] calls vs one
/// [`bucket_collision_counts`] sweep over the same arena.  Returns the
/// speedup (scalar wall / batch wall, best-of-3 each); the offline
/// gate requires ≥ 1.2× at b ≤ 8, where the packed query plane lives.
fn batch_kernel_speedup(h: &mut Harness, bits: u8, k: usize, items: &[Vec<u32>]) -> f64 {
    let wpr = packed_words(k, bits);
    let n = items.len().min(4096);
    let mut arena = vec![0u64; n * wpr];
    for (i, it) in items.iter().take(n).enumerate() {
        pack_row(it, bits, &mut arena[i * wpr..(i + 1) * wpr]);
    }
    let mut q = vec![0u64; wpr];
    pack_row(&items[0], bits, &mut q);
    let slots: Vec<u64> = (0..n as u64).collect();
    const PASSES: usize = 20;

    let mut scalar_wall = std::time::Duration::MAX;
    for _ in 0..3 {
        let t0 = Instant::now();
        for _ in 0..PASSES {
            let mut acc = 0usize;
            for &slot in &slots {
                let s = slot as usize;
                acc += collision_count(&q, &arena[s * wpr..(s + 1) * wpr], k, bits);
            }
            black_box(acc);
        }
        scalar_wall = scalar_wall.min(t0.elapsed());
    }
    h.report(
        &format!("scalar bucket score {n} rows x {PASSES} (best of 3), K={k}, bits={bits}"),
        scalar_wall,
        (n * PASSES) as u64,
    );

    let mut batch_wall = std::time::Duration::MAX;
    for _ in 0..3 {
        let t0 = Instant::now();
        for _ in 0..PASSES {
            let counts = bucket_collision_counts(&q, &arena, wpr, &slots, k, bits);
            black_box(counts);
        }
        batch_wall = batch_wall.min(t0.elapsed());
    }
    h.report(
        &format!("batch bucket score {n} rows x {PASSES} (best of 3), K={k}, bits={bits}"),
        batch_wall,
        (n * PASSES) as u64,
    );

    // equivalence spot check under bench shapes (the full matrix lives
    // in the unit tests)
    let counts = bucket_collision_counts(&q, &arena, wpr, &slots, k, bits);
    for (i, &c) in counts.iter().enumerate() {
        assert_eq!(
            c,
            collision_count(&q, &arena[i * wpr..(i + 1) * wpr], k, bits),
            "kernel diverges from scalar at row {i}, K={k}, bits={bits}"
        );
    }

    scalar_wall.as_secs_f64() / batch_wall.as_secs_f64()
}

fn main() {
    let fast = std::env::var("CMINHASH_BENCH_FAST").is_ok_and(|v| v == "1");
    let n = if fast { 20_000 } else { 60_000 };
    let mut h = Harness::new("bbit_query");
    let mut results = Vec::new();

    for &k in &[128usize, 256] {
        println!("corpus: {n} sketches of K={k}");
        let items = corpus(n, k);
        let mut baseline_qps = 0.0f64;
        // widest first so bits=32 is the in-cache baseline every
        // packed width is compared against
        for &bits in SUPPORTED_BITS.iter().rev() {
            let (ins, qry, bytes) = run(&mut h, bits, k, &items);
            let speedup = batch_kernel_speedup(&mut h, bits, k, &items);
            if bits == 32 {
                baseline_qps = qry;
            }
            let vs = if baseline_qps > 0.0 {
                qry / baseline_qps
            } else {
                1.0
            };
            println!(
                "  -> bits={bits:2}: {ins:9.0} inserts/s, {qry:8.0} queries/s \
                 ({vs:.2}x vs unpacked), {bytes:4} B/item, \
                 batch kernel {speedup:.2}x vs scalar"
            );
            results.push(Json::obj(vec![
                ("bits", Json::Num(f64::from(bits))),
                ("k", Json::Num(k as f64)),
                ("insert_per_s", Json::Num(ins)),
                ("query_per_s", Json::Num(qry)),
                ("bytes_per_item", Json::Num(bytes as f64)),
                ("batch_score_speedup", Json::Num(speedup)),
            ]));
        }
    }

    let out = Json::obj(vec![
        ("bench", Json::str("bbit_query")),
        ("items", Json::Num(n as f64)),
        ("queries", Json::Num(QUERIES as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_bbit_query.json", out.to_string()).unwrap();
    println!("wrote BENCH_bbit_query.json");
    h.write_csv().unwrap();
}
