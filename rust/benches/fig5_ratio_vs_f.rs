//! Figure 5 bench: variance ratio vs f for D ∈ {500, 1000},
//! K ∈ {100..800} — regenerates the series and checks the paper's two
//! monotonicity claims (ratio grows with K and with f).

use cminhash::bench::Harness;
use cminhash::theory::variance_ratio;
use std::path::Path;

fn main() {
    let mut h = Harness::new("fig5_ratio_vs_f");
    h.bench("full fig5 sweep (2 D x 4 K x ~25 f)", || {
        let mut acc = 0.0;
        for &d in &[500usize, 1000] {
            for &k in &[100usize, 200, 400, 800] {
                if k > d {
                    continue;
                }
                let mut f = 20;
                while f <= d {
                    acc += variance_ratio(d, f, f / 2, k).unwrap_or(1.0);
                    f += d / 25;
                }
            }
        }
        acc
    });

    let out = Path::new("results");
    cminhash::figures::fig5(out).expect("fig5");
    println!("wrote results/fig5_ratio_vs_f.csv");

    for &d in &[500usize, 1000] {
        let k_max = 800.min(d - 100);
        let r_lowk = variance_ratio(d, d / 2, d / 4, 100).unwrap();
        let r_highk = variance_ratio(d, d / 2, d / 4, k_max).unwrap();
        let r_lowf = variance_ratio(d, d / 10, d / 20, k_max).unwrap();
        let r_highf = variance_ratio(d, (4 * d) / 5, (2 * d) / 5, k_max).unwrap();
        println!(
            "PAPER-CHECK fig5 D={d}: ratio(K=100)={r_lowk:.3} < ratio(K={k_max})={r_highk:.3}; \
             ratio(f=D/10)={r_lowf:.3} < ratio(f=4D/5)={r_highf:.3}"
        );
        assert!(r_highk > r_lowk, "ratio must grow with K");
        assert!(r_highf > r_lowf, "ratio must grow with f");
        assert!(r_lowk > 1.0);
    }
    h.write_csv().unwrap();
}
