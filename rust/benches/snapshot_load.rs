//! Snapshot-load bench: the serial `insert_with_id` replay loop (the
//! pre-bulk-loader recovery path) vs [`ShardedIndex::load_items`],
//! which takes each shard's write lock once and rebuilds band postings
//! shard-parallel above the fan-out threshold.  Emits
//! `BENCH_snapshot_load.json`, gated by `tools/check_bench.py` in
//! `make verify` / CI: the bulk loader must open ≥ 1.5× faster than
//! the serial replay — no measured win, no merge.
//!
//! Both paths are also pinned against each other for state identity
//! here (items, counters, fresh-id floor), mirroring the unit test in
//! `store/sharded.rs` at bench scale.

use cminhash::bench::Harness;
use cminhash::index::IndexConfig;
use cminhash::store::ShardedIndex;
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use std::time::Instant;

const SHARDS: usize = 4;
const K: usize = 64;

/// Snapshot-shaped items: id-sorted rows with near-duplicate families
/// so the rebuilt band postings carry realistic bucket fan-out.
fn snapshot_items(n: usize) -> Vec<(u64, Vec<u32>)> {
    let mut rng = Rng::seed_from_u64(11);
    let bases: Vec<Vec<u32>> = (0..512)
        .map(|_| (0..K).map(|_| rng.range_u32(0, 1 << 20)).collect())
        .collect();
    (0..n)
        .map(|i| {
            let mut sk = bases[i % bases.len()].clone();
            for _ in 0..rng.range_usize(1, K / 4) {
                let pos = rng.range_usize(0, K);
                sk[pos] = rng.range_u32(0, 1 << 20);
            }
            (i as u64, sk)
        })
        .collect()
}

fn fresh_index() -> ShardedIndex {
    let cfg = IndexConfig {
        bands: 16,
        rows_per_band: 4,
    };
    ShardedIndex::new(K, cfg, SHARDS).unwrap()
}

fn main() {
    let fast = std::env::var("CMINHASH_BENCH_FAST").is_ok_and(|v| v == "1");
    let n = if fast { 20_000 } else { 100_000 };
    let mut h = Harness::new("snapshot_load");
    println!("snapshot image: {n} items of K={K}, {SHARDS} shards");
    let items = snapshot_items(n);

    // Serial replay: one insert_with_id per row, exactly what
    // `PersistentIndex::open` did before the bulk loader existed.
    let mut serial_wall = std::time::Duration::MAX;
    for _ in 0..3 {
        let idx = fresh_index();
        let t0 = Instant::now();
        for (id, sk) in &items {
            idx.insert_with_id(*id, sk).unwrap();
        }
        serial_wall = serial_wall.min(t0.elapsed());
        assert_eq!(idx.len(), n);
    }
    h.report(
        &format!("serial insert_with_id replay, {n} items (best of 3)"),
        serial_wall,
        n as u64,
    );

    // Bulk load: shard-grouped, one lock per shard, scoped thread per
    // shard above the fan-out threshold.
    let mut bulk_wall = std::time::Duration::MAX;
    let mut bulk_state = None;
    for _ in 0..3 {
        let idx = fresh_index();
        let t0 = Instant::now();
        idx.load_items(&items).unwrap();
        bulk_wall = bulk_wall.min(t0.elapsed());
        assert_eq!(idx.len(), n);
        bulk_state = Some(idx);
    }
    h.report(
        &format!("parallel load_items, {n} items (best of 3)"),
        bulk_wall,
        n as u64,
    );

    // State identity at bench scale: same items, same counters, same
    // fresh-id floor as the serial path.
    let serial_idx = fresh_index();
    for (id, sk) in &items {
        serial_idx.insert_with_id(*id, sk).unwrap();
    }
    let bulk_idx = bulk_state.expect("three bulk passes ran");
    assert_eq!(bulk_idx.items(), serial_idx.items(), "bulk load must be identical");
    assert_eq!(bulk_idx.next_id(), serial_idx.next_id());
    assert_eq!(bulk_idx.shard_ops(), serial_idx.shard_ops());

    let serial_per_s = n as f64 / serial_wall.as_secs_f64();
    let bulk_per_s = n as f64 / bulk_wall.as_secs_f64();
    let speedup = serial_wall.as_secs_f64() / bulk_wall.as_secs_f64();
    println!(
        "  -> serial {serial_per_s:9.0} items/s, parallel {bulk_per_s:9.0} items/s \
         ({speedup:.2}x)"
    );

    let out = Json::obj(vec![
        ("bench", Json::str("snapshot_load")),
        ("items", Json::Num(n as f64)),
        ("shards", Json::Num(SHARDS as f64)),
        ("k", Json::Num(K as f64)),
        (
            "results",
            Json::Arr(vec![Json::obj(vec![
                ("serial_items_per_s", Json::Num(serial_per_s)),
                ("parallel_items_per_s", Json::Num(bulk_per_s)),
                ("speedup", Json::Num(speedup)),
            ])]),
        ),
    ]);
    std::fs::write("BENCH_snapshot_load.json", out.to_string()).unwrap();
    println!("wrote BENCH_snapshot_load.json");
    h.write_csv().unwrap();
}
