//! End-to-end serving throughput: the full coordinator + TCP + batcher
//! stack under closed-loop load, for both engines.  The L3 overhead
//! claim (coordinator ≪ hash compute) is quantified by comparing the
//! rust-engine serving throughput against the bare hasher throughput.

use cminhash::bench::Harness;
use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::{CMinHasher, Sketcher};
use cminhash::util::rng::Rng;
use std::path::Path;
use std::time::Instant;

fn drive(addr: &str, dim: u32, nnz: usize, requests: usize, conns: usize) -> (f64, f64) {
    let per_conn = requests / conns;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..conns {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || {
            let mut client = BlockingClient::connect(&addr).unwrap();
            let mut rng = Rng::seed_from_u64(c as u64);
            let mut lat = 0.0f64;
            for _ in 0..per_conn {
                let mut idx: Vec<u32> =
                    (0..nnz).map(|_| rng.range_u32(0, dim)).collect();
                idx.sort_unstable();
                idx.dedup();
                let t = Instant::now();
                let _ = client.sketch(dim, idx).unwrap();
                lat += t.elapsed().as_secs_f64();
            }
            lat / per_conn as f64
        }));
    }
    let mean_lat: f64 =
        joins.into_iter().map(|j| j.join().unwrap()).sum::<f64>() / conns as f64;
    let wall = t0.elapsed().as_secs_f64();
    ((requests as f64) / wall, mean_lat * 1e3)
}

fn run_engine(h: &mut Harness, engine: EngineKind, policy: BatchPolicy, dim: usize, k: usize) {
    let cfg = ServeConfig {
        engine,
        artifacts_dir: Path::new("artifacts").to_path_buf(),
        dim,
        num_hashes: k,
        seed: 42,
        batch: BatchConfig {
            max_batch: 64,
            max_delay_us: 1_000,
            policy,
        },
        index: IndexSettings {
            bands: 32,
            rows_per_band: 4,
        },
        store: Default::default(),
        addr: "127.0.0.1:0".into(),
    };
    let svc = match Coordinator::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            println!("(skipping {engine:?} serving bench: {e})");
            return;
        }
    };
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    // warmup
    let _ = drive(&addr, dim as u32, 64, 64, 8);
    let t0 = Instant::now();
    let (rps, lat_ms) = drive(&addr, dim as u32, 64, 1024, 8);
    h.report(
        &format!("serve {engine:?}/{policy:?} D={dim} K={k} (8 conns)"),
        t0.elapsed(),
        1024,
    );
    let (snap, _) = svc.stats();
    println!(
        "  -> {rps:.0} req/s, {lat_ms:.2} ms mean latency, mean batch fill {:.1}, \
         batch exec mean {:.2} ms",
        snap.mean_batch_fill,
        snap.batch_latency.mean_us as f64 / 1e3
    );
}

fn main() {
    let mut h = Harness::new("serving_throughput");
    let (dim, k) = (4096usize, 256usize);

    // Baseline: bare hasher throughput on one core.
    let hasher = CMinHasher::new(dim, k, 42);
    let mut rng = Rng::seed_from_u64(9);
    let idx: Vec<u32> = (0..64).map(|_| rng.range_u32(0, dim as u32)).collect();
    let bare = h.bench("bare hasher sketch D=4096 K=256", || {
        hasher.sketch_sparse(&idx)
    });
    let bare_ns = bare.mean_ns;

    // Policy ablation on the rust engine (DESIGN.md ablation item).
    run_engine(&mut h, EngineKind::Rust, BatchPolicy::Eager, dim, k);
    run_engine(&mut h, EngineKind::Rust, BatchPolicy::Deadline, dim, k);
    run_engine(&mut h, EngineKind::Xla, BatchPolicy::Eager, dim, k);

    println!(
        "PAPER-CHECK L3 overhead: bare hash = {:.1} µs/sketch; serving adds \
         protocol+batching on top (see serve lines above)",
        bare_ns / 1e3
    );
    h.write_csv().unwrap();
}
