//! End-to-end serving throughput: the full coordinator + TCP + batcher
//! stack under closed-loop load, for both engines.  The L3 overhead
//! claim (coordinator ≪ hash compute) is quantified by comparing the
//! rust-engine serving throughput against the bare hasher throughput,
//! and the batch-protocol claim (one round-trip per *batch* beats one
//! per *vector*) is measured by driving the same row budget through
//! per-item `sketch` ops vs `sketch_batch`/`insert_batch` ops and
//! recorded in `BENCH_serving_batch.json`.

use cminhash::bench::Harness;
use cminhash::config::{
    BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig, SketchSettings,
};
use cminhash::coordinator::Coordinator;
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::{pack_row, packed_words, CMinHasher, SketchScheme, Sketcher};
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn rand_rows(dim: u32, nnz: usize, n: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let mut idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, dim)).collect();
            idx.sort_unstable();
            idx.dedup();
            idx
        })
        .collect()
}

fn drive(addr: &str, dim: u32, nnz: usize, requests: usize, conns: usize) -> (f64, f64) {
    let per_conn = requests / conns;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..conns {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || {
            let mut client = BlockingClient::connect(&addr).unwrap();
            let rows = rand_rows(dim, nnz, per_conn, c as u64);
            let mut lat = 0.0f64;
            for idx in rows {
                let t = Instant::now();
                let _ = client.sketch(dim, idx).unwrap();
                lat += t.elapsed().as_secs_f64();
            }
            lat / per_conn as f64
        }));
    }
    let mean_lat: f64 =
        joins.into_iter().map(|j| j.join().unwrap()).sum::<f64>() / conns as f64;
    let wall = t0.elapsed().as_secs_f64();
    ((requests as f64) / wall, mean_lat * 1e3)
}

/// Same row budget as [`drive`], but `wire_batch` rows per request
/// line through `sketch_batch` — one round-trip, one response line,
/// one engine submission per client batch.
fn drive_batched(
    addr: &str,
    dim: u32,
    nnz: usize,
    requests: usize,
    conns: usize,
    wire_batch: usize,
) -> f64 {
    let per_conn = requests / conns;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..conns {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || {
            let mut client = BlockingClient::connect(&addr).unwrap();
            let rows = rand_rows(dim, nnz, per_conn, 1000 + c as u64);
            for chunk in rows.chunks(wire_batch) {
                let got = client.sketch_batch(dim, chunk.to_vec()).unwrap();
                assert_eq!(got.len(), chunk.len());
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    (requests as f64) / t0.elapsed().as_secs_f64()
}

fn start(
    engine: EngineKind,
    policy: BatchPolicy,
    dim: usize,
    k: usize,
    bits: u8,
) -> Option<(Arc<Coordinator>, Server)> {
    let cfg = ServeConfig {
        engine,
        artifacts_dir: Path::new("artifacts").to_path_buf(),
        dim,
        num_hashes: k,
        seed: 42,
        sketch: SketchSettings {
            scheme: SketchScheme::Cmh,
            bits,
        },
        batch: BatchConfig {
            max_batch: 64,
            max_delay_us: 1_000,
            policy,
        },
        index: IndexSettings {
            bands: 32,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = match Coordinator::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            println!("(skipping {engine:?} serving bench: {e})");
            return None;
        }
    };
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    Some((svc, server))
}

fn run_engine(h: &mut Harness, engine: EngineKind, policy: BatchPolicy, dim: usize, k: usize) {
    let Some((svc, server)) = start(engine, policy, dim, k, 32) else {
        return;
    };
    let addr = server.addr().to_string();
    // warmup
    let _ = drive(&addr, dim as u32, 64, 64, 8);
    let t0 = Instant::now();
    let (rps, lat_ms) = drive(&addr, dim as u32, 64, 1024, 8);
    h.report(
        &format!("serve {engine:?}/{policy:?} D={dim} K={k} (8 conns)"),
        t0.elapsed(),
        1024,
    );
    let (snap, _) = svc.stats();
    println!(
        "  -> {rps:.0} req/s, {lat_ms:.2} ms mean latency, mean batch fill {:.1}, \
         batch exec mean {:.2} ms",
        snap.mean_batch_fill,
        snap.batch_latency.mean_us as f64 / 1e3
    );
}

/// Per-item vs batched wire ops over the same row budget; returns the
/// JSON record for `BENCH_serving_batch.json`.
fn run_batch_comparison(h: &mut Harness, dim: usize, k: usize, rows: usize) -> Json {
    let (svc, server) = start(EngineKind::Rust, BatchPolicy::Eager, dim, k, 32)
        .expect("rust engine always starts");
    let addr = server.addr().to_string();
    let conns = 8usize;

    // warmup both paths
    let _ = drive(&addr, dim as u32, 64, 256, conns);
    let _ = drive_batched(&addr, dim as u32, 64, 256, conns, 32);

    let t0 = Instant::now();
    let (item_rps, item_lat) = drive(&addr, dim as u32, 64, rows, conns);
    h.report(
        &format!("wire per-item sketch x{rows} ({conns} conns)"),
        t0.elapsed(),
        rows as u64,
    );

    let mut batched = Vec::new();
    for wire_batch in [8usize, 32, 128] {
        let t0 = Instant::now();
        let rps = drive_batched(&addr, dim as u32, 64, rows, conns, wire_batch);
        h.report(
            &format!("wire sketch_batch B={wire_batch} x{rows} ({conns} conns)"),
            t0.elapsed(),
            rows as u64,
        );
        println!(
            "  -> sketch_batch B={wire_batch}: {rps:.0} rows/s ({:.2}x per-item)",
            rps / item_rps
        );
        batched.push(Json::obj(vec![
            ("wire_batch", Json::Num(wire_batch as f64)),
            ("rows_per_s", Json::Num(rps)),
            ("speedup_vs_per_item", Json::Num(rps / item_rps)),
        ]));
    }

    // Bulk ingest: insert_batch against per-item insert, single conn
    // (the `cminhash load` shape).
    let ingest_rows = rand_rows(dim as u32, 64, rows.min(2048), 77);
    let mut client = BlockingClient::connect(&addr).unwrap();
    let t0 = Instant::now();
    for r in &ingest_rows {
        client.insert(dim as u32, r.clone()).unwrap();
    }
    let item_ingest = ingest_rows.len() as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for chunk in ingest_rows.chunks(256) {
        client.insert_batch(dim as u32, chunk.to_vec()).unwrap();
    }
    let batch_ingest = ingest_rows.len() as f64 / t0.elapsed().as_secs_f64();
    println!(
        "  -> ingest: per-item {item_ingest:.0} rows/s, insert_batch(256) \
         {batch_ingest:.0} rows/s ({:.2}x)",
        batch_ingest / item_ingest
    );

    let (snap, _) = svc.stats();
    Json::obj(vec![
        ("bench", Json::str("serving_batch")),
        ("dim", Json::Num(dim as f64)),
        ("k", Json::Num(k as f64)),
        ("rows", Json::Num(rows as f64)),
        ("conns", Json::Num(conns as f64)),
        ("per_item_rows_per_s", Json::Num(item_rps)),
        ("per_item_mean_latency_ms", Json::Num(item_lat)),
        ("batched", Json::Arr(batched)),
        ("ingest_per_item_rows_per_s", Json::Num(item_ingest)),
        ("ingest_insert_batch_rows_per_s", Json::Num(batch_ingest)),
        ("mean_engine_batch_fill", Json::Num(snap.mean_batch_fill)),
    ])
}

/// JSON-lines vs `bin1` wire format over the same row budget; returns
/// the JSON record for `BENCH_wire_format.json`.
///
/// The comparison is the offline-sketch ingest shape: the binary side
/// packs its rows BEFORE the timed region (that work happens in an
/// offline sketching job, or amortised across `cminhash load` client
/// cores) and ships `insert_packed` frames the server memcpys into the
/// packed arena; the JSON side ships raw indices the server must
/// parse and sketch inline.  That asymmetry is the point of bin1.
fn run_wire_format_comparison(h: &mut Harness, dim: usize, k: usize, rows: usize) -> Json {
    let bits = 8u8;
    let (_svc, server) = start(EngineKind::Rust, BatchPolicy::Eager, dim, k, bits)
        .expect("rust engine always starts");
    let addr = server.addr().to_string();
    let raw = rand_rows(dim as u32, 64, rows, 123);
    let chunk = 256usize;

    // JSON-lines ingest: raw indices, server-side sketch + pack.
    let mut cj = BlockingClient::connect(&addr).unwrap();
    cj.insert_batch(dim as u32, raw[..chunk.min(rows)].to_vec())
        .unwrap(); // warmup
    let t0 = Instant::now();
    for c in raw.chunks(chunk) {
        cj.insert_batch(dim as u32, c.to_vec()).unwrap();
    }
    let json_ingest = rows as f64 / t0.elapsed().as_secs_f64();
    h.report(&format!("ingest jsonl insert_batch x{rows}"), t0.elapsed(), rows as u64);

    // bin1 ingest: rows sketched and packed outside the timed region,
    // shipped as checksummed insert_packed frames.
    let hasher = CMinHasher::new(dim, k, 42);
    let wpr = packed_words(k, bits);
    let packed: Vec<Vec<u64>> = raw
        .iter()
        .map(|idx| {
            let mut row = vec![0u64; wpr];
            pack_row(&hasher.sketch_sparse(idx), bits, &mut row);
            row
        })
        .collect();
    let mut cb = BlockingClient::connect(&addr).unwrap();
    cb.binary().unwrap();
    cb.insert_packed(packed[..chunk.min(rows)].to_vec()).unwrap(); // warmup
    let t0 = Instant::now();
    for c in packed.chunks(chunk) {
        cb.insert_packed(c.to_vec()).unwrap();
    }
    let bin_ingest = rows as f64 / t0.elapsed().as_secs_f64();
    h.report(&format!("ingest bin1 insert_packed x{rows}"), t0.elapsed(), rows as u64);
    println!(
        "  -> ingest: jsonl {json_ingest:.0} rows/s, bin1 {bin_ingest:.0} rows/s \
         ({:.2}x)",
        bin_ingest / json_ingest
    );

    // Query path, same query set in both formats.
    let nq = rows.min(1024);
    let queries = raw[..nq].to_vec();
    let t0 = Instant::now();
    for c in queries.chunks(64) {
        let got = cj.query_batch(dim as u32, c.to_vec(), 10).unwrap();
        assert_eq!(got.len(), c.len());
    }
    let json_query = nq as f64 / t0.elapsed().as_secs_f64();
    h.report(&format!("query jsonl query_batch x{nq}"), t0.elapsed(), nq as u64);
    let t0 = Instant::now();
    for c in queries.chunks(64) {
        let got = cb.query_batch(dim as u32, c.to_vec(), 10).unwrap();
        assert_eq!(got.len(), c.len());
    }
    let bin_query = nq as f64 / t0.elapsed().as_secs_f64();
    h.report(&format!("query bin1 query_batch x{nq}"), t0.elapsed(), nq as u64);
    println!(
        "  -> query: jsonl {json_query:.0} rows/s, bin1 {bin_query:.0} rows/s \
         ({:.2}x)",
        bin_query / json_query
    );

    Json::obj(vec![
        ("bench", Json::str("wire_format")),
        ("dim", Json::Num(dim as f64)),
        ("k", Json::Num(k as f64)),
        ("bits", Json::Num(f64::from(bits))),
        ("rows", Json::Num(rows as f64)),
        ("json_insert_rows_per_s", Json::Num(json_ingest)),
        ("bin_insert_rows_per_s", Json::Num(bin_ingest)),
        ("json_query_rows_per_s", Json::Num(json_query)),
        ("bin_query_rows_per_s", Json::Num(bin_query)),
    ])
}

fn main() {
    let fast = std::env::var("CMINHASH_BENCH_FAST").is_ok_and(|v| v == "1");
    let mut h = Harness::new("serving_throughput");
    let (dim, k) = (4096usize, 256usize);

    // Baseline: bare hasher throughput on one core.
    let hasher = CMinHasher::new(dim, k, 42);
    let mut rng = Rng::seed_from_u64(9);
    let idx: Vec<u32> = (0..64).map(|_| rng.range_u32(0, dim as u32)).collect();
    let bare = h.bench("bare hasher sketch D=4096 K=256", || {
        hasher.sketch_sparse(&idx)
    });
    let bare_ns = bare.mean_ns;

    // Policy ablation on the rust engine (DESIGN.md ablation item).
    run_engine(&mut h, EngineKind::Rust, BatchPolicy::Eager, dim, k);
    run_engine(&mut h, EngineKind::Rust, BatchPolicy::Deadline, dim, k);
    run_engine(&mut h, EngineKind::Xla, BatchPolicy::Eager, dim, k);

    // Batched vs per-item wire ops (the batch-protocol claim).
    let rows = if fast { 1024 } else { 8192 };
    let record = run_batch_comparison(&mut h, dim, k, rows);
    std::fs::write("BENCH_serving_batch.json", record.to_string()).unwrap();
    println!("wrote BENCH_serving_batch.json");

    // JSON-lines vs bin1 framing (the PROTOCOL.md binary-wins claim).
    let wire_rows = if fast { 2048 } else { 8192 };
    let record = run_wire_format_comparison(&mut h, dim, k, wire_rows);
    std::fs::write("BENCH_wire_format.json", record.to_string()).unwrap();
    println!("wrote BENCH_wire_format.json");

    println!(
        "PAPER-CHECK L3 overhead: bare hash = {:.1} µs/sketch; serving adds \
         protocol+batching on top (see serve lines above)",
        bare_ns / 1e3
    );
    h.write_csv().unwrap();
}
