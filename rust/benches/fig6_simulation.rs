//! Figure 6 bench: the §4.1 sanity-check simulation — empirical MSE of
//! all three estimators on structured (D=128) pairs against the exact
//! theory (Theorems 2.2 and 3.1), plus timing of the simulation loop.
//!
//! `CMINHASH_BENCH_FAST=1` (or default) runs a reduced rep count; the
//! full figure regeneration uses `cminhash figures --fig 6`.

use cminhash::bench::Harness;
use cminhash::sketch::{estimate, CMinHasher, Perm, Sketcher};
use cminhash::theory::{var_minhash, var_sigma_pi, var_zero_pi, LocationVector};
use cminhash::util::rng::Rng;
use std::path::Path;

fn simulate_sigma_pi(x: &LocationVector, k: usize, reps: usize, seed: u64) -> f64 {
    let d = x.d();
    let (v, w) = x.realize();
    let truth = x.jaccard();
    let mut rng = Rng::seed_from_u64(seed);
    let mut sq = 0.0;
    for _ in 0..reps {
        let sigma = Perm::from_values(rng.permutation(d)).unwrap();
        let pi = Perm::from_values(rng.permutation(d)).unwrap();
        let h = CMinHasher::from_perms(k, &sigma, &pi).unwrap();
        let e = estimate(&h.sketch_sparse(v.indices()), &h.sketch_sparse(w.indices()));
        sq += (e - truth) * (e - truth);
    }
    sq / reps as f64
}

fn main() {
    let mut h = Harness::new("fig6_simulation");
    let x = LocationVector::contiguous(128, 64, 32);

    h.bench("one (sigma,pi) draw + sketch pair (D=128,K=64)", || {
        simulate_sigma_pi(&x, 64, 1, 7)
    });

    // Regenerate the figure data (fast reps here; full via CLI).
    let out = Path::new("results");
    cminhash::figures::fig6(out, 600).expect("fig6");
    println!("wrote results/fig6_simulation.csv");

    // Paper-shape checks: empirical MSE tracks theoretical variance for
    // each method, and Var_{σ,π} < Var_MH while Var_{0,π} is
    // location-specific.
    for &(f, a, k) in &[(64usize, 32usize, 32usize), (32, 8, 64), (96, 48, 128)] {
        let x = LocationVector::contiguous(128, f, a);
        let emp = simulate_sigma_pi(&x, k, 4000, 11);
        let theo = var_sigma_pi(128, f, a, k);
        let mh = var_minhash(x.jaccard(), k);
        let zp = var_zero_pi(&x, k);
        println!(
            "PAPER-CHECK fig6 (f={f},a={a},K={k}): emp={emp:.5} vs theo={theo:.5} \
             | MH={mh:.5} 0pi={zp:.5}"
        );
        assert!(
            (emp - theo).abs() < 0.15 * theo.max(1e-5),
            "simulation does not match Theorem 3.1"
        );
        assert!(theo < mh, "Theorem 3.4");
    }
    h.write_csv().unwrap();
}
