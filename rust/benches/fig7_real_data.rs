//! Figure 7 bench: all-pairs Jaccard-estimation MAE on the four §4.2
//! corpus stand-ins (text-like ×2, image-like ×2), all three methods —
//! regenerates the series and asserts the paper's qualitative ordering:
//! MAE(σ,π) < MAE(MinHash) everywhere on average, and (0,π) degrades
//! hardest on image-structured data.

use cminhash::bench::Harness;
use cminhash::data::CorpusKind;
use cminhash::figures::fig7_orderings;
use cminhash::sketch::{CMinHasher, Sketcher};
use std::path::Path;

fn main() {
    let mut h = Harness::new("fig7_real_data");

    // Sketch throughput on each corpus kind (the pipeline hot loop).
    for kind in CorpusKind::all() {
        let corpus = kind.generate(24, 1);
        let d = corpus.dim() as usize;
        let hasher = CMinHasher::new(d, 256, 5);
        h.bench(&format!("sketch 24 docs {} K=256", kind.name()), || {
            corpus
                .rows()
                .iter()
                .map(|r| hasher.sketch_sparse(r.indices()).len())
                .sum::<usize>()
        });
    }

    // Regenerate the figure (reduced size here; full via CLI --fig 7).
    let out = Path::new("results");
    cminhash::figures::fig7(out, 32, 3).expect("fig7");
    println!("wrote results/fig7_real_data.csv");

    // Paper-shape check on the image corpus (strong structure).
    let (mh, zero_pi, sigma_pi) = fig7_orderings(24, 256, 5);
    println!(
        "PAPER-CHECK fig7 mnist-like K=256: MAE minhash={mh:.4}  (0,pi)={zero_pi:.4}  (sigma,pi)={sigma_pi:.4}"
    );
    assert!(sigma_pi < mh, "(sigma,pi) must beat MinHash");
    assert!(zero_pi > sigma_pi, "(0,pi) must degrade on structured images");
    h.write_csv().unwrap();
}
