//! Figure 4 bench: variance ratio vs J at D=1000, K=800 — regenerates
//! the series and verifies Proposition 3.5 (the ratio is *flat* in J).

use cminhash::bench::Harness;
use cminhash::theory::variance_ratio;
use std::path::Path;

fn main() {
    let mut h = Harness::new("fig4_ratio_vs_j");
    h.bench("variance_ratio(D=1000,f=500,K=800)", || {
        variance_ratio(1000, 500, 250, 800).unwrap()
    });

    let out = Path::new("results");
    cminhash::figures::fig4(out).expect("fig4");
    println!("wrote results/fig4_ratio_vs_j.csv");

    // Paper-shape check: constant across a (within float noise), > 1.
    for &f in &[200usize, 500, 800] {
        let base = variance_ratio(1000, f, 1, 800).unwrap();
        let mut max_dev = 0.0f64;
        for a in (1..f).step_by((f / 37).max(1)) {
            let r = variance_ratio(1000, f, a, 800).unwrap();
            max_dev = max_dev.max(((r - base) / base).abs());
        }
        println!(
            "PAPER-CHECK fig4 f={f}: ratio={base:.4} (>1), max relative deviation over a = {max_dev:.2e}"
        );
        assert!(base > 1.0);
        // ~1e-6 relative noise from exp(ln-choose) paths is expected
        assert!(max_dev < 1e-5, "Prop 3.5 flatness violated: {max_dev}");
    }
    h.write_csv().unwrap();
}
