//! Figure 2 bench: regenerate Var[Ĵ_{σ,π}] / Var[Ĵ_MH] vs J
//! (D=1000, f ∈ {200,500,800}, K ∈ {500,800}) and time the exact
//! evaluator.  Prints the paper-comparison summary lines that
//! EXPERIMENTS.md records.

use cminhash::bench::Harness;
use cminhash::theory::{var_minhash, var_sigma_pi};
use std::path::Path;

fn main() {
    let mut h = Harness::new("fig2_variance_vs_j");

    // Timing: one exact variance evaluation at the paper's scale.
    h.bench("var_sigma_pi(D=1000,f=500,a=250,K=800)", || {
        var_sigma_pi(1000, 500, 250, 800)
    });
    h.bench("var_sigma_pi(D=1000,f=800,a=400,K=500)", || {
        var_sigma_pi(1000, 800, 400, 500)
    });

    // Regenerate the figure data.
    let out = Path::new("results");
    cminhash::figures::fig2(out).expect("fig2");
    println!("wrote results/fig2_variance_vs_j.csv");

    // Paper-shape checks (Figure 2's visual claims).
    let d = 1000;
    for &k in &[500usize, 800] {
        for &f in &[200usize, 500, 800] {
            // symmetric about J=1/2 and always below MinHash
            let a_lo = f / 4;
            let v_lo = var_sigma_pi(d, f, a_lo, k);
            let v_hi = var_sigma_pi(d, f, f - a_lo, k);
            assert!((v_lo - v_hi).abs() < 1e-6 * v_lo, "symmetry");
            let peak = var_sigma_pi(d, f, f / 2, k);
            let mh_peak = var_minhash(0.5, k);
            println!(
                "PAPER-CHECK fig2 K={k} f={f}: peak Var_C={peak:.3e} < Var_MH={mh_peak:.3e} (ratio {:.3})",
                mh_peak / peak
            );
            assert!(peak < mh_peak);
        }
    }
    h.write_csv().unwrap();
}
