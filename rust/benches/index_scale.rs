//! Sharded vs. single-shard index throughput at scale: concurrent
//! inserts and queries against `ShardedIndex` at 100k items (20k under
//! `CMINHASH_BENCH_FAST=1`), sweeping the shard count.  Emits
//! `BENCH_index_scale.json` alongside the usual CSV so the perf
//! trajectory of the store subsystem is machine-readable.
//!
//! The corpus is families of near-duplicate sketches (mutated copies
//! of ~1k bases) so band postings actually collide and queries do real
//! re-ranking work, without paying 100k full hashing passes.

use cminhash::bench::Harness;
use cminhash::index::IndexConfig;
use cminhash::store::ShardedIndex;
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use std::time::Instant;

const K: usize = 128;
const QUERIES: usize = 2_000;

fn corpus(n: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::seed_from_u64(7);
    let bases: Vec<Vec<u32>> = (0..1024)
        .map(|_| (0..K).map(|_| rng.range_u32(0, 1 << 20)).collect())
        .collect();
    (0..n)
        .map(|i| {
            let mut sk = bases[i % bases.len()].clone();
            for _ in 0..rng.range_usize(1, K / 4) {
                let pos = rng.range_usize(0, K);
                sk[pos] = rng.range_u32(0, 1 << 20);
            }
            sk
        })
        .collect()
}

/// Insert the whole corpus from `threads` writers, then issue QUERIES
/// top-10 queries from the same number of readers, at `bits` per
/// stored hash (32 = the classic full-width store).  Returns
/// (inserts/s, queries/s).
fn run(
    h: &mut Harness,
    shards: usize,
    bits: u8,
    items: &[Vec<u32>],
    threads: usize,
) -> (f64, f64) {
    let cfg = IndexConfig {
        bands: 16,
        rows_per_band: 8,
    };
    let idx = ShardedIndex::with_bits(K, cfg, bits, shards).unwrap();

    let t0 = Instant::now();
    std::thread::scope(|s| {
        for chunk in items.chunks(items.len() / threads + 1) {
            let idx = &idx;
            s.spawn(move || {
                for sk in chunk {
                    idx.insert(sk).unwrap();
                }
            });
        }
    });
    let insert_wall = t0.elapsed();
    h.report(
        &format!(
            "insert {} items, {shards} shard(s), bits={bits}, {threads} writers",
            items.len()
        ),
        insert_wall,
        items.len() as u64,
    );
    assert_eq!(idx.len(), items.len());

    let per = QUERIES / threads;
    let total = per * threads;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let idx = &idx;
            s.spawn(move || {
                for q in 0..per {
                    let probe = &items[(t * per + q) * items.len() / total];
                    let hits = idx.query(probe, 10).unwrap();
                    assert!(!hits.is_empty());
                }
            });
        }
    });
    let query_wall = t0.elapsed();
    h.report(
        &format!(
            "query {total} probes, {shards} shard(s), bits={bits}, {threads} readers"
        ),
        query_wall,
        total as u64,
    );

    (
        items.len() as f64 / insert_wall.as_secs_f64(),
        total as f64 / query_wall.as_secs_f64(),
    )
}

fn main() {
    let fast = std::env::var("CMINHASH_BENCH_FAST").is_ok_and(|v| v == "1");
    let n = if fast { 20_000 } else { 100_000 };
    let threads = std::thread::available_parallelism()
        .map(|p| p.get().min(8))
        .unwrap_or(4);
    let mut h = Harness::new("index_scale");
    println!("corpus: {n} sketches of K={K}, {threads} client threads");
    let items = corpus(n);

    let mut results = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let (ins, qry) = run(&mut h, shards, 32, &items, threads);
        println!("  -> {shards} shard(s): {ins:.0} inserts/s, {qry:.0} queries/s");
        results.push(Json::obj(vec![
            ("shards", Json::Num(shards as f64)),
            ("bits", Json::Num(32.0)),
            ("insert_per_s", Json::Num(ins)),
            ("query_per_s", Json::Num(qry)),
        ]));
    }

    // The packed plane under the same concurrent load: sharding and
    // b-bit storage compose (bits=8 → 4× less resident sketch memory,
    // popcount re-ranking).
    let mut packed_results = Vec::new();
    for shards in [1usize, 4] {
        let (ins, qry) = run(&mut h, shards, 8, &items, threads);
        println!(
            "  -> {shards} shard(s), bits=8: {ins:.0} inserts/s, {qry:.0} queries/s"
        );
        packed_results.push(Json::obj(vec![
            ("shards", Json::Num(shards as f64)),
            ("bits", Json::Num(8.0)),
            ("insert_per_s", Json::Num(ins)),
            ("query_per_s", Json::Num(qry)),
        ]));
    }

    let out = Json::obj(vec![
        ("bench", Json::str("index_scale")),
        ("items", Json::Num(n as f64)),
        ("k", Json::Num(K as f64)),
        ("queries", Json::Num(QUERIES as f64)),
        ("threads", Json::Num(threads as f64)),
        ("results", Json::Arr(results)),
        ("packed_results", Json::Arr(packed_results)),
    ]);
    std::fs::write("BENCH_index_scale.json", out.to_string()).unwrap();
    println!("wrote BENCH_index_scale.json");
    h.write_csv().unwrap();
}
