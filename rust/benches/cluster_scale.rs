//! Cluster ingest/query scaling: the same row budget driven through
//! 1-, 2- and 4-node clusters of in-process servers, all on one
//! machine.  Each node is a full single-node stack (own coordinator,
//! own batch pump, own store), so adding nodes adds sketch-compute
//! threads — the scaling claim gated by `check_bench.py` is that two
//! nodes ingest at least 1.6x the single-node rate.  Emits
//! `BENCH_cluster_scale.json`.

use cminhash::bench::Harness;
use cminhash::config::{
    BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig, SketchSettings,
};
use cminhash::coordinator::Coordinator;
use cminhash::server::{ClusterClient, ClusterConfig, ClusterNode, Server};
use cminhash::sketch::SketchScheme;
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn rand_rows(dim: u32, nnz: usize, n: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let mut idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, dim)).collect();
            idx.sort_unstable();
            idx.dedup();
            idx
        })
        .collect()
}

fn start_node(dim: usize, k: usize) -> (Arc<Coordinator>, Server) {
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        artifacts_dir: Path::new("artifacts").to_path_buf(),
        dim,
        num_hashes: k,
        seed: 42,
        sketch: SketchSettings {
            scheme: SketchScheme::Cmh,
            bits: 32,
        },
        batch: BatchConfig {
            max_batch: 64,
            max_delay_us: 1_000,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 32,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg).expect("rust engine always starts");
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    (svc, server)
}

/// Spin up `n` nodes and describe them as a cluster topology.
fn start_cluster(n: usize, dim: usize, k: usize) -> (Vec<(Arc<Coordinator>, Server)>, ClusterConfig) {
    let nodes: Vec<(Arc<Coordinator>, Server)> =
        (0..n).map(|_| start_node(dim, k)).collect();
    let cfg = ClusterConfig {
        timeout_ms: 30_000,
        nodes: nodes
            .iter()
            .enumerate()
            .map(|(i, (_, s))| ClusterNode {
                id: format!("node-{i}"),
                addr: s.addr().to_string(),
            })
            .collect(),
    };
    (nodes, cfg)
}

/// Closed-loop cluster ingest: `conns` client threads, each with its
/// own [`ClusterClient`], splitting the row budget into 256-row chunks
/// that rendezvous routing fans across the nodes.  Returns rows/s.
fn ingest(cfg: &ClusterConfig, dim: u32, rows: usize, conns: usize) -> f64 {
    let per_conn = rows / conns;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..conns {
        let cfg = cfg.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = ClusterClient::connect(cfg).unwrap();
            let rows = rand_rows(dim, 64, per_conn, 31 * c as u64 + 1);
            for chunk in rows.chunks(256) {
                let out = client.insert_batch(dim, chunk.to_vec()).unwrap();
                assert!(!out.degraded, "no node may fail during the bench");
                assert_eq!(out.inserted as usize, chunk.len());
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    (conns * per_conn) as f64 / t0.elapsed().as_secs_f64()
}

/// Fan-out query throughput over the loaded cluster (single client —
/// queries hit every node, so the cluster-side cost is what varies).
fn query(cfg: &ClusterConfig, dim: u32, n: usize) -> f64 {
    let mut client = ClusterClient::connect(cfg.clone()).unwrap();
    let rows = rand_rows(dim, 64, n, 9_000);
    let t0 = Instant::now();
    for chunk in rows.chunks(64) {
        let out = client.query_batch(dim, chunk.to_vec(), 10).unwrap();
        assert!(!out.degraded);
        assert_eq!(out.results.len(), chunk.len());
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let fast = std::env::var("CMINHASH_BENCH_FAST").is_ok_and(|v| v == "1");
    let mut h = Harness::new("cluster_scale");
    let (dim, k) = (4096usize, 256usize);
    let rows = if fast { 4096 } else { 16384 };
    let conns = 4usize;

    let mut records = Vec::new();
    let mut single_node = 0.0f64;
    for n in [1usize, 2, 4] {
        // Keep every node's server+pump alive for the whole measurement.
        let (nodes, cfg) = start_cluster(n, dim, k);
        let _ = ingest(&cfg, dim as u32, 512, conns); // warmup
        let t0 = Instant::now();
        let rps = ingest(&cfg, dim as u32, rows, conns);
        h.report(
            &format!("cluster ingest {n} node(s) x{rows} ({conns} conns)"),
            t0.elapsed(),
            rows as u64,
        );
        let qn = rows.min(2048);
        let qps = query(&cfg, dim as u32, qn);
        if n == 1 {
            single_node = rps;
        }
        println!(
            "  -> {n} node(s): ingest {rps:.0} rows/s ({:.2}x single), \
             fan-out query {qps:.0} rows/s",
            rps / single_node.max(1e-9)
        );
        // Spread check: rendezvous routing must use every node.
        for (i, (svc, _)) in nodes.iter().enumerate() {
            let (_, store) = svc.stats();
            assert!(
                store.stored > 0,
                "node {i} of {n} received no rows — routing is broken"
            );
        }
        records.push(Json::obj(vec![
            ("nodes", Json::Num(n as f64)),
            ("ingest_rows_per_s", Json::Num(rps)),
            ("query_rows_per_s", Json::Num(qps)),
            ("speedup_vs_single", Json::Num(rps / single_node.max(1e-9))),
        ]));
    }

    let record = Json::obj(vec![
        ("bench", Json::str("cluster_scale")),
        ("dim", Json::Num(dim as f64)),
        ("k", Json::Num(k as f64)),
        ("rows", Json::Num(rows as f64)),
        ("conns", Json::Num(conns as f64)),
        ("nodes", Json::Arr(records)),
    ]);
    std::fs::write("BENCH_cluster_scale.json", record.to_string()).unwrap();
    println!("wrote BENCH_cluster_scale.json");
    h.write_csv().unwrap();
}
