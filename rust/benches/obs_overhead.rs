//! Observability overhead gate: the always-on tracing path (request
//! guard + per-stage spans + per-shard counters) must cost under 3% of
//! hot-path throughput versus the same stack with tracing disabled
//! (`obs.trace_ring = 0` — counters stay on either way; they are not a
//! knob).  Both sides run in-process through the same dispatch shape
//! the server uses (begin → stage spans inside the coordinator →
//! finish), so the measured delta is exactly what a production `serve`
//! pays for `trace` being available.
//!
//! Writes `BENCH_obs_overhead.json`; `tools/check_bench.py` fails CI
//! when the instrumented/uninstrumented ratio drops below 0.97.

use cminhash::bench::Harness;
use cminhash::config::{EngineKind, IndexSettings, ObsSettings, ServeConfig, SketchSettings};
use cminhash::coordinator::Coordinator;
use cminhash::obs::OpKind;
use cminhash::sketch::{SketchScheme, SparseVec};
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

const DIM: usize = 4096;
const K: usize = 256;
const NNZ: usize = 64;

fn rand_vecs(n: usize, seed: u64) -> Vec<SparseVec> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let mut idx: Vec<u32> = (0..NNZ).map(|_| rng.range_u32(0, DIM as u32)).collect();
            idx.sort_unstable();
            idx.dedup();
            SparseVec::new(DIM as u32, idx).unwrap()
        })
        .collect()
}

fn start(trace_ring: usize) -> Arc<Coordinator> {
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        artifacts_dir: Path::new("artifacts").to_path_buf(),
        dim: DIM,
        num_hashes: K,
        seed: 42,
        sketch: SketchSettings {
            scheme: SketchScheme::Cmh,
            bits: 32,
        },
        index: IndexSettings {
            bands: 32,
            rows_per_band: 4,
        },
        obs: ObsSettings {
            trace_ring,
            // Effectively never trips, so the pinned deque stays empty
            // and both sides do identical publish work per request.
            slow_threshold_us: u64::MAX,
            pinned: 32,
        },
        ..ServeConfig::default()
    };
    Coordinator::start(cfg).expect("rust engine always starts")
}

/// Drive `queries` through the coordinator wrapped exactly as the
/// server wraps them (request guard + finish), returning rows/s.  The
/// inner `svc.query` drops BandLookup/Score stage guards and bumps
/// shard counters on both sides; only the `trace_ring` knob differs.
fn drive_queries(svc: &Arc<Coordinator>, queries: &[SparseVec], topk: usize) -> f64 {
    let t0 = Instant::now();
    for q in queries {
        let mut guard = svc.obs().begin_at(OpKind::Query, Instant::now());
        let got = svc.query(q.clone(), topk).unwrap();
        std::hint::black_box(&got);
        guard.finish(1);
    }
    queries.len() as f64 / t0.elapsed().as_secs_f64()
}

/// Same shape for the ingest path (insert → sketch + WAL-less store).
fn drive_inserts(svc: &Arc<Coordinator>, rows: &[SparseVec]) -> f64 {
    let t0 = Instant::now();
    for r in rows {
        let mut guard = svc.obs().begin_at(OpKind::Insert, Instant::now());
        let got = svc.insert(r.clone()).unwrap();
        std::hint::black_box(&got);
        guard.finish(1);
    }
    rows.len() as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let fast = std::env::var("CMINHASH_BENCH_FAST").is_ok_and(|v| v == "1");
    let mut h = Harness::new("obs_overhead");
    let corpus = if fast { 2_000 } else { 8_000 };
    let n_queries = if fast { 2_000 } else { 8_000 };

    let seed_rows = rand_vecs(corpus, 7);
    let queries = rand_vecs(n_queries, 8);

    // Two identical stacks; only `obs.trace_ring` differs.
    let on = start(256);
    let off = start(0);
    assert!(on.obs().enabled());
    assert!(!off.obs().enabled());

    for r in &seed_rows {
        on.insert(r.clone()).unwrap();
        off.insert(r.clone()).unwrap();
    }

    // Warm both paths (allocator, page cache, branch predictors).
    let _ = drive_queries(&on, &queries[..queries.len() / 4], 10);
    let _ = drive_queries(&off, &queries[..queries.len() / 4], 10);

    // Interleave measurement rounds so ambient machine noise (thermal
    // drift, a background task) hits both sides evenly instead of
    // biasing whichever ran second.
    let rounds = 4usize;
    let per_round = queries.len() / rounds;
    let (mut qps_on, mut qps_off) = (0.0f64, 0.0f64);
    let t_all = Instant::now();
    for r in 0..rounds {
        let slice = &queries[r * per_round..(r + 1) * per_round];
        qps_on += drive_queries(&on, slice, 10) / rounds as f64;
        qps_off += drive_queries(&off, slice, 10) / rounds as f64;
    }
    h.report("query tracing on+off interleaved", t_all.elapsed(), (2 * queries.len()) as u64);

    let extra = rand_vecs(if fast { 1_000 } else { 4_000 }, 9);
    let ins_on = drive_inserts(&on, &extra);
    let ins_off = drive_inserts(&off, &extra);

    let ratio = qps_on / qps_off;
    let ins_ratio = ins_on / ins_off;
    println!(
        "query: tracing-on {qps_on:.0} q/s vs tracing-off {qps_off:.0} q/s \
         -> ratio {ratio:.4}"
    );
    println!(
        "insert: tracing-on {ins_on:.0} rows/s vs tracing-off {ins_off:.0} rows/s \
         -> ratio {ins_ratio:.4}"
    );

    // Sanity: the instrumented side actually captured traces and the
    // uninstrumented side captured none, so the ratio compares what it
    // claims to compare.
    assert!(!on.obs().recent(1).is_empty(), "tracing-on produced no traces");
    assert!(off.obs().recent(1).is_empty(), "tracing-off produced traces");

    let record = Json::obj(vec![
        ("bench", Json::str("obs_overhead")),
        ("dim", Json::Num(DIM as f64)),
        ("k", Json::Num(K as f64)),
        ("corpus", Json::Num(corpus as f64)),
        ("queries", Json::Num(queries.len() as f64)),
        ("qps_on", Json::Num(qps_on)),
        ("qps_off", Json::Num(qps_off)),
        ("ratio", Json::Num(ratio)),
        ("insert_rows_per_s_on", Json::Num(ins_on)),
        ("insert_rows_per_s_off", Json::Num(ins_off)),
        ("insert_ratio", Json::Num(ins_ratio)),
    ]);
    std::fs::write("BENCH_obs_overhead.json", record.to_string()).unwrap();
    println!("wrote BENCH_obs_overhead.json");
    h.write_csv().unwrap();
}
