//! Figure 3 bench: Ẽ versus D for f = 10 / f = 30 — regenerates the
//! curves, checks Lemma 3.3's monotone increase and the J² asymptote,
//! and times the three exact evaluation paths against each other
//! (run-decomposition vs paper-style enumeration).

use cminhash::bench::Harness;
use cminhash::theory::{e_tilde, e_tilde_enum, e_tilde_mc};
use std::path::Path;

fn main() {
    let mut h = Harness::new("fig3_etilde_vs_d");

    // The production path (run decomposition) vs the paper's enumeration.
    h.bench("e_tilde runs (D=500,f=30,a=15)", || e_tilde(500, 30, 15));
    h.bench("e_tilde enum (D=500,f=30,a=15)", || e_tilde_enum(500, 30, 15));
    h.bench("e_tilde runs (D=5000,f=30,a=15)", || e_tilde(5000, 30, 15));
    h.bench("e_tilde mc 10k (D=500,f=30,a=15)", || {
        e_tilde_mc(500, 30, 15, 10_000, 1)
    });

    let out = Path::new("results");
    cminhash::figures::fig3(out).expect("fig3");
    println!("wrote results/fig3_etilde_vs_d.csv");

    // Paper-shape checks: strictly increasing in D, converging to J².
    for &(f, a) in &[(10usize, 5usize), (30, 15)] {
        let j2 = (a as f64 / f as f64).powi(2);
        let e_small = e_tilde(f, f, a);
        let e_mid = e_tilde(10 * f, f, a);
        let e_big = e_tilde(200 * f, f, a);
        assert!(e_small < e_mid && e_mid < e_big && e_big < j2);
        println!(
            "PAPER-CHECK fig3 f={f} a={a}: E(D=f)={e_small:.4} < E(10f)={e_mid:.4} < E(200f)={e_big:.4} < J^2={j2:.4}"
        );
    }
    h.write_csv().unwrap();
}
