//! Hot-path microbenchmarks: ns/sketch for the pure-Rust hashers
//! across (D, f, K), permutation-memory footprint, the XLA artifact
//! batch execution (when artifacts are present), and a **scheme
//! sweep** — sketch throughput and estimate MSE vs K for all six
//! [`SketchScheme`]s, emitted machine-readable as
//! `BENCH_scheme_sweep.json` (gated by `tools/check_bench.py`: the
//! O(1)-state `iuh` scheme must stay within 1.5× of `cmh` ns/sketch).
//! This is the §Perf baseline/after instrument.

use cminhash::bench::{black_box, Harness};
use cminhash::runtime::{HostTensor, XlaEngine};
use cminhash::sketch::{
    estimate, CMinHasher, ClassicMinHasher, Perm, Role, SketchScheme, Sketcher,
    ZeroPiHasher,
};
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use cminhash::util::testutil::overlap_pair;
use std::path::Path;

fn doc(rng: &mut Rng, d: u32, f: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..f).map(|_| rng.range_u32(0, d)).collect();
    idx.sort_unstable();
    idx.dedup();
    idx
}

/// The scheme sweep: for every [`SketchScheme`] × K, measure sketch
/// throughput (ns/sketch at D = 4096, f ≈ 256) and estimator MSE
/// against exact Jaccard (pairs at J = 1/3, averaged over seeds).
/// Emits `BENCH_scheme_sweep.json` so the scheme-selection guide in
/// `docs/SCHEMES.md` is backed by regenerable numbers.
fn scheme_sweep(h: &mut Harness, fast: bool) {
    let d = 4096usize;
    let f = 256usize;
    let seeds = if fast { 8u64 } else { 50 };
    let mut rng = Rng::seed_from_u64(3);
    // Overlapping windows from the shared structured-pair generator:
    // exact J = (f/2) / (3f/2) = 1/3 — the same corpus the statistical
    // suites gate against.
    let (va, wb, truth) =
        overlap_pair(d as u32, f as u32, f as u32, f as u32 / 2);
    let (v, w) = (va.indices().to_vec(), wb.indices().to_vec());
    let idx: Vec<u32> = {
        let mut i: Vec<u32> = (0..f).map(|_| rng.range_u32(0, d as u32)).collect();
        i.sort_unstable();
        i.dedup();
        i
    };

    let mut rows = Vec::new();
    for &k in &[16usize, 64, 256] {
        for scheme in SketchScheme::ALL {
            let hasher = scheme.build(d, k, 7).expect("K divides D=4096");
            let stats = h
                .bench(&format!("scheme {scheme} D={d} f={} K={k}", idx.len()), || {
                    black_box(hasher.sketch_sparse(&idx))
                })
                .clone();
            // MSE of the collision estimator over independent seeds.
            let mut sq = 0.0f64;
            for seed in 0..seeds {
                let hs = scheme.build(d, k, 1000 + seed).unwrap();
                let e = estimate(&hs.sketch_sparse(&v), &hs.sketch_sparse(&w));
                sq += (e - truth) * (e - truth);
            }
            let mse = sq / seeds as f64;
            println!(
                "  scheme={scheme:8} K={k:4}: {:9.0} ns/sketch, MSE {mse:.5}",
                stats.mean_ns
            );
            rows.push(Json::obj(vec![
                ("scheme", Json::str(scheme.as_str())),
                ("k", Json::Num(k as f64)),
                ("ns_per_sketch", Json::Num(stats.mean_ns)),
                ("estimate_mse", Json::Num(mse)),
            ]));
        }
        // Shape check: every scheme's MSE at this K is in the same
        // ballpark as the binomial variance J(1-J)/K (unbiased
        // estimators; OPH variants can be tighter, classic/cmh are
        // pinned near it).
        let bound = truth * (1.0 - truth) / k as f64;
        for row in rows.iter().rev().take(SketchScheme::ALL.len()) {
            let mse = row.get("estimate_mse").unwrap().as_f64().unwrap();
            assert!(
                mse < 6.0 * bound + 1e-4,
                "MSE {mse} implausible vs binomial bound {bound}"
            );
        }
    }
    let out = Json::obj(vec![
        ("bench", Json::str("scheme_sweep")),
        ("dim", Json::Num(d as f64)),
        ("nnz", Json::Num(idx.len() as f64)),
        ("jaccard", Json::Num(truth)),
        ("seeds", Json::Num(seeds as f64)),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_scheme_sweep.json", out.to_string()).unwrap();
    println!("wrote BENCH_scheme_sweep.json");
}

fn main() {
    let fast = std::env::var("CMINHASH_BENCH_FAST").is_ok_and(|v| v == "1");
    let mut h = Harness::new("hasher_hotpath");
    let mut rng = Rng::seed_from_u64(1);

    scheme_sweep(&mut h, fast);

    for &(d, f, k) in &[
        (4096usize, 64usize, 256usize),
        (4096, 512, 256),
        (65536, 400, 512),
        (65536, 400, 2048),
        (1 << 20, 1000, 1024),
    ] {
        let idx = doc(&mut rng, d as u32, f);
        let cm = CMinHasher::new(d, k, 7);
        let zp = ZeroPiHasher::new(d, k, 7);
        h.bench(
            &format!("cminhash-(s,p)  D={d} f={} K={k}", idx.len()),
            || black_box(cm.sketch_sparse(&idx)),
        );
        h.bench(
            &format!("cminhash-(0,p)  D={d} f={} K={k}", idx.len()),
            || black_box(zp.sketch_sparse(&idx)),
        );
        // classic only at small K*D (its permutation matrix is O(K*D))
        if k * d <= 4096 * 1024 {
            let mh = ClassicMinHasher::new(d, k, 7);
            h.bench(
                &format!("classic minhash D={d} f={} K={k} ({} MB perms)",
                    idx.len(), mh.perm_bytes() / (1 << 20)),
                || black_box(mh.sketch_sparse(&idx)),
            );
        }
    }

    // Memory story (the paper's headline practical claim).
    for &(d, k) in &[(1usize << 20, 1024usize)] {
        let two_perm = 2 * 4 * d;
        let classic = k * 4 * d;
        println!(
            "PAPER-CHECK memory D=2^20 K={k}: C-MinHash {:.1} MB vs classic {:.1} MB ({}x)",
            two_perm as f64 / 1e6,
            classic as f64 / 1e6,
            classic / two_perm
        );
    }

    // XLA artifact batch execution (L1+L2 through PJRT).
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = XlaEngine::load(dir).expect("engine");
        for (variant, b, d) in [
            ("cminhash_b8_d1024_k128", 8usize, 1024usize),
            ("cminhash_b64_d4096_k256", 64, 4096),
        ] {
            let mut bits = vec![0i32; b * d];
            let mut r = Rng::seed_from_u64(2);
            for row in 0..b {
                for _ in 0..d / 32 {
                    bits[row * d + r.range_usize(0, d)] = 1;
                }
            }
            let sigma = Perm::generate(d, 7, Role::Sigma).values_i32();
            let pi2 = Perm::generate(d, 7, Role::Pi).doubled_i32();
            let stats = h
                .bench(&format!("XLA batch {variant}"), || {
                    engine
                        .execute(
                            variant,
                            &[
                                HostTensor::I32(bits.clone()),
                                HostTensor::I32(sigma.clone()),
                                HostTensor::I32(pi2.clone()),
                            ],
                        )
                        .unwrap()
                })
                .clone();
            println!(
                "  -> {:.1} µs/row through the XLA path",
                stats.mean_ns / 1e3 / b as f64
            );
        }
        // The sparse (gather) variants — the optimized serving path.
        for (variant, b, d, f_max) in [
            ("cminhashs_b8_d1024_f128_k128", 8usize, 1024usize, 128usize),
            ("cminhashs_b64_d4096_f512_k256", 64, 4096, 512),
        ] {
            let mut r = Rng::seed_from_u64(2);
            let pad = 2 * d as i32;
            let mut idx = vec![pad; b * f_max];
            for row in 0..b {
                for j in 0..d / 32 {
                    idx[row * f_max + j] = r.range_usize(0, d) as i32;
                }
            }
            let sigma = Perm::generate(d, 7, Role::Sigma);
            let inv_sigma = sigma.inverse().values_i32();
            let pi3 = Perm::generate(d, 7, Role::Pi).tripled_sentinel_i32();
            let stats = h
                .bench(&format!("XLA sparse batch {variant}"), || {
                    engine
                        .execute(
                            variant,
                            &[
                                HostTensor::I32(idx.clone()),
                                HostTensor::I32(inv_sigma.clone()),
                                HostTensor::I32(pi3.clone()),
                            ],
                        )
                        .unwrap()
                })
                .clone();
            println!(
                "  -> {:.1} µs/row through the sparse XLA path",
                stats.mean_ns / 1e3 / b as f64
            );
        }
    } else {
        println!("(artifacts missing; skipping XLA hot-path bench)");
    }
    h.write_csv().unwrap();
}
