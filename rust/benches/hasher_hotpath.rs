//! Hot-path microbenchmarks: ns/sketch for the three pure-Rust hashers
//! across (D, f, K), permutation-memory footprint, and the XLA artifact
//! batch execution (when artifacts are present).  This is the §Perf
//! baseline/after instrument.

use cminhash::bench::{black_box, Harness};
use cminhash::runtime::{HostTensor, XlaEngine};
use cminhash::sketch::{
    CMinHasher, ClassicMinHasher, Perm, Role, Sketcher, ZeroPiHasher,
};
use cminhash::util::rng::Rng;
use std::path::Path;

fn doc(rng: &mut Rng, d: u32, f: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..f).map(|_| rng.range_u32(0, d)).collect();
    idx.sort_unstable();
    idx.dedup();
    idx
}

fn main() {
    let mut h = Harness::new("hasher_hotpath");
    let mut rng = Rng::seed_from_u64(1);

    for &(d, f, k) in &[
        (4096usize, 64usize, 256usize),
        (4096, 512, 256),
        (65536, 400, 512),
        (65536, 400, 2048),
        (1 << 20, 1000, 1024),
    ] {
        let idx = doc(&mut rng, d as u32, f);
        let cm = CMinHasher::new(d, k, 7);
        let zp = ZeroPiHasher::new(d, k, 7);
        h.bench(
            &format!("cminhash-(s,p)  D={d} f={} K={k}", idx.len()),
            || black_box(cm.sketch_sparse(&idx)),
        );
        h.bench(
            &format!("cminhash-(0,p)  D={d} f={} K={k}", idx.len()),
            || black_box(zp.sketch_sparse(&idx)),
        );
        // classic only at small K*D (its permutation matrix is O(K*D))
        if k * d <= 4096 * 1024 {
            let mh = ClassicMinHasher::new(d, k, 7);
            h.bench(
                &format!("classic minhash D={d} f={} K={k} ({} MB perms)",
                    idx.len(), mh.perm_bytes() / (1 << 20)),
                || black_box(mh.sketch_sparse(&idx)),
            );
        }
    }

    // Memory story (the paper's headline practical claim).
    for &(d, k) in &[(1usize << 20, 1024usize)] {
        let two_perm = 2 * 4 * d;
        let classic = k * 4 * d;
        println!(
            "PAPER-CHECK memory D=2^20 K={k}: C-MinHash {:.1} MB vs classic {:.1} MB ({}x)",
            two_perm as f64 / 1e6,
            classic as f64 / 1e6,
            classic / two_perm
        );
    }

    // XLA artifact batch execution (L1+L2 through PJRT).
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = XlaEngine::load(dir).expect("engine");
        for (variant, b, d) in [
            ("cminhash_b8_d1024_k128", 8usize, 1024usize),
            ("cminhash_b64_d4096_k256", 64, 4096),
        ] {
            let mut bits = vec![0i32; b * d];
            let mut r = Rng::seed_from_u64(2);
            for row in 0..b {
                for _ in 0..d / 32 {
                    bits[row * d + r.range_usize(0, d)] = 1;
                }
            }
            let sigma = Perm::generate(d, 7, Role::Sigma).values_i32();
            let pi2 = Perm::generate(d, 7, Role::Pi).doubled_i32();
            let stats = h
                .bench(&format!("XLA batch {variant}"), || {
                    engine
                        .execute(
                            variant,
                            &[
                                HostTensor::I32(bits.clone()),
                                HostTensor::I32(sigma.clone()),
                                HostTensor::I32(pi2.clone()),
                            ],
                        )
                        .unwrap()
                })
                .clone();
            println!(
                "  -> {:.1} µs/row through the XLA path",
                stats.mean_ns / 1e3 / b as f64
            );
        }
        // The sparse (gather) variants — the optimized serving path.
        for (variant, b, d, f_max) in [
            ("cminhashs_b8_d1024_f128_k128", 8usize, 1024usize, 128usize),
            ("cminhashs_b64_d4096_f512_k256", 64, 4096, 512),
        ] {
            let mut r = Rng::seed_from_u64(2);
            let pad = 2 * d as i32;
            let mut idx = vec![pad; b * f_max];
            for row in 0..b {
                for j in 0..d / 32 {
                    idx[row * f_max + j] = r.range_usize(0, d) as i32;
                }
            }
            let sigma = Perm::generate(d, 7, Role::Sigma);
            let inv_sigma = sigma.inverse().values_i32();
            let pi3 = Perm::generate(d, 7, Role::Pi).tripled_sentinel_i32();
            let stats = h
                .bench(&format!("XLA sparse batch {variant}"), || {
                    engine
                        .execute(
                            variant,
                            &[
                                HostTensor::I32(idx.clone()),
                                HostTensor::I32(inv_sigma.clone()),
                                HostTensor::I32(pi3.clone()),
                            ],
                        )
                        .unwrap()
                })
                .clone();
            println!(
                "  -> {:.1} µs/row through the sparse XLA path",
                stats.mean_ns / 1e3 / b as f64
            );
        }
    } else {
        println!("(artifacts missing; skipping XLA hot-path bench)");
    }
    h.write_csv().unwrap();
}
