//! Binary wire protocol (`bin1`) test pass: codec roundtrip properties,
//! a golden byte-layout pin, hello negotiation edge cases, and an
//! end-to-end TCP check that binary and JSON clients produce identical
//! results on an identical corpus.  The hostile-input side (mutated
//! frames) lives in `protocol_fuzz.rs`.

use cminhash::config::{
    BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig, SketchSettings,
};
use cminhash::coordinator::Coordinator;
use cminhash::server::frame::{op, BinRequest, BinResponse, FrameReader, FrameWriter};
use cminhash::server::protocol::{Request, WireNeighbor, MAX_WIRE_BATCH};
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::{SketchScheme, SparseVec};
use cminhash::util::rng::Rng;
use cminhash::util::testutil::{overlap_pair, property};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn start_server(bits: u8) -> (Server, Arc<Coordinator>, ServeConfig) {
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: 512,
        num_hashes: 64,
        seed: 9,
        sketch: SketchSettings {
            scheme: SketchScheme::Cmh,
            bits,
        },
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg.clone()).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    (server, svc, cfg)
}

fn random_vec(rng: &mut Rng, dim: u32) -> SparseVec {
    let nnz = rng.range_usize(1, 24);
    let idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, dim)).collect();
    SparseVec::new(dim, idx).unwrap()
}

fn roundtrip_request(req: &BinRequest) -> BinRequest {
    let (op, payload) = req.encode();
    let mut wire = Vec::new();
    FrameWriter::new(&mut wire).write_frame(op, &payload).unwrap();
    let (op2, payload2) = FrameReader::new(wire.as_slice())
        .read_frame()
        .unwrap()
        .expect("one frame");
    assert_eq!(op, op2);
    BinRequest::decode(op2, &payload2).unwrap()
}

fn roundtrip_response(resp: &BinResponse) -> BinResponse {
    let (op, payload) = resp.encode();
    let mut wire = Vec::new();
    FrameWriter::new(&mut wire).write_frame(op, &payload).unwrap();
    let (op2, payload2) = FrameReader::new(wire.as_slice())
        .read_frame()
        .unwrap()
        .expect("one frame");
    BinResponse::decode(op2, &payload2).unwrap()
}

// ---------------------------------------------------------------- codec

#[test]
fn random_requests_roundtrip_through_the_frame_layer() {
    property(60, |rng| {
        let dim = rng.range_u32(32, 4096);
        let req = match rng.below(7) {
            0 => BinRequest::Ping,
            1 => BinRequest::Sketch(random_vec(rng, dim)),
            2 => {
                let n = rng.range_usize(0, 9);
                BinRequest::SketchBatch((0..n).map(|_| random_vec(rng, dim)).collect())
            }
            3 => {
                let wpr = rng.range_usize(1, 9);
                let n = rng.range_usize(0, 6);
                BinRequest::InsertPacked {
                    words_per_row: wpr,
                    rows: (0..n)
                        .map(|_| (0..wpr).map(|_| rng.next_u64()).collect())
                        .collect(),
                }
            }
            4 => BinRequest::QueryBatch {
                vecs: (0..rng.range_usize(0, 5))
                    .map(|_| random_vec(rng, dim))
                    .collect(),
                topk: rng.range_usize(1, 50),
            },
            5 => BinRequest::Delete(rng.next_u64()),
            _ => BinRequest::Estimate(rng.next_u64(), rng.next_u64()),
        };
        assert_eq!(roundtrip_request(&req), req);
    });
}

#[test]
fn random_responses_roundtrip_through_the_frame_layer() {
    property(60, |rng| {
        let resp = match rng.below(8) {
            0 => BinResponse::Pong,
            1 => BinResponse::Err(format!("error #{:x}", rng.next_u64())),
            2 => BinResponse::Sketch(
                (0..rng.range_usize(0, 64)).map(|_| rng.range_u32(0, 512)).collect(),
            ),
            3 => BinResponse::SketchBatch(
                (0..rng.range_usize(0, 5))
                    .map(|_| (0..8).map(|_| rng.range_u32(0, 512)).collect())
                    .collect(),
            ),
            4 => BinResponse::Ids((0..rng.range_usize(0, 9)).map(|_| rng.next_u64()).collect()),
            5 => BinResponse::Results(
                (0..rng.range_usize(0, 4))
                    .map(|_| {
                        (0..rng.range_usize(0, 4))
                            .map(|_| WireNeighbor {
                                id: rng.next_u64(),
                                score: rng.next_f64(),
                            })
                            .collect()
                    })
                    .collect(),
            ),
            6 => BinResponse::Deleted(rng.next_u64()),
            _ => BinResponse::Estimate(rng.next_f64()),
        };
        assert_eq!(roundtrip_response(&resp), resp);
    });
}

#[test]
fn zero_row_and_cap_sized_batches_roundtrip() {
    // Zero rows is legal at the codec layer (the dispatcher rejects it,
    // mirroring the JSON policy) and the cap itself is inclusive.
    let empty = BinRequest::InsertPacked {
        words_per_row: 4,
        rows: Vec::new(),
    };
    assert_eq!(roundtrip_request(&empty), empty);

    let full = BinRequest::InsertPacked {
        words_per_row: 1,
        rows: vec![vec![7u64]; MAX_WIRE_BATCH],
    };
    assert_eq!(roundtrip_request(&full), full);

    let queries = BinRequest::QueryBatch {
        vecs: vec![SparseVec::new(8, vec![1]).unwrap(); MAX_WIRE_BATCH],
        topk: 3,
    };
    assert_eq!(roundtrip_request(&queries), queries);
}

/// Pins the bin1 byte layout against independently computed values
/// (FNV-1a32 literals were derived outside this codebase).  If this
/// test breaks, the wire format changed: bump the protocol name.
#[test]
fn golden_bin1_byte_layout() {
    // ping: len=1 | crc=fnv1a32([0x01]) | op
    let mut wire = Vec::new();
    FrameWriter::new(&mut wire).write_frame(op::PING, &[]).unwrap();
    let mut want = vec![0x01, 0x00, 0x00, 0x00];
    want.extend_from_slice(&0x040c_5b8cu32.to_le_bytes());
    want.push(0x01);
    assert_eq!(wire, want);

    // pong: same shape on the response plane
    let (o, p) = BinResponse::Pong.encode();
    let mut wire = Vec::new();
    FrameWriter::new(&mut wire).write_frame(o, &p).unwrap();
    let mut want = vec![0x01, 0x00, 0x00, 0x00];
    want.extend_from_slice(&0x840b_920cu32.to_le_bytes());
    want.push(0x81);
    assert_eq!(wire, want);

    // delete(7): u64le payload
    let (o, p) = BinRequest::Delete(7).encode();
    let mut wire = Vec::new();
    FrameWriter::new(&mut wire).write_frame(o, &p).unwrap();
    let mut want = vec![0x09, 0x00, 0x00, 0x00];
    want.extend_from_slice(&0x593a_dbbeu32.to_le_bytes());
    want.push(0x06);
    want.extend_from_slice(&7u64.to_le_bytes());
    assert_eq!(wire, want);

    // sketch({dim:16, indices:[1,5]}): dim, nnz, then indices, all u32le
    let (o, p) = BinRequest::Sketch(SparseVec::new(16, vec![1, 5]).unwrap()).encode();
    let mut wire = Vec::new();
    FrameWriter::new(&mut wire).write_frame(o, &p).unwrap();
    let hex: String = wire.iter().map(|b| format!("{b:02x}")).collect();
    assert_eq!(
        hex,
        "11000000a36379ee0210000000020000000100000005000000"
    );

    // insert_packed, 1 row x 2 words: count, wpr u32le then u64le words
    let (o, p) = BinRequest::InsertPacked {
        words_per_row: 2,
        rows: vec![vec![0x0123_4567_89ab_cdef, 0xff]],
    }
    .encode();
    let mut wire = Vec::new();
    FrameWriter::new(&mut wire).write_frame(o, &p).unwrap();
    let mut want = vec![0x19, 0x00, 0x00, 0x00];
    want.extend_from_slice(&0xd2bc_f58fu32.to_le_bytes());
    want.push(0x04);
    want.extend_from_slice(&1u32.to_le_bytes());
    want.extend_from_slice(&2u32.to_le_bytes());
    want.extend_from_slice(&0x0123_4567_89ab_cdefu64.to_le_bytes());
    want.extend_from_slice(&0xffu64.to_le_bytes());
    assert_eq!(wire, want);

    // op-code table is part of the contract
    assert_eq!(
        [
            op::PING,
            op::SKETCH,
            op::SKETCH_BATCH,
            op::INSERT_PACKED,
            op::QUERY_BATCH,
            op::DELETE,
            op::ESTIMATE,
            op::R_ERR,
            op::R_PONG,
            op::R_SKETCH,
            op::R_SKETCH_BATCH,
            op::R_IDS,
            op::R_RESULTS,
            op::R_DELETED,
            op::R_ESTIMATE,
        ],
        [
            0x01, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x80, 0x81, 0x82, 0x83, 0x84,
            0x85, 0x86, 0x87,
        ]
    );
}

// ----------------------------------------------------------- negotiation

fn send_line(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    resp
}

fn raw_conn(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

#[test]
fn hello_bin1_advertises_the_sketch_parameters() {
    let (server, _svc, cfg) = start_server(8);
    let (mut stream, mut reader) = raw_conn(&server.addr().to_string());
    let resp = send_line(
        &mut stream,
        &mut reader,
        r#"{"op":"hello","proto":"bin1"}"#,
    );
    let j = cminhash::util::json::Json::parse(&resp).unwrap();
    assert!(j.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(j.get("proto").unwrap().as_str().unwrap(), "bin1");
    assert_eq!(j.get("scheme").unwrap().as_str().unwrap(), "cmh");
    assert_eq!(j.get("dim").unwrap().as_usize().unwrap(), cfg.dim);
    assert_eq!(j.get("k").unwrap().as_usize().unwrap(), cfg.num_hashes);
    assert_eq!(j.get("seed").unwrap().as_u64().unwrap(), cfg.seed);
    assert_eq!(j.get("bits").unwrap().as_u64().unwrap(), 8);
    assert_eq!(
        j.get("max_batch").unwrap().as_usize().unwrap(),
        MAX_WIRE_BATCH
    );
}

#[test]
fn unknown_proto_falls_back_to_jsonl_and_the_connection_stays_usable() {
    let (server, _svc, _cfg) = start_server(32);
    let (mut stream, mut reader) = raw_conn(&server.addr().to_string());
    let resp = send_line(
        &mut stream,
        &mut reader,
        r#"{"op":"hello","proto":"msgpack9000"}"#,
    );
    let j = cminhash::util::json::Json::parse(&resp).unwrap();
    assert!(j.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(j.get("proto").unwrap().as_str().unwrap(), "jsonl");

    // still a JSON-lines connection
    let resp = send_line(&mut stream, &mut reader, r#"{"op":"ping"}"#);
    assert!(resp.contains("\"pong\""), "resp={resp}");
}

#[test]
fn second_hello_is_an_error_but_not_fatal() {
    let (server, _svc, _cfg) = start_server(32);
    let (mut stream, mut reader) = raw_conn(&server.addr().to_string());
    // first hello settles on jsonl
    let resp = send_line(
        &mut stream,
        &mut reader,
        r#"{"op":"hello","proto":"nope"}"#,
    );
    assert!(resp.contains("\"jsonl\""), "resp={resp}");
    // a second attempt (even for bin1) is rejected...
    let resp = send_line(
        &mut stream,
        &mut reader,
        r#"{"op":"hello","proto":"bin1"}"#,
    );
    let j = cminhash::util::json::Json::parse(&resp).unwrap();
    assert!(!j.get("ok").unwrap().as_bool().unwrap());
    assert!(
        j.get("error").unwrap().as_str().unwrap().contains("hello"),
        "resp={resp}"
    );
    // ...without killing the connection
    let resp = send_line(&mut stream, &mut reader, r#"{"op":"ping"}"#);
    assert!(resp.contains("\"pong\""), "resp={resp}");
}

#[test]
fn malformed_hello_leaves_negotiation_open() {
    let (server, _svc, _cfg) = start_server(32);
    let (mut stream, mut reader) = raw_conn(&server.addr().to_string());
    // hello without a proto field is an error...
    let resp = send_line(&mut stream, &mut reader, r#"{"op":"hello"}"#);
    let j = cminhash::util::json::Json::parse(&resp).unwrap();
    assert!(!j.get("ok").unwrap().as_bool().unwrap());
    // ...but does not burn the one negotiation slot
    let resp = send_line(
        &mut stream,
        &mut reader,
        r#"{"op":"hello","proto":"bin1"}"#,
    );
    let j = cminhash::util::json::Json::parse(&resp).unwrap();
    assert!(j.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(j.get("proto").unwrap().as_str().unwrap(), "bin1");
}

#[test]
fn binary_frame_before_hello_is_rejected_cleanly() {
    let (server, _svc, _cfg) = start_server(32);
    let (mut stream, mut reader) = raw_conn(&server.addr().to_string());
    // A raw bin1 ping with no preceding hello.  The line reader never
    // sees a newline, so close the write half to flush it through.
    let mut frame = Vec::new();
    FrameWriter::new(&mut frame).write_frame(op::PING, &[]).unwrap();
    stream.write_all(&frame).unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let j = cminhash::util::json::Json::parse(&resp).unwrap();
    assert!(!j.get("ok").unwrap().as_bool().unwrap(), "resp={resp}");

    // the server itself is unharmed
    let mut c = BlockingClient::connect(&server.addr().to_string()).unwrap();
    c.ping().unwrap();
}

// -------------------------------------------------- JSON/binary parity

fn corpus(dim: u32, rows: usize) -> Vec<SparseVec> {
    let mut rng = Rng::seed_from_u64(0xb1_b1);
    let (a, b, _j) = overlap_pair(dim, 40, 40, 20);
    let mut vecs = vec![a, b];
    while vecs.len() < rows {
        vecs.push(random_vec(&mut rng, dim));
    }
    vecs
}

fn parity_at(bits: u8) {
    // Two identically configured servers; one ingests over JSON (the
    // server sketches), one over bin1 (the client sketches and packs,
    // the server memcpys).  Every downstream answer must be identical.
    let (srv_json, _svc_j, cfg) = start_server(bits);
    let (srv_bin, _svc_b, _) = start_server(bits);
    let dim = cfg.dim as u32;
    let docs = corpus(dim, 40);

    let mut cj = BlockingClient::connect(&srv_json.addr().to_string()).unwrap();
    let mut cb = BlockingClient::connect(&srv_bin.addr().to_string()).unwrap();
    cb.binary().unwrap();
    assert!(cb.is_binary() && !cj.is_binary());

    let ids_json = cj.insert_batch_vecs(docs.clone()).unwrap();
    let ids_bin = cb.insert_batch_vecs(docs.clone()).unwrap();
    assert_eq!(ids_json, ids_bin, "id assignment must match at bits={bits}");

    // sketches agree lane-for-lane (binary sketches locally on insert,
    // but the sketch op itself still round-trips to the server)
    let probe: Vec<u32> = vec![3, 9, 100, 257];
    assert_eq!(
        cj.sketch(dim, probe.clone()).unwrap(),
        cb.sketch(dim, probe.clone()).unwrap()
    );

    // batch queries: corpus members and fresh probes
    let mut queries: Vec<Vec<u32>> = docs[..6].iter().map(|v| v.indices().to_vec()).collect();
    queries.push(probe);
    queries.push((100..160).collect());
    let rj = cj.query_batch(dim, queries.clone(), 5).unwrap();
    let rb = cb.query_batch(dim, queries.clone(), 5).unwrap();
    assert_eq!(rj, rb, "query results must match at bits={bits}");
    // self-queries really found something
    assert_eq!(rj[0][0].id, ids_json[0]);
    assert_eq!(rj[0][0].score, 1.0);

    // a JSON connection to the binary-fed server sees the same index:
    // binary ingest landed byte-identical rows
    let mut cj2 = BlockingClient::connect(&srv_bin.addr().to_string()).unwrap();
    assert_eq!(rj, cj2.query_batch(dim, queries.clone(), 5).unwrap());

    // deletes propagate identically in both modes
    cj.delete(ids_json[1]).unwrap();
    cb.delete(ids_bin[1]).unwrap();
    let rj = cj.query_batch(dim, queries.clone(), 5).unwrap();
    let rb = cb.query_batch(dim, queries, 5).unwrap();
    assert_eq!(rj, rb, "post-delete results must match at bits={bits}");
    assert!(rj[1].iter().all(|n| n.id != ids_json[1]));
}

#[test]
fn binary_and_json_results_are_identical_at_bits_8() {
    parity_at(8);
}

#[test]
fn binary_and_json_results_are_identical_at_bits_32() {
    parity_at(32);
}

#[test]
fn binary_mode_fences_json_entry_points_and_vice_versa() {
    let (server, _svc, _cfg) = start_server(8);
    let mut c = BlockingClient::connect(&server.addr().to_string()).unwrap();
    // insert_packed before negotiation is refused with a hint
    let err = c.insert_packed(vec![vec![0u64]]).unwrap_err().to_string();
    assert!(err.contains("binary mode"), "err={err}");
    c.binary().unwrap();
    // negotiating twice is a local error, connection still fine
    let err = c.binary().unwrap_err().to_string();
    assert!(err.contains("already"), "err={err}");
    // raw JSON calls are fenced off after the switch
    let err = c.call(&Request::Ping).unwrap_err().to_string();
    assert!(err.contains("bin1"), "err={err}");
    c.ping().unwrap();

    // zero-row batches are rejected by the dispatcher, not the codec
    let err = c.insert_packed(Vec::new()).unwrap_err().to_string();
    assert!(err.contains("zero rows"), "err={err}");
    let err = c.query_batch(512, Vec::new(), 3).unwrap_err().to_string();
    assert!(err.contains("zero rows"), "err={err}");
    c.ping().unwrap();
}

#[test]
fn bad_packed_rows_are_rejected_with_specific_errors() {
    // K=40 at bits=4 is 160 bits: three words with 32 bits of padding
    // in the last one, so both the width check and the dirty-padding
    // check are reachable.
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: 512,
        num_hashes: 40,
        seed: 9,
        sketch: SketchSettings {
            scheme: SketchScheme::Cmh,
            bits: 4,
        },
        index: IndexSettings {
            bands: 10,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg).unwrap();
    let server = Server::spawn(svc, "127.0.0.1:0").unwrap();
    let mut c = BlockingClient::connect(&server.addr().to_string()).unwrap();
    c.binary().unwrap();

    // wrong width: server expects ceil(40 * 4 / 64) = 3 words
    let err = c.insert_packed(vec![vec![0u64; 2]]).unwrap_err().to_string();
    assert!(err.contains("packed row words"), "err={err}");

    // right width but garbage in the padding bits of the last word
    let dirty = vec![0u64, 0, 1u64 << 63];
    let err = c.insert_packed(vec![dirty]).unwrap_err().to_string();
    assert!(err.contains("padding"), "err={err}");

    // an honest all-zero row is accepted, and the connection lives
    let ids = c.insert_packed(vec![vec![0u64; 3]]).unwrap();
    assert_eq!(ids.len(), 1);
    c.ping().unwrap();
}

// ------------------------------------------------ frame_errors metric

#[test]
fn mid_frame_death_counts_as_a_frame_error_not_a_json_error() {
    let (server, svc, _cfg) = start_server(8);
    let (errors_before, _) = {
        let (m, s) = svc.stats();
        (m.errors, s)
    };

    let (mut stream, mut reader) = raw_conn(&server.addr().to_string());
    let resp = send_line(
        &mut stream,
        &mut reader,
        r#"{"op":"hello","proto":"bin1"}"#,
    );
    assert!(resp.contains("\"bin1\""), "resp={resp}");
    // Header declares a 64-byte frame; send only 3 payload bytes and die.
    let mut partial = Vec::new();
    partial.extend_from_slice(&64u32.to_le_bytes());
    partial.extend_from_slice(&0xdead_beefu32.to_le_bytes());
    partial.extend_from_slice(&[0x01, 0x02, 0x03]);
    stream.write_all(&partial).unwrap();
    drop(stream);
    drop(reader);

    // the worker notices asynchronously; poll the metric
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let (m, _) = svc.stats();
        if m.frame_errors >= 1 {
            // a dead binary peer is a frame error, not a JSON parse error
            assert_eq!(m.errors, errors_before, "json errors moved: {m:?}");
            break;
        }
        assert!(Instant::now() < deadline, "frame_errors never incremented");
        std::thread::sleep(Duration::from_millis(10));
    }

    // the pool worker survived
    let mut c = BlockingClient::connect(&server.addr().to_string()).unwrap();
    c.ping().unwrap();
}

#[test]
fn oversized_frame_gets_an_error_frame_then_close() {
    let (server, svc, _cfg) = start_server(8);
    let (mut stream, mut reader) = raw_conn(&server.addr().to_string());
    let resp = send_line(
        &mut stream,
        &mut reader,
        r#"{"op":"hello","proto":"bin1"}"#,
    );
    assert!(resp.contains("\"bin1\""), "resp={resp}");

    // length prefix far past MAX_FRAME_BYTES
    stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
    stream.write_all(&[0u8; 5]).unwrap();
    stream.flush().unwrap();

    // one R_ERR frame, then EOF
    let (op_byte, payload) = FrameReader::new(&mut reader)
        .read_frame()
        .unwrap()
        .expect("an error frame before close");
    assert_eq!(op_byte, op::R_ERR);
    let msg = String::from_utf8(payload).unwrap();
    assert!(msg.contains("cap"), "msg={msg}");
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "expected close after error frame");

    let (m, _) = svc.stats();
    assert!(m.frame_errors >= 1);
}
