//! Property-test suite for the packed b-bit plane's row codec and
//! scoring kernel: pack→unpack identity across widths, exact
//! equivalence of `bits = 32` packed scoring with the unpacked
//! estimator, cross-word-boundary lane layouts, and the packed
//! [`PackedRows`]/[`BandingIndex`] storage semantics.

use cminhash::index::{BandingIndex, IndexConfig, PackedRows};
use cminhash::sketch::{
    collision_count, corrected_estimate, estimate, pack_row, packed_words, unpack_row,
    BBitSketch, CMinHasher, Sketcher, SUPPORTED_BITS,
};
use cminhash::util::rng::Rng;
use cminhash::util::testutil::property;

/// Random full-width sketch values in the realistic `0..D` range.
fn random_sketch(rng: &mut Rng, k: usize) -> Vec<u32> {
    (0..k).map(|_| rng.range_u32(0, 1 << 20)).collect()
}

#[test]
fn pack_unpack_is_the_identity_on_masked_lanes_for_all_widths() {
    // For every width (including the scalar-path widths 3/5/12 the
    // serving plane rejects but the codec supports), unpack(pack(x))
    // must equal x masked to b bits — on random sketches of random
    // lengths, including K = 1 and K not a multiple of the lane count.
    property(25, |rng: &mut Rng| {
        let k = rng.range_usize(1, 300);
        let full = random_sketch(rng, k);
        for b in [1u8, 2, 3, 4, 5, 8, 12, 16, 32] {
            let mask = if b == 32 { u32::MAX } else { (1u32 << b) - 1 };
            let masked: Vec<u32> = full.iter().map(|&v| v & mask).collect();
            let mut words = vec![0u64; packed_words(k, b)];
            pack_row(&full, b, &mut words);
            assert_eq!(unpack_row(&words, k, b), masked, "b={b} k={k}");
            // packing the already-masked row is byte-identical
            let mut words2 = vec![0u64; packed_words(k, b)];
            pack_row(&masked, b, &mut words2);
            assert_eq!(words, words2, "b={b} k={k}: packing is canonical");
        }
    });
}

#[test]
fn thirty_two_bit_packed_scoring_equals_unpacked_estimate_exactly() {
    // bits = 32 is the no-loss width: the packed kernel's collision
    // count and corrected estimate must equal the unpacked estimator
    // bit for bit (f64 ==, not approximately).
    property(25, |rng: &mut Rng| {
        let k = rng.range_usize(1, 200);
        let a = random_sketch(rng, k);
        // correlate some slots so collisions occur
        let b: Vec<u32> = a
            .iter()
            .map(|&v| if rng.bool_with(0.4) { v } else { rng.range_u32(0, 1 << 20) })
            .collect();
        let sa = BBitSketch::compress(&a, 32);
        let sb = BBitSketch::compress(&b, 32);
        let scalar = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        assert_eq!(collision_count(sa.words(), sb.words(), k, 32), scalar);
        assert_eq!(corrected_estimate(scalar, k, 32), estimate(&a, &b));
        assert_eq!(sa.estimate(&sb), estimate(&a, &b), "k={k}");
    });
}

#[test]
fn kernel_matches_scalar_scoring_for_every_supported_width() {
    property(25, |rng: &mut Rng| {
        let k = rng.range_usize(1, 200);
        let a = random_sketch(rng, k);
        let b: Vec<u32> = a
            .iter()
            .map(|&v| if rng.bool_with(0.5) { v } else { rng.range_u32(0, 1 << 20) })
            .collect();
        for bits in SUPPORTED_BITS {
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let scalar = a
                .iter()
                .zip(&b)
                .filter(|(&x, &y)| x & mask == y & mask)
                .count();
            let sa = BBitSketch::compress(&a, bits);
            let sb = BBitSketch::compress(&b, bits);
            assert_eq!(
                collision_count(sa.words(), sb.words(), k, bits),
                scalar,
                "bits={bits} k={k}"
            );
        }
    });
}

#[test]
fn cross_word_boundary_slots_roundtrip() {
    // The satellite cases: b = 4 with K not a multiple of 16 (the last
    // word is partially filled) and b = 16 lanes at word seams (lane 4
    // of K = 5 starts exactly at bit 64).  Also b = 12, whose lanes
    // genuinely straddle word boundaries (the scalar codec path).
    for (k, b) in [
        (100usize, 4u8), // 400 bits → 6¼ words
        (17, 4),
        (5, 16), // lane 4 begins at the word seam
        (9, 16),
        (21, 12), // 252 bits, lanes straddle words
        (65, 1),  // one bit spills into a second word
    ] {
        let full: Vec<u32> = (0..k as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let sk = BBitSketch::compress(&full, b);
        assert_eq!(sk.words().len(), packed_words(k, b), "k={k} b={b}");
        let mask = (1u64 << b) - 1;
        for (i, &h) in full.iter().enumerate() {
            assert_eq!(sk.get(i), u64::from(h) & mask, "k={k} b={b} slot {i}");
        }
        let masked: Vec<u32> = full.iter().map(|&v| (u64::from(v) & mask) as u32).collect();
        assert_eq!(unpack_row(sk.words(), k, b), masked, "k={k} b={b}");
        // a reconstructed sketch scores identically against itself
        let back = BBitSketch::from_words(b, k, sk.words().to_vec()).unwrap();
        assert_eq!(back.estimate(&sk), 1.0, "k={k} b={b}");
    }
}

#[test]
fn packed_rows_roundtrip_under_churn() {
    // Insert/remove/reinsert churn over the arena: every resident row
    // stays retrievable and masked correctly; slots recycle without
    // growing the arena.
    property(10, |rng: &mut Rng| {
        let k = 48usize;
        let bits = 8u8;
        let mut rows = PackedRows::new(k, bits);
        let mut shadow: std::collections::HashMap<u64, Vec<u32>> =
            std::collections::HashMap::new();
        for step in 0..200u64 {
            let id = rng.below(40);
            if shadow.contains_key(&id) {
                let want = shadow.remove(&id).unwrap();
                assert_eq!(rows.remove(id), Some(want), "step {step}");
            } else {
                let full = random_sketch(rng, k);
                let masked: Vec<u32> = full.iter().map(|&v| v & 0xff).collect();
                rows.insert(id, &full);
                shadow.insert(id, masked);
            }
            assert_eq!(rows.len(), shadow.len());
        }
        for (&id, want) in &shadow {
            assert_eq!(rows.get(id).as_ref(), Some(want));
        }
        // arena never exceeds the high-water mark of 40 live ids
        assert!(rows.arena_bytes() <= 40 * rows.words_per_row() * 8);
    });
}

#[test]
fn packed_index_scores_match_the_bbit_estimator() {
    // The packed BandingIndex's query scores must equal what the
    // BBitSketch estimator computes for the same (query, stored) pair
    // — the index is a faster layout, not a different statistic.
    let d = 2048usize;
    let k = 64usize;
    let h = CMinHasher::new(d, k, 17);
    let cfg = IndexConfig {
        bands: 4,
        rows_per_band: 16,
    };
    let docs: Vec<Vec<u32>> = (0..30u32)
        .map(|i| (i * 13..i * 13 + 120).collect())
        .collect();
    for bits in [1u8, 2, 4, 8, 16] {
        let mut idx = BandingIndex::with_bits(k, cfg, bits).unwrap();
        let sketches: Vec<Vec<u32>> = docs.iter().map(|nz| h.sketch_sparse(nz)).collect();
        for (i, sk) in sketches.iter().enumerate() {
            idx.insert(i as u64, sk).unwrap();
        }
        let probe = h.sketch_sparse(&docs[0]);
        let qb = BBitSketch::compress(&probe, bits);
        for n in idx.query(&probe, 30) {
            let want = qb.estimate(&BBitSketch::compress(&sketches[n.id as usize], bits));
            assert_eq!(n.score, want, "bits={bits} id={}", n.id);
        }
    }
}
