//! Lock-discipline interleaving tests for the audited
//! WAL-append-under-lock path (the five `locks/io-under-lock`
//! exceptions in `tools/staticlint/allowlist.json`).
//!
//! The persist lock in `rust/src/store/mod.rs` is deliberately held
//! across the WAL append (and, in `compact`, across fsync + truncate +
//! snapshot write): that hold is what makes WAL order equal apply
//! order, so replay reconstructs exactly the applied state.  These
//! tests drive the two writers the allowlist reasons about — ingest
//! and compaction — against each other, first on a deterministic
//! barrier-stepped schedule and then freely concurrent, and assert the
//! reopened store is byte-identical to an uninterrupted control run.

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::index::Neighbor;
use cminhash::sketch::SparseVec;
use cminhash::util::testutil::TempDir;
use std::path::PathBuf;
use std::sync::{Arc, Barrier};

const DIM: usize = 512;
const K: usize = 64;

fn cfg_with(persist_dir: Option<PathBuf>, shards: usize) -> ServeConfig {
    let mut cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: DIM,
        num_hashes: K,
        seed: 9,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    cfg.store.shards = shards;
    cfg.store.persist_dir = persist_dir;
    cfg
}

fn doc(i: u32) -> SparseVec {
    SparseVec::new(DIM as u32, (i * 3..i * 3 + 40).collect()).unwrap()
}

/// Deterministic schedule: the writer and the compactor alternate in
/// barrier-enforced lockstep, so every round ends with a compaction
/// whose snapshot covers some batches and whose WAL tail covers the
/// rest.  Every interleaving point is fixed; a failure here reproduces
/// exactly.
#[test]
fn lockstep_insert_compact_rounds_recover_exactly() {
    const ROUNDS: u32 = 6;
    const PER_ROUND: u32 = 5;

    let dir = TempDir::new().unwrap();
    // `Coordinator::start` already hands back an `Arc` — clone it into
    // both threads directly.
    let svc = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 4)).unwrap();
    let barrier = Arc::new(Barrier::new(2));

    let writer = {
        let svc = Arc::clone(&svc);
        let barrier = Arc::clone(&barrier);
        std::thread::spawn(move || {
            let mut ids = Vec::new();
            for r in 0..ROUNDS {
                for i in 0..PER_ROUND {
                    let (id, _) = svc.insert(doc(r * PER_ROUND + i)).unwrap();
                    ids.push(id);
                }
                // Round boundary: hand the store to the compactor and
                // wait until it has folded the WAL into a snapshot.
                barrier.wait();
                barrier.wait();
                // Delete one id from the batch the compactor just
                // snapshotted, so the next round's WAL tail holds a
                // delete of a snapshot-resident id.
                if r % 2 == 0 {
                    let victim = ids.remove(ids.len() - 2);
                    svc.delete(victim).unwrap();
                }
            }
            ids
        })
    };
    let compactor = {
        let svc = Arc::clone(&svc);
        let barrier = Arc::clone(&barrier);
        std::thread::spawn(move || {
            for _ in 0..ROUNDS {
                barrier.wait();
                assert!(svc.save().unwrap() > 0, "each round has new state");
                barrier.wait();
            }
        })
    };
    let live = writer.join().expect("writer panicked");
    compactor.join().expect("compactor panicked");
    drop(svc); // final WAL tail (last round's deletes) is uncompacted

    // Control: the identical op sequence, single-threaded, in memory.
    let control = Coordinator::start(cfg_with(None, 4)).unwrap();
    let mut control_live = Vec::new();
    for r in 0..ROUNDS {
        for i in 0..PER_ROUND {
            let (id, _) = control.insert(doc(r * PER_ROUND + i)).unwrap();
            control_live.push(id);
        }
        if r % 2 == 0 {
            let victim = control_live.remove(control_live.len() - 2);
            control.delete(victim).unwrap();
        }
    }
    assert_eq!(live, control_live, "id sequences must line up");

    let recovered = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 4)).unwrap();
    let (_, store) = recovered.stats();
    assert_eq!(store.stored, live.len());
    for i in 0..ROUNDS * PER_ROUND {
        let got: Vec<Neighbor> = recovered.query(doc(i), 10).unwrap();
        let want: Vec<Neighbor> = control.query(doc(i), 10).unwrap();
        assert_eq!(got, want, "query mismatch for probe {i}");
    }
    for pair in live.windows(2) {
        let got = recovered.estimate_ids(pair[0], pair[1]).unwrap();
        let want = control.estimate_ids(pair[0], pair[1]).unwrap();
        assert_eq!(got.to_bits(), want.to_bits());
    }
}

/// Free-running race: one thread ingests a fixed sequence while the
/// other compacts as fast as it can.  The persist lock serializes the
/// two writers, so whatever interleaving the scheduler picks, the
/// reopened store must contain exactly the inserted set and answer
/// queries identically to an uninterrupted control run.
#[test]
fn concurrent_inserts_race_compaction_without_loss() {
    const DOCS: u32 = 60;

    let dir = TempDir::new().unwrap();
    let svc = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 4)).unwrap();
    let start = Arc::new(Barrier::new(2));
    let done = Arc::new(std::sync::atomic::AtomicBool::new(false));

    let writer = {
        let svc = Arc::clone(&svc);
        let start = Arc::clone(&start);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            start.wait();
            let mut ids = Vec::new();
            for i in 0..DOCS {
                ids.push(svc.insert(doc(i)).unwrap().0);
            }
            done.store(true, std::sync::atomic::Ordering::Release);
            ids
        })
    };
    let compactor = {
        let svc = Arc::clone(&svc);
        let start = Arc::clone(&start);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            start.wait();
            let mut saves = 0u32;
            loop {
                svc.save().unwrap();
                saves += 1;
                if done.load(std::sync::atomic::Ordering::Acquire) {
                    break;
                }
                std::thread::yield_now();
            }
            saves
        })
    };
    let live = writer.join().expect("writer panicked");
    let saves = compactor.join().expect("compactor panicked");
    assert!(saves > 0, "compactor never ran");
    // One final compaction concurrent with nothing, so the test also
    // covers the snapshot-of-everything endpoint.
    svc.save().unwrap();
    drop(svc);

    let control = Coordinator::start(cfg_with(None, 4)).unwrap();
    let control_live: Vec<u64> = (0..DOCS)
        .map(|i| control.insert(doc(i)).unwrap().0)
        .collect();
    assert_eq!(live, control_live, "racing compactions must not skew ids");

    let recovered = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 4)).unwrap();
    let (_, store) = recovered.stats();
    assert_eq!(store.stored, DOCS as usize, "no insert may be lost");
    for i in 0..DOCS {
        let got: Vec<Neighbor> = recovered.query(doc(i), 10).unwrap();
        let want: Vec<Neighbor> = control.query(doc(i), 10).unwrap();
        assert_eq!(got, want, "query mismatch for probe {i}");
    }
}
