//! Cross-validation of the four independent Ẽ evaluation paths and the
//! two variance theorems against direct simulation — the mathematical
//! core of the reproduction.

use cminhash::sketch::{estimate, CMinHasher, Perm, Sketcher};
use cminhash::theory::{
    e_tilde, e_tilde_brute, e_tilde_enum, e_tilde_mc, var_minhash, var_sigma_pi, var_zero_pi,
    LocationVector,
};
use cminhash::util::rng::Rng;

#[test]
fn all_four_e_tilde_paths_agree_small() {
    for (d, f, a) in [(9usize, 5usize, 2usize), (10, 4, 3), (11, 7, 4), (12, 6, 1)] {
        let brute = e_tilde_brute(d, f, a);
        let runs = e_tilde(d, f, a);
        let en = e_tilde_enum(d, f, a);
        let mc = e_tilde_mc(d, f, a, 200_000, 42);
        assert!(
            (brute - runs).abs() < 1e-12,
            "runs vs brute at ({d},{f},{a}): {runs} vs {brute}"
        );
        assert!(
            (brute - en).abs() < 1e-10,
            "enum vs brute at ({d},{f},{a}): {en} vs {brute}"
        );
        assert!(
            (brute - mc).abs() < 5e-3,
            "mc vs brute at ({d},{f},{a}): {mc} vs {brute}"
        );
    }
}

#[test]
fn enum_matches_runs_at_medium_sizes() {
    for (d, f, a) in [(40usize, 12usize, 5usize), (60, 20, 10), (50, 30, 3)] {
        let runs = e_tilde(d, f, a);
        let en = e_tilde_enum(d, f, a);
        assert!(
            (runs - en).abs() < 1e-9 * runs.max(1e-12),
            "({d},{f},{a}): runs={runs} enum={en}"
        );
    }
}

/// Empirical Var[Ĵ_{σ,π}] by direct simulation of Algorithm 3.
fn empirical_var_sigma_pi(d: usize, f: usize, a: usize, k: usize, reps: usize) -> f64 {
    let x = LocationVector::contiguous(d, f, a);
    let (v, w) = x.realize();
    let mut rng = Rng::seed_from_u64(17);
    let (mut s1, mut s2) = (0.0f64, 0.0f64);
    for _ in 0..reps {
        let sigma = Perm::from_values(rng.permutation(d)).unwrap();
        let pi = Perm::from_values(rng.permutation(d)).unwrap();
        let h = CMinHasher::from_perms(k, &sigma, &pi).unwrap();
        let est = estimate(&h.sketch_sparse(v.indices()), &h.sketch_sparse(w.indices()));
        s1 += est;
        s2 += est * est;
    }
    let mean = s1 / reps as f64;
    s2 / reps as f64 - mean * mean
}

#[test]
fn theorem_3_1_matches_simulation() {
    let (d, f, a, k) = (96usize, 36usize, 12usize, 48usize);
    let theo = var_sigma_pi(d, f, a, k);
    let emp = empirical_var_sigma_pi(d, f, a, k, 40_000);
    assert!(
        (theo - emp).abs() < 0.08 * theo,
        "theory {theo} vs empirical {emp}"
    );
}

#[test]
fn estimator_is_unbiased_empirically() {
    let (d, f, a, k) = (80usize, 30usize, 10usize, 40usize);
    let x = LocationVector::contiguous(d, f, a);
    let (v, w) = x.realize();
    let mut rng = Rng::seed_from_u64(5);
    let reps = 30_000;
    let mut acc = 0.0;
    for _ in 0..reps {
        let sigma = Perm::from_values(rng.permutation(d)).unwrap();
        let pi = Perm::from_values(rng.permutation(d)).unwrap();
        let h = CMinHasher::from_perms(k, &sigma, &pi).unwrap();
        acc += estimate(&h.sketch_sparse(v.indices()), &h.sketch_sparse(w.indices()));
    }
    let mean = acc / reps as f64;
    let j = a as f64 / f as f64;
    // sd of the mean ≈ sqrt(Var/reps) ≈ 6e-4 here; 5 sigma
    assert!((mean - j).abs() < 5e-3, "mean {mean} vs J {j}");
}

#[test]
fn variance_hierarchy_on_structured_data() {
    // On the paper's structured pairs: Var_{σ,π} < Var_MH and the
    // (0,π) variance at the *contiguous* pattern differs from both
    // (location dependence, §2).
    let (d, f, a, k) = (128usize, 48usize, 16usize, 64usize);
    let j = a as f64 / f as f64;
    let x = LocationVector::contiguous(d, f, a);
    let v_mh = var_minhash(j, k);
    let v_spi = var_sigma_pi(d, f, a, k);
    let v_0pi = var_zero_pi(&x, k);
    assert!(v_spi < v_mh);
    assert!((v_0pi - v_spi).abs() > 1e-6, "0pi should be location-specific");
}

#[test]
fn variance_ratio_reproduces_paper_magnitude() {
    // Figure 5's right panel (D=1000, K=800) shows ratios well above 1
    // and growing in f.  Pin the qualitative claim and a stable value.
    let r_small_f = cminhash::theory::variance_ratio(1000, 100, 50, 800).unwrap();
    let r_big_f = cminhash::theory::variance_ratio(1000, 800, 400, 800).unwrap();
    assert!(r_small_f > 1.0);
    assert!(r_big_f > r_small_f);
    assert!(r_big_f > 1.5, "ratio at f=800 should be substantial: {r_big_f}");
}
