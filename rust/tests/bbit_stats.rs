//! Statistical acceptance suite for the b-bit corrected estimator:
//! on seeded *structured* data (contiguous index runs from the shared
//! [`overlap_pair`] generator) at J ∈ {0.1, 0.5, 0.9}, the corrected
//! Ĵ_b must be unbiased within a binomial-derived gate for
//! b ∈ {1, 2, 8}, and the empirical variance ordering
//! Var(Ĵ_1) ≥ Var(Ĵ_2) ≥ Var(Ĵ_8) ≥ Var(Ĵ_32) must hold — less kept
//! information can never *reduce* estimator variance.
//!
//! Gating style mirrors `scheme_consistency.rs`: means over many
//! seeds, tolerances derived from the estimator's own binomial
//! variance (5σ), so a pass is strong evidence of unbiasedness and a
//! fail is a real defect, not noise.  All b-widths of one trial are
//! compressed from the *same* full sketch (common random numbers), so
//! the variance comparison is paired, not independent.

use cminhash::sketch::{estimate, BBitSketch, CMinHasher, Sketcher};
use cminhash::util::testutil::overlap_pair;

/// Universe size and vector weight are chosen so the correction's
/// false-collision model actually applies: a C-MinHash slot value is
/// the *minimum* of f permutation values, concentrated on a scale of
/// ≈ D/f, and two distinct minima only collide on their low b bits
/// with probability ≈ 2⁻ᵇ when that scale is ≫ 2ᵇ.  D/f ≈ 330 here
/// keeps the residual model error an order of magnitude inside the
/// statistical gate for every tested b (at f ≈ 500 the b ≤ 2 biases
/// would sit right at 5σ — measured, not hypothetical).
const DIM: usize = 8192;
const K: usize = 64;
const TRIALS: u64 = 400;

/// The three J levels of the acceptance gate, realized as exact
/// contiguous-run pairs over the shared generator.
fn levels() -> Vec<(Vec<u32>, Vec<u32>, f64)> {
    [
        (22u32, 22u32, 4u32), // J = 4/40  = 0.1
        (30, 30, 20),         // J = 20/40 = 0.5
        (38, 38, 36),         // J = 36/40 = 0.9
    ]
    .into_iter()
    .map(|(a, b, inter)| {
        let (v, w, j) = overlap_pair(DIM as u32, a, b, inter);
        (v.indices().to_vec(), w.indices().to_vec(), j)
    })
    .collect()
}

/// Theoretical per-trial variance of the corrected estimator:
/// Var[Ĵ_b] = c(1−c) / (K (1−r)²) with c = J + (1−J)r, r = 2^{−b}.
fn var_theory(j: f64, bits: u8) -> f64 {
    let r = if bits >= 32 {
        0.0
    } else {
        1.0 / (1u64 << bits) as f64
    };
    let c = j + (1.0 - j) * r;
    c * (1.0 - c) / (K as f64 * (1.0 - r) * (1.0 - r))
}

/// Mean and (population) variance of a sample.
fn mean_var(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var)
}

/// One row of estimates per width, all trials, common random numbers:
/// `out[w][t]` is width `WIDTHS[w]`'s estimate on trial `t`.
const WIDTHS: [u8; 4] = [1, 2, 8, 32];

fn run_trials(v: &[u32], w: &[u32]) -> Vec<Vec<f64>> {
    let mut out = vec![Vec::with_capacity(TRIALS as usize); WIDTHS.len()];
    for t in 0..TRIALS {
        let h = CMinHasher::new(DIM, K, 1000 + t);
        let sv = h.sketch_sparse(v);
        let sw = h.sketch_sparse(w);
        for (row, &bits) in out.iter_mut().zip(WIDTHS.iter()) {
            let e = if bits == 32 {
                estimate(&sv, &sw)
            } else {
                BBitSketch::compress(&sv, bits).estimate(&BBitSketch::compress(&sw, bits))
            };
            row.push(e);
        }
    }
    out
}

#[test]
fn corrected_estimator_is_unbiased_within_binomial_gate() {
    for (v, w, j) in levels() {
        let trials = run_trials(&v, &w);
        for (row, &bits) in trials.iter().zip(WIDTHS.iter()) {
            let (mean, var_emp) = mean_var(row);
            // 5σ gate from the estimator's own binomial variance: a
            // systematic bias (e.g. a wrong correction constant, or a
            // packing bug favoring low lanes) trips it; noise cannot.
            let se = (var_theory(j, bits) / TRIALS as f64).sqrt();
            assert!(
                (mean - j).abs() < 5.0 * se + 1e-9,
                "b={bits} J={j}: mean {mean:.5} off by {:.5} (5σ = {:.5})",
                (mean - j).abs(),
                5.0 * se
            );
            // empirical variance must be in the ballpark of theory —
            // catches both a broken correction (inflates) and
            // accidentally-shared randomness across trials (deflates)
            let vt = var_theory(j, bits);
            assert!(
                var_emp > 0.4 * vt && var_emp < 2.5 * vt,
                "b={bits} J={j}: empirical var {var_emp:.6} vs theory {vt:.6}"
            );
        }
    }
}

#[test]
fn variance_ordering_fewer_bits_never_helps() {
    // Paired (common-random-number) empirical variances must be
    // monotone non-increasing in b.  The gaps 1→2→8 are large (≥ 1.3×
    // in theory at every tested J) and asserted strictly; 8→32 is a
    // ~1–2% theoretical gap, asserted with a small noise allowance —
    // the ordering claim, not a precision claim.
    for (v, w, j) in levels() {
        let trials = run_trials(&v, &w);
        let vars: Vec<f64> = trials.iter().map(|row| mean_var(row).1).collect();
        let (v1, v2, v8, v32) = (vars[0], vars[1], vars[2], vars[3]);
        assert!(
            v1 > v2 && v2 > v8,
            "J={j}: want Var₁ > Var₂ > Var₈, got {v1:.6} / {v2:.6} / {v8:.6}"
        );
        assert!(
            v8 >= 0.9 * v32,
            "J={j}: Var₈ {v8:.6} implausibly below Var₃₂ {v32:.6}"
        );
        // and the big-picture claim against theory: each width's
        // variance ratio to full-width tracks its prediction within 2×
        for (&var, &bits) in vars.iter().zip(WIDTHS.iter()) {
            let want = var_theory(j, bits) / var_theory(j, 32);
            let got = var / v32;
            assert!(
                got < 2.0 * want + 0.5 && got > want / 2.0 - 0.1,
                "b={bits} J={j}: var ratio {got:.3} vs theory {want:.3}"
            );
        }
    }
}
