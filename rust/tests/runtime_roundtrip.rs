//! End-to-end runtime test: load the real AOT artifacts via PJRT,
//! execute them, and compare against the pure-Rust hashers — proving
//! that L1 (Pallas) ≡ L2 (jax pipeline) ≡ L3 (Rust oracle) on the very
//! bytes the server ships.
//!
//! Requires `make artifacts`; tests self-skip when the directory is
//! absent so `cargo test` stays green on a fresh clone.

use cminhash::runtime::{EngineHandle, HostTensor, XlaEngine};
use cminhash::sketch::{estimate, CMinHasher, Perm, Role, Sketcher};
use cminhash::util::rng::Rng;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts` first");
        None
    }
}

fn make_inputs(b: usize, d: usize, seed: u64) -> (Vec<i32>, Vec<Vec<u32>>, Perm, Perm) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut bits = vec![0i32; b * d];
    let mut sparse_rows = Vec::with_capacity(b);
    for row in 0..b {
        let nnz = rng.range_usize(0, d / 8 + 2); // includes possibly-empty rows
        let mut idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, d as u32)).collect();
        idx.sort_unstable();
        idx.dedup();
        for &i in &idx {
            bits[row * d + i as usize] = 1;
        }
        sparse_rows.push(idx);
    }
    let sigma = Perm::generate(d, seed, Role::Sigma);
    let pi = Perm::generate(d, seed, Role::Pi);
    (bits, sparse_rows, sigma, pi)
}

#[test]
fn artifact_sketches_match_rust_hasher() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).expect("engine load");
    let (b, d, k) = (8usize, 1024usize, 128usize);
    let variant = "cminhash_b8_d1024_k128";
    let (bits, rows, sigma, pi) = make_inputs(b, d, 7);
    let out = engine
        .execute(
            variant,
            &[
                HostTensor::I32(bits),
                HostTensor::I32(sigma.values_i32()),
                HostTensor::I32(pi.doubled_i32()),
            ],
        )
        .expect("execute");
    let hashes = out[0].as_i32().unwrap();
    let hasher = CMinHasher::from_perms(k, &sigma, &pi).unwrap();
    for (row, idx) in rows.iter().enumerate() {
        let want = hasher.sketch_sparse(idx);
        let got: Vec<u32> = hashes[row * k..(row + 1) * k]
            .iter()
            .map(|&v| v as u32)
            .collect();
        assert_eq!(got, want, "row {row} mismatch (XLA vs Rust)");
    }
}

#[test]
fn sparse_artifact_matches_rust_hasher() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).expect("engine load");
    let (b, d, f_max, k) = (8usize, 1024usize, 128usize, 128usize);
    let variant = "cminhashs_b8_d1024_f128_k128";
    let (_bits, rows, sigma, pi) = make_inputs(b, d, 13);
    // Pack padded index rows (pad = 2D -> sentinel tail of pi3).
    let pad = 2 * d as i32;
    let mut idx = vec![pad; b * f_max];
    for (row, r) in rows.iter().enumerate() {
        for (j, &i) in r.iter().enumerate() {
            idx[row * f_max + j] = i as i32;
        }
    }
    let out = engine
        .execute(
            variant,
            &[
                HostTensor::I32(idx),
                HostTensor::I32(sigma.inverse().values_i32()),
                HostTensor::I32(pi.tripled_sentinel_i32()),
            ],
        )
        .expect("execute sparse");
    let hashes = out[0].as_i32().unwrap();
    let hasher = CMinHasher::from_perms(k, &sigma, &pi).unwrap();
    for (row, r) in rows.iter().enumerate() {
        let want = hasher.sketch_sparse(r);
        let got: Vec<u32> = hashes[row * k..(row + 1) * k]
            .iter()
            .map(|&v| v as u32)
            .collect();
        assert_eq!(got, want, "sparse row {row} mismatch (XLA vs Rust)");
    }
}

#[test]
fn estimator_artifact_matches_rust_estimate() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).expect("engine load");
    let (n, k) = (8usize, 128usize);
    let mut rng = Rng::seed_from_u64(3);
    let h1: Vec<i32> = (0..n * k).map(|_| rng.range_u32(0, 64) as i32).collect();
    let h2: Vec<i32> = (0..n * k).map(|_| rng.range_u32(0, 64) as i32).collect();
    let out = engine
        .execute(
            "estimate_n8_m8_k128",
            &[HostTensor::I32(h1.clone()), HostTensor::I32(h2.clone())],
        )
        .expect("execute");
    let jhat = out[0].as_f32().unwrap();
    for i in 0..n {
        for j in 0..n {
            let a: Vec<u32> = h1[i * k..(i + 1) * k].iter().map(|&v| v as u32).collect();
            let b: Vec<u32> = h2[j * k..(j + 1) * k].iter().map(|&v| v as u32).collect();
            let want = estimate(&a, &b) as f32;
            assert!(
                (jhat[i * n + j] - want).abs() < 1e-6,
                "estimate mismatch at ({i},{j}): {} vs {want}",
                jhat[i * n + j]
            );
        }
    }
}

#[test]
fn zero_pi_and_classic_artifacts_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).expect("engine load");
    let (b, d, k) = (8usize, 1024usize, 128usize);
    let (bits, rows, _sigma, pi) = make_inputs(b, d, 11);
    // (0, pi) ablation artifact
    let out = engine
        .execute(
            "cminhash0_b8_d1024_k128",
            &[HostTensor::I32(bits.clone()), HostTensor::I32(pi.doubled_i32())],
        )
        .expect("execute 0pi");
    let hashes = out[0].as_i32().unwrap();
    let zp = cminhash::sketch::ZeroPiHasher::from_perm(k, &pi).unwrap();
    for (row, idx) in rows.iter().enumerate() {
        let want = zp.sketch_sparse(idx);
        let got: Vec<u32> = hashes[row * k..(row + 1) * k]
            .iter()
            .map(|&v| v as u32)
            .collect();
        assert_eq!(got, want, "0pi row {row}");
    }
    // classic MinHash artifact
    let perms: Vec<Perm> = (0..k as u32)
        .map(|i| Perm::generate(d, 5, Role::Classic(i)))
        .collect();
    let mut pmat = Vec::with_capacity(k * d);
    for p in &perms {
        pmat.extend(p.values_i32());
    }
    let out = engine
        .execute(
            "minhash_b8_d1024_k128",
            &[HostTensor::I32(bits), HostTensor::I32(pmat)],
        )
        .expect("execute classic");
    let hashes = out[0].as_i32().unwrap();
    let mh = cminhash::sketch::ClassicMinHasher::from_perms(&perms).unwrap();
    for (row, idx) in rows.iter().enumerate() {
        let want = mh.sketch_sparse(idx);
        let got: Vec<u32> = hashes[row * k..(row + 1) * k]
            .iter()
            .map(|&v| v as u32)
            .collect();
        assert_eq!(got, want, "classic row {row}");
    }
}

#[test]
fn engine_handle_executes_from_other_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let handle = EngineHandle::spawn(&dir).expect("spawn");
    let (b, d, k) = (8usize, 1024usize, 128usize);
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let (bits, rows, sigma, pi) = make_inputs(b, d, 100 + t);
            let out = h
                .execute(
                    "cminhash_b8_d1024_k128",
                    vec![
                        HostTensor::I32(bits),
                        HostTensor::I32(sigma.values_i32()),
                        HostTensor::I32(pi.doubled_i32()),
                    ],
                )
                .expect("execute");
            let hashes = out[0].as_i32().unwrap();
            let hasher = CMinHasher::from_perms(k, &sigma, &pi).unwrap();
            for (row, idx) in rows.iter().enumerate() {
                let want = hasher.sketch_sparse(idx);
                let got: Vec<u32> = hashes[row * k..(row + 1) * k]
                    .iter()
                    .map(|&v| v as u32)
                    .collect();
                assert_eq!(got, want);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    handle.shutdown();
}

#[test]
fn engine_rejects_bad_shapes_and_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).expect("engine load");
    assert!(engine.execute("nonexistent", &[]).is_err());
    // wrong input count
    assert!(engine
        .execute("cminhash_b8_d1024_k128", &[HostTensor::I32(vec![0; 8])])
        .is_err());
    // wrong element count
    assert!(engine
        .execute(
            "cminhash_b8_d1024_k128",
            &[
                HostTensor::I32(vec![0; 17]),
                HostTensor::I32(vec![0; 1024]),
                HostTensor::I32(vec![0; 2048]),
            ],
        )
        .is_err());
    // wrong dtype
    assert!(engine
        .execute(
            "cminhash_b8_d1024_k128",
            &[
                HostTensor::F32(vec![0.0; 8 * 1024]),
                HostTensor::I32(vec![0; 1024]),
                HostTensor::I32(vec![0; 2048]),
            ],
        )
        .is_err());
}
