//! Fuzz-style property test for the wire protocol: random mutations
//! of valid request lines (truncations, wrong types, huge ints, bad
//! unicode escapes, garbage splices) must each yield exactly one
//! clean response line — `ok:true` if the mutation stayed valid,
//! `ok:false` otherwise — and must never panic a worker or drop the
//! connection.  This turns PR 3's `catch_unwind` containment from a
//! safety net into a tested property: the net is there, but nothing
//! in the parser should ever hit it.
//!
//! The second half applies the same treatment to `bin1` framing:
//! single-byte corruptions on a live connection must each earn exactly
//! one `R_ERR` frame (the CRC resynchronises the stream), and header
//! mutations — truncations, oversized lengths, raw garbage — must end
//! in error frames and/or a clean close, never a hung or dead worker.

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::server::frame::{op, BinRequest, BinResponse, FrameReader, FrameWriter};
use cminhash::server::protocol::Request;
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::SparseVec;
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

const DIM: u32 = 256;

fn start_server() -> (Server, Arc<Coordinator>) {
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: DIM as usize,
        num_hashes: 64,
        seed: 5,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    (server, svc)
}

/// Valid request lines covering every op — the fuzz seeds.
fn valid_lines() -> Vec<String> {
    vec![
        r#"{"op":"ping"}"#.into(),
        format!(r#"{{"op":"sketch","vec":{{"dim":{DIM},"indices":[3,17,90]}}}}"#),
        format!(r#"{{"op":"insert","vec":{{"dim":{DIM},"indices":[1,2,3]}}}}"#),
        format!(r#"{{"op":"query","vec":{{"dim":{DIM},"indices":[1,2,3]}},"topk":5}}"#),
        format!(
            r#"{{"op":"query_above","vec":{{"dim":{DIM},"indices":[4,5]}},"threshold":0.5}}"#
        ),
        format!(
            r#"{{"op":"sketch_batch","vecs":[{{"dim":{DIM},"indices":[7]}},{{"dim":{DIM},"indices":[8]}}]}}"#
        ),
        format!(r#"{{"op":"insert_batch","vecs":[{{"dim":{DIM},"indices":[9,10]}}]}}"#),
        format!(
            r#"{{"op":"query_batch","vecs":[{{"dim":{DIM},"indices":[1]}}],"topk":2}}"#
        ),
        r#"{"op":"estimate","a":0,"b":0}"#.into(),
        format!(
            r#"{{"op":"estimate_vecs","v":{{"dim":{DIM},"indices":[1]}},"w":{{"dim":{DIM},"indices":[2]}}}}"#
        ),
        r#"{"op":"delete","id":12345}"#.into(),
        r#"{"op":"save"}"#.into(),
        r#"{"op":"stats"}"#.into(),
    ]
}

/// Hand-picked adversarial lines: the classic parser killers.
fn nasty_lines() -> Vec<String> {
    let deep_open = "[".repeat(300);
    vec![
        // truncations mid-structure / mid-string
        r#"{"op":"ping""#.into(),
        r#"{"op":"pi"#.into(),
        r#"{"#.into(),
        // wrong types everywhere
        r#"{"op":42}"#.into(),
        r#"{"op":"sketch","vec":"not an object"}"#.into(),
        format!(r#"{{"op":"sketch","vec":{{"dim":{DIM},"indices":"nope"}}}}"#),
        format!(r#"{{"op":"sketch","vec":{{"dim":"{DIM}","indices":[1]}}}}"#),
        r#"{"op":"delete","id":3.5}"#.into(),
        r#"{"op":"delete","id":-1}"#.into(),
        r#"{"op":"estimate","a":"x","b":2}"#.into(),
        format!(r#"{{"op":"query","vec":{{"dim":{DIM},"indices":[0]}},"topk":"five"}}"#),
        r#"{"op":"sketch_batch","vecs":{"dim":4}}"#.into(),
        // huge / degenerate numbers
        format!(
            r#"{{"op":"query","vec":{{"dim":{DIM},"indices":[0]}},"topk":99999999999999999999999999}}"#
        ),
        r#"{"op":"sketch","vec":{"dim":1e308,"indices":[0]}}"#.into(),
        r#"{"op":"sketch","vec":{"dim":1e999,"indices":[0]}}"#.into(),
        format!(r#"{{"op":"sketch","vec":{{"dim":{DIM},"indices":[4294967296]}}}}"#),
        format!(r#"{{"op":"query","vec":{{"dim":{DIM},"indices":[0]}},"topk":-3}}"#),
        // bad unicode escapes (valid UTF-8 on the wire, broken inside)
        r#"{"op":"\ud800"}"#.into(),
        r#"{"op":"ping","x":"\uZZZZ"}"#.into(),
        r#"{"op":"ping","x":"\ud800A"}"#.into(),
        r#"{"op":"\q"}"#.into(),
        // non-object documents
        "[1,2,3]".into(),
        "null".into(),
        "true".into(),
        "\"just a string\"".into(),
        "12345".into(),
        // pathological nesting (the parser's depth cap must answer,
        // not blow the stack)
        format!(r#"{{"op":{deep_open}"#),
        format!("{}{}", "[".repeat(200), "]".repeat(200)),
        // trailing garbage
        r#"{"op":"ping"} extra"#.into(),
        r#"{"op":"ping"}{"op":"ping"}"#.into(),
    ]
}

/// Apply 1–3 random structure-agnostic mutations to a line, keeping
/// it a single non-blank line of valid UTF-8.
fn mutate(rng: &mut Rng, line: &str) -> String {
    const POOL: &[char] = &[
        '{', '}', '[', ']', '"', ':', ',', 'x', '9', '-', '.', 'e', '\\', 'u', ' ',
    ];
    let mut chars: Vec<char> = line.chars().collect();
    for _ in 0..rng.range_usize(1, 4) {
        match rng.below(4) {
            0 => {
                // truncate (keep at least one char)
                let keep = rng.range_usize(1, chars.len().max(2));
                chars.truncate(keep);
            }
            1 => {
                // replace one char
                let at = rng.range_usize(0, chars.len());
                chars[at] = POOL[rng.range_usize(0, POOL.len())];
            }
            2 => {
                // insert one char
                let at = rng.range_usize(0, chars.len() + 1);
                chars.insert(at, POOL[rng.range_usize(0, POOL.len())]);
            }
            _ => {
                // duplicate a chunk (stutter)
                let start = rng.range_usize(0, chars.len());
                let end = rng.range_usize(start, chars.len() + 1).min(start + 12);
                let chunk: Vec<char> = chars[start..end].to_vec();
                for (i, c) in chunk.into_iter().enumerate() {
                    chars.insert(start + i, c);
                }
            }
        }
        if chars.is_empty() {
            chars.push('{');
        }
    }
    let out: String = chars.into_iter().collect();
    if out.trim().is_empty() {
        "{".to_string() // blank lines are skipped by design; force a response
    } else {
        out
    }
}

#[test]
fn parser_survives_mutated_lines_in_process() {
    // The codec layer alone: no input may panic Json::parse or
    // Request::from_json; outcomes are Ok or a typed error, nothing
    // else.  (A panic fails this test directly.)
    let mut rng = Rng::seed_from_u64(0xf022);
    let seeds = valid_lines();
    for line in nasty_lines() {
        let _ = Json::parse(&line).map(|j| Request::from_json(&j));
    }
    for trial in 0..2000u64 {
        let base = &seeds[(trial % seeds.len() as u64) as usize];
        let mutated = mutate(&mut rng, base);
        let _ = Json::parse(&mutated).map(|j| Request::from_json(&j));
    }
}

#[test]
fn every_mutated_line_gets_one_response_and_the_connection_lives() {
    let (server, svc) = start_server();
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let send_and_check = |writer: &mut TcpStream,
                              reader: &mut BufReader<TcpStream>,
                              line: &str| {
        assert!(!line.contains('\n') && !line.contains('\r'), "{line:?}");
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        let n = reader.read_line(&mut resp).unwrap();
        assert!(n > 0, "connection dropped after {line:?}");
        let parsed = Json::parse(resp.trim_end())
            .unwrap_or_else(|e| panic!("non-JSON response to {line:?}: {e}"));
        parsed
            .get("ok")
            .and_then(|v| v.as_bool())
            .unwrap_or_else(|_| panic!("response to {line:?} lacks ok: {resp}"));
    };

    // the hand-picked killers first
    for line in nasty_lines() {
        send_and_check(&mut writer, &mut reader, &line);
    }

    // then seeded random mutations, with a live-ness ping every 10
    let mut rng = Rng::seed_from_u64(0xbeef);
    let seeds = valid_lines();
    for trial in 0..300u64 {
        let base = &seeds[(trial % seeds.len() as u64) as usize];
        let mutated = mutate(&mut rng, base);
        send_and_check(&mut writer, &mut reader, &mutated);
        if trial % 10 == 9 {
            writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
            let mut resp = String::new();
            assert!(reader.read_line(&mut resp).unwrap() > 0, "ping dropped");
            assert!(resp.contains("\"pong\":true"), "out of sync: {resp}");
        }
    }

    // the connection still does real work afterwards
    writer
        .write_all(
            format!(r#"{{"op":"insert","vec":{{"dim":{DIM},"indices":[1,2,3]}}}}"#)
                .as_bytes(),
        )
        .unwrap();
    writer.write_all(b"\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert!(resp.contains("\"ok\":true"), "{resp}");

    // and no worker was lost: a second connection is admitted and serves
    let stream2 = TcpStream::connect(server.addr()).unwrap();
    let mut writer2 = stream2.try_clone().unwrap();
    let mut reader2 = BufReader::new(stream2);
    writer2.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut resp2 = String::new();
    assert!(reader2.read_line(&mut resp2).unwrap() > 0);
    assert!(resp2.contains("\"pong\":true"), "{resp2}");

    let (snap, _) = svc.stats();
    assert!(snap.errors > 0, "the fuzz run must have exercised error paths");
}

// ===================================================== binary framing

/// Valid `bin1` frames covering every request op — the binary fuzz
/// seeds, as complete wire images (header + body).
fn valid_frames() -> Vec<Vec<u8>> {
    let sv = |idx: Vec<u32>| SparseVec::new(DIM, idx).unwrap();
    let reqs = vec![
        BinRequest::Ping,
        BinRequest::Sketch(sv(vec![3, 17, 90])),
        BinRequest::SketchBatch(vec![sv(vec![7]), sv(vec![8])]),
        BinRequest::InsertPacked {
            words_per_row: 2,
            rows: vec![vec![0xdead_beef, 0x0123], vec![1, 2]],
        },
        BinRequest::QueryBatch {
            vecs: vec![sv(vec![1, 2, 3])],
            topk: 5,
        },
        BinRequest::Delete(12345),
        BinRequest::Estimate(0, 1),
    ];
    reqs.iter()
        .map(|r| {
            let (op, payload) = r.encode();
            let mut wire = Vec::new();
            FrameWriter::new(&mut wire).write_frame(op, &payload).unwrap();
            wire
        })
        .collect()
}

/// Open a connection and negotiate `bin1` over the JSON hello.
fn bin_conn(server: &Server) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer
        .write_all(b"{\"op\":\"hello\",\"proto\":\"bin1\"}\n")
        .unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert!(resp.contains("\"bin1\""), "hello failed: {resp}");
    (writer, reader)
}

fn read_bin(reader: &mut BufReader<TcpStream>) -> Option<(u8, Vec<u8>)> {
    FrameReader::new(reader).read_frame().unwrap()
}

/// Pass 1: 400 single-byte corruptions (CRC field, op byte, or body)
/// with the length prefix left intact, all down ONE connection.  Each
/// must earn exactly one `R_ERR` frame and leave the stream in sync —
/// proven by a binary ping every tenth trial.
#[test]
fn corrupt_frame_bodies_each_get_one_error_frame_on_a_live_connection() {
    let (server, svc) = start_server();
    let (mut writer, mut reader) = bin_conn(&server);
    let seeds = valid_frames();
    let mut rng = Rng::seed_from_u64(0xb11);

    for trial in 0..400u64 {
        let mut frame = seeds[(trial % seeds.len() as u64) as usize].clone();
        // corrupt one byte anywhere past the length prefix: the CRC
        // (bytes 4..8), the op byte (8), or the payload (9..)
        let at = rng.range_usize(4, frame.len());
        frame[at] ^= (rng.range_u32(1, 256)) as u8;
        writer.write_all(&frame).unwrap();

        let (op_byte, payload) = read_bin(&mut reader).expect("connection died");
        assert_eq!(op_byte, op::R_ERR, "trial {trial}: wanted an error frame");
        let msg = String::from_utf8(payload).unwrap();
        assert!(
            msg.contains("checksum") || msg.contains("unknown frame op"),
            "trial {trial}: msg={msg}"
        );

        if trial % 10 == 9 {
            let (o, p) = BinRequest::Ping.encode();
            FrameWriter::new(&mut writer).write_frame(o, &p).unwrap();
            let (op_byte, payload) = read_bin(&mut reader).expect("ping died");
            let resp = BinResponse::decode(op_byte, &payload).unwrap();
            assert!(
                matches!(resp, BinResponse::Pong),
                "trial {trial}: stream out of sync: {resp:?}"
            );
        }
    }

    // the connection still does real work afterwards
    let (o, p) = BinRequest::QueryBatch {
        vecs: vec![SparseVec::new(DIM, vec![1, 2, 3]).unwrap()],
        topk: 2,
    }
    .encode();
    FrameWriter::new(&mut writer).write_frame(o, &p).unwrap();
    let (op_byte, _) = read_bin(&mut reader).unwrap();
    assert_eq!(op_byte, op::R_RESULTS);

    let (snap, _) = svc.stats();
    assert!(snap.frame_errors >= 400, "frame_errors={}", snap.frame_errors);
}

/// Pass 2: 150 header-level mutations — truncated frames, corrupted or
/// oversized length prefixes, zero lengths, raw garbage — on fresh
/// negotiated connections.  Legal outcomes are error frames and/or a
/// clean close; illegal ones are hangs, partial response frames, or a
/// poisoned worker pool (checked at the end).
#[test]
fn hostile_frame_headers_end_in_error_frames_or_a_clean_close() {
    let (server, svc) = start_server();
    let seeds = valid_frames();
    let mut rng = Rng::seed_from_u64(0xb12);

    for trial in 0..150u64 {
        let (mut writer, mut reader) = bin_conn(&server);
        let base = seeds[(trial % seeds.len() as u64) as usize].clone();
        let bytes: Vec<u8> = match rng.below(5) {
            0 => {
                // truncate mid-frame (at least one byte short)
                let keep = rng.range_usize(1, base.len());
                base[..keep].to_vec()
            }
            1 => {
                // oversized declared length, a few garbage body bytes
                let len = rng.range_u32((64 << 20) + 1, u32::MAX);
                let mut b = len.to_le_bytes().to_vec();
                b.extend_from_slice(&[0xAA; 7]);
                b
            }
            2 => {
                // zero-length frame (header full of zeros, no body)
                vec![0u8; 8]
            }
            3 => {
                // corrupt one byte of the length prefix
                let mut b = base;
                let at = rng.range_usize(0, 4);
                b[at] ^= (rng.range_u32(1, 256)) as u8;
                b
            }
            _ => {
                // raw garbage of random length
                (0..rng.range_usize(8, 64)).map(|_| rng.next_u64() as u8).collect()
            }
        };
        writer.write_all(&bytes).unwrap();
        writer.shutdown(Shutdown::Write).unwrap();

        // Drain: any complete frames the server answers must be R_ERR,
        // then the server must close (EOF) rather than hang.  A raw
        // read_to_end guards against the server emitting a torn frame.
        let mut leftover = Vec::new();
        loop {
            match FrameReader::new(&mut reader).read_frame() {
                Ok(None) => break,
                Ok(Some((op_byte, _payload))) => {
                    assert_eq!(op_byte, op::R_ERR, "trial {trial}");
                }
                Err(e) => {
                    // a torn response frame would surface here
                    panic!("trial {trial}: server sent a broken frame: {e}");
                }
            }
        }
        reader.read_to_end(&mut leftover).unwrap();
        assert!(leftover.is_empty(), "trial {trial}: bytes after EOF");
    }

    // No worker was lost to any of the 150 kills: a fresh binary
    // connection negotiates and serves...
    let mut c = BlockingClient::connect(&server.addr().to_string()).unwrap();
    c.binary().unwrap();
    c.ping().unwrap();
    let id = c.insert(DIM, vec![1, 2, 3]).unwrap();
    let hits = c.query(DIM, vec![1, 2, 3], 1).unwrap();
    assert_eq!(hits[0].id, id);
    // ...and so does a fresh JSON one.
    let mut cj = BlockingClient::connect(&server.addr().to_string()).unwrap();
    cj.ping().unwrap();

    let (snap, _) = svc.stats();
    assert!(snap.frame_errors > 0, "binary fuzz never hit the frame path");
}

// ===================================================== replicate stream

/// A durable server with a non-trivial image: a compacted snapshot
/// plus a live WAL tail — the seed every replicate mutation starts
/// from.
fn start_durable_server(dir: &std::path::Path) -> (Server, Arc<Coordinator>) {
    let mut cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: DIM as usize,
        num_hashes: 64,
        seed: 5,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    cfg.store.persist_dir = Some(dir.to_path_buf());
    let svc = Coordinator::start(cfg).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let mut c = BlockingClient::connect(&server.addr().to_string()).unwrap();
    let rows: Vec<Vec<u32>> = (0..30u32).map(|i| vec![i, i + 7, i + 31]).collect();
    c.insert_batch(DIM, rows).unwrap();
    c.call(&cminhash::server::protocol::Request::Save).unwrap();
    let tail: Vec<Vec<u32>> = (0..10u32).map(|i| vec![i + 2, i + 50]).collect();
    c.insert_batch(DIM, tail).unwrap();
    (server, svc)
}

/// Seeded mutations of a real replicate image — torn snapshot streams
/// and corrupted WAL-tail records — must each fail `replicate_apply`
/// with one clean typed error and leave the receiving store untouched:
/// still empty, and still able to join from the pristine image.
#[test]
fn mutated_replicate_images_fail_cleanly_and_leave_the_joiner_untouched() {
    let dir = cminhash::util::testutil::TempDir::new().unwrap();
    let (server, svc) = start_durable_server(dir.path());
    let (_, stats) = svc.stats();
    assert_eq!(stats.stored, 40);

    // Fetch the image over the wire, binary mode — the exact frame a
    // joining peer would receive.
    let mut c = BlockingClient::connect(&server.addr().to_string()).unwrap();
    c.binary().unwrap();
    let (snap, wal) = c.replicate().unwrap();
    assert!(snap.starts_with(b"CMHSNAP"));
    assert!(!wal.is_empty(), "the post-save tail must be in the image");

    // The joiner: a fresh in-memory node of the same shape.  It is
    // shared across every trial on purpose — any mutation that leaked
    // state would wedge all later trials (apply requires a fresh
    // store) and the final pristine join.
    let joiner = Coordinator::start(ServeConfig {
        engine: EngineKind::Rust,
        dim: DIM as usize,
        num_hashes: 64,
        seed: 5,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    })
    .unwrap();

    let untouched = |trial: &str| {
        let (_, st) = joiner.stats();
        assert_eq!(st.stored, 0, "{trial}: a failed apply must not leak state");
    };
    let expect_clean = |r: cminhash::Result<u64>, trial: String| {
        match r {
            Err(cminhash::Error::Invalid(msg)) => {
                assert!(msg.contains("replicate"), "{trial}: {msg}")
            }
            other => panic!("{trial}: wanted a typed replicate error, got {other:?}"),
        }
        untouched(&trial);
    };

    let mut rng = Rng::seed_from_u64(0xcafe);
    // torn snapshot stream: cut anywhere strictly inside the image
    for trial in 0..60u64 {
        let cut = rng.range_usize(1, snap.len());
        expect_clean(
            joiner.replicate_apply(&snap[..cut], &wal),
            format!("torn snapshot at {cut} (trial {trial})"),
        );
    }
    // corrupted snapshot byte: the image checksum must catch any flip
    for trial in 0..40u64 {
        let mut bad = snap.clone();
        let at = rng.range_usize(0, bad.len());
        bad[at] ^= (rng.range_u32(1, 256)) as u8;
        expect_clean(
            joiner.replicate_apply(&bad, &wal),
            format!("snapshot flip at {at} (trial {trial})"),
        );
    }
    // corrupted WAL-tail record: per-record CRCs must catch any flip
    for trial in 0..60u64 {
        let mut bad = wal.clone();
        let at = rng.range_usize(0, bad.len());
        bad[at] ^= (rng.range_u32(1, 256)) as u8;
        expect_clean(
            joiner.replicate_apply(&snap, &bad),
            format!("WAL flip at {at} (trial {trial})"),
        );
    }

    // The joiner survived every mutation fresh: the pristine image
    // still applies, proving no trial half-installed anything.
    assert_eq!(joiner.replicate_apply(&snap, &wal).unwrap(), 40);
}

/// Frame-layer mutations of a real `R_REPLICATE` wire image: an
/// oversized declared snapshot length (both "past the payload end" and
/// "overflows usize") and a torn payload must each decode to one
/// `Malformed` error, and the connection that produced the image must
/// stay usable.
#[test]
fn oversized_replicate_lengths_are_malformed_at_the_frame_layer() {
    use cminhash::server::frame::FrameError;

    let dir = cminhash::util::testutil::TempDir::new().unwrap();
    let (server, _svc) = start_durable_server(dir.path());
    let (mut writer, mut reader) = bin_conn(&server);

    // A real replicate exchange, at the raw frame level.
    let (o, p) = BinRequest::Replicate.encode();
    FrameWriter::new(&mut writer).write_frame(o, &p).unwrap();
    let (op_byte, payload) = read_bin(&mut reader).expect("replicate died");
    assert_eq!(op_byte, op::R_REPLICATE);
    let snap_len = match BinResponse::decode(op_byte, &payload).unwrap() {
        BinResponse::Replicate { snapshot, wal } => {
            assert!(snapshot.starts_with(b"CMHSNAP"));
            assert!(!wal.is_empty());
            snapshot.len()
        }
        other => panic!("unexpected replicate decode: {other:?}"),
    };

    // snap_len declared one byte past the payload's actual end
    let mut oversized = payload.clone();
    let declared = (payload.len() - 8 + 1) as u64;
    oversized[..8].copy_from_slice(&declared.to_le_bytes());
    match BinResponse::decode(op::R_REPLICATE, &oversized) {
        Err(FrameError::Malformed(msg)) => {
            assert!(msg.contains("ends early"), "{msg}")
        }
        other => panic!("oversized snap_len decoded as {other:?}"),
    }

    // snap_len = u64::MAX must refuse before any allocation
    let mut huge = payload.clone();
    huge[..8].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(matches!(
        BinResponse::decode(op::R_REPLICATE, &huge),
        Err(FrameError::Malformed(_))
    ));

    // torn payload: any cut before the declared snapshot stream ends
    // (inside the length prefix or inside the snapshot bytes) must
    // refuse — a shorter cut tears the u64, a longer one leaves fewer
    // snapshot bytes than declared.  Cuts past `8 + snap_len` are NOT
    // torn (the WAL tail is just "the rest"), so stay strictly below.
    let mut rng = Rng::seed_from_u64(0xd0d0);
    for trial in 0..40u64 {
        let cut = rng.range_usize(0, 8 + snap_len);
        match BinResponse::decode(op::R_REPLICATE, &payload[..cut]) {
            Err(FrameError::Malformed(_)) | Err(FrameError::Truncated) => {}
            other => panic!("trial {trial} (cut {cut}): decoded as {other:?}"),
        }
    }

    // a REPLICATE request with a non-empty payload is a protocol
    // error the server answers, not a dropped connection
    let mut frame = Vec::new();
    FrameWriter::new(&mut frame)
        .write_frame(op::REPLICATE, &[0xAA, 0xBB])
        .unwrap();
    writer.write_all(&frame).unwrap();
    let (op_byte, _) = read_bin(&mut reader).expect("connection died");
    assert_eq!(op_byte, op::R_ERR);

    // and the stream is still in sync
    let (o, p) = BinRequest::Ping.encode();
    FrameWriter::new(&mut writer).write_frame(o, &p).unwrap();
    let (op_byte, payload) = read_bin(&mut reader).unwrap();
    assert!(matches!(
        BinResponse::decode(op_byte, &payload).unwrap(),
        BinResponse::Pong
    ));
}

/// An in-memory node has no durable image to offer: `replicate` must
/// answer a clean error in both wire modes and keep the connection.
#[test]
fn replicate_against_an_in_memory_node_errors_cleanly() {
    let (server, _svc) = start_server();
    let mut c = BlockingClient::connect(&server.addr().to_string()).unwrap();
    let err = c.replicate().unwrap_err();
    assert!(err.to_string().contains("persist"), "{err}");
    c.ping().unwrap();

    let mut cb = BlockingClient::connect(&server.addr().to_string()).unwrap();
    cb.binary().unwrap();
    let err = cb.replicate().unwrap_err();
    assert!(err.to_string().contains("persist"), "{err}");
    cb.ping().unwrap();
}
