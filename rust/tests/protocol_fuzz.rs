//! Fuzz-style property test for the wire protocol: random mutations
//! of valid request lines (truncations, wrong types, huge ints, bad
//! unicode escapes, garbage splices) must each yield exactly one
//! clean response line — `ok:true` if the mutation stayed valid,
//! `ok:false` otherwise — and must never panic a worker or drop the
//! connection.  This turns PR 3's `catch_unwind` containment from a
//! safety net into a tested property: the net is there, but nothing
//! in the parser should ever hit it.

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::server::protocol::Request;
use cminhash::server::Server;
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

const DIM: u32 = 256;

fn start_server() -> (Server, Arc<Coordinator>) {
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: DIM as usize,
        num_hashes: 64,
        seed: 5,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    (server, svc)
}

/// Valid request lines covering every op — the fuzz seeds.
fn valid_lines() -> Vec<String> {
    vec![
        r#"{"op":"ping"}"#.into(),
        format!(r#"{{"op":"sketch","vec":{{"dim":{DIM},"indices":[3,17,90]}}}}"#),
        format!(r#"{{"op":"insert","vec":{{"dim":{DIM},"indices":[1,2,3]}}}}"#),
        format!(r#"{{"op":"query","vec":{{"dim":{DIM},"indices":[1,2,3]}},"topk":5}}"#),
        format!(
            r#"{{"op":"query_above","vec":{{"dim":{DIM},"indices":[4,5]}},"threshold":0.5}}"#
        ),
        format!(
            r#"{{"op":"sketch_batch","vecs":[{{"dim":{DIM},"indices":[7]}},{{"dim":{DIM},"indices":[8]}}]}}"#
        ),
        format!(r#"{{"op":"insert_batch","vecs":[{{"dim":{DIM},"indices":[9,10]}}]}}"#),
        format!(
            r#"{{"op":"query_batch","vecs":[{{"dim":{DIM},"indices":[1]}}],"topk":2}}"#
        ),
        r#"{"op":"estimate","a":0,"b":0}"#.into(),
        format!(
            r#"{{"op":"estimate_vecs","v":{{"dim":{DIM},"indices":[1]}},"w":{{"dim":{DIM},"indices":[2]}}}}"#
        ),
        r#"{"op":"delete","id":12345}"#.into(),
        r#"{"op":"save"}"#.into(),
        r#"{"op":"stats"}"#.into(),
    ]
}

/// Hand-picked adversarial lines: the classic parser killers.
fn nasty_lines() -> Vec<String> {
    let deep_open = "[".repeat(300);
    vec![
        // truncations mid-structure / mid-string
        r#"{"op":"ping""#.into(),
        r#"{"op":"pi"#.into(),
        r#"{"#.into(),
        // wrong types everywhere
        r#"{"op":42}"#.into(),
        r#"{"op":"sketch","vec":"not an object"}"#.into(),
        format!(r#"{{"op":"sketch","vec":{{"dim":{DIM},"indices":"nope"}}}}"#),
        format!(r#"{{"op":"sketch","vec":{{"dim":"{DIM}","indices":[1]}}}}"#),
        r#"{"op":"delete","id":3.5}"#.into(),
        r#"{"op":"delete","id":-1}"#.into(),
        r#"{"op":"estimate","a":"x","b":2}"#.into(),
        format!(r#"{{"op":"query","vec":{{"dim":{DIM},"indices":[0]}},"topk":"five"}}"#),
        r#"{"op":"sketch_batch","vecs":{"dim":4}}"#.into(),
        // huge / degenerate numbers
        format!(
            r#"{{"op":"query","vec":{{"dim":{DIM},"indices":[0]}},"topk":99999999999999999999999999}}"#
        ),
        r#"{"op":"sketch","vec":{"dim":1e308,"indices":[0]}}"#.into(),
        r#"{"op":"sketch","vec":{"dim":1e999,"indices":[0]}}"#.into(),
        format!(r#"{{"op":"sketch","vec":{{"dim":{DIM},"indices":[4294967296]}}}}"#),
        format!(r#"{{"op":"query","vec":{{"dim":{DIM},"indices":[0]}},"topk":-3}}"#),
        // bad unicode escapes (valid UTF-8 on the wire, broken inside)
        r#"{"op":"\ud800"}"#.into(),
        r#"{"op":"ping","x":"\uZZZZ"}"#.into(),
        r#"{"op":"ping","x":"\ud800A"}"#.into(),
        r#"{"op":"\q"}"#.into(),
        // non-object documents
        "[1,2,3]".into(),
        "null".into(),
        "true".into(),
        "\"just a string\"".into(),
        "12345".into(),
        // pathological nesting (the parser's depth cap must answer,
        // not blow the stack)
        format!(r#"{{"op":{deep_open}"#),
        format!("{}{}", "[".repeat(200), "]".repeat(200)),
        // trailing garbage
        r#"{"op":"ping"} extra"#.into(),
        r#"{"op":"ping"}{"op":"ping"}"#.into(),
    ]
}

/// Apply 1–3 random structure-agnostic mutations to a line, keeping
/// it a single non-blank line of valid UTF-8.
fn mutate(rng: &mut Rng, line: &str) -> String {
    const POOL: &[char] = &[
        '{', '}', '[', ']', '"', ':', ',', 'x', '9', '-', '.', 'e', '\\', 'u', ' ',
    ];
    let mut chars: Vec<char> = line.chars().collect();
    for _ in 0..rng.range_usize(1, 4) {
        match rng.below(4) {
            0 => {
                // truncate (keep at least one char)
                let keep = rng.range_usize(1, chars.len().max(2));
                chars.truncate(keep);
            }
            1 => {
                // replace one char
                let at = rng.range_usize(0, chars.len());
                chars[at] = POOL[rng.range_usize(0, POOL.len())];
            }
            2 => {
                // insert one char
                let at = rng.range_usize(0, chars.len() + 1);
                chars.insert(at, POOL[rng.range_usize(0, POOL.len())]);
            }
            _ => {
                // duplicate a chunk (stutter)
                let start = rng.range_usize(0, chars.len());
                let end = rng.range_usize(start, chars.len() + 1).min(start + 12);
                let chunk: Vec<char> = chars[start..end].to_vec();
                for (i, c) in chunk.into_iter().enumerate() {
                    chars.insert(start + i, c);
                }
            }
        }
        if chars.is_empty() {
            chars.push('{');
        }
    }
    let out: String = chars.into_iter().collect();
    if out.trim().is_empty() {
        "{".to_string() // blank lines are skipped by design; force a response
    } else {
        out
    }
}

#[test]
fn parser_survives_mutated_lines_in_process() {
    // The codec layer alone: no input may panic Json::parse or
    // Request::from_json; outcomes are Ok or a typed error, nothing
    // else.  (A panic fails this test directly.)
    let mut rng = Rng::seed_from_u64(0xf022);
    let seeds = valid_lines();
    for line in nasty_lines() {
        let _ = Json::parse(&line).map(|j| Request::from_json(&j));
    }
    for trial in 0..2000u64 {
        let base = &seeds[(trial % seeds.len() as u64) as usize];
        let mutated = mutate(&mut rng, base);
        let _ = Json::parse(&mutated).map(|j| Request::from_json(&j));
    }
}

#[test]
fn every_mutated_line_gets_one_response_and_the_connection_lives() {
    let (server, svc) = start_server();
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let send_and_check = |writer: &mut TcpStream,
                              reader: &mut BufReader<TcpStream>,
                              line: &str| {
        assert!(!line.contains('\n') && !line.contains('\r'), "{line:?}");
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        let n = reader.read_line(&mut resp).unwrap();
        assert!(n > 0, "connection dropped after {line:?}");
        let parsed = Json::parse(resp.trim_end())
            .unwrap_or_else(|e| panic!("non-JSON response to {line:?}: {e}"));
        parsed
            .get("ok")
            .and_then(|v| v.as_bool())
            .unwrap_or_else(|_| panic!("response to {line:?} lacks ok: {resp}"));
    };

    // the hand-picked killers first
    for line in nasty_lines() {
        send_and_check(&mut writer, &mut reader, &line);
    }

    // then seeded random mutations, with a live-ness ping every 10
    let mut rng = Rng::seed_from_u64(0xbeef);
    let seeds = valid_lines();
    for trial in 0..300u64 {
        let base = &seeds[(trial % seeds.len() as u64) as usize];
        let mutated = mutate(&mut rng, base);
        send_and_check(&mut writer, &mut reader, &mutated);
        if trial % 10 == 9 {
            writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
            let mut resp = String::new();
            assert!(reader.read_line(&mut resp).unwrap() > 0, "ping dropped");
            assert!(resp.contains("\"pong\":true"), "out of sync: {resp}");
        }
    }

    // the connection still does real work afterwards
    writer
        .write_all(
            format!(r#"{{"op":"insert","vec":{{"dim":{DIM},"indices":[1,2,3]}}}}"#)
                .as_bytes(),
        )
        .unwrap();
    writer.write_all(b"\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert!(resp.contains("\"ok\":true"), "{resp}");

    // and no worker was lost: a second connection is admitted and serves
    let stream2 = TcpStream::connect(server.addr()).unwrap();
    let mut writer2 = stream2.try_clone().unwrap();
    let mut reader2 = BufReader::new(stream2);
    writer2.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut resp2 = String::new();
    assert!(reader2.read_line(&mut resp2).unwrap() > 0);
    assert!(resp2.contains("\"pong\":true"), "{resp2}");

    let (snap, _) = svc.stats();
    assert!(snap.errors > 0, "the fuzz run must have exercised error paths");
}
