//! Golden-vector test: the pure-Rust hashers must agree **bit-for-bit**
//! with the Python oracles in `python/compile/kernels/ref.py` (which the
//! Pallas kernel itself is verified against), over the cases exported by
//! `make artifacts` into `artifacts/golden.json`.
//!
//! This closes the loop Rust ⇄ Python: same conventions, same hashes.

use cminhash::sketch::{
    CMinHasher, ClassicMinHasher, Perm, Sketcher, SparseVec, ZeroPiHasher,
};
use cminhash::util::json::Json;
use std::path::Path;

fn load_golden() -> Option<Json> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden.json");
    if !path.exists() {
        eprintln!(
            "SKIP: {} missing — run `make artifacts` first",
            path.display()
        );
        return None;
    }
    Some(Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

fn rows_to_sparse(dim: u32, bits: &Json) -> Vec<SparseVec> {
    bits.as_arr()
        .unwrap()
        .iter()
        .map(|row| {
            let dense: Vec<u32> = row.as_u32_vec().unwrap();
            let idx: Vec<u32> = dense
                .iter()
                .enumerate()
                .filter(|(_, &b)| b != 0)
                .map(|(i, _)| i as u32)
                .collect();
            SparseVec::new(dim, idx).unwrap()
        })
        .collect()
}

fn expect_matrix(j: &Json) -> Vec<Vec<u32>> {
    j.as_arr()
        .unwrap()
        .iter()
        .map(|r| r.as_u32_vec().unwrap())
        .collect()
}

#[test]
fn rust_hashers_match_python_oracles() {
    let Some(golden) = load_golden() else { return };
    let cases = golden.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 3, "golden file has too few cases");
    for (ci, case) in cases.iter().enumerate() {
        let d = case.get("d").unwrap().as_usize().unwrap();
        let k = case.get("k").unwrap().as_usize().unwrap();
        let rows = rows_to_sparse(d as u32, case.get("bits").unwrap());
        let sigma =
            Perm::from_values(case.get("sigma").unwrap().as_u32_vec().unwrap()).unwrap();
        let pi = Perm::from_values(case.get("pi").unwrap().as_u32_vec().unwrap()).unwrap();
        let perm_rows: Vec<Perm> = case
            .get("perms")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|p| Perm::from_values(p.as_u32_vec().unwrap()).unwrap())
            .collect();

        let minhash = ClassicMinHasher::from_perms(&perm_rows).unwrap();
        let zero_pi = ZeroPiHasher::from_perm(k, &pi).unwrap();
        let sigma_pi = CMinHasher::from_perms(k, &sigma, &pi).unwrap();

        let want_mh = expect_matrix(case.get("minhash").unwrap());
        let want_0pi = expect_matrix(case.get("cminhash_0pi").unwrap());
        let want_spi = expect_matrix(case.get("cminhash_sigma_pi").unwrap());

        for (ri, row) in rows.iter().enumerate() {
            assert_eq!(
                minhash.sketch_sparse(row.indices()),
                want_mh[ri],
                "minhash mismatch case {ci} row {ri}"
            );
            assert_eq!(
                zero_pi.sketch_sparse(row.indices()),
                want_0pi[ri],
                "cminhash-(0,pi) mismatch case {ci} row {ri}"
            );
            assert_eq!(
                sigma_pi.sketch_sparse(row.indices()),
                want_spi[ri],
                "cminhash-(sigma,pi) mismatch case {ci} row {ri}"
            );
        }
    }
}
