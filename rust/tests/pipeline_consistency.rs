//! Full three-layer consistency: XLA-engine-backed coordinator behind
//! the TCP server must produce the exact sketches the pure-Rust hasher
//! computes with the same seed — i.e. L1 (Pallas HLO) == L3 (Rust)
//! through the complete serving stack, batcher and all.
//!
//! Self-skips without artifacts.

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::{CMinHasher, Sketcher};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts` first");
        None
    }
}

#[test]
fn xla_serving_stack_matches_rust_hasher() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServeConfig {
        engine: EngineKind::Xla,
        artifacts_dir: dir,
        dim: 1024,
        num_hashes: 128,
        seed: 31,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 500,
            policy: BatchPolicy::Deadline,
        },
        index: IndexSettings {
            bands: 32,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg.clone()).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let oracle = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);

    // Concurrent clients force real batching through the XLA engine.
    let mut joins = Vec::new();
    for t in 0..6u32 {
        let addr = addr.clone();
        let want = oracle.sketch_sparse(&[t, t * 7 + 3, 500 + t, 1023 - t]);
        joins.push(std::thread::spawn(move || {
            let mut c = BlockingClient::connect(&addr).unwrap();
            for _ in 0..5 {
                let got = c
                    .sketch(1024, vec![t, t * 7 + 3, 500 + t, 1023 - t])
                    .unwrap();
                assert_eq!(got, want, "XLA stack != Rust oracle");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    // Batching actually happened (fewer batches than requests).
    let (snap, _) = svc.stats();
    assert_eq!(snap.sketches, 30);
    assert!(
        snap.batches < 30,
        "expected coalescing, got {} batches for 30 requests",
        snap.batches
    );

    // Empty vectors are rejected at the boundary (their sentinel
    // sketch would estimate Ĵ = 1.0 against every other empty vector).
    let mut c = BlockingClient::connect(&addr).unwrap();
    match c.sketch(1024, vec![]) {
        Err(cminhash::Error::Protocol(msg)) => assert!(msg.contains("empty vector"), "{msg}"),
        other => panic!("empty vector must be rejected, got {other:?}"),
    }

    // insert + query through the XLA path.
    let doc: Vec<u32> = (100..200).collect();
    let id = c.insert(1024, doc.clone()).unwrap();
    let hits = c.query(1024, doc.clone(), 3).unwrap();
    assert_eq!(hits[0].id, id);
    assert_eq!(hits[0].score, 1.0);

    // batch wire ops through the XLA engine match the oracle too.
    let rows: Vec<Vec<u32>> = (0..5u32).map(|t| vec![t, t * 3 + 7, 900 + t]).collect();
    let sks = c.sketch_batch(1024, rows.clone()).unwrap();
    for (row, sk) in rows.iter().zip(&sks) {
        assert_eq!(*sk, oracle.sketch_sparse(row), "batched XLA != oracle");
    }
    let results = c.query_batch(1024, vec![doc], 3).unwrap();
    assert_eq!(results[0][0].id, id);
}

#[test]
fn heavy_rows_fall_back_to_dense_artifact() {
    // D=1024 has a sparse variant with F_max=128; a row with more
    // nonzeros must route to the dense artifact and stay bit-exact.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServeConfig {
        engine: EngineKind::Xla,
        artifacts_dir: dir,
        dim: 1024,
        num_hashes: 128,
        seed: 77,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 200,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 32,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg.clone()).unwrap();
    let oracle = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);

    // light row -> sparse path
    let light: Vec<u32> = (0..50).collect();
    let got = svc
        .sketch(cminhash::sketch::SparseVec::new(1024, light.clone()).unwrap())
        .unwrap();
    assert_eq!(got, oracle.sketch_sparse(&light));

    // heavy row (600 > F_max=128) -> dense fallback
    let heavy: Vec<u32> = (0..600).collect();
    let got = svc
        .sketch(cminhash::sketch::SparseVec::new(1024, heavy.clone()).unwrap())
        .unwrap();
    assert_eq!(got, oracle.sketch_sparse(&heavy));

    let (snap, _) = svc.stats();
    assert!(snap.sparse_batches >= 1, "light row should use sparse path");
    assert!(
        snap.batches > snap.sparse_batches,
        "heavy row should use the dense path"
    );
}
