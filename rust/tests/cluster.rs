//! Deterministic fault-injection suite for the cluster plane.
//!
//! Every test spins up real in-process servers on ephemeral ports and
//! drives them through [`ClusterClient`]; faulty members are simulated
//! with bare [`TcpListener`] threads that accept a connection and then
//! misbehave on cue — close mid-query (dead node), go silent past the
//! read timeout (stalled node), or truncate a replicate response
//! mid-stream.  Nothing here sleeps to "wait for" anything except the
//! stall itself; all routing, merging and degradation outcomes are
//! pure functions of (node ids, row contents), so each assertion is
//! exact, not probabilistic.

use cminhash::config::{
    BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig,
};
use cminhash::coordinator::Coordinator;
use cminhash::server::protocol::{Request, Response};
use cminhash::server::{BlockingClient, ClusterClient, ClusterConfig, ClusterNode, Server};
use cminhash::store::{SNAPSHOT_FILE, WAL_FILE};
use cminhash::util::rng::Rng;
use cminhash::util::testutil::TempDir;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

const DIM: usize = 256;
const K: usize = 64;

/// All nodes share one seed so their hashers agree lane for lane —
/// a row inserted on any node scores identically everywhere, which is
/// what makes the single-node reference comparisons exact.
fn cfg(persist: Option<PathBuf>) -> ServeConfig {
    let mut c = ServeConfig {
        engine: EngineKind::Rust,
        dim: DIM,
        num_hashes: K,
        seed: 5,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    c.store.shards = 2;
    c.store.persist_dir = persist;
    c
}

fn node(persist: Option<PathBuf>) -> (Arc<Coordinator>, Server) {
    let svc = Coordinator::start(cfg(persist)).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    (svc, server)
}

fn topology(members: &[(&str, String)], timeout_ms: u64) -> ClusterConfig {
    ClusterConfig {
        timeout_ms,
        nodes: members
            .iter()
            .map(|(id, addr)| ClusterNode {
                id: (*id).to_string(),
                addr: addr.clone(),
            })
            .collect(),
    }
}

fn rows(n: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let mut idx: Vec<u32> =
                (0..24).map(|_| rng.range_u32(0, DIM as u32)).collect();
            idx.sort_unstable();
            idx.dedup();
            idx
        })
        .collect()
}

/// A member that dies mid-query: accepts each connection, reads the
/// request line (so the client's write succeeds and the kill lands
/// after the query was sent), then closes without answering.  Loops
/// forever so redials find the same corpse.
fn dead_node() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let mut line = String::new();
            let _ = BufReader::new(stream).read_line(&mut line);
            // stream drops here: EOF mid-query on the client side
        }
    });
    addr
}

/// A member that stalls: accepts, reads the request line, then holds
/// the socket silently for `hold` — long past any test timeout — so
/// the client's read-timeout path is what fires, not EOF.
fn stalled_node(hold: Duration) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let mut line = String::new();
            let _ = BufReader::new(&stream).read_line(&mut line);
            std::thread::sleep(hold);
        }
    });
    addr
}

/// A peer that tears the replicate transfer: accepts, reads the
/// request line, writes the first `cut` bytes of `response_line` (no
/// newline ever arrives), then closes — a peer crash mid-snapshot
/// stream as the joiner sees it.
fn torn_replicate_peer(response_line: String, cut: usize) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let mut reader = BufReader::new(stream);
            let mut line = String::new();
            let _ = reader.read_line(&mut line);
            let _ = reader
                .get_mut()
                .write_all(&response_line.as_bytes()[..cut]);
        }
    });
    addr
}

#[test]
fn one_node_cluster_matches_single_node_exactly() {
    let (_svc_ref, srv_ref) = node(None);
    let (_svc_solo, srv_solo) = node(None);

    let corpus = rows(60, 11);
    let mut direct = BlockingClient::connect(&srv_ref.addr().to_string()).unwrap();
    let direct_ids = direct
        .insert_batch(DIM as u32, corpus.clone())
        .unwrap();

    let topo = topology(&[("solo", srv_solo.addr().to_string())], 2_000);
    let mut cluster = ClusterClient::connect(topo).unwrap();
    let out = cluster.insert_batch(DIM as u32, corpus.clone()).unwrap();
    assert!(!out.degraded);
    assert!(out.failed_nodes.is_empty());
    assert_eq!(out.inserted, 60);
    // One node owns everything, batches preserve slot order, and both
    // stores started from id 0 — so the assigned ids line up exactly.
    for (slot, got) in out.ids.iter().enumerate() {
        let (node_id, row_id) = got.as_ref().unwrap();
        assert_eq!(node_id, "solo");
        assert_eq!(*row_id, direct_ids[slot], "slot {slot}");
    }

    // Every query answer is identical: same ids, same scores, same
    // order — the cluster total order degenerates to sort_neighbors.
    for probe in rows(10, 77) {
        let reference = direct
            .query_batch(DIM as u32, vec![probe.clone()], 8)
            .unwrap()
            .remove(0);
        let (merged, degraded, failed) =
            cluster.query(DIM as u32, probe, 8).unwrap();
        assert!(!degraded);
        assert!(failed.is_empty());
        assert_eq!(merged.len(), reference.len());
        for (m, r) in merged.iter().zip(&reference) {
            assert_eq!(m.node, "solo");
            assert_eq!(m.id, r.id);
            assert_eq!(m.score, r.score, "scores must be bit-identical");
        }
    }
    assert_eq!(cluster.metrics().node_errors.load(Ordering::Relaxed), 0);
}

#[test]
fn fan_out_merge_matches_single_node_reference() {
    let members: Vec<(Arc<Coordinator>, Server)> =
        (0..3).map(|_| node(None)).collect();
    let (svc_ref, srv_ref) = node(None);

    let topo = topology(
        &[
            ("n0", members[0].1.addr().to_string()),
            ("n1", members[1].1.addr().to_string()),
            ("n2", members[2].1.addr().to_string()),
        ],
        2_000,
    );
    let mut cluster = ClusterClient::connect(topo).unwrap();

    let corpus = rows(300, 21);
    let out = cluster.insert_batch(DIM as u32, corpus.clone()).unwrap();
    assert!(!out.degraded);
    assert_eq!(out.inserted, 300);
    // The reported owner must agree with the router's own answer.
    for (slot, got) in out.ids.iter().enumerate() {
        let owner = cluster.route(DIM as u32, &corpus[slot]).unwrap();
        assert_eq!(got.as_ref().unwrap().0, cluster.node_id(owner));
    }
    // Rendezvous routing must actually spread the corpus.
    let mut total = 0usize;
    for (i, (svc, _)) in members.iter().enumerate() {
        let (_, store) = svc.stats();
        assert!(store.stored > 0, "node {i} received no rows");
        total += store.stored;
    }
    assert_eq!(total, 300, "every row has exactly one owner");

    // Same corpus on one reference node (same seed = same scores).
    let mut direct = BlockingClient::connect(&srv_ref.addr().to_string()).unwrap();
    direct.insert_batch(DIM as u32, corpus).unwrap();
    let (_, store) = svc_ref.stats();
    assert_eq!(store.stored, 300);

    // Per-node top-k lists always cover the global top-k, so the
    // merged score sequence equals the single-node score sequence.
    for probe in rows(20, 99) {
        let reference = direct
            .query_batch(DIM as u32, vec![probe.clone()], 10)
            .unwrap()
            .remove(0);
        let (merged, degraded, _) =
            cluster.query(DIM as u32, probe.clone(), 10).unwrap();
        assert!(!degraded);
        let merged_scores: Vec<f64> = merged.iter().map(|n| n.score).collect();
        let ref_scores: Vec<f64> = reference.iter().map(|n| n.score).collect();
        assert_eq!(merged_scores, ref_scores);
        // And the merge itself is deterministic: ask again, get the
        // exact same list (nodes, ids and all).
        let (again, _, _) = cluster.query(DIM as u32, probe, 10).unwrap();
        assert_eq!(again, merged);
    }
    assert_eq!(cluster.metrics().node_errors.load(Ordering::Relaxed), 0);
}

#[test]
fn dead_node_mid_query_degrades_and_survivors_answer() {
    let (_svc0, srv0) = node(None);
    let (_svc1, srv1) = node(None);
    let ghost = dead_node();

    let live_topo = topology(
        &[
            ("n0", srv0.addr().to_string()),
            ("n1", srv1.addr().to_string()),
        ],
        2_000,
    );
    let full_topo = topology(
        &[
            ("n0", srv0.addr().to_string()),
            ("n1", srv1.addr().to_string()),
            ("ghost", ghost),
        ],
        2_000,
    );

    let corpus = rows(200, 42);
    let mut cluster = ClusterClient::connect(full_topo).unwrap();
    let out = cluster.insert_batch(DIM as u32, corpus.clone()).unwrap();
    assert!(out.degraded, "ghost owns part of a 200-row corpus");
    assert_eq!(out.failed_nodes, vec!["ghost".to_string()]);
    assert!(out.inserted > 0, "live nodes must still ingest their rows");
    assert!((out.inserted as usize) < 200, "ghost's rows were skipped");
    // Exactly the ghost-routed slots are unfilled.
    for (slot, got) in out.ids.iter().enumerate() {
        let owner = cluster.route(DIM as u32, &corpus[slot]).unwrap();
        if cluster.node_id(owner) == "ghost" {
            assert!(got.is_none(), "slot {slot} owned by the dead node");
        } else {
            assert_eq!(got.as_ref().unwrap().0, cluster.node_id(owner));
        }
    }
    let errs_after_insert = cluster.metrics().node_errors.load(Ordering::Relaxed);
    assert!(errs_after_insert >= 1);

    // A parallel 2-node cluster over only the live members is the
    // ground truth for what a degraded merge must return.
    let mut live = ClusterClient::connect(live_topo).unwrap();
    for probe in rows(10, 7) {
        let (merged, degraded, failed) =
            cluster.query(DIM as u32, probe.clone(), 10).unwrap();
        assert!(degraded);
        assert_eq!(failed, vec!["ghost".to_string()]);
        assert!(merged.iter().all(|n| n.node == "n0" || n.node == "n1"));
        let (expect, live_degraded, _) =
            live.query(DIM as u32, probe, 10).unwrap();
        assert!(!live_degraded);
        assert_eq!(merged, expect, "merge must cover exactly the survivors");
    }
    // Each degraded fan-out redialed the corpse and failed again.
    assert!(
        cluster.metrics().node_errors.load(Ordering::Relaxed)
            >= errs_after_insert + 10
    );
}

#[test]
fn stalled_node_times_out_and_cluster_stays_responsive() {
    let (_svc0, srv0) = node(None);
    let (_svc1, srv1) = node(None);
    let stall = stalled_node(Duration::from_secs(20));

    let live_topo = topology(
        &[
            ("n0", srv0.addr().to_string()),
            ("n1", srv1.addr().to_string()),
        ],
        2_000,
    );
    // Load through the live pair first so the stalled member's only
    // role is to stall queries.
    let mut live = ClusterClient::connect(live_topo).unwrap();
    let out = live.insert_batch(DIM as u32, rows(120, 63)).unwrap();
    assert!(!out.degraded);
    assert_eq!(out.inserted, 120);

    let full_topo = topology(
        &[
            ("n0", srv0.addr().to_string()),
            ("n1", srv1.addr().to_string()),
            ("stall", stall),
        ],
        250,
    );
    let mut cluster = ClusterClient::connect(full_topo).unwrap();
    let t0 = Instant::now();
    let (merged, degraded, failed) = cluster
        .query(DIM as u32, rows(1, 8)[0].clone(), 10)
        .unwrap();
    let elapsed = t0.elapsed();
    assert!(degraded);
    assert_eq!(failed, vec!["stall".to_string()]);
    assert!(!merged.is_empty());
    assert!(
        elapsed < Duration::from_secs(5),
        "stall must cost one read timeout (~250ms), not the 20s hold; \
         took {elapsed:?}"
    );
    let (expect, _, _) = live
        .query(DIM as u32, rows(1, 8)[0].clone(), 10)
        .unwrap();
    assert_eq!(merged, expect);

    // The timed-out connection was dropped; the next call redials,
    // times out again, and degrades again — no wedged state.
    let (_, degraded, failed) = cluster
        .query(DIM as u32, rows(1, 9)[0].clone(), 10)
        .unwrap();
    assert!(degraded);
    assert_eq!(failed, vec!["stall".to_string()]);
    assert_eq!(cluster.metrics().node_errors.load(Ordering::Relaxed), 2);
}

#[test]
fn replicate_rejoin_is_byte_identical() {
    let dir_a = TempDir::new().unwrap();
    let dir_b = TempDir::new().unwrap();

    // Seed node A with a snapshot AND a live WAL tail: insert, save
    // (compaction), insert more, delete one — so the export exercises
    // both streams, not just the snapshot.
    let (svc_a, srv_a) = node(Some(dir_a.path().to_path_buf()));
    let mut client = BlockingClient::connect(&srv_a.addr().to_string()).unwrap();
    let ids = client.insert_batch(DIM as u32, rows(40, 3)).unwrap();
    match client.call(&Request::Save).unwrap() {
        Response::Saved { persisted_bytes } => assert!(persisted_bytes > 0),
        other => panic!("unexpected save response {other:?}"),
    }
    client.insert_batch(DIM as u32, rows(15, 4)).unwrap();
    client.delete(ids[0]).unwrap();
    let (_, stats_a) = svc_a.stats();
    assert_eq!(stats_a.stored, 54);

    // Export over the wire in both modes — the bytes must agree.
    let (snap, wal) = client.replicate().unwrap();
    assert!(snap.starts_with(b"CMHSNAP"), "snapshot ships verbatim");
    assert!(!wal.is_empty(), "the post-save tail must be in the image");
    let mut bin = BlockingClient::connect(&srv_a.addr().to_string()).unwrap();
    bin.binary().unwrap();
    assert_eq!(bin.replicate().unwrap(), (snap.clone(), wal.clone()));

    // ClusterClient path reaches the same image.
    let topo = topology(&[("a", srv_a.addr().to_string())], 2_000);
    let mut cc = ClusterClient::connect(topo).unwrap();
    assert_eq!(cc.replicate_from(0).unwrap(), (snap.clone(), wal.clone()));

    // A fresh durable node joins from the image; its on-disk pair must
    // be byte-identical to the peer's export, and its answers equal.
    {
        let (svc_b, _srv_b) = node(Some(dir_b.path().to_path_buf()));
        assert_eq!(svc_b.replicate_apply(&snap, &wal).unwrap(), 54);
        assert_eq!(std::fs::read(dir_b.path().join(SNAPSHOT_FILE)).unwrap(), snap);
        assert_eq!(std::fs::read(dir_b.path().join(WAL_FILE)).unwrap(), wal);
        assert_eq!(svc_b.replicate_export().unwrap(), (snap.clone(), wal.clone()));
        for probe in rows(8, 70) {
            let v = cminhash::sketch::SparseVec::new(DIM as u32, probe).unwrap();
            let a = svc_a.query(v.clone(), 10).unwrap();
            let b = svc_b.query(v, 10).unwrap();
            assert_eq!(a, b, "joined node must answer like its peer");
        }
        // A second apply must refuse: joining is a bootstrap, not a merge.
        assert!(svc_b.replicate_apply(&snap, &wal).is_err());
    }

    // The joined image is durable: a restart from B's directory
    // recovers the same corpus.
    let recovered = Coordinator::start(cfg(Some(dir_b.path().to_path_buf()))).unwrap();
    let (_, stats_b) = recovered.stats();
    assert_eq!(stats_b.stored, 54);
}

#[test]
fn replicate_killed_mid_transfer_leaves_joiner_untouched() {
    let dir_a = TempDir::new().unwrap();
    let (svc_a, srv_a) = node(Some(dir_a.path().to_path_buf()));
    let mut client = BlockingClient::connect(&srv_a.addr().to_string()).unwrap();
    client.insert_batch(DIM as u32, rows(30, 5)).unwrap();
    match client.call(&Request::Save).unwrap() {
        Response::Saved { .. } => {}
        other => panic!("unexpected save response {other:?}"),
    }
    client.insert_batch(DIM as u32, rows(10, 6)).unwrap();

    // Build the exact line a healthy peer would send, then a peer that
    // dies after shipping half of it.
    let (snap, wal) = svc_a.replicate_export().unwrap();
    let line = {
        let mut l = Response::Replicate {
            snapshot: snap.clone(),
            wal: wal.clone(),
        }
        .to_json()
        .to_string();
        l.push('\n');
        l
    };
    let torn = torn_replicate_peer(line.clone(), line.len() / 2);

    let dir_b = TempDir::new().unwrap();
    let (svc_b, _srv_b) = node(Some(dir_b.path().to_path_buf()));

    // Direct fetch from the torn peer: one clean error, nothing applied.
    let mut join = BlockingClient::connect(&torn).unwrap();
    join.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert!(join.replicate().is_err(), "half a response line cannot parse");

    // Via the cluster client the fault lands in node_errors too.
    let topo = topology(
        &[("torn", torn), ("a", srv_a.addr().to_string())],
        5_000,
    );
    let mut cc = ClusterClient::connect(topo).unwrap();
    assert!(cc.replicate_from(0).is_err());
    assert_eq!(cc.metrics().node_errors.load(Ordering::Relaxed), 1);

    // The joiner is still fresh: empty store, and the retry against
    // the healthy peer succeeds from the same state.
    let (_, stats_b) = svc_b.stats();
    assert_eq!(stats_b.stored, 0, "a torn transfer must not leak state");
    let (snap2, wal2) = cc.replicate_from(1).unwrap();
    assert_eq!((snap2.clone(), wal2.clone()), (snap, wal));
    assert_eq!(svc_b.replicate_apply(&snap2, &wal2).unwrap(), 40);
    assert_eq!(cc.metrics().node_errors.load(Ordering::Relaxed), 1);
}
