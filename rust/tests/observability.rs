//! End-to-end observability-plane tests: metrics correctness under
//! concurrency, exact bucket boundaries, the Prometheus text ↔ JSON
//! `stats` consistency contract, and the `trace` op over both wire
//! dialects (JSON lines and `bin1`), including slow-trace pinning.

use cminhash::config::{
    BatchConfig, BatchPolicy, EngineKind, IndexSettings, ObsSettings, ServeConfig,
    StoreSettings,
};
use cminhash::coordinator::Coordinator;
use cminhash::metrics::{LatencyHistogram, LatencySnapshot, BUCKETS};
use cminhash::server::protocol::Request;
use cminhash::server::{BlockingClient, Server};
use cminhash::util::json::Json;
use std::collections::HashMap;
use std::sync::Arc;

fn start_server_with_obs(obs: ObsSettings) -> (Server, Arc<Coordinator>) {
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: 512,
        num_hashes: 64,
        seed: 9,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        obs,
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    (server, svc)
}

fn start_server() -> (Server, Arc<Coordinator>) {
    start_server_with_obs(ObsSettings::default())
}

// ---- metrics correctness --------------------------------------------

#[test]
fn concurrent_records_sum_exactly() {
    let h = Arc::new(LatencyHistogram::default());
    let threads = 8usize;
    let per_thread = 10_000u64;
    let mut joins = Vec::new();
    for t in 0..threads {
        let h = h.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..per_thread {
                // deterministic spread over several buckets
                h.record((t as u64 * per_thread + i) % 5_000);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let snap = LatencySnapshot::from(&*h);
    let n = threads as u64 * per_thread;
    assert_eq!(snap.count, n, "no lost increments under contention");
    let expected_sum: u64 = (0..threads as u64)
        .flat_map(|t| (0..per_thread).map(move |i| (t * per_thread + i) % 5_000))
        .sum();
    assert_eq!(snap.sum_us, expected_sum, "sum_us must be exact, not sampled");
    assert_eq!(snap.buckets.iter().sum::<u64>(), n, "buckets partition the count");
}

#[test]
fn bucket_boundaries_are_exact() {
    // us = 0 clamps to 1 -> bucket 0; us = 2^k lands exactly in bucket
    // k (bucket i covers [2^i, 2^(i+1)) µs); beyond the table both
    // land in the last bucket.
    for k in 0..BUCKETS {
        let h = LatencyHistogram::default();
        h.record(1u64 << k);
        let snap = LatencySnapshot::from(&h);
        assert_eq!(snap.buckets[k], 1, "2^{k} must land in bucket {k}");
        assert_eq!(snap.buckets.iter().sum::<u64>(), 1);
    }
    let h = LatencyHistogram::default();
    h.record(0);
    assert_eq!(LatencySnapshot::from(&h).buckets[0], 1, "0 µs -> bucket 0");
    let h = LatencyHistogram::default();
    h.record(u64::MAX);
    assert_eq!(
        LatencySnapshot::from(&h).buckets[BUCKETS - 1],
        1,
        "overflow clamps to the last bucket"
    );
    // one observation just below a boundary stays in the lower bucket
    let h = LatencyHistogram::default();
    h.record((1u64 << 10) - 1);
    assert_eq!(LatencySnapshot::from(&h).buckets[9], 1);
}

#[test]
fn quantiles_never_exceed_the_observed_max() {
    // Regression: a quantile read from a log2 bucket's upper edge used
    // to exceed the largest recorded value (bucket [65536,131072)
    // reported 131072 for a 100000 µs observation).
    let h = LatencyHistogram::default();
    h.record(100_000);
    let snap = LatencySnapshot::from(&h);
    assert_eq!(snap.max_us, 100_000);
    assert!(
        snap.p50_us <= snap.max_us && snap.p99_us <= snap.max_us,
        "quantiles clamp to max: p50={} p99={} max={}",
        snap.p50_us,
        snap.p99_us,
        snap.max_us
    );
}

// ---- Prometheus ↔ JSON stats consistency ----------------------------

/// Parse exposition text into `series{labels} -> value`, skipping
/// comments.  Keys keep their label block verbatim.
fn parse_prom(text: &str) -> HashMap<String, f64> {
    let mut out = HashMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect(line);
        out.insert(series.to_string(), value.parse::<f64>().expect(line));
    }
    out
}

#[test]
fn prom_text_matches_json_stats_field_for_field() {
    let (server, _svc) = start_server();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();

    // traffic touching every counter family
    let a: Vec<u32> = (0..60).collect();
    let b: Vec<u32> = (30..90).collect();
    let ia = c.insert(512, a.clone()).unwrap();
    let ib = c.insert(512, b.clone()).unwrap();
    let _ = c.sketch(512, vec![1, 2, 3]).unwrap();
    let _ = c.query(512, a.clone(), 5).unwrap();
    match c.call(&Request::Estimate { a: ia, b: ib }).unwrap() {
        cminhash::server::protocol::Response::Estimate { .. } => {}
        other => panic!("{other:?}"),
    }
    c.delete(ib).unwrap();

    let json = c.call_raw(&Request::Stats).unwrap();
    let prom = parse_prom(&c.metrics_text().unwrap());
    let m = json.get("metrics").unwrap();
    let num = |j: &Json, k: &str| j.get(k).unwrap().as_f64().unwrap();

    // scalar counters mirror exactly
    for (series, field) in [
        ("cminhash_sketches_total", "sketches"),
        ("cminhash_batches_total", "batches"),
        ("cminhash_sparse_batches_total", "sparse_batches"),
        ("cminhash_pad_rows_total", "pad_rows"),
        ("cminhash_queries_total", "queries"),
        ("cminhash_estimates_total", "estimates"),
        ("cminhash_deletes_total", "deletes"),
        ("cminhash_errors_total", "errors"),
        ("cminhash_frame_errors_total", "frame_errors"),
        ("cminhash_busy_rejections_total", "busy_rejections"),
        ("cminhash_accept_errors_total", "accept_errors"),
        ("cminhash_mean_batch_fill", "mean_batch_fill"),
    ] {
        assert_eq!(prom[series], num(m, field), "{series} vs metrics.{field}");
    }

    // latency histograms: count, sum, and every cumulative bucket
    // (fsync_latency lives at the stats top level, not under metrics)
    for (parent, series, field) in [
        (m, "cminhash_sketch_latency_us", "sketch_latency"),
        (m, "cminhash_batch_latency_us", "batch_latency"),
        (m, "cminhash_query_latency_us", "query_latency"),
        (m, "cminhash_estimate_latency_us", "estimate_latency"),
        (&json, "cminhash_fsync_latency_us", "fsync_latency"),
    ] {
        let h = parent.get(field).unwrap();
        assert_eq!(prom[&format!("{series}_count")], num(h, "count"), "{series}");
        assert_eq!(prom[&format!("{series}_sum")], num(h, "sum_us"), "{series}");
        let buckets = h.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), BUCKETS, "stats exports the raw bucket table");
        let mut acc = 0.0;
        for (i, bj) in buckets.iter().enumerate() {
            acc += bj.as_f64().unwrap();
            let le = 1u128 << (i + 1);
            let key = format!("{series}_bucket{{le=\"{le}\"}}");
            assert_eq!(prom[&key], acc, "{key}");
        }
        assert_eq!(prom[&format!("{series}_bucket{{le=\"+Inf\"}}")], num(h, "count"));
    }

    // store gauges + per-shard counters mirror exactly
    assert_eq!(prom["cminhash_stored_items"], num(&json, "stored"));
    assert_eq!(prom["cminhash_candidates_scored_total"], num(&json, "candidates"));
    assert_eq!(prom["cminhash_band_buckets"], num(&json, "band_buckets"));
    assert_eq!(prom["cminhash_band_max_bucket"], num(&json, "band_max_bucket"));
    assert_eq!(prom["cminhash_persisted_bytes"], num(&json, "persisted_bytes"));
    assert_eq!(
        prom["cminhash_wal_appended_bytes_total"],
        num(&json, "wal_appended_bytes")
    );
    assert_eq!(prom["cminhash_sketch_bytes"], num(&json, "sketch_bytes"));
    let shards = json.get("shards").unwrap().as_arr().unwrap();
    assert!(!shards.is_empty());
    for (i, sj) in shards.iter().enumerate() {
        let key = format!("cminhash_shard_items{{shard=\"{i}\"}}");
        assert_eq!(prom[&key], sj.as_f64().unwrap(), "{key}");
    }
    let stored: f64 = shards.iter().map(|sj| sj.as_f64().unwrap()).sum();
    assert_eq!(stored, num(&json, "stored"), "shards partition the store");
    let shard_ops = json.get("shard_ops").unwrap().as_arr().unwrap();
    assert!(!shard_ops.is_empty());
    for (i, so) in shard_ops.iter().enumerate() {
        for kind in ["insert", "delete", "query"] {
            let key = format!("cminhash_shard_ops_total{{shard=\"{i}\",kind=\"{kind}\"}}");
            let field = match kind {
                "insert" => "inserts",
                "delete" => "deletes",
                _ => "queries",
            };
            assert_eq!(prom[&key], num(so, field), "{key}");
        }
    }
    // shard insert counters must account for every insert (one was
    // deleted but the insert still happened)
    let ins: f64 = shard_ops.iter().map(|so| num(so, "inserts")).sum();
    assert_eq!(ins, 2.0);
    let del: f64 = shard_ops.iter().map(|so| num(so, "deletes")).sum();
    assert_eq!(del, 1.0);

    // per-op request counters: ops untouched by the two stats fetches
    // themselves mirror exactly; the fetch ops only grow
    let requests = json.get("requests").unwrap();
    for op in ["insert", "sketch", "query", "estimate", "delete", "ping"] {
        let key = format!("cminhash_requests_total{{op=\"{op}\"}}");
        assert_eq!(prom[&key], num(requests, op), "{key}");
    }
    assert!(prom["cminhash_requests_total{op=\"stats\"}"] >= num(requests, "stats"));
    assert!(prom["cminhash_requests_total{op=\"metrics\"}"] >= 1.0);

    // identity + uptime are present and sane
    assert!(prom.keys().any(|k| k.starts_with("cminhash_build_info{")
        && k.contains("scheme=\"cmh\"")));
    assert!(prom["cminhash_uptime_seconds"] >= 0.0);
    assert!(num(m, "uptime_s") >= 0.0);
}

// ---- the trace op over both dialects --------------------------------

#[test]
fn trace_returns_per_stage_spans_on_both_dialects() {
    let (server, _svc) = start_server();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();

    let a: Vec<u32> = (0..60).collect();
    let ia = c.insert(512, a.clone()).unwrap();
    let hits = c.query(512, a.clone(), 5).unwrap();
    assert_eq!(hits[0].id, ia);

    // JSON dialect
    let traces = c.trace(16, false).unwrap();
    assert!(!traces.is_empty(), "ring must hold the traffic just sent");
    let q = traces
        .iter()
        .find(|t| t.op == cminhash::obs::OpKind::Query)
        .expect("a query trace is in the ring");
    assert_eq!(q.items, 1);
    let stage_sum: u64 = q.stages_us.iter().sum();
    assert!(
        stage_sum <= q.total_us,
        "stages are disjoint: sum {stage_sum} <= total {}",
        q.total_us
    );
    assert!(traces.iter().any(|t| t.op == cminhash::obs::OpKind::Insert));
    // newest first
    for w in traces.windows(2) {
        assert!(w[0].seq > w[1].seq);
    }

    // bin1 dialect sees the same ring (and its own ops get traced too)
    let mut cb = BlockingClient::connect(&addr).unwrap();
    cb.binary().unwrap();
    cb.ping().unwrap();
    let bin_traces = cb.trace(32, false).unwrap();
    assert!(bin_traces.iter().any(|t| t.op == cminhash::obs::OpKind::Query));
    assert!(bin_traces.iter().any(|t| t.op == cminhash::obs::OpKind::Ping));
    // the metrics op works over bin1 as well
    let text = cb.metrics_text().unwrap();
    assert!(text.contains("cminhash_build_info"), "{text}");
    assert!(text.contains("cminhash_requests_total{op=\"ping\"}"));
}

#[test]
fn fanned_out_queries_attribute_band_and_score_stages() {
    // Regression: above the parallel fan-out threshold (8192 resident
    // items) shard queries run on scoped worker threads, and their
    // BandLookup/Score spans used to vanish — the workers'
    // thread-local sinks were never armed, so the time showed up in
    // the request total but in no stage.  The fan-out now arms each
    // worker and credits the slowest worker's breakdown, so a traced
    // query over a big index must show band/score attribution while
    // the stage sum stays within the request total.
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: 4096,
        num_hashes: 64,
        seed: 9,
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        store: StoreSettings {
            shards: 4,
            persist_dir: None,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();
    // near-duplicate corpus (groups of 16 identical docs, adjacent
    // groups overlapping) -> fat candidate sets on every shard
    let doc = |i: u32| -> Vec<u32> { ((i / 16) * 7..(i / 16) * 7 + 80).collect() };
    let total = 8192 + 64u32;
    let mut inserted = 0u32;
    while inserted < total {
        let n = (total - inserted).min(512);
        let rows: Vec<Vec<u32>> = (inserted..inserted + n).map(doc).collect();
        c.insert_batch(4096, rows).unwrap();
        inserted += n;
    }
    // 64 probes in one request: per-worker band/score work is well
    // above the µs resolution of the stage clocks, so the nonzero
    // assertion below cannot flake on a fast machine.
    let probes: Vec<Vec<u32>> = (0..64).map(|p| doc(p * 128)).collect();
    let hits = c.query_batch(4096, probes, 5).unwrap();
    assert_eq!(hits.len(), 64);
    assert!(hits.iter().all(|ns| !ns.is_empty()));
    let traces = c.trace(8, false).unwrap();
    let q = traces
        .iter()
        .find(|t| t.op == cminhash::obs::OpKind::QueryBatch)
        .expect("query_batch trace in the ring");
    let band = q.stages_us[cminhash::obs::Stage::BandLookup as usize];
    let score = q.stages_us[cminhash::obs::Stage::Score as usize];
    assert!(
        band + score > 0,
        "fanned-out band/score work must attribute to stages, got {:?}",
        q.stages_us
    );
    let sum: u64 = q.stages_us.iter().sum();
    assert!(sum <= q.total_us, "stage sum {sum} <= total {}", q.total_us);
}

#[test]
fn slow_traces_pin_past_ring_churn() {
    // threshold 0: every request counts as slow.  Tiny ring (2 slots)
    // churns fast, but pinned traces survive it.
    let (server, _svc) = start_server_with_obs(ObsSettings {
        trace_ring: 2,
        slow_threshold_us: 0,
        pinned: 8,
    });
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();
    c.insert(512, (0..40).collect()).unwrap();
    for _ in 0..6 {
        c.ping().unwrap();
    }
    // the insert has long since churned out of the 2-slot ring...
    let recent = c.trace(16, false).unwrap();
    assert!(recent.len() <= 2);
    // ...but is still pinned
    let pinned = c.trace(16, true).unwrap();
    assert!(pinned.iter().all(|t| t.slow));
    assert!(
        pinned.iter().any(|t| t.op == cminhash::obs::OpKind::Insert),
        "slow insert must stay pinned past ring churn"
    );
}

#[test]
fn trace_ring_zero_disables_capture_but_not_counters() {
    let (server, svc) = start_server_with_obs(ObsSettings {
        trace_ring: 0,
        slow_threshold_us: 10_000,
        pinned: 8,
    });
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();
    c.ping().unwrap();
    c.ping().unwrap();
    assert!(c.trace(16, false).unwrap().is_empty(), "tracing disabled");
    let counts: HashMap<&str, u64> = svc.obs().op_counts().into_iter().collect();
    assert_eq!(counts["ping"], 2, "per-op counters are not a knob");
    assert_eq!(counts["trace"], 1);
}

#[test]
fn estimate_latency_is_recorded_via_the_wire() {
    let (server, _svc) = start_server();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();
    let ia = c.insert(512, (0..50).collect()).unwrap();
    let ib = c.insert(512, (25..75).collect()).unwrap();
    for _ in 0..3 {
        match c.call(&Request::Estimate { a: ia, b: ib }).unwrap() {
            cminhash::server::protocol::Response::Estimate { .. } => {}
            other => panic!("{other:?}"),
        }
    }
    let json = c.call_raw(&Request::Stats).unwrap();
    let est = json.get("metrics").unwrap().get("estimate_latency").unwrap();
    assert_eq!(est.get("count").unwrap().as_u64().unwrap(), 3);
    assert_eq!(
        json.get("metrics")
            .unwrap()
            .get("estimates")
            .unwrap()
            .as_u64()
            .unwrap(),
        3
    );
}
