//! Property tests (seeded randomized invariants, via the in-tree
//! `util::testutil::property` driver) over hashers, estimators, the
//! batcher, the LSH index, the JSON codec and the theory layer.

use cminhash::coordinator::{Batcher, FlushReason};
use cminhash::index::{BandingIndex, IndexConfig};
use cminhash::sketch::{
    estimate, CMinHasher, ClassicMinHasher, Perm, Role, Sketcher, SparseVec, ZeroPiHasher,
};
use cminhash::theory::{var_minhash, var_sigma_pi};
use cminhash::util::json::Json;
use cminhash::util::rng::Rng;
use cminhash::util::testutil::property;
use std::time::{Duration, Instant};

fn random_sparse(rng: &mut Rng, d: u32) -> Vec<u32> {
    let nnz = rng.range_usize(0, (d as usize / 4).max(1) + 1);
    let mut idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, d)).collect();
    idx.sort_unstable();
    idx.dedup();
    idx
}

#[test]
fn prop_hash_values_always_in_range() {
    property(40, |rng| {
        let d = rng.range_usize(2, 200);
        let k = rng.range_usize(1, d + 1);
        let seed = rng.next_u64();
        let idx = random_sparse(rng, d as u32);
        for hasher in [
            Box::new(CMinHasher::new(d, k, seed)) as Box<dyn Sketcher>,
            Box::new(ZeroPiHasher::new(d, k, seed)),
            Box::new(ClassicMinHasher::new(d, k, seed)),
        ] {
            let h = hasher.sketch_sparse(&idx);
            assert_eq!(h.len(), k);
            if idx.is_empty() {
                assert!(h.iter().all(|&v| v == d as u32));
            } else {
                assert!(h.iter().all(|&v| v < d as u32));
            }
        }
    });
}

#[test]
fn prop_identical_inputs_identical_sketches_estimate_one() {
    property(25, |rng| {
        let d = rng.range_usize(4, 150);
        let k = rng.range_usize(1, d + 1);
        let hasher = CMinHasher::new(d, k, rng.next_u64());
        let idx = random_sparse(rng, d as u32);
        if idx.is_empty() {
            return;
        }
        let h1 = hasher.sketch_sparse(&idx);
        let h2 = hasher.sketch_sparse(&idx);
        assert_eq!(h1, h2);
        assert_eq!(estimate(&h1, &h2), 1.0);
    });
}

#[test]
fn prop_estimate_symmetric_and_bounded() {
    property(25, |rng| {
        let d = rng.range_usize(8, 120);
        let k = rng.range_usize(1, d + 1);
        let hasher = CMinHasher::new(d, k, rng.next_u64());
        let a = hasher.sketch_sparse(&random_sparse(rng, d as u32));
        let b = hasher.sketch_sparse(&random_sparse(rng, d as u32));
        let j1 = estimate(&a, &b);
        let j2 = estimate(&b, &a);
        assert_eq!(j1, j2);
        assert!((0.0..=1.0).contains(&j1));
    });
}

#[test]
fn prop_sigma_only_permutes_never_changes_multiset_of_minima_stats() {
    // h_k over (σ,π) equals h_k over (0,π) applied to σ-permuted input.
    property(25, |rng| {
        let d = rng.range_usize(4, 120);
        let k = rng.range_usize(1, d + 1);
        let sigma = Perm::from_values(rng.permutation(d)).unwrap();
        let pi = Perm::from_values(rng.permutation(d)).unwrap();
        let cm = CMinHasher::from_perms(k, &sigma, &pi).unwrap();
        let zp = ZeroPiHasher::from_perm(k, &pi).unwrap();
        let idx = random_sparse(rng, d as u32);
        let inv = sigma.inverse();
        let mut permuted: Vec<u32> = idx.iter().map(|&s| inv.at(s as usize)).collect();
        permuted.sort_unstable();
        assert_eq!(cm.sketch_sparse(&idx), zp.sketch_sparse(&permuted));
    });
}

#[test]
fn prop_perm_generate_bijective_and_role_separated() {
    property(25, |rng| {
        let d = rng.range_usize(1, 500);
        let seed = rng.next_u64();
        let sigma = Perm::generate(d, seed, Role::Sigma);
        let pi = Perm::generate(d, seed, Role::Pi);
        let mut seen = vec![false; d];
        for &v in sigma.values() {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
        if d > 3 {
            assert_ne!(sigma.values(), pi.values());
        }
        // inverse really inverts
        let inv = sigma.inverse();
        for i in 0..d {
            assert_eq!(inv.at(sigma.at(i) as usize), i as u32);
        }
    });
}

#[test]
fn prop_batcher_never_drops_never_reorders() {
    property(30, |rng| {
        let max_batch = rng.range_usize(1, 12);
        let n = rng.range_usize(0, 100);
        let mut b: Batcher<usize> = Batcher::new(max_batch, Duration::from_millis(1));
        let t0 = Instant::now();
        let mut out: Vec<usize> = Vec::new();
        for i in 0..n {
            if let Some((batch, why)) = b.push(i, t0) {
                assert_eq!(why, FlushReason::Full);
                assert_eq!(batch.len(), max_batch);
                out.extend(batch);
            }
        }
        if let Some((batch, why)) = b.drain() {
            assert_eq!(why, FlushReason::Drain);
            out.extend(batch);
        }
        assert_eq!(out, (0..n).collect::<Vec<_>>(), "dropped or reordered");
    });
}

#[test]
fn prop_index_always_finds_exact_duplicates() {
    property(15, |rng| {
        let d = 512usize;
        let k = 64usize;
        let hasher = CMinHasher::new(d, k, rng.next_u64());
        let mut idx = BandingIndex::new(
            k,
            IndexConfig {
                bands: 16,
                rows_per_band: 4,
            },
        )
        .unwrap();
        let n = rng.range_usize(1, 30);
        let mut docs = Vec::new();
        for i in 0..n {
            let doc = random_sparse(rng, d as u32);
            idx.insert(i as u64, &hasher.sketch_sparse(&doc)).unwrap();
            docs.push(doc);
        }
        // every inserted doc is its own (score-1) neighbor
        for (i, doc) in docs.iter().enumerate() {
            let hits = idx.query(&hasher.sketch_sparse(doc), n);
            assert!(
                hits.iter().any(|h| h.id == i as u64 && h.score == 1.0),
                "doc {i} lost"
            );
        }
    });
}

#[test]
fn prop_index_config_s_curve_monotone_and_bounded() {
    property(60, |rng| {
        let cfg = IndexConfig {
            bands: rng.range_usize(1, 65),
            rows_per_band: rng.range_usize(1, 9),
        };
        // candidate_probability is in [0, 1], monotone non-decreasing
        // in j, and pinned at the endpoints
        assert_eq!(cfg.candidate_probability(0.0), 0.0);
        assert!((cfg.candidate_probability(1.0) - 1.0).abs() < 1e-12);
        let mut prev = 0.0f64;
        for step in 0..=100 {
            let j = f64::from(step) / 100.0;
            let p = cfg.candidate_probability(j);
            assert!(
                (-1e-12..=1.0 + 1e-12).contains(&p),
                "p({j}) = {p} out of [0,1] for {cfg:?}"
            );
            assert!(
                p + 1e-12 >= prev,
                "not monotone at j={j} for {cfg:?}: {p} < {prev}"
            );
            prev = p;
        }
    });
}

#[test]
fn prop_index_config_threshold_brackets_the_half_point() {
    property(60, |rng| {
        let cfg = IndexConfig {
            bands: rng.range_usize(1, 65),
            rows_per_band: rng.range_usize(1, 9),
        };
        let t = cfg.threshold();
        assert!(t > 0.0 && t <= 1.0, "threshold {t} for {cfg:?}");
        // p(t) = 1 - (1 - 1/b)^b >= 1 - 1/e > 0.5: the S-curve has
        // already crossed one half by the threshold...
        assert!(
            cfg.candidate_probability(t) >= 0.5,
            "p(threshold) < 0.5 for {cfg:?}"
        );
        // ...and had not yet crossed it at half the threshold, so the
        // ~0.5 crossing sits in (t/2, t]
        assert!(
            cfg.candidate_probability(t / 2.0) <= 0.5 + 1e-12,
            "p(threshold/2) > 0.5 for {cfg:?}"
        );
    });
}

#[test]
fn prop_index_candidates_subset_of_inserted() {
    property(15, |rng| {
        let k = 32usize;
        let mut idx = BandingIndex::new(
            k,
            IndexConfig {
                bands: 8,
                rows_per_band: 4,
            },
        )
        .unwrap();
        let n = rng.range_usize(0, 20);
        for i in 0..n {
            let sk: Vec<u32> = (0..k).map(|_| rng.range_u32(0, 50)).collect();
            idx.insert(i as u64, &sk).unwrap();
        }
        let probe: Vec<u32> = (0..k).map(|_| rng.range_u32(0, 50)).collect();
        for cand in idx.candidates(&probe) {
            assert!(cand < n as u64);
        }
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_trees() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.range_usize(0, 4) } else { rng.range_usize(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool_with(0.5)),
            2 => Json::Num((rng.range_u32(0, 1_000_000) as f64) - 500_000.0),
            3 => {
                let n = rng.range_usize(0, 8);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            char::from_u32(rng.range_u32(32, 0x2FF)).unwrap_or('x')
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.range_usize(0, 5)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.range_usize(0, 5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    property(60, |rng| {
        let j = random_json(rng, 0);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j, "roundtrip failed for {s}");
    });
}

#[test]
fn prop_theorem_3_4_random_points() {
    property(40, |rng| {
        let d = rng.range_usize(3, 1500);
        let f = rng.range_usize(2, d + 1);
        let a = rng.range_usize(1, f);
        let k = rng.range_usize(2, d.min(1000) + 1);
        let j = a as f64 / f as f64;
        let vs = var_sigma_pi(d, f, a, k);
        let vm = var_minhash(j, k);
        assert!(
            vs < vm + 1e-12,
            "Thm 3.4 violated at D={d} f={f} a={a} K={k}: {vs} >= {vm}"
        );
    });
}

#[test]
fn prop_sparsevec_json_roundtrip() {
    property(30, |rng| {
        let d = rng.range_u32(1, 1000);
        let v = SparseVec::new(d, random_sparse(rng, d)).unwrap();
        let back = SparseVec::from_json(&Json::parse(&v.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, v);
    });
}
