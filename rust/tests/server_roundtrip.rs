//! Full-stack server test over real TCP: coordinator + batcher + engine
//! + index behind the JSON-line protocol.  Uses the Rust engine (no
//! artifacts needed) so it runs on a fresh clone; the XLA path over TCP
//! is covered by `pipeline_consistency.rs` and the e2e example.

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::server::protocol::{Request, Response};
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::{CMinHasher, Sketcher, SparseVec};
use std::sync::Arc;

fn start_server() -> (Server, Arc<Coordinator>, ServeConfig) {
    let cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: 512,
        num_hashes: 64,
        seed: 9,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    let svc = Coordinator::start(cfg.clone()).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    (server, svc, cfg)
}

#[test]
fn ping_sketch_insert_estimate_query() {
    let (server, _svc, cfg) = start_server();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();

    // ping
    assert!(matches!(c.call(&Request::Ping).unwrap(), Response::Pong));

    // sketch matches the local hasher bit-for-bit
    let hasher = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);
    let idx = vec![5u32, 100, 400];
    let sk = c.sketch(512, idx.clone()).unwrap();
    assert_eq!(sk, hasher.sketch_sparse(&idx));

    // insert two overlapping docs, estimate by id
    let a: Vec<u32> = (0..60).collect();
    let b: Vec<u32> = (30..90).collect();
    let ia = c.insert(512, a.clone()).unwrap();
    let ib = c.insert(512, b.clone()).unwrap();
    match c.call(&Request::Estimate { a: ia, b: ib }).unwrap() {
        Response::Estimate { jhat } => {
            // true J = 1/3; K = 64 so allow wide but meaningful bounds
            assert!(jhat > 0.05 && jhat < 0.7, "jhat={jhat}");
        }
        other => panic!("{other:?}"),
    }

    // query returns the identical doc first with score 1.0
    let hits = c.query(512, a.clone(), 5).unwrap();
    assert_eq!(hits[0].id, ia);
    assert_eq!(hits[0].score, 1.0);

    // stats reflect the traffic
    let raw = c.call_raw(&Request::Stats).unwrap();
    assert!(raw.get("ok").unwrap().as_bool().unwrap());
    assert!(raw.get("stored").unwrap().as_u64().unwrap() == 2);
    let sketches = raw
        .get("metrics")
        .unwrap()
        .get("sketches")
        .unwrap()
        .as_u64()
        .unwrap();
    assert!(sketches >= 4, "sketches={sketches}");
}

#[test]
fn protocol_errors_are_reported_not_fatal() {
    let (server, _svc, _cfg) = start_server();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();

    // wrong dimension -> typed error, connection stays usable
    match c.call(&Request::Sketch {
        vec: SparseVec::new(16, vec![1]).unwrap(),
    }) {
        Ok(Response::Err { error }) => assert!(error.contains("shape mismatch"), "{error}"),
        other => panic!("{other:?}"),
    }
    // unknown id estimate
    match c.call(&Request::Estimate { a: 10_000, b: 2 }).unwrap() {
        Response::Err { error } => assert!(error.contains("unknown id")),
        other => panic!("{other:?}"),
    }
    // topk == 0 is a clean client error, not an empty result
    match c
        .call(&Request::Query {
            vec: SparseVec::new(512, vec![1, 2]).unwrap(),
            topk: 0,
        })
        .unwrap()
    {
        Response::Err { error } => assert!(error.contains("topk"), "{error}"),
        other => panic!("{other:?}"),
    }
    // dim-mismatched query vector: clean error on the query path too
    match c
        .call(&Request::Query {
            vec: SparseVec::new(16, vec![1]).unwrap(),
            topk: 5,
        })
        .unwrap()
    {
        Response::Err { error } => assert!(error.contains("shape mismatch"), "{error}"),
        other => panic!("{other:?}"),
    }
    // delete of an unknown id
    match c.call(&Request::Delete { id: 31_337 }).unwrap() {
        Response::Err { error } => assert!(error.contains("unknown id"), "{error}"),
        other => panic!("{other:?}"),
    }
    // save without a persist_dir configured
    match c.call(&Request::Save).unwrap() {
        Response::Err { error } => assert!(error.contains("persist"), "{error}"),
        other => panic!("{other:?}"),
    }
    // still alive after every error
    assert!(matches!(c.call(&Request::Ping).unwrap(), Response::Pong));
}

#[test]
fn batch_ops_roundtrip_and_match_singletons() {
    let (server, _svc, cfg) = start_server();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();
    let hasher = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);

    // N = 1 batch results are identical to the singleton ops.
    let row: Vec<u32> = (0..40).collect();
    let batch_sk = c.sketch_batch(512, vec![row.clone()]).unwrap();
    assert_eq!(batch_sk.len(), 1);
    assert_eq!(batch_sk[0], c.sketch(512, row.clone()).unwrap());
    assert_eq!(batch_sk[0], hasher.sketch_sparse(&row));

    // insert_batch assigns consecutive ids and stores every row.
    let rows: Vec<Vec<u32>> = (0..5u32)
        .map(|i| (i * 25..i * 25 + 50).collect())
        .collect();
    let ids = c.insert_batch(512, rows.clone()).unwrap();
    assert_eq!(ids.len(), 5);
    for w in ids.windows(2) {
        assert_eq!(w[1], w[0] + 1, "batch ids are consecutive");
    }

    // query_batch: one neighbor list per row, each matching the
    // singleton query for that row.
    let results = c.query_batch(512, rows.clone(), 3).unwrap();
    assert_eq!(results.len(), 5);
    for (row_i, (hits, row)) in results.iter().zip(&rows).enumerate() {
        assert_eq!(hits[0].id, ids[row_i], "row {row_i}: self is top hit");
        assert_eq!(hits[0].score, 1.0);
        let single = c.query(512, row.clone(), 3).unwrap();
        assert_eq!(*hits, single, "row {row_i} diverged from singleton query");
    }

    // stats sees the batched traffic: 5 stored rows + row counters.
    let raw = c.call_raw(&Request::Stats).unwrap();
    assert_eq!(raw.get("stored").unwrap().as_u64().unwrap(), 5);
    let m = raw.get("metrics").unwrap();
    assert_eq!(m.get("queries").unwrap().as_u64().unwrap(), 10, "5 batched + 5 single");

    // an empty vecs array is a protocol error, not a crash
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    w.write_all(b"{\"op\":\"sketch_batch\",\"vecs\":[]}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("vecs"), "{line}");
}

#[test]
fn empty_vectors_rejected_over_the_wire() {
    // Regression: two empty vectors used to estimate Ĵ = 1.0 (both
    // sketch to the all-D sentinel, which collides in every slot).
    let (server, _svc, _cfg) = start_server();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();
    let empty = SparseVec::new(512, vec![]).unwrap();
    let full = SparseVec::new(512, vec![1, 2, 3]).unwrap();

    // estimate_vecs of two empties: clean error, not jhat = 1.0
    match c
        .call(&Request::EstimateVecs {
            v: empty.clone(),
            w: empty.clone(),
        })
        .unwrap()
    {
        Response::Err { error } => assert!(error.contains("empty vector"), "{error}"),
        other => panic!("empty ∩ empty must not estimate: {other:?}"),
    }
    // sketch / insert / query of an empty vector: same clean error
    for req in [
        Request::Sketch { vec: empty.clone() },
        Request::Insert { vec: empty.clone() },
        Request::Query {
            vec: empty.clone(),
            topk: 3,
        },
        Request::QueryAbove {
            vec: empty.clone(),
            threshold: 0.5,
        },
        Request::EstimateVecs {
            v: full.clone(),
            w: empty.clone(),
        },
    ] {
        match c.call(&req).unwrap() {
            Response::Err { error } => {
                assert!(error.contains("empty vector"), "{req:?}: {error}")
            }
            other => panic!("{req:?} must be rejected, got {other:?}"),
        }
    }
    // a batch containing one empty row is rejected wholesale
    match c
        .call(&Request::InsertBatch {
            vecs: vec![full.clone(), empty],
        })
        .unwrap()
    {
        Response::Err { error } => assert!(error.contains("empty vector"), "{error}"),
        other => panic!("{other:?}"),
    }
    let raw = c.call_raw(&Request::Stats).unwrap();
    assert_eq!(
        raw.get("stored").unwrap().as_u64().unwrap(),
        0,
        "the rejected batch must not partially insert"
    );
    // the connection survives and serves normal traffic
    let id = c.insert(512, (0..50).collect()).unwrap();
    assert_eq!(c.query(512, (0..50).collect(), 1).unwrap()[0].id, id);
}

#[test]
fn delete_over_the_wire() {
    let (server, _svc, _cfg) = start_server();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();
    let a: Vec<u32> = (0..60).collect();
    let id = c.insert(512, a.clone()).unwrap();
    let hits = c.query(512, a.clone(), 3).unwrap();
    assert_eq!(hits[0].id, id);
    c.delete(id).unwrap();
    assert!(c.delete(id).is_err(), "double delete is an error");
    let hits = c.query(512, a, 3).unwrap();
    assert!(hits.iter().all(|h| h.id != id), "deleted id resurfaced");
    // stats reflect the shard occupancy and the delete
    let raw = c.call_raw(&Request::Stats).unwrap();
    assert_eq!(raw.get("stored").unwrap().as_u64().unwrap(), 0);
    assert!(!raw.get("shards").unwrap().as_arr().unwrap().is_empty());
    assert_eq!(raw.get("persisted_bytes").unwrap().as_u64().unwrap(), 0);
    assert_eq!(
        raw.get("metrics")
            .unwrap()
            .get("deletes")
            .unwrap()
            .as_u64()
            .unwrap(),
        1
    );
}

#[test]
fn malformed_json_gets_error_line() {
    use std::io::{BufRead, BufReader, Write};
    let (server, _svc, _cfg) = start_server();
    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    w.write_all(b"this is not json\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    // and an unknown op
    w.write_all(b"{\"op\":\"evil\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("unknown op"), "{line}");
    // estimate with a missing id
    w.write_all(b"{\"op\":\"estimate\",\"a\":424242,\"b\":0}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("unknown id"), "{line}");
    // the connection survived all three errors
    w.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"pong\":true"), "{line}");
}

#[test]
fn concurrent_clients_get_consistent_sketches() {
    let (server, svc, cfg) = start_server();
    let addr = server.addr().to_string();
    let hasher = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);
    let mut joins = Vec::new();
    for t in 0..8u32 {
        let addr = addr.clone();
        let want = hasher.sketch_sparse(&[t, t + 50, t + 200]);
        joins.push(std::thread::spawn(move || {
            let mut c = BlockingClient::connect(&addr).unwrap();
            for _ in 0..20 {
                let sk = c.sketch(512, vec![t, t + 50, t + 200]).unwrap();
                assert_eq!(sk, want);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let (snap, _) = svc.stats();
    assert_eq!(snap.sketches, 160);
    assert!(
        snap.batches <= 160,
        "batching should coalesce at least some requests"
    );
}

#[test]
fn near_duplicate_detection_over_wire() {
    // The dedup use-case end-to-end: insert a corpus with duplicate
    // families, query, and check family members rank on top.
    let (server, _svc, _cfg) = start_server();
    let addr = server.addr().to_string();
    let corpus = cminhash::data::near_duplicate_corpus(6, 3, 512, 60, 3, 4);
    let mut c = BlockingClient::connect(&addr).unwrap();
    let mut ids = Vec::new();
    for row in corpus.rows() {
        ids.push(c.insert(512, row.indices().to_vec()).unwrap());
    }
    // Query with family 0's first member: its 2 siblings must appear in
    // the top 3 (itself + siblings).
    let hits = c.query(512, corpus.rows()[0].indices().to_vec(), 3).unwrap();
    let top: Vec<u64> = hits.iter().map(|h| h.id).collect();
    for sibling in [ids[0], ids[1], ids[2]] {
        assert!(top.contains(&sibling), "top={top:?} missing {sibling}");
    }
}
