//! Cross-scheme consistency suite: every [`SketchScheme`] must (1)
//! estimate Jaccard unbiasedly within tolerance on seeded
//! small-universe data, (2) share the crate-wide sketch conventions
//! (value range, sentinel, determinism), (3) serve end to end through
//! the full TCP stack with `stats` reporting the scheme, and (4)
//! refuse to load a persisted store stamped with a different scheme.

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::server::protocol::Request;
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::{estimate, SketchScheme, Sketcher, SparseVec};
use cminhash::util::testutil::{overlap_pair, TempDir};
use std::path::PathBuf;

const DIM: usize = 64;
const K: usize = 16;

/// Seeded overlapping-range pairs spanning several J levels, drawn
/// from the one shared structured-pair generator
/// ([`overlap_pair`], also behind the bench gates).  Ranges are
/// deliberately *structured* (contiguous index runs): schemes that
/// skip their scrambling permutation would be biased on exactly this
/// data, so unbiasedness here exercises the σ machinery too.
fn pairs() -> Vec<(SparseVec, SparseVec, f64)> {
    vec![
        overlap_pair(DIM as u32, 24, 24, 12), // J = 1/3
        overlap_pair(DIM as u32, 40, 34, 10), // J = 10/64
        overlap_pair(DIM as u32, 32, 32, 32), // J = 1
        overlap_pair(DIM as u32, 16, 16, 0),  // J = 0
    ]
}

#[test]
fn every_scheme_is_unbiased_within_tolerance() {
    // Mean estimate over many seeds must track exact Jaccard: the
    // per-seed estimator has sd <= 1/(2*sqrt(K)) = 0.125, so over 300
    // seeds the standard error is ~0.008; 0.035 is a > 4-sigma gate
    // that still fails on any systematic bias (the deterministic-
    // binning C-OPH bug this suite was written against showed +0.04).
    let trials = 300u64;
    for scheme in SketchScheme::ALL {
        for (v, w, truth) in pairs() {
            let mut sum = 0.0;
            for seed in 0..trials {
                let h = scheme.build(DIM, K, seed).unwrap();
                sum += estimate(
                    &h.sketch_sparse(v.indices()),
                    &h.sketch_sparse(w.indices()),
                );
            }
            let mean = sum / trials as f64;
            assert!(
                (mean - truth).abs() < 0.035,
                "{scheme}: mean {mean:.4} vs exact J {truth:.4}"
            );
        }
    }
}

#[test]
fn identical_and_disjoint_vectors_are_exact_for_every_scheme() {
    // J = 1 must estimate exactly 1 (same sketch), and J = 0 on
    // *dense-enough* disjoint vectors stays small; both hold for every
    // scheme and every seed, not just on average.
    let v = SparseVec::new(DIM as u32, (0..32).collect()).unwrap();
    for scheme in SketchScheme::ALL {
        for seed in [0u64, 7, 99] {
            let h = scheme.build(DIM, K, seed).unwrap();
            let sk = h.sketch_sparse(v.indices());
            assert_eq!(estimate(&sk, &sk), 1.0, "{scheme}");
            assert!(sk.iter().all(|&x| x < DIM as u32), "{scheme}: range");
        }
    }
}

fn cfg_for(scheme: SketchScheme, persist: Option<PathBuf>) -> ServeConfig {
    let mut cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: DIM,
        num_hashes: K,
        seed: 11,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 4,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    cfg.sketch.scheme = scheme;
    cfg.store.persist_dir = persist;
    cfg
}

#[test]
fn coph_serves_end_to_end_and_stats_reports_the_scheme() {
    // The acceptance scenario: `serve --scheme coph` handles
    // sketch/insert/query over the wire and `stats` names the scheme.
    let svc = Coordinator::start(cfg_for(SketchScheme::Coph, None)).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();

    let direct = SketchScheme::Coph.build(DIM, K, 11).unwrap();
    let nz: Vec<u32> = (0..24).collect();
    let sk = c.sketch(DIM as u32, nz.clone()).unwrap();
    assert_eq!(sk, direct.sketch_sparse(&nz), "wire sketch == direct hasher");

    let id = c.insert(DIM as u32, nz.clone()).unwrap();
    let hits = c.query(DIM as u32, nz, 3).unwrap();
    assert_eq!(hits[0].id, id);
    assert_eq!(hits[0].score, 1.0);

    let stats = c.call_raw(&Request::Stats).unwrap();
    assert!(stats.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(stats.get("scheme").unwrap().as_str().unwrap(), "coph");
    assert_eq!(stats.get("stored").unwrap().as_u64().unwrap(), 1);
}

#[test]
fn every_scheme_serves_the_coordinator_api() {
    for scheme in SketchScheme::ALL {
        let svc = Coordinator::start(cfg_for(scheme, None)).unwrap();
        let v = SparseVec::new(DIM as u32, (0..24).collect()).unwrap();
        let w = SparseVec::new(DIM as u32, (12..36).collect()).unwrap();
        let (id, sk) = svc.insert(v.clone()).unwrap();
        assert_eq!(sk.len(), K, "{scheme}");
        svc.insert(w.clone()).unwrap();
        let hits = svc.query(v.clone(), 2).unwrap();
        assert_eq!(hits[0].id, id, "{scheme}: self is the top hit");
        let jhat = svc.estimate_vecs(v, w).unwrap();
        assert!((0.0..=1.0).contains(&jhat), "{scheme}");
    }
}

#[test]
fn snapshot_scheme_mismatch_fails_with_a_clean_error() {
    let dir = TempDir::new().unwrap();
    // Build + persist a store under cmh, folding the WAL into a
    // scheme-stamped snapshot.
    {
        let svc = Coordinator::start(cfg_for(
            SketchScheme::Cmh,
            Some(dir.path().to_path_buf()),
        ))
        .unwrap();
        let v = SparseVec::new(DIM as u32, (0..24).collect()).unwrap();
        svc.insert(v).unwrap();
        assert!(svc.save().unwrap() > 0);
    }
    // Reopening under coph must fail with an error naming both schemes
    // (not a panic, not silent corruption).
    match Coordinator::start(cfg_for(
        SketchScheme::Coph,
        Some(dir.path().to_path_buf()),
    )) {
        Err(cminhash::Error::Invalid(msg)) => {
            assert!(msg.contains("cmh"), "{msg}");
            assert!(msg.contains("coph"), "{msg}");
        }
        Err(other) => panic!("expected Invalid, got {other:?}"),
        Ok(_) => panic!("scheme mismatch must refuse to open"),
    }
    // The stamped scheme still opens and serves its data.
    let svc = Coordinator::start(cfg_for(
        SketchScheme::Cmh,
        Some(dir.path().to_path_buf()),
    ))
    .unwrap();
    let (_, store) = svc.stats();
    assert_eq!(store.stored, 1);
}

#[test]
fn iuh_is_unbiased_at_five_sigma() {
    // Dedicated tighter gate for the O(1)-state scheme: its keyed
    // bijections replace stored permutation tables outright, so any
    // structural bias (a weak mix, a walk that favours low values)
    // would show up here.  600 seeds put the standard error of the
    // mean at 0.125/sqrt(600) ~ 0.0051; 0.026 is a 5-sigma gate.
    let trials = 600u64;
    for (v, w, truth) in pairs() {
        let mut sum = 0.0;
        for seed in 0..trials {
            let h = SketchScheme::Iuh.build(DIM, K, seed).unwrap();
            sum += estimate(
                &h.sketch_sparse(v.indices()),
                &h.sketch_sparse(w.indices()),
            );
        }
        let mean = sum / trials as f64;
        assert!(
            (mean - truth).abs() < 0.026,
            "iuh 5-sigma gate: mean {mean:.4} vs exact J {truth:.4}"
        );
    }
}

#[test]
fn iuh_snapshot_stamp_roundtrips_and_mismatch_refuses() {
    let dir = TempDir::new().unwrap();
    // Persist under iuh; the snapshot carries scheme code 6.
    {
        let svc = Coordinator::start(cfg_for(
            SketchScheme::Iuh,
            Some(dir.path().to_path_buf()),
        ))
        .unwrap();
        let v = SparseVec::new(DIM as u32, (0..24).collect()).unwrap();
        svc.insert(v).unwrap();
        assert!(svc.save().unwrap() > 0);
    }
    // A cmh server must refuse the iuh-stamped store, naming both.
    match Coordinator::start(cfg_for(
        SketchScheme::Cmh,
        Some(dir.path().to_path_buf()),
    )) {
        Err(cminhash::Error::Invalid(msg)) => {
            assert!(msg.contains("iuh"), "{msg}");
            assert!(msg.contains("cmh"), "{msg}");
        }
        Err(other) => panic!("expected Invalid, got {other:?}"),
        Ok(_) => panic!("scheme mismatch must refuse to open"),
    }
    // Reopening under iuh serves the persisted row.
    let svc = Coordinator::start(cfg_for(
        SketchScheme::Iuh,
        Some(dir.path().to_path_buf()),
    ))
    .unwrap();
    let (_, store) = svc.stats();
    assert_eq!(store.stored, 1);
}
