//! Bounded connection pool + bulk loading over real TCP: saturate
//! `server.max_connections`, assert overflow clients get the clean
//! `busy` protocol error while the server stays live, and round-trip a
//! JSONL file through `cminhash`'s `load_jsonl` into stats occupancy.

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::server::protocol::{Request, Response};
use cminhash::server::{load_jsonl, BlockingClient, Server};
use cminhash::sketch::SparseVec;
use cminhash::util::testutil::TempDir;
use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn start_server(max_connections: usize) -> (Server, Arc<Coordinator>) {
    let mut cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: 256,
        num_hashes: 64,
        seed: 5,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    cfg.server.max_connections = max_connections;
    let svc = Coordinator::start(cfg).unwrap();
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    (server, svc)
}

fn ping_ok(c: &mut BlockingClient) -> bool {
    matches!(c.call(&Request::Ping), Ok(Response::Pong))
}

#[test]
fn overflow_connections_get_busy_and_server_stays_live() {
    let (server, svc) = start_server(2);
    let addr = server.addr().to_string();

    // Fill both pool slots; a ping round-trip proves each connection
    // is actually being served by a worker before we overflow.
    let mut c1 = BlockingClient::connect(&addr).unwrap();
    assert!(ping_ok(&mut c1));
    let mut c2 = BlockingClient::connect(&addr).unwrap();
    assert!(ping_ok(&mut c2));

    // Overflow: the server sends one busy error line unprompted and
    // closes; no request needs to be written to observe it.
    for _ in 0..3 {
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "{line}");
        assert!(line.contains("busy"), "{line}");
        // closed after the error line: next read sees EOF
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "socket must close");
    }

    // The pool members were never disturbed.
    assert!(ping_ok(&mut c1), "existing connection 1 survived saturation");
    assert!(ping_ok(&mut c2), "existing connection 2 survived saturation");
    let (snap, _) = svc.stats();
    assert_eq!(snap.busy_rejections, 3, "each overflow is counted");

    // Freeing one slot re-admits new connections (the worker notices
    // EOF asynchronously, so poll briefly).
    drop(c1);
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut admitted = false;
    while Instant::now() < deadline {
        if let Ok(mut c) = BlockingClient::connect(&addr) {
            if ping_ok(&mut c) {
                admitted = true;
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(admitted, "a freed worker slot must re-admit connections");
}

#[test]
fn saturated_pool_still_serves_real_traffic() {
    // One worker, one working client, many rejected ones: the single
    // slot keeps doing real request work throughout.
    let (server, _svc) = start_server(1);
    let addr = server.addr().to_string();
    let mut c = BlockingClient::connect(&addr).unwrap();
    assert!(ping_ok(&mut c));
    for i in 0..4u32 {
        // each overflow connection is turned away...
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("busy"), "{line}");
        // ...while the admitted connection keeps inserting
        let id = c.insert(256, vec![i, i + 10, i + 20]).unwrap();
        assert_eq!(id, u64::from(i));
    }
    let hits = c.query(256, vec![0, 10, 20], 1).unwrap();
    assert_eq!(hits[0].id, 0);
}

#[test]
fn load_jsonl_roundtrips_into_stats_occupancy() {
    let (server, svc) = start_server(4);
    let addr = server.addr().to_string();

    // 11 rows with batch 4 -> 3 insert_batch round-trips (4+4+3),
    // plus blank lines that must be skipped.
    let dir = TempDir::new().unwrap();
    let path = dir.path().join("vectors.jsonl");
    let mut lines = Vec::new();
    for i in 0..11u32 {
        let v = SparseVec::new(256, vec![i, i + 30, i + 90]).unwrap();
        lines.push(v.to_json().to_string());
        if i % 4 == 0 {
            lines.push(String::new());
        }
    }
    std::fs::write(&path, lines.join("\n")).unwrap();

    let mut progress_calls = 0u64;
    let report = load_jsonl(&addr, &path, 4, |_| progress_calls += 1).unwrap();
    assert_eq!(report.rows, 11);
    assert_eq!(report.batches, 3);
    assert_eq!(progress_calls, 3, "one progress call per round-trip");
    assert!(report.secs >= 0.0 && report.rows_per_sec() >= 0.0);

    // stats occupancy reflects exactly the loaded rows
    let mut c = BlockingClient::connect(&addr).unwrap();
    let raw = c.call_raw(&Request::Stats).unwrap();
    assert_eq!(raw.get("stored").unwrap().as_u64().unwrap(), 11);
    // and the rows are queryable
    let hits = c.query(256, vec![3, 33, 93], 1).unwrap();
    assert_eq!(hits[0].score, 1.0);
    drop(server);
    let (snap, _) = svc.stats();
    assert_eq!(snap.sketches, 12, "11 loaded + 1 query probe");
}

#[test]
fn load_jsonl_reports_bad_lines_and_rejected_batches() {
    let (server, _svc) = start_server(4);
    let addr = server.addr().to_string();
    let dir = TempDir::new().unwrap();

    // malformed JSON names the file and line
    let bad = dir.path().join("bad.jsonl");
    std::fs::write(
        &bad,
        "{\"dim\":256,\"indices\":[1]}\nthis is not json\n",
    )
    .unwrap();
    match load_jsonl(&addr, &bad, 8, |_| {}) {
        Err(cminhash::Error::Invalid(msg)) => {
            assert!(msg.contains("bad.jsonl:2"), "{msg}");
        }
        other => panic!("{other:?}"),
    }

    // an empty vector row is rejected by the server (whole batch) and
    // surfaces the offending batch's starting line
    let empty = dir.path().join("empty_row.jsonl");
    std::fs::write(
        &empty,
        "{\"dim\":256,\"indices\":[1]}\n{\"dim\":256,\"indices\":[]}\n",
    )
    .unwrap();
    match load_jsonl(&addr, &empty, 8, |_| {}) {
        Err(cminhash::Error::Protocol(msg)) => {
            assert!(msg.contains("line 1"), "{msg}");
            assert!(msg.contains("empty vector"), "{msg}");
        }
        other => panic!("{other:?}"),
    }

    // zero batch size is a client error before any I/O
    assert!(load_jsonl(&addr, &bad, 0, |_| {}).is_err());
    let _ = server;
}
