//! Integration tests for the store subsystem (rust/src/store/):
//! crash recovery through the full coordinator, sharding-is-pure-
//! scaling golden checks, and end-to-end persistence over the wire.

use cminhash::config::{BatchConfig, BatchPolicy, EngineKind, IndexSettings, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::index::{BandingIndex, IndexConfig, Neighbor};
use cminhash::server::protocol::Request;
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::{CMinHasher, Sketcher, SparseVec};
use cminhash::store::ShardedIndex;
use cminhash::util::testutil::TempDir;
use std::path::PathBuf;

const DIM: usize = 512;
const K: usize = 64;

fn cfg_with(persist_dir: Option<PathBuf>, shards: usize) -> ServeConfig {
    let mut cfg = ServeConfig {
        engine: EngineKind::Rust,
        dim: DIM,
        num_hashes: K,
        seed: 9,
        batch: BatchConfig {
            max_batch: 8,
            max_delay_us: 300,
            policy: BatchPolicy::Eager,
        },
        index: IndexSettings {
            bands: 16,
            rows_per_band: 4,
        },
        addr: "127.0.0.1:0".into(),
        ..ServeConfig::default()
    };
    cfg.store.shards = shards;
    cfg.store.persist_dir = persist_dir;
    cfg
}

fn doc(i: u32) -> SparseVec {
    SparseVec::new(DIM as u32, (i * 3..i * 3 + 40).collect()).unwrap()
}

/// A mixed insert/delete workload with a mid-stream compaction, so the
/// final on-disk state is snapshot + non-empty WAL tail.  Returns
/// (live ids, deleted ids).
fn run_workload(svc: &Coordinator, compact: bool) -> (Vec<u64>, Vec<u64>) {
    let mut live = Vec::new();
    let mut deleted = Vec::new();
    for i in 0..30u32 {
        let (id, _) = svc.insert(doc(i)).unwrap();
        live.push(id);
    }
    for id in 5..10u64 {
        svc.delete(id).unwrap();
        live.retain(|&x| x != id);
        deleted.push(id);
    }
    if compact {
        assert!(svc.save().unwrap() > 0);
    }
    // post-snapshot tail: fresh inserts plus deletes of one
    // pre-snapshot id and one post-snapshot id (WAL-only state)
    for i in 30..40u32 {
        let (id, _) = svc.insert(doc(i)).unwrap();
        live.push(id);
    }
    for id in [2u64, 35] {
        svc.delete(id).unwrap();
        live.retain(|&x| x != id);
        deleted.push(id);
    }
    (live, deleted)
}

#[test]
fn crash_recovery_is_byte_identical_to_uninterrupted_run() {
    let dir = TempDir::new().unwrap();

    // interrupted run: workload with a mid-stream compaction, then the
    // coordinator is dropped with a non-empty, uncompacted WAL tail
    let (live, deleted) = {
        let svc = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 4)).unwrap();
        run_workload(&svc, true)
    };

    // control: same op sequence, purely in-memory, never interrupted
    let control = Coordinator::start(cfg_with(None, 4)).unwrap();
    let (control_live, control_deleted) = run_workload(&control, false);
    assert_eq!(live, control_live, "id sequences must line up");
    assert_eq!(deleted, control_deleted);

    // recover from snapshot + WAL replay
    let recovered = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 4)).unwrap();
    let (_, store) = recovered.stats();
    assert_eq!(store.stored, live.len());
    assert!(store.persisted_bytes > 0);

    // every query answer is byte-identical to the uninterrupted run,
    // and deleted ids never reappear as neighbors
    for i in 0..40u32 {
        let got: Vec<Neighbor> = recovered.query(doc(i), 10).unwrap();
        let want: Vec<Neighbor> = control.query(doc(i), 10).unwrap();
        assert_eq!(got, want, "query mismatch for probe {i}");
        assert!(
            got.iter().all(|n| !deleted.contains(&n.id)),
            "deleted id resurfaced for probe {i}: {got:?}"
        );
        let above = recovered.query_above(doc(i), 0.3).unwrap();
        assert_eq!(above, control.query_above(doc(i), 0.3).unwrap());
    }

    // estimates between live ids are byte-identical too
    for pair in live.windows(2) {
        let got = recovered.estimate_ids(pair[0], pair[1]).unwrap();
        let want = control.estimate_ids(pair[0], pair[1]).unwrap();
        assert_eq!(got.to_bits(), want.to_bits());
    }
    // deleted ids are gone from the estimate path as well
    assert!(recovered.estimate_ids(deleted[0], live[0]).is_err());

    // fresh ids continue past everything ever allocated (no reuse)
    let (fresh, _) = recovered.insert(doc(99)).unwrap();
    assert_eq!(fresh, 40);
}

#[test]
fn recovery_without_snapshot_is_pure_wal_replay() {
    let dir = TempDir::new().unwrap();
    let (live, deleted) = {
        let svc = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 2)).unwrap();
        run_workload(&svc, false) // never compacted: WAL only
    };
    let recovered = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 2)).unwrap();
    let (_, store) = recovered.stats();
    assert_eq!(store.stored, live.len());
    for &id in &deleted {
        assert!(recovered.estimate_ids(id, id).is_err(), "id {id} survived");
    }
    for &id in &live {
        assert!(recovered.estimate_ids(id, id).is_ok(), "id {id} lost");
    }
}

#[test]
fn sharded_n1_is_identical_to_banding_index() {
    let hasher = CMinHasher::new(1024, K, 5);
    let cfg = IndexConfig {
        bands: 16,
        rows_per_band: 4,
    };
    let sketches: Vec<Vec<u32>> = (0..64u32)
        .map(|i| {
            // overlapping shingle windows -> plenty of near neighbors
            let d: Vec<u32> = (i * 5..i * 5 + 60).collect();
            hasher.sketch_sparse(&d)
        })
        .collect();

    let mut golden = BandingIndex::new(K, cfg).unwrap();
    let single = ShardedIndex::new(K, cfg, 1).unwrap();
    let wide = ShardedIndex::new(K, cfg, 4).unwrap();
    for (i, sk) in sketches.iter().enumerate() {
        golden.insert(i as u64, sk).unwrap();
        assert_eq!(single.insert(sk).unwrap(), i as u64);
        assert_eq!(wide.insert(sk).unwrap(), i as u64);
    }

    for sk in &sketches {
        let want = golden.query(sk, 7);
        assert_eq!(single.query(sk, 7).unwrap(), want, "N=1 must be identical");
        assert_eq!(
            wide.query(sk, 7).unwrap(),
            want,
            "sharding is a scaling knob, not a semantics change"
        );
        let want_above = golden.query_above(sk, 0.4);
        assert_eq!(single.query_above(sk, 0.4).unwrap(), want_above);
        assert_eq!(wide.query_above(sk, 0.4).unwrap(), want_above);
    }
}

#[test]
fn save_and_recover_over_the_wire() {
    let dir = TempDir::new().unwrap();
    let addr;
    {
        let svc = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 2)).unwrap();
        let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
        addr = server.addr().to_string();
        let mut c = BlockingClient::connect(&addr).unwrap();
        let a = c.insert(DIM as u32, (0..50).collect()).unwrap();
        let _b = c.insert(DIM as u32, (25..75).collect()).unwrap();
        c.delete(a).unwrap();
        // explicit save folds the WAL into the snapshot
        let raw = c.call_raw(&Request::Save).unwrap();
        assert!(raw.get("ok").unwrap().as_bool().unwrap());
        let bytes = raw.get("persisted_bytes").unwrap().as_u64().unwrap();
        assert!(bytes > 0);
        let stats = c.call_raw(&Request::Stats).unwrap();
        assert_eq!(stats.get("persisted_bytes").unwrap().as_u64().unwrap(), bytes);
        drop(c);
    }
    // a fresh service over the same directory serves the saved state
    let svc = Coordinator::start(cfg_with(Some(dir.path().to_path_buf()), 2)).unwrap();
    let (_, store) = svc.stats();
    assert_eq!(store.stored, 1);
    let hits = svc
        .query(SparseVec::new(DIM as u32, (25..75).collect()).unwrap(), 3)
        .unwrap();
    assert_eq!(hits[0].id, 1, "survivor keeps its id across restart");
    assert_eq!(hits[0].score, 1.0);
}
