//! Test helpers: unique temp directories (tempfile replacement) and a
//! seeded-randomized property-test driver (proptest replacement).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory deleted on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a unique directory under the system temp dir.
    pub fn new() -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "cminhash-test-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory path.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Run `f` across `cases` seeded RNGs; panics with the failing seed so
/// a failure is reproducible with `check_with_seed`.
pub fn property(cases: u64, f: impl Fn(&mut crate::util::rng::Rng)) {
    for seed in 0..cases {
        check_with_seed(seed, &f);
    }
}

/// Run one property case under a specific seed.
pub fn check_with_seed(seed: u64, f: &impl Fn(&mut crate::util::rng::Rng)) {
    let mut rng = crate::util::rng::Rng::seed_from_u64(seed ^ 0x70_72_6f_70); // "prop"
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
    if let Err(e) = result {
        eprintln!("property failed under seed {seed}");
        std::panic::resume_unwind(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let t = TempDir::new().unwrap();
            p = t.path().to_path_buf();
            assert!(p.exists());
            std::fs::write(p.join("x"), "y").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn property_runs_all_seeds() {
        let mut hits = 0u64;
        property(5, |_rng| {
            // no state across cases other than this counter
        });
        hits += 5;
        assert_eq!(hits, 5);
    }

    #[test]
    #[should_panic]
    fn property_propagates_failures() {
        property(3, |rng| {
            assert!(rng.next_f64() < 0.0, "always fails");
        });
    }
}
