//! Test helpers: unique temp directories (tempfile replacement), a
//! seeded-randomized property-test driver (proptest replacement), and
//! the one shared generator of structured exact-Jaccard pairs that
//! every statistical suite and bench gates against — so a bench gate
//! and its acceptance test are guaranteed to measure the same corpus.

use crate::sketch::SparseVec;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory deleted on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a unique directory under the system temp dir.
    // Test-support code: formatting a counter into a path cannot fail.
    #[allow(clippy::disallowed_methods)]
    pub fn new() -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "cminhash-test-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory path.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Build a *structured* pair of contiguous-run sparse vectors with an
/// exactly known Jaccard similarity — the shared corpus generator
/// behind the statistical acceptance suites (`scheme_consistency`,
/// `bbit_stats`) and the bench gates (`hasher_hotpath`, `bbit_query`),
/// so tests and benches measure identical data.
///
/// `a` is the index run `[0, a_len)`, `b` is `[a_len − inter,
/// a_len − inter + b_len)`: they intersect in exactly `inter` indices
/// and their union spans `a_len + b_len − inter`, so
/// J = inter / (a_len + b_len − inter) with no sampling error.
/// Contiguous runs are deliberate: schemes or widths that mishandle
/// structure (the reason σ exists) are biased on exactly this data.
///
/// ```
/// use cminhash::util::testutil::overlap_pair;
/// let (a, b, j) = overlap_pair(64, 24, 24, 12);
/// assert_eq!(j, 12.0 / 36.0);
/// assert_eq!(a.jaccard(&b), j);
/// ```
// Test-support code: the constructed index ranges are in `0..dim` by
// the assertions above, so `SparseVec::new` cannot reject them.
#[allow(clippy::disallowed_methods)]
pub fn overlap_pair(
    dim: u32,
    a_len: u32,
    b_len: u32,
    inter: u32,
) -> (SparseVec, SparseVec, f64) {
    assert!(inter <= a_len && inter <= b_len, "inter exceeds a set size");
    assert!(a_len > 0 && b_len > 0, "empty sets have no Jaccard");
    let union = a_len + b_len - inter;
    assert!(a_len - inter + b_len <= dim, "union spills past dim");
    let a = SparseVec::new(dim, (0..a_len).collect()).unwrap();
    let b = SparseVec::new(dim, (a_len - inter..a_len - inter + b_len).collect())
        .unwrap();
    (a, b, f64::from(inter) / f64::from(union))
}

/// Run `f` across `cases` seeded RNGs; panics with the failing seed so
/// a failure is reproducible with `check_with_seed`.
pub fn property(cases: u64, f: impl Fn(&mut crate::util::rng::Rng)) {
    for seed in 0..cases {
        check_with_seed(seed, &f);
    }
}

/// Run one property case under a specific seed.
pub fn check_with_seed(seed: u64, f: &impl Fn(&mut crate::util::rng::Rng)) {
    let mut rng = crate::util::rng::Rng::seed_from_u64(seed ^ 0x70_72_6f_70); // "prop"
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
    if let Err(e) = result {
        eprintln!("property failed under seed {seed}");
        std::panic::resume_unwind(e);
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let t = TempDir::new().unwrap();
            p = t.path().to_path_buf();
            assert!(p.exists());
            std::fs::write(p.join("x"), "y").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn overlap_pair_matches_exact_jaccard() {
        // the canonical J levels used across suites and benches
        for (a_len, b_len, inter, want) in [
            (22u32, 22u32, 4u32, 0.1),
            (24, 24, 12, 1.0 / 3.0),
            (30, 30, 20, 0.5),
            (38, 38, 36, 0.9),
            (32, 32, 32, 1.0),
            (16, 16, 0, 0.0),
            (40, 34, 10, 10.0 / 64.0), // unequal sizes work too
        ] {
            let (a, b, j) = overlap_pair(64, a_len, b_len, inter);
            assert_eq!(j, want, "a={a_len} b={b_len} inter={inter}");
            assert_eq!(a.jaccard(&b), want);
            assert_eq!(a.nnz() as u32, a_len);
            assert_eq!(b.nnz() as u32, b_len);
        }
    }

    #[test]
    fn property_runs_all_seeds() {
        let mut hits = 0u64;
        property(5, |_rng| {
            // no state across cases other than this counter
        });
        hits += 5;
        assert_eq!(hits, 5);
    }

    #[test]
    #[should_panic]
    fn property_propagates_failures() {
        property(3, |rng| {
            assert!(rng.next_f64() < 0.0, "always fails");
        });
    }
}
