//! Deterministic PRNG: Xoshiro256++ seeded through SplitMix64.
//!
//! The paper's entire practical pitch rests on *reproducible*
//! permutations — σ and π must be regenerable from a seed on any
//! machine, forever.  This in-tree implementation pins the exact bit
//! stream (the published reference constants of Blackman & Vigna),
//! independent of any external crate's versioning.

/// SplitMix64 step — used to expand a 64-bit seed into the 256-bit
/// Xoshiro state (the reference seeding procedure), and by the `iuh`
/// hasher to derive its O(1) key material from a seed.
#[inline]
pub(crate) fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (never all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform u32 in `[lo, hi)`.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below(u64::from(hi - lo)) as u32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A fresh permutation of `0..d` as a value array.
    pub fn permutation(&mut self, d: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..d as u32).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn reference_vector_xoshiro256pp() {
        // Reference: seeding state = [1,2,3,4] must produce the known
        // first outputs of xoshiro256++ (from the public reference
        // implementation).
        let mut r = Rng { s: [1, 2, 3, 4] };
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(got, vec![41943041, 58720359, 3588806011781223, 3591011842654386]);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        let mut c = Rng::seed_from_u64(8);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(1);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(2);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(3);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &v in &p {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
    }

    #[test]
    fn shuffle_uniformity_smoke() {
        // Position of element 0 after shuffling [0,1,2] ~ uniform.
        let mut r = Rng::seed_from_u64(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            let mut v = [0usize, 1, 2];
            r.shuffle(&mut v);
            counts[v.iter().position(|&x| x == 0).unwrap()] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }
}
