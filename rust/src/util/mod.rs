//! In-tree substrates for the offline build (DESIGN.md
//! "Substitutions"): a deterministic RNG ([`rng`]), a JSON codec
//! ([`json`]), the shared on-disk checksum ([`fnv`]), and small test
//! helpers ([`testutil`]).

pub mod fnv;
pub mod json;
pub mod rng;
pub mod testutil;
