//! Minimal JSON codec (RFC 8259 subset) — the in-tree replacement for
//! serde_json in this offline build.
//!
//! Supports everything the repo needs: objects, arrays, strings with
//! escapes (incl. `\uXXXX`), numbers (f64; integers round-trip exactly
//! up to 2⁵³), bools, null.  Parsing is recursive-descent with a depth
//! cap; serialization emits compact one-line output (the wire protocol
//! is JSON-lines).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64 internally).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted map; key order is not significant in our formats).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the failure.
    pub at: usize,
    /// Description.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl From<ParseError> for crate::Error {
    fn from(e: ParseError) -> Self {
        crate::Error::Protocol(e.to_string())
    }
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError {
            at: self.i,
            msg: msg.to_string(),
        })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, ParseError> {
        if depth > MAX_DEPTH {
            return self.err("nesting too deep");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(&format!("unexpected {:?}", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected {s}"))
        }
    }

    // The slice is all ASCII digits/sign/dot by the scan above, so
    // `from_utf8` cannot fail.
    #[allow(clippy::disallowed_methods)]
    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match text.parse::<f64>() {
            Ok(x) if x.is_finite() => Ok(Json::Num(x)),
            _ => self.err("bad number"),
        }
    }

    // `chars().next().unwrap()` follows a successful non-empty utf-8
    // validation of the same bytes.
    #[allow(clippy::disallowed_methods)]
    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            match ch {
                                Some(c) => out.push(c),
                                None => return self.err("bad unicode escape"),
                            }
                            continue; // hex4 advanced past the digits
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(_) => {
                    // copy one UTF-8 code point
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| ParseError {
                            at: self.i,
                            msg: "invalid utf-8".into(),
                        })?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.peek() {
                Some(c) => c,
                None => return self.err("eof in \\u"),
            };
            let d = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return self.err("bad hex digit"),
            };
            v = (v << 4) | u32::from(d);
            self.i += 1;
        }
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value(depth + 1)?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return p.err("trailing garbage");
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ----- constructors -----

    /// Object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of u32 values.
    pub fn from_u32s(xs: &[u32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(f64::from(x))).collect())
    }

    /// String value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- accessors (all return crate errors for protocol hygiene) -----

    /// Object field lookup.
    pub fn get(&self, key: &str) -> crate::Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| crate::Error::Protocol(format!("missing field {key:?}"))),
            _ => Err(crate::Error::Protocol(format!(
                "expected object with field {key:?}"
            ))),
        }
    }

    /// Optional object field.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> crate::Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(crate::Error::Protocol("expected number".into())),
        }
    }

    /// As u64 (must be a non-negative integer ≤ 2⁵³).
    pub fn as_u64(&self) -> crate::Result<u64> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > 9.0e15 {
            return Err(crate::Error::Protocol(format!("expected integer, got {x}")));
        }
        Ok(x as u64)
    }

    /// As usize.
    pub fn as_usize(&self) -> crate::Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    /// As u32.
    pub fn as_u32(&self) -> crate::Result<u32> {
        let v = self.as_u64()?;
        u32::try_from(v).map_err(|_| crate::Error::Protocol(format!("{v} out of u32 range")))
    }

    /// As bool.
    pub fn as_bool(&self) -> crate::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(crate::Error::Protocol("expected bool".into())),
        }
    }

    /// As str.
    pub fn as_str(&self) -> crate::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(crate::Error::Protocol("expected string".into())),
        }
    }

    /// As array.
    pub fn as_arr(&self) -> crate::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(crate::Error::Protocol("expected array".into())),
        }
    }

    /// As `Vec<u32>`.
    pub fn as_u32_vec(&self) -> crate::Result<Vec<u32>> {
        self.as_arr()?.iter().map(|v| v.as_u32()).collect()
    }

    /// As `Vec<usize>`.
    pub fn as_usize_vec(&self) -> crate::Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn parse_basic_document() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\ny"}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), 2.5);
        assert!(j.get("b").unwrap().get("c").unwrap().as_bool().unwrap());
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip_is_stable() {
        let src = r#"{"arr":[0,1,18446744073709],"neg":-2.5,"s":"q\"uo\\te","t":true}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn integers_roundtrip_exactly() {
        let j = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(j.to_string(), "9007199254740992");
        let j = Json::from_u32s(&[0, 1, u32::MAX]);
        assert_eq!(j.as_u32_vec().unwrap(), vec![0, 1, u32::MAX]);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
        // non-ASCII survives a write/parse cycle
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "{", "[1,", "\"abc", "{\"a\" 1}", "tru", "01x", "[1]]", "", "nan",
            "{\"a\":}",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn accessor_errors_are_typed() {
        let j = Json::parse(r#"{"x": "s"}"#).unwrap();
        assert!(j.get("x").unwrap().as_u64().is_err());
        assert!(j.get("missing").is_err());
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
    }

    #[test]
    fn deep_nesting_capped() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
    }
}
