//! FNV-1a over bytes — the one shared implementation behind every
//! on-disk checksum (WAL records, snapshots).  The constants are part
//! of the persisted formats: changing them invalidates existing files,
//! which is exactly why they live in one place.

/// 64-bit FNV-1a.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// 32-bit FNV-1a.
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= u32::from(b);
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // classic FNV-1a test vectors
        assert_eq!(fnv1a32(b""), 0x811c_9dc5);
        assert_eq!(fnv1a32(b"a"), 0xe40c_292c);
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
