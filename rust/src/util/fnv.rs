//! FNV-1a over bytes — the one shared implementation behind every
//! on-disk checksum (WAL records, snapshots).  The constants are part
//! of the persisted formats: changing them invalidates existing files,
//! which is exactly why they live in one place.

/// 64-bit FNV-1a.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The 32-bit FNV-1a offset basis — the initial state for
/// [`fnv1a32_more`] when checksumming incrementally.
pub const FNV32_INIT: u32 = 0x811c_9dc5;

/// 32-bit FNV-1a.
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    fnv1a32_more(FNV32_INIT, bytes)
}

/// Fold more bytes into a running 32-bit FNV-1a state, so a checksum
/// can span discontiguous buffers (e.g. a frame's op byte followed by
/// its payload) without concatenating them first.  Start from
/// [`FNV32_INIT`]; `fnv1a32_more(fnv1a32_more(FNV32_INIT, a), b)` ==
/// `fnv1a32(a ++ b)`.
pub fn fnv1a32_more(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state ^= u32::from(b);
        state = state.wrapping_mul(0x0100_0193);
    }
    state
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // classic FNV-1a test vectors
        assert_eq!(fnv1a32(b""), 0x811c_9dc5);
        assert_eq!(fnv1a32(b"a"), 0xe40c_292c);
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn resumable_state_matches_one_shot() {
        assert_eq!(fnv1a32_more(FNV32_INIT, b""), fnv1a32(b""));
        let whole = fnv1a32(b"hello, frame");
        let split = fnv1a32_more(fnv1a32_more(FNV32_INIT, b"hello, "), b"frame");
        assert_eq!(split, whole);
        // byte-at-a-time folding also agrees
        let mut h = FNV32_INIT;
        for b in b"hello, frame" {
            h = fnv1a32_more(h, &[*b]);
        }
        assert_eq!(h, whole);
    }
}
