//! The observability plane: per-request traces with per-stage spans,
//! per-op request counters, and the Prometheus text renderer.
//!
//! Design constraints (this is on every request's hot path):
//!
//! * **Always-on-cheap.**  Span state lives in a preallocated
//!   thread-local array; counters are relaxed atomics; the trace ring
//!   is a fixed vector of slots, each behind its own tiny mutex, so
//!   two finishing requests only contend when they hash to the same
//!   slot.  With tracing disabled (`obs.trace_ring = 0`) every guard
//!   is inert: one relaxed counter bump per request, nothing else.
//! * **Zero dependencies**, like the rest of the crate.
//!
//! A request trace is captured by the server layer: it calls
//! [`Obs::begin_at`] once the op is known (passing the instant the
//! raw bytes arrived, so decode time is inside the total), the layers
//! underneath drop [`stage`] guards around their work (sketch, WAL
//! append, shard routing, band lookup, scoring), and the server calls
//! [`RequestGuard::finish`] after the response bytes are written.
//! Stage spans are attributed through a thread-local sink.  Inline
//! paths record directly; the scoped-thread shard fan-out (large
//! indexes) arms each worker's own sink via [`capture_stages`] and
//! folds the **slowest worker's** stage breakdown back into the
//! request — the critical path the request actually waited on — so
//! band/score time attributes on the threaded path too, and the stage
//! sum stays ≤ the request total (see `docs/OBSERVABILITY.md`).
//!
//! Slow requests (total ≥ `obs.slow_threshold_us`) are additionally
//! **pinned** into a small bounded deque so they survive ring churn
//! under high traffic; the `trace` wire op can read either view.

pub mod prom;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Number of per-request pipeline stages.
pub const NUM_STAGES: usize = 7;

/// A request pipeline stage.  The stages are non-overlapping by
/// construction (no stage guard wraps another), so a trace's stage
/// spans are disjoint slices of its total.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Wire read + parse (JSON line or binary frame → request).
    Decode = 0,
    /// Sketch computation through the batch pump (includes queue wait).
    Sketch = 1,
    /// Write-ahead-log append (durable stores only).
    WalAppend = 2,
    /// Shard routing: batch grouping on ingest, result merge on query.
    ShardRoute = 3,
    /// Band-signature hashing + posting-list collection.
    BandLookup = 4,
    /// Candidate scoring (estimate / popcount kernel).
    Score = 5,
    /// Response serialization + socket write.
    Encode = 6,
}

impl Stage {
    /// All stages, in pipeline order.
    pub const ALL: [Stage; NUM_STAGES] = [
        Stage::Decode,
        Stage::Sketch,
        Stage::WalAppend,
        Stage::ShardRoute,
        Stage::BandLookup,
        Stage::Score,
        Stage::Encode,
    ];

    /// Stable wire/display name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Decode => "decode",
            Stage::Sketch => "sketch",
            Stage::WalAppend => "wal_append",
            Stage::ShardRoute => "shard_route",
            Stage::BandLookup => "band_lookup",
            Stage::Score => "score",
            Stage::Encode => "encode",
        }
    }
}

/// Number of request kinds ([`OpKind`] variants).
pub const NUM_OPS: usize = 17;

/// Every request kind the wire protocols can carry — the label set for
/// the per-op request counters and the `op` field of a trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// `ping`
    Ping = 0,
    /// `sketch`
    Sketch = 1,
    /// `sketch_batch`
    SketchBatch = 2,
    /// `insert`
    Insert = 3,
    /// `insert_batch`
    InsertBatch = 4,
    /// `insert_packed` (binary wire only)
    InsertPacked = 5,
    /// `delete`
    Delete = 6,
    /// `estimate` (by stored ids)
    Estimate = 7,
    /// `estimate_vecs` (by inline vectors)
    EstimateVecs = 8,
    /// `query`
    Query = 9,
    /// `query_batch`
    QueryBatch = 10,
    /// `query_above`
    QueryAbove = 11,
    /// `save`
    Save = 12,
    /// `stats`
    Stats = 13,
    /// `trace`
    Trace = 14,
    /// `metrics`
    Metrics = 15,
    /// `replicate`
    Replicate = 16,
}

impl OpKind {
    /// All ops, in wire-op order.
    pub const ALL: [OpKind; NUM_OPS] = [
        OpKind::Ping,
        OpKind::Sketch,
        OpKind::SketchBatch,
        OpKind::Insert,
        OpKind::InsertBatch,
        OpKind::InsertPacked,
        OpKind::Delete,
        OpKind::Estimate,
        OpKind::EstimateVecs,
        OpKind::Query,
        OpKind::QueryBatch,
        OpKind::QueryAbove,
        OpKind::Save,
        OpKind::Stats,
        OpKind::Trace,
        OpKind::Metrics,
        OpKind::Replicate,
    ];

    /// Stable wire/display name (matches the JSON protocol op strings).
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Ping => "ping",
            OpKind::Sketch => "sketch",
            OpKind::SketchBatch => "sketch_batch",
            OpKind::Insert => "insert",
            OpKind::InsertBatch => "insert_batch",
            OpKind::InsertPacked => "insert_packed",
            OpKind::Delete => "delete",
            OpKind::Estimate => "estimate",
            OpKind::EstimateVecs => "estimate_vecs",
            OpKind::Query => "query",
            OpKind::QueryBatch => "query_batch",
            OpKind::QueryAbove => "query_above",
            OpKind::Save => "save",
            OpKind::Stats => "stats",
            OpKind::Trace => "trace",
            OpKind::Metrics => "metrics",
            OpKind::Replicate => "replicate",
        }
    }

    /// Inverse of [`OpKind::name`].
    pub fn from_name(s: &str) -> Option<OpKind> {
        OpKind::ALL.iter().copied().find(|op| op.name() == s)
    }

    /// The op at discriminant `i` (the binary wire encodes ops as u8).
    pub fn from_index(i: u8) -> Option<OpKind> {
        OpKind::ALL.get(i as usize).copied()
    }
}

/// One completed request: identity, size, wall time, and per-stage
/// spans (µs).  Stage spans are disjoint and sum to ≤ `total_us`
/// (scheduling gaps and un-instrumented glue make up the rest).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Trace {
    /// Completion sequence number (monotonic per server).
    pub seq: u64,
    /// Request kind.
    pub op: OpKind,
    /// Rows in the request (1 for singleton ops).
    pub items: u32,
    /// Wall-clock µs from first request byte to response written.
    pub total_us: u64,
    /// True iff `total_us` ≥ the configured slow threshold
    /// (such traces are pinned past ring churn).
    pub slow: bool,
    /// Per-stage µs, indexed by [`Stage`] discriminant.
    pub stages_us: [u64; NUM_STAGES],
}

impl Trace {
    /// JSON form served by the `trace` wire op.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let stages: Vec<(&str, Json)> = Stage::ALL
            .iter()
            .map(|&s| (s.name(), Json::Num(self.stages_us[s as usize] as f64)))
            .collect();
        Json::obj(vec![
            ("seq", Json::Num(self.seq as f64)),
            ("op", Json::str(self.op.name())),
            ("items", Json::Num(f64::from(self.items))),
            ("total_us", Json::Num(self.total_us as f64)),
            ("slow", Json::Bool(self.slow)),
            ("stages", Json::obj(stages)),
        ])
    }

    /// Parse the [`Trace::to_json`] form (client side of the wire).
    pub fn from_json(j: &crate::util::json::Json) -> crate::Result<Trace> {
        let op_name = j.get("op")?.as_str()?;
        let op = OpKind::from_name(op_name).ok_or_else(|| {
            crate::Error::Invalid(format!("unknown trace op {op_name:?}"))
        })?;
        let stages = j.get("stages")?;
        let mut stages_us = [0u64; NUM_STAGES];
        for s in Stage::ALL {
            stages_us[s as usize] = stages.get(s.name())?.as_u64()?;
        }
        Ok(Trace {
            seq: j.get("seq")?.as_u64()?,
            op,
            items: j.get("items")?.as_u64()? as u32,
            total_us: j.get("total_us")?.as_u64()?,
            slow: j.get("slow")?.as_bool()?,
            stages_us,
        })
    }
}

/// Per-thread span sink.  Inactive outside a traced request, so stage
/// guards dropped by background work (batch pump, recovery) are no-ops.
struct StageSink {
    active: bool,
    us: [u64; NUM_STAGES],
}

thread_local! {
    static SINK: RefCell<StageSink> = const {
        RefCell::new(StageSink {
            active: false,
            us: [0; NUM_STAGES],
        })
    };
}

/// Times one pipeline stage of the current thread's active request;
/// inert (no clock read) when no traced request is active on this
/// thread.  Obtain via [`stage`]; the span is recorded on drop.
pub struct StageGuard {
    stage: Stage,
    start: Option<Instant>,
}

impl Drop for StageGuard {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let us = t0.elapsed().as_micros() as u64;
            SINK.with(|s| s.borrow_mut().us[self.stage as usize] += us);
        }
    }
}

/// Open a span for `st` covering the guard's lifetime.
pub fn stage(st: Stage) -> StageGuard {
    let active = SINK.with(|s| s.borrow().active);
    StageGuard {
        stage: st,
        start: active.then(Instant::now),
    }
}

/// Credit `us` microseconds to `st` directly — for spans measured
/// before the request's op was known (wire decode happens before
/// [`Obs::begin_at`] can run), and for folding worker-side spans
/// captured by [`capture_stages`] back into the request.  No-op when
/// no request is active.
pub fn add_stage_us(st: Stage, us: u64) {
    SINK.with(|s| {
        let mut s = s.borrow_mut();
        if s.active {
            s.us[st as usize] += us;
        }
    });
}

/// True iff the current thread is inside a traced request (its span
/// sink is armed).  Fan-out code checks this before paying for
/// worker-side span capture.
pub fn sink_active() -> bool {
    SINK.with(|s| s.borrow().active)
}

/// Run `f` with *this thread's* sink armed and return `f`'s result
/// together with the per-stage µs its [`stage`] guards recorded.
///
/// This is how scoped worker threads spawned inside a traced request
/// attribute their work: a fresh worker's thread-local sink is
/// inactive, so stage guards dropped on it would be inert — arming it
/// here makes them record normally, and the caller decides how to fold
/// the captured spans back into the request via [`add_stage_us`] (the
/// shard fan-out credits the slowest worker's breakdown: the critical
/// path the request actually waited on, which keeps the stage sum ≤
/// the request total).  The sink is disarmed and zeroed on return, so
/// nothing leaks into later work on the same thread.
pub fn capture_stages<R>(f: impl FnOnce() -> R) -> (R, [u64; NUM_STAGES]) {
    SINK.with(|s| {
        let mut s = s.borrow_mut();
        s.active = true;
        s.us = [0; NUM_STAGES];
    });
    let r = f();
    let us = SINK.with(|s| {
        let mut s = s.borrow_mut();
        s.active = false;
        let us = s.us;
        s.us = [0; NUM_STAGES];
        us
    });
    (r, us)
}

/// Tracks one in-flight request; created by [`Obs::begin_at`].  Call
/// [`RequestGuard::finish`] after the response is written; a guard
/// dropped unfinished (worker error path) deactivates the thread's
/// sink without recording a trace.
pub struct RequestGuard<'a> {
    obs: &'a Obs,
    op: OpKind,
    start: Instant,
    active: bool,
    done: bool,
}

impl RequestGuard<'_> {
    /// Complete the request: capture the thread's stage spans, stamp a
    /// sequence number, and publish the trace into the ring (and the
    /// pinned deque when slow).  `items` is the request's row count.
    pub fn finish(&mut self, items: u32) {
        if self.done {
            return;
        }
        self.done = true;
        if !self.active {
            return;
        }
        let stages_us = SINK.with(|s| {
            let mut s = s.borrow_mut();
            s.active = false;
            s.us
        });
        let total_us = self.start.elapsed().as_micros() as u64;
        let seq = self.obs.seq.fetch_add(1, Ordering::Relaxed);
        let t = Trace {
            seq,
            op: self.op,
            items,
            total_us,
            slow: total_us >= self.obs.slow_threshold_us,
            stages_us,
        };
        self.obs.publish(t);
    }
}

impl Drop for RequestGuard<'_> {
    fn drop(&mut self) {
        if !self.done && self.active {
            SINK.with(|s| s.borrow_mut().active = false);
        }
    }
}

/// The per-server observability state: trace ring, pinned slow traces,
/// and per-op request counters.  One instance per [`crate::coordinator::Coordinator`].
pub struct Obs {
    slow_threshold_us: u64,
    /// Completion sequence; also the ring write cursor.
    seq: AtomicU64,
    /// The trace ring: slot `seq % len`.  Empty = tracing disabled.
    slots: Vec<Mutex<Option<Trace>>>,
    /// Slow traces pinned past ring churn (bounded, FIFO eviction).
    pinned: Mutex<VecDeque<Trace>>,
    pinned_cap: usize,
    /// Requests begun, by [`OpKind`] discriminant.
    ops: [AtomicU64; NUM_OPS],
}

// A poisoned ring/pinned mutex means a tracer panicked mid-publish;
// crashing beats silently serving torn traces.  Every
// `.lock().unwrap()` in this impl is that idiom (see clippy.toml).
#[allow(clippy::disallowed_methods)]
impl Obs {
    /// Build with an explicit ring size (`0` disables tracing — per-op
    /// counters still count), slow threshold, and pinned capacity.
    pub fn new(trace_ring: usize, slow_threshold_us: u64, pinned_cap: usize) -> Obs {
        Obs {
            slow_threshold_us,
            seq: AtomicU64::new(0),
            slots: (0..trace_ring).map(|_| Mutex::new(None)).collect(),
            pinned: Mutex::new(VecDeque::new()),
            pinned_cap,
            ops: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// True iff traces are being captured (`trace_ring > 0`).
    pub fn enabled(&self) -> bool {
        !self.slots.is_empty()
    }

    /// The configured slow-request threshold (µs).
    pub fn slow_threshold_us(&self) -> u64 {
        self.slow_threshold_us
    }

    /// Begin a request of kind `op` whose bytes started arriving at
    /// `start` (so decode time counts toward the total).  Always bumps
    /// the per-op counter; activates span capture only when tracing is
    /// enabled.
    pub fn begin_at(&self, op: OpKind, start: Instant) -> RequestGuard<'_> {
        self.ops[op as usize].fetch_add(1, Ordering::Relaxed);
        let active = self.enabled();
        if active {
            SINK.with(|s| {
                let mut s = s.borrow_mut();
                s.active = true;
                s.us = [0; NUM_STAGES];
            });
        }
        RequestGuard {
            obs: self,
            op,
            start,
            active,
            done: false,
        }
    }

    fn publish(&self, t: Trace) {
        if t.slow && self.pinned_cap > 0 {
            let mut p = self.pinned.lock().unwrap();
            if p.len() == self.pinned_cap {
                p.pop_front();
            }
            p.push_back(t.clone());
        }
        let slot = (t.seq as usize) % self.slots.len();
        *self.slots[slot].lock().unwrap() = Some(t);
    }

    /// The most recent `n` completed traces, newest first.
    pub fn recent(&self, n: usize) -> Vec<Trace> {
        let mut out: Vec<Trace> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        out.sort_by(|a, b| b.seq.cmp(&a.seq));
        out.truncate(n);
        out
    }

    /// The pinned slow traces (up to the configured capacity), newest
    /// first, capped at `n`.
    pub fn pinned(&self, n: usize) -> Vec<Trace> {
        let p = self.pinned.lock().unwrap();
        let mut out: Vec<Trace> = p.iter().rev().take(n).cloned().collect();
        out.sort_by(|a, b| b.seq.cmp(&a.seq));
        out
    }

    /// `(op name, requests begun)` for every op, in [`OpKind::ALL`]
    /// order (zero rows included, so scrape series never appear and
    /// disappear).
    pub fn op_counts(&self) -> Vec<(&'static str, u64)> {
        OpKind::ALL
            .iter()
            .map(|&op| (op.name(), self.ops[op as usize].load(Ordering::Relaxed)))
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    fn run_request(obs: &Obs, op: OpKind, spans: &[(Stage, u64)]) {
        let mut g = obs.begin_at(op, Instant::now());
        for &(st, us) in spans {
            add_stage_us(st, us);
        }
        g.finish(1);
    }

    #[test]
    fn disabled_obs_counts_ops_but_keeps_no_traces() {
        let obs = Obs::new(0, 10, 4);
        assert!(!obs.enabled());
        run_request(&obs, OpKind::Query, &[(Stage::Score, 5)]);
        run_request(&obs, OpKind::Query, &[]);
        run_request(&obs, OpKind::Insert, &[]);
        assert!(obs.recent(10).is_empty());
        assert!(obs.pinned(10).is_empty());
        let counts: std::collections::HashMap<_, _> =
            obs.op_counts().into_iter().collect();
        assert_eq!(counts["query"], 2);
        assert_eq!(counts["insert"], 1);
        assert_eq!(counts["ping"], 0, "unused ops report zero, not absent");
        assert_eq!(obs.op_counts().len(), NUM_OPS);
    }

    #[test]
    fn ring_keeps_the_last_n_traces_newest_first() {
        let obs = Obs::new(4, u64::MAX, 4);
        for i in 0..10 {
            run_request(
                &obs,
                OpKind::Ping,
                &[(Stage::Decode, u64::from(i) + 1)],
            );
        }
        let recent = obs.recent(16);
        assert_eq!(recent.len(), 4, "ring capacity bounds retention");
        let seqs: Vec<u64> = recent.iter().map(|t| t.seq).collect();
        assert_eq!(seqs, vec![9, 8, 7, 6], "newest first");
        assert_eq!(recent[0].stages_us[Stage::Decode as usize], 10);
        assert_eq!(recent[0].op, OpKind::Ping);
        assert_eq!(obs.recent(2).len(), 2, "n caps the answer");
    }

    #[test]
    fn slow_traces_pin_past_ring_churn() {
        // threshold 0: every request is "slow" (total_us >= 0).
        let obs = Obs::new(2, 0, 3);
        for _ in 0..8 {
            run_request(&obs, OpKind::Query, &[]);
        }
        assert_eq!(obs.recent(16).len(), 2, "ring churned down to 2");
        let pinned = obs.pinned(16);
        assert_eq!(pinned.len(), 3, "pinned deque holds the cap");
        assert!(pinned.iter().all(|t| t.slow));
        let seqs: Vec<u64> = pinned.iter().map(|t| t.seq).collect();
        assert_eq!(seqs, vec![7, 6, 5], "FIFO eviction keeps the newest");
        // an impossible threshold pins nothing
        let quiet = Obs::new(2, u64::MAX, 3);
        run_request(&quiet, OpKind::Query, &[]);
        assert!(quiet.pinned(16).is_empty());
        assert!(!quiet.recent(1)[0].slow);
    }

    #[test]
    fn stage_guards_are_inert_without_an_active_request() {
        let obs = Obs::new(4, u64::MAX, 0);
        // no begin_at: guards and add_stage_us must not leak into the
        // next request's trace
        {
            let _g = stage(Stage::Score);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        add_stage_us(Stage::Score, 1_000_000);
        run_request(&obs, OpKind::Ping, &[]);
        let t = &obs.recent(1)[0];
        assert_eq!(t.stages_us[Stage::Score as usize], 0);
    }

    #[test]
    fn stage_guard_measures_inside_an_active_request() {
        let obs = Obs::new(4, u64::MAX, 0);
        let mut g = obs.begin_at(OpKind::Sketch, Instant::now());
        {
            let _s = stage(Stage::Sketch);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        g.finish(3);
        let t = &obs.recent(1)[0];
        assert_eq!(t.op, OpKind::Sketch);
        assert_eq!(t.items, 3);
        assert!(
            t.stages_us[Stage::Sketch as usize] >= 1_000,
            "span {}µs too short",
            t.stages_us[Stage::Sketch as usize]
        );
        assert!(t.total_us >= t.stages_us[Stage::Sketch as usize]);
    }

    #[test]
    fn unfinished_guard_deactivates_the_sink() {
        let obs = Obs::new(4, u64::MAX, 0);
        {
            let _g = obs.begin_at(OpKind::Query, Instant::now());
            // dropped without finish (error path)
        }
        assert!(obs.recent(4).is_empty(), "no trace recorded");
        add_stage_us(Stage::Score, 999);
        run_request(&obs, OpKind::Ping, &[]);
        assert_eq!(
            obs.recent(1)[0].stages_us[Stage::Score as usize],
            0,
            "sink was deactivated; stray spans don't leak forward"
        );
    }

    #[test]
    fn capture_stages_records_worker_spans_without_leaking() {
        let (val, us) = capture_stages(|| {
            add_stage_us(Stage::Score, 7);
            add_stage_us(Stage::BandLookup, 3);
            42
        });
        assert_eq!(val, 42);
        assert_eq!(us[Stage::Score as usize], 7);
        assert_eq!(us[Stage::BandLookup as usize], 3);
        assert!(!sink_active(), "sink disarmed after capture");
        // nothing leaks into a later request on this thread
        let obs = Obs::new(4, u64::MAX, 0);
        let mut g = obs.begin_at(OpKind::Query, Instant::now());
        assert!(sink_active(), "begin_at arms the sink");
        g.finish(1);
        assert_eq!(obs.recent(1)[0].stages_us, [0; NUM_STAGES]);
        assert!(!sink_active());
    }

    #[test]
    fn trace_json_roundtrip() {
        let t = Trace {
            seq: 41,
            op: OpKind::QueryBatch,
            items: 128,
            total_us: 2_250,
            slow: true,
            stages_us: [1, 2, 3, 4, 5, 6, 7],
        };
        let j = t.to_json();
        assert_eq!(Trace::from_json(&j).unwrap(), t);
        // op names and indices roundtrip for every op
        for op in OpKind::ALL {
            assert_eq!(OpKind::from_name(op.name()), Some(op));
            assert_eq!(OpKind::from_index(op as u8), Some(op));
        }
        assert_eq!(OpKind::from_index(NUM_OPS as u8), None);
        assert!(OpKind::from_name("nope").is_none());
    }

    #[test]
    fn concurrent_requests_all_land() {
        let obs = std::sync::Arc::new(Obs::new(64, u64::MAX, 0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let obs = obs.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..4 {
                    let mut g = obs.begin_at(OpKind::Query, Instant::now());
                    add_stage_us(Stage::Score, 1);
                    g.finish(1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(obs.recent(64).len(), 32);
        let counts: std::collections::HashMap<_, _> =
            obs.op_counts().into_iter().collect();
        assert_eq!(counts["query"], 32);
        // every seq 0..32 appears exactly once
        let mut seqs: Vec<u64> = obs.recent(64).iter().map(|t| t.seq).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (0..32).collect::<Vec<u64>>());
    }
}
