//! Prometheus text exposition (version 0.0.4) for the serving
//! metrics — the `metrics` wire op and `cminhash stats --prom`.
//!
//! The renderer consumes the **same** snapshot structs the JSON
//! `stats` op serializes ([`MetricsSnapshot`], [`StoreStats`], the
//! per-op counters), so the two surfaces can never drift: a field
//! added to one is a field added to both, and the round-trip test in
//! `rust/tests/observability.rs` compares them value-for-value.
//!
//! Naming follows the Prometheus conventions: `_total` suffix on
//! counters, base-unit-suffixed gauges, classic `_bucket`/`_sum`/
//! `_count` histogram triplets with cumulative `le` labels.  Our log2
//! histogram buckets cover `[2^i, 2^(i+1))` µs, so the exported `le`
//! bounds are the powers of two `2^(i+1)`.

use crate::metrics::{LatencySnapshot, MetricsSnapshot, BUCKETS};
use crate::sketch::SketchScheme;
use crate::store::StoreStats;
use std::fmt::Write;

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    header(out, name, "counter", help);
    let _ = writeln!(out, "{name} {v}");
}

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    header(out, name, "gauge", help);
    let _ = writeln!(out, "{name} {v}");
}

/// One latency histogram as the classic cumulative-`le` triplet.
fn histogram(out: &mut String, name: &str, help: &str, h: &LatencySnapshot) {
    header(out, name, "histogram", help);
    let mut acc = 0u64;
    for (i, &b) in h.buckets.iter().enumerate() {
        acc += b;
        let le = 1u128 << (i + 1);
        let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {acc}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
    let _ = writeln!(out, "{name}_sum {}", h.sum_us);
    let _ = writeln!(out, "{name}_count {}", h.count);
}

/// Render the full metrics surface as Prometheus text.  `ops` is the
/// per-op request counter table from [`crate::obs::Obs::op_counts`].
pub fn render(
    scheme: SketchScheme,
    m: &MetricsSnapshot,
    s: &StoreStats,
    ops: &[(&'static str, u64)],
) -> String {
    debug_assert_eq!(m.query_latency.buckets.len(), BUCKETS);
    let mut out = String::with_capacity(8192);

    header(
        &mut out,
        "cminhash_build_info",
        "gauge",
        "Build/config identity (value is always 1).",
    );
    let _ = writeln!(
        out,
        "cminhash_build_info{{version=\"{}\",scheme=\"{scheme}\",bits=\"{}\"}} 1",
        env!("CARGO_PKG_VERSION"),
        s.bits
    );
    gauge(
        &mut out,
        "cminhash_uptime_seconds",
        "Seconds since service start.",
        m.uptime_s,
    );

    // Per-op request counters (every op, zeros included, so series
    // never appear/disappear between scrapes).
    header(
        &mut out,
        "cminhash_requests_total",
        "counter",
        "Requests received, by wire op.",
    );
    for &(op, n) in ops {
        let _ = writeln!(out, "cminhash_requests_total{{op=\"{op}\"}} {n}");
    }

    counter(
        &mut out,
        "cminhash_sketches_total",
        "Sketch rows computed.",
        m.sketches,
    );
    counter(
        &mut out,
        "cminhash_batches_total",
        "Engine batches executed.",
        m.batches,
    );
    counter(
        &mut out,
        "cminhash_sparse_batches_total",
        "Batches routed to the sparse artifact.",
        m.sparse_batches,
    );
    counter(
        &mut out,
        "cminhash_pad_rows_total",
        "Padding rows added to partial batches.",
        m.pad_rows,
    );
    counter(
        &mut out,
        "cminhash_queries_total",
        "Query requests served.",
        m.queries,
    );
    counter(
        &mut out,
        "cminhash_estimates_total",
        "Estimate requests served.",
        m.estimates,
    );
    counter(
        &mut out,
        "cminhash_deletes_total",
        "Deletes applied.",
        m.deletes,
    );
    counter(
        &mut out,
        "cminhash_errors_total",
        "Requests rejected with an error.",
        m.errors,
    );
    counter(
        &mut out,
        "cminhash_frame_errors_total",
        "Malformed binary frames survived.",
        m.frame_errors,
    );
    counter(
        &mut out,
        "cminhash_busy_rejections_total",
        "Connections rejected busy (pool saturated).",
        m.busy_rejections,
    );
    counter(
        &mut out,
        "cminhash_accept_errors_total",
        "Transient accept() failures survived.",
        m.accept_errors,
    );
    counter(
        &mut out,
        "cminhash_node_errors_total",
        "Cluster fan-out sub-requests skipped (degraded merges).",
        m.node_errors,
    );
    gauge(
        &mut out,
        "cminhash_mean_batch_fill",
        "Mean rows per executed engine batch.",
        m.mean_batch_fill,
    );

    histogram(
        &mut out,
        "cminhash_sketch_latency_us",
        "End-to-end sketch request latency (µs).",
        &m.sketch_latency,
    );
    histogram(
        &mut out,
        "cminhash_batch_latency_us",
        "Engine execute latency per batch (µs).",
        &m.batch_latency,
    );
    histogram(
        &mut out,
        "cminhash_query_latency_us",
        "Query latency (µs).",
        &m.query_latency,
    );
    histogram(
        &mut out,
        "cminhash_estimate_latency_us",
        "Estimate latency (µs).",
        &m.estimate_latency,
    );
    histogram(
        &mut out,
        "cminhash_fsync_latency_us",
        "Snapshot+WAL durability fsync latency at compaction (µs).",
        &s.fsync,
    );

    gauge(
        &mut out,
        "cminhash_stored_items",
        "Sketches resident in the store.",
        s.stored as f64,
    );
    header(
        &mut out,
        "cminhash_shard_items",
        "gauge",
        "Sketches resident, by shard.",
    );
    for (i, &n) in s.shards.iter().enumerate() {
        let _ = writeln!(out, "cminhash_shard_items{{shard=\"{i}\"}} {n}");
    }
    header(
        &mut out,
        "cminhash_shard_ops_total",
        "counter",
        "Store operations, by shard and kind.",
    );
    for (i, ops) in s.shard_ops.iter().enumerate() {
        let _ = writeln!(
            out,
            "cminhash_shard_ops_total{{shard=\"{i}\",kind=\"insert\"}} {}",
            ops.inserts
        );
        let _ = writeln!(
            out,
            "cminhash_shard_ops_total{{shard=\"{i}\",kind=\"delete\"}} {}",
            ops.deletes
        );
        let _ = writeln!(
            out,
            "cminhash_shard_ops_total{{shard=\"{i}\",kind=\"query\"}} {}",
            ops.queries
        );
    }
    counter(
        &mut out,
        "cminhash_candidates_scored_total",
        "LSH candidates scored across all queries.",
        s.candidates,
    );
    gauge(
        &mut out,
        "cminhash_band_buckets",
        "Occupied band-signature buckets across all shards.",
        s.band_buckets as f64,
    );
    gauge(
        &mut out,
        "cminhash_band_max_bucket",
        "Largest band posting list (collision hot spot).",
        s.band_max_bucket as f64,
    );
    gauge(
        &mut out,
        "cminhash_persisted_bytes",
        "Bytes on disk (snapshot + WAL); 0 without persistence.",
        s.persisted_bytes as f64,
    );
    counter(
        &mut out,
        "cminhash_wal_appended_bytes_total",
        "WAL bytes appended since service start.",
        s.wal_appended_bytes,
    );
    gauge(
        &mut out,
        "cminhash_sketch_bytes",
        "Resident bytes per stored sketch.",
        s.sketch_bytes as f64,
    );
    out
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::metrics::Metrics;

    fn sample() -> (MetricsSnapshot, StoreStats) {
        let m = Metrics::default();
        m.query_latency.record(100);
        m.query_latency.record(200_000);
        m.estimate_latency.record(9);
        m.queries.store(2, std::sync::atomic::Ordering::Relaxed);
        let s = StoreStats {
            stored: 5,
            shards: vec![2, 3],
            persisted_bytes: 77,
            bits: 8,
            sketch_bytes: 16,
            wal_appended_bytes: 1234,
            fsync: LatencySnapshot::default(),
            shard_ops: vec![
                crate::store::ShardOps {
                    inserts: 2,
                    deletes: 0,
                    queries: 4,
                },
                crate::store::ShardOps {
                    inserts: 3,
                    deletes: 1,
                    queries: 4,
                },
            ],
            band_buckets: 40,
            band_max_bucket: 3,
            candidates: 17,
        };
        (m.snapshot(), s)
    }

    #[test]
    fn renders_well_formed_exposition_text() {
        let (m, s) = sample();
        let ops = vec![("query", 2u64), ("ping", 0u64)];
        let text = render(SketchScheme::Cmh, &m, &s, &ops);
        // every non-comment line is `name[{labels}] value`
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(
                    line.starts_with("# HELP ") || line.starts_with("# TYPE "),
                    "{line}"
                );
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect(line);
            assert!(!series.is_empty(), "{line}");
            assert!(value.parse::<f64>().is_ok(), "unparsable value: {line}");
        }
        // spot-check the key series
        assert!(text.contains("cminhash_requests_total{op=\"query\"} 2"));
        assert!(text.contains("cminhash_requests_total{op=\"ping\"} 0"));
        assert!(text.contains("cminhash_queries_total 2"));
        assert!(text.contains("cminhash_query_latency_us_count 2"));
        assert!(text.contains(&format!(
            "cminhash_query_latency_us_sum {}",
            100 + 200_000
        )));
        assert!(text.contains("cminhash_query_latency_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("cminhash_shard_items{shard=\"1\"} 3"));
        assert!(text
            .contains("cminhash_shard_ops_total{shard=\"1\",kind=\"delete\"} 1"));
        assert!(text.contains("cminhash_candidates_scored_total 17"));
        assert!(text.contains("scheme=\"cmh\""));
        assert!(text.contains("bits=\"8\""));
        // cumulative le buckets are monotone and end at count
        let mut last = 0u64;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("cminhash_query_latency_us_bucket{le=\"") {
                let v: u64 = rest.rsplit_once(' ').unwrap().1.parse().unwrap();
                assert!(v >= last, "{line}");
                last = v;
            }
        }
        assert_eq!(last, 2);
    }

    #[test]
    fn bucket_count_matches_histogram_width() {
        let (m, s) = sample();
        let text = render(SketchScheme::Oph, &m, &s, &[]);
        let n = text
            .lines()
            .filter(|l| l.starts_with("cminhash_query_latency_us_bucket{le=\""))
            .count();
        assert_eq!(n, BUCKETS + 1, "every bucket plus +Inf");
    }
}
