//! Client-side half of the wire protocol: the blocking single-node
//! client used by examples, benches and tests, the JSONL bulk loader,
//! and the **cluster client** that spreads a corpus over several
//! independent server processes.
//!
//! Cluster model: every node is a complete single-node server (own
//! store, own id space, own durability directory); nothing on the
//! server side knows it is part of a cluster.  The client owns all
//! cluster semantics:
//!
//! - **Routing** — each inserted row is assigned to exactly one node
//!   by rendezvous (highest-random-weight) hashing: the row's content
//!   key is mixed with every node's id key through the same SplitMix64
//!   finalizer the sharded store uses, and the node with the maximal
//!   mix wins.  Rendezvous hashing means adding a node only moves the
//!   keys that node wins — there is no modulo reshuffle — and routing
//!   is a pure function of (node ids, row content), so any client
//!   instance with the same `cluster.json` routes identically.
//! - **Fan-out queries** — queries go to every node (each holds a
//!   disjoint slice of the corpus) and the per-node top-k lists are
//!   merged per row under the same total order
//!   [`crate::index::sort_neighbors`] uses, extended with the node id
//!   as the final tiebreak — so an N=1 cluster reproduces a direct
//!   single-node query exactly.
//! - **Degraded merges** — a node that fails a sub-request (dead,
//!   stalled past the read timeout, or answering garbage) is skipped:
//!   the merge covers the nodes that answered, the outcome is flagged
//!   [`degraded`](ClusterQuery::degraded) with the failed node ids
//!   listed, and each skipped sub-request increments the client-owned
//!   `node_errors` counter.  Only when **every** node fails does a
//!   cluster call return an error.
//!
//! A stalled node is detected with a socket read timeout; once a
//! timeout fires mid-response the stream position is untrustworthy, so
//! the client drops that connection and redials on the node's next use.

use super::frame;
use super::protocol::{self, Request, Response, WireNeighbor};
use crate::metrics::Metrics;
use crate::sketch::SparseVec;
use crate::store::mix64;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Everything a binary-mode client needs to sketch locally: a hasher
/// rebuilt from the server's advertised scheme/dim/K/seed (schemes are
/// deterministic, so lanes match the server bit-for-bit — the same
/// guarantee offline sketching jobs rely on) plus the packing
/// geometry.
struct BinInfo {
    hasher: Arc<dyn crate::sketch::Sketcher>,
    dim: u32,
    k: usize,
    bits: u8,
}

impl BinInfo {
    /// Sketch + mask + pack one vector exactly as the server would
    /// have on a JSON insert.
    fn pack(&self, v: &SparseVec) -> crate::Result<Vec<u64>> {
        if v.dim() != self.dim {
            return Err(crate::Error::ShapeMismatch {
                what: "vector dim",
                expected: self.dim as usize,
                got: v.dim() as usize,
            });
        }
        if v.nnz() == 0 {
            return Err(crate::Error::Invalid("empty vector".into()));
        }
        let full = self.hasher.sketch_sparse(v.indices());
        let mut out = vec![0u64; crate::sketch::packed_words(self.k, self.bits)];
        crate::sketch::pack_row(&full, self.bits, &mut out);
        Ok(out)
    }
}

/// A minimal blocking client for examples/benches/tests.  Speaks JSON
/// lines by default; [`BlockingClient::binary`] negotiates `bin1` and
/// reroutes the conveniences through binary frames — inserts are
/// sketched **client-side** with the hasher the server advertised and
/// shipped as packed rows (the zero-copy ingest path).
pub struct BlockingClient {
    reader: BufReader<TcpStream>,
    bin: Option<BinInfo>,
}

impl BlockingClient {
    /// Connect to a running server (JSON-lines mode).
    pub fn connect(addr: &str) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(BlockingClient {
            reader: BufReader::new(stream),
            bin: None,
        })
    }

    /// Set (or clear) the socket read timeout.  The cluster client
    /// uses this to detect stalled peers: a node that accepts the
    /// connection but never answers surfaces as a timed-out read
    /// instead of hanging the whole fan-out forever.  After a timeout
    /// fires mid-response the stream position is no longer
    /// trustworthy — drop the client and reconnect.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> crate::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    /// Negotiate `bin1` framing on this connection and build the local
    /// hasher from the parameters the server advertised.  Errors if
    /// the server declines (it stays on JSON and the connection
    /// remains usable) or if negotiation already happened.
    pub fn binary(&mut self) -> crate::Result<()> {
        if self.bin.is_some() {
            return Err(crate::Error::Invalid(
                "connection is already in binary mode".into(),
            ));
        }
        let hello = Json::obj(vec![
            ("op", Json::str("hello")),
            ("proto", Json::str(frame::PROTO_NAME)),
        ]);
        let mut line = hello.to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        let j = Json::parse(&resp)?;
        if !j.get("ok")?.as_bool()? {
            return Err(crate::Error::Protocol(j.get("error")?.as_str()?.to_string()));
        }
        let proto = j.get("proto")?.as_str()?;
        if proto != frame::PROTO_NAME {
            return Err(crate::Error::Protocol(format!(
                "server declined binary mode (answered proto {proto:?})"
            )));
        }
        let scheme = crate::sketch::SketchScheme::parse(j.get("scheme")?.as_str()?)?;
        let dim = j.get("dim")?.as_u32()?;
        let k = j.get("k")?.as_usize()?;
        let seed = j.get("seed")?.as_u64()?;
        let bits = u8::try_from(j.get("bits")?.as_u32()?)
            .map_err(|_| crate::Error::Protocol("advertised bits out of range".into()))?;
        crate::sketch::check_sketch_bits(bits)?;
        let hasher = scheme.build(dim as usize, k, seed)?;
        self.bin = Some(BinInfo {
            hasher,
            dim,
            k,
            bits,
        });
        Ok(())
    }

    /// True once [`BlockingClient::binary`] has negotiated `bin1`.
    pub fn is_binary(&self) -> bool {
        self.bin.is_some()
    }

    /// Guard for the raw JSON entry points after a `bin1` switch.
    fn reject_json_mode(&self) -> crate::Result<()> {
        if self.bin.is_some() {
            return Err(crate::Error::Invalid(
                "connection negotiated bin1; raw JSON ops are unavailable (open \
                 a second JSON connection for save/stats)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Send one request and read one response (JSON mode only).
    pub fn call(&mut self, req: &Request) -> crate::Result<Response> {
        self.reject_json_mode()?;
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        Response::from_json(&Json::parse(&resp)?)
    }

    /// Send one request and return the raw JSON response line
    /// (used for `stats`; JSON mode only).
    pub fn call_raw(&mut self, req: &Request) -> crate::Result<Json> {
        self.reject_json_mode()?;
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        Ok(Json::parse(&resp)?)
    }

    /// Send one binary request frame and read one response frame.
    fn bin_call(&mut self, req: &frame::BinRequest) -> crate::Result<frame::BinResponse> {
        debug_assert!(self.bin.is_some());
        let (op, payload) = req.encode();
        frame::FrameWriter::new(self.reader.get_mut())
            .write_frame(op, &payload)
            .map_err(crate::Error::from)?;
        match frame::FrameReader::new(&mut self.reader)
            .read_frame()
            .map_err(crate::Error::from)?
        {
            None => Err(crate::Error::Shutdown),
            Some((op, payload)) => {
                frame::BinResponse::decode(op, &payload).map_err(crate::Error::from)
            }
        }
    }

    fn vecs(dim: u32, rows: Vec<Vec<u32>>) -> crate::Result<Vec<SparseVec>> {
        rows.into_iter().map(|r| SparseVec::new(dim, r)).collect()
    }

    fn unexpected<T>(resp: impl std::fmt::Debug) -> crate::Result<T> {
        Err(crate::Error::Protocol(format!(
            "unexpected response {resp:?}"
        )))
    }

    /// Convenience: liveness check (either mode).
    pub fn ping(&mut self) -> crate::Result<()> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Ping)? {
                frame::BinResponse::Pong => Ok(()),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: sketch a sparse vector.
    pub fn sketch(&mut self, dim: u32, indices: Vec<u32>) -> crate::Result<Vec<u32>> {
        let vec = SparseVec::new(dim, indices)?;
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Sketch(vec))? {
                frame::BinResponse::Sketch(lanes) => Ok(lanes),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Sketch { vec })? {
            Response::Sketch { sketch } => Ok(sketch),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: sketch many vectors in one round-trip.
    pub fn sketch_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
    ) -> crate::Result<Vec<Vec<u32>>> {
        let vecs = Self::vecs(dim, rows)?;
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::SketchBatch(vecs))? {
                frame::BinResponse::SketchBatch(sketches) => Ok(sketches),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::SketchBatch { vecs })? {
            Response::SketchBatch { sketches } => Ok(sketches),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: insert a sparse vector.  In binary mode the row is
    /// sketched and packed locally, then shipped as a one-row
    /// `insert_packed` frame.
    // `expect("checked")` follows the `self.bin.is_some()` test above it.
    #[allow(clippy::disallowed_methods)]
    pub fn insert(&mut self, dim: u32, indices: Vec<u32>) -> crate::Result<u64> {
        let vec = SparseVec::new(dim, indices)?;
        if self.bin.is_some() {
            let row = self.bin.as_ref().expect("checked").pack(&vec)?;
            let mut ids = self.insert_packed(vec![row])?;
            return match ids.pop() {
                Some(id) if ids.is_empty() => Ok(id),
                _ => Self::unexpected("insert_packed id count != 1"),
            };
        }
        match self.call(&Request::Insert { vec })? {
            Response::Insert { id, .. } => Ok(id),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: insert many vectors as one unit; returns the
    /// assigned (consecutive) ids in row order.
    pub fn insert_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
    ) -> crate::Result<Vec<u64>> {
        self.insert_batch_vecs(Self::vecs(dim, rows)?)
    }

    /// Insert pre-validated vectors as one unit.  JSON mode sends
    /// `insert_batch` (the server sketches); binary mode sketches and
    /// packs every row locally and ships one `insert_packed` frame.
    // `expect("checked")` follows the `self.bin.is_some()` test above it.
    #[allow(clippy::disallowed_methods)]
    pub fn insert_batch_vecs(&mut self, vecs: Vec<SparseVec>) -> crate::Result<Vec<u64>> {
        if self.bin.is_some() {
            let bin = self.bin.as_ref().expect("checked");
            let rows = vecs
                .iter()
                .map(|v| bin.pack(v))
                .collect::<crate::Result<Vec<_>>>()?;
            return self.insert_packed(rows);
        }
        match self.call(&Request::InsertBatch { vecs })? {
            Response::InsertBatch { ids } => Ok(ids),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Ship pre-packed sketch rows ([`crate::sketch::pack_row`] output
    /// at the server's K and b, e.g. from an offline sketching job)
    /// down the zero-copy ingest path.  Binary mode only.
    pub fn insert_packed(&mut self, rows: Vec<Vec<u64>>) -> crate::Result<Vec<u64>> {
        if self.bin.is_none() {
            return Err(crate::Error::Invalid(
                "insert_packed requires binary mode (call binary() first)".into(),
            ));
        }
        let words_per_row = rows.first().map_or(0, Vec::len);
        match self.bin_call(&frame::BinRequest::InsertPacked {
            words_per_row,
            rows,
        })? {
            frame::BinResponse::Ids(ids) => Ok(ids),
            frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: delete a stored id.
    pub fn delete(&mut self, id: u64) -> crate::Result<()> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Delete(id))? {
                frame::BinResponse::Deleted(_) => Ok(()),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Delete { id })? {
            Response::Deleted { .. } => Ok(()),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: estimate Ĵ between two stored ids (either mode).
    pub fn estimate(&mut self, a: u64, b: u64) -> crate::Result<f64> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Estimate(a, b))? {
                frame::BinResponse::Estimate(jhat) => Ok(jhat),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Estimate { a, b })? {
            Response::Estimate { jhat } => Ok(jhat),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: top-k query (a one-row `query_batch` in binary
    /// mode — binary keeps the batch surface only).
    pub fn query(
        &mut self,
        dim: u32,
        indices: Vec<u32>,
        topk: usize,
    ) -> crate::Result<Vec<WireNeighbor>> {
        let vec = SparseVec::new(dim, indices)?;
        if self.bin.is_some() {
            let mut results = match self.bin_call(&frame::BinRequest::QueryBatch {
                vecs: vec![vec],
                topk,
            })? {
                frame::BinResponse::Results(results) => results,
                frame::BinResponse::Err(error) => {
                    return Err(crate::Error::Protocol(error))
                }
                other => return Self::unexpected(other),
            };
            return match results.pop() {
                Some(ns) if results.is_empty() => Ok(ns),
                _ => Self::unexpected("query result row count != 1"),
            };
        }
        match self.call(&Request::Query { vec, topk })? {
            Response::Query { neighbors } => Ok(neighbors),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: fetch up to `n` recent request traces, newest
    /// first — or the pinned slow-trace FIFO when `pinned` is true
    /// (either mode).
    pub fn trace(
        &mut self,
        n: usize,
        pinned: bool,
    ) -> crate::Result<Vec<crate::obs::Trace>> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Trace { n, pinned })? {
                frame::BinResponse::Trace(traces) => Ok(traces),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Trace { n, pinned })? {
            Response::Trace { traces } => Ok(traces),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: fetch the server's Prometheus text exposition
    /// (either mode).
    pub fn metrics_text(&mut self) -> crate::Result<String> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Metrics)? {
                frame::BinResponse::Metrics(text) => Ok(text),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Metrics)? {
            Response::Metrics { text } => Ok(text),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: fetch the server's durable image — raw snapshot
    /// bytes plus the WAL tail written since that snapshot — so a
    /// fresh node can bootstrap from this one (either mode).  Errors
    /// if the server runs without persistence.
    pub fn replicate(&mut self) -> crate::Result<(Vec<u8>, Vec<u8>)> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Replicate)? {
                frame::BinResponse::Replicate { snapshot, wal } => Ok((snapshot, wal)),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Replicate)? {
            Response::Replicate { snapshot, wal } => Ok((snapshot, wal)),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: top-k queries for many vectors in one round-trip;
    /// one neighbor list per row, in row order.
    pub fn query_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
        topk: usize,
    ) -> crate::Result<Vec<Vec<WireNeighbor>>> {
        let vecs = Self::vecs(dim, rows)?;
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::QueryBatch { vecs, topk })? {
                frame::BinResponse::Results(results) => Ok(results),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::QueryBatch { vecs, topk })? {
            Response::QueryBatch { results } => Ok(results),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }
}

/// One member of a cluster: a stable id (the routing identity — it,
/// not the address, is what rendezvous hashing keys on, so a node can
/// move ports without reshuffling the corpus) and its `host:port`.
#[derive(Clone, Debug)]
pub struct ClusterNode {
    /// Stable routing identity; must be unique within the cluster.
    pub id: String,
    /// The node's `host:port` listen address.
    pub addr: String,
}

/// Cluster topology + client behavior, loaded from `configs/
/// cluster.json`: `{"timeout_ms": 2000, "nodes": [{"id": "a",
/// "addr": "127.0.0.1:7878"}, ...]}`.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Socket read timeout per sub-request in milliseconds; a node
    /// that stays silent this long is treated as failed for the
    /// current call.  `0` disables the timeout (a stalled node then
    /// blocks its call forever — only sensible in controlled tests).
    pub timeout_ms: u64,
    /// The member nodes.  One node is a valid (if pointless) cluster
    /// and behaves exactly like a direct single-node client.
    pub nodes: Vec<ClusterNode>,
}

impl ClusterConfig {
    /// Default per-sub-request read timeout when the file omits
    /// `timeout_ms`.
    pub const DEFAULT_TIMEOUT_MS: u64 = 2_000;

    /// Parse and validate a topology document.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let timeout_ms = match j.get_opt("timeout_ms") {
            Some(t) => t.as_u64()?,
            None => Self::DEFAULT_TIMEOUT_MS,
        };
        let mut nodes = Vec::new();
        for n in j.get("nodes")?.as_arr()? {
            let id = n.get("id")?.as_str()?.to_string();
            let addr = n.get("addr")?.as_str()?.to_string();
            if id.is_empty() {
                return Err(crate::Error::Invalid(
                    "cluster node id must be non-empty".into(),
                ));
            }
            nodes.push(ClusterNode { id, addr });
        }
        if nodes.is_empty() {
            return Err(crate::Error::Invalid(
                "cluster config needs at least one node".into(),
            ));
        }
        for i in 1..nodes.len() {
            if nodes[..i].iter().any(|n| n.id == nodes[i].id) {
                return Err(crate::Error::Invalid(format!(
                    "duplicate cluster node id {:?}",
                    nodes[i].id
                )));
            }
        }
        Ok(ClusterConfig { timeout_ms, nodes })
    }

    /// Load and validate a topology file.
    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?).map_err(|e| {
            crate::Error::Invalid(format!("{}: {e}", path.display()))
        })
    }
}

/// A neighbor from a cluster query.  Ids are only unique **per node**
/// (every node runs its own id assigner), so a cluster result carries
/// the answering node's id alongside.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterNeighbor {
    /// Id of the node holding this row.
    pub node: String,
    /// The row's id within that node.
    pub id: u64,
    /// Estimated Jaccard similarity.
    pub score: f64,
}

/// Outcome of a cluster query fan-out.
#[derive(Clone, Debug)]
pub struct ClusterQuery {
    /// Merged neighbor lists, one per query row, each under the
    /// cluster total order (score desc, id asc, node id asc).
    pub results: Vec<Vec<ClusterNeighbor>>,
    /// True when at least one node failed and the merge is partial.
    pub degraded: bool,
    /// Ids of the nodes that failed this call, in topology order.
    pub failed_nodes: Vec<String>,
}

/// Outcome of a cluster batched insert.
#[derive(Clone, Debug)]
pub struct ClusterInsert {
    /// Per input row (in order): the owning node's id and the id it
    /// assigned, or `None` when the owner was down and the row was
    /// skipped.
    pub ids: Vec<Option<(String, u64)>>,
    /// Rows actually inserted (`ids` entries that are `Some`).
    pub inserted: u64,
    /// True when at least one owning node failed and rows were skipped.
    pub degraded: bool,
    /// Ids of the nodes that failed this call, in topology order.
    pub failed_nodes: Vec<String>,
}

/// FNV-1a 64-bit over a byte stream — the content hash rendezvous
/// routing feeds into [`mix64`].
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content key of one vector: dim plus every index, order-sensitive
/// (SparseVec indices are validated strictly increasing, so equal sets
/// hash equal).
fn row_key(v: &SparseVec) -> u64 {
    let mut bytes = Vec::with_capacity(4 + v.indices().len() * 4);
    bytes.extend_from_slice(&v.dim().to_le_bytes());
    for &i in v.indices() {
        bytes.extend_from_slice(&i.to_le_bytes());
    }
    fnv1a64(&bytes)
}

/// Rendezvous (highest-random-weight) choice: every node scores
/// `mix64(node_key ^ key)` and the highest score wins, with the lower
/// node index breaking (astronomically unlikely) ties.
fn rendezvous(node_keys: &[u64], key: u64) -> usize {
    let mut best = 0usize;
    let mut best_score = 0u64;
    for (i, &nk) in node_keys.iter().enumerate() {
        let score = mix64(nk ^ key);
        if i == 0 || score > best_score {
            best = i;
            best_score = score;
        }
    }
    best
}

/// Sort one merged result row under the cluster total order: the
/// [`crate::index::sort_neighbors`] order (score desc, id asc)
/// extended with the node id as the final tiebreak, so merged output
/// is deterministic no matter which node answered first.
fn sort_cluster_neighbors(xs: &mut [ClusterNeighbor]) {
    xs.sort_by(|x, y| {
        y.score
            .total_cmp(&x.score)
            .then(x.id.cmp(&y.id))
            .then(x.node.cmp(&y.node))
    });
}

/// Client-side cluster coordinator: routes inserts by rendezvous
/// hashing, fans queries out to every node, merges deterministically,
/// and degrades gracefully when members die (see the module docs).
/// Connections are dialed lazily per node and redialed after any
/// failure; the client owns its own [`Metrics`] registry, whose
/// `node_errors` counter tallies skipped sub-requests.
pub struct ClusterClient {
    nodes: Vec<ClusterNode>,
    node_keys: Vec<u64>,
    conns: Vec<Option<BlockingClient>>,
    timeout: Option<Duration>,
    metrics: Arc<Metrics>,
}

impl ClusterClient {
    /// Build a client over a validated topology.  No sockets are
    /// opened yet — each node is dialed on first use, so a dead member
    /// costs its own sub-requests only.
    pub fn connect(cfg: ClusterConfig) -> crate::Result<Self> {
        let node_keys = cfg
            .nodes
            .iter()
            .map(|n| fnv1a64(n.id.as_bytes()))
            .collect();
        let conns = cfg.nodes.iter().map(|_| None).collect();
        Ok(ClusterClient {
            nodes: cfg.nodes,
            node_keys,
            conns,
            timeout: (cfg.timeout_ms > 0).then(|| Duration::from_millis(cfg.timeout_ms)),
            metrics: Arc::new(Metrics::default()),
        })
    }

    /// Number of member nodes.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// The id of node `i` (topology order).
    pub fn node_id(&self, i: usize) -> &str {
        &self.nodes[i].id
    }

    /// The client-owned metrics registry (`node_errors` lives here).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Which node owns a row with these contents.
    pub fn route(&self, dim: u32, indices: &[u32]) -> crate::Result<usize> {
        let v = SparseVec::new(dim, indices.to_vec())?;
        Ok(rendezvous(&self.node_keys, row_key(&v)))
    }

    /// Lazily dial node `i` (with the read timeout applied).
    fn conn(&mut self, i: usize) -> crate::Result<&mut BlockingClient> {
        if self.conns[i].is_none() {
            let mut c = BlockingClient::connect(&self.nodes[i].addr)?;
            c.set_read_timeout(self.timeout)?;
            self.conns[i] = Some(c);
        }
        // just ensured above; the ok_or_else can never fire
        self.conns[i].as_mut().ok_or(crate::Error::Shutdown)
    }

    /// Run one sub-request against node `i`.  Any failure (dial,
    /// timeout, I/O, protocol) drops that node's connection — a
    /// timed-out stream is at an unknown position — and bumps
    /// `node_errors`; the caller decides whether the whole call
    /// degrades or fails.
    fn try_node<T>(
        &mut self,
        i: usize,
        f: impl FnOnce(&mut BlockingClient) -> crate::Result<T>,
    ) -> crate::Result<T> {
        let r = match self.conn(i) {
            Ok(c) => f(c),
            Err(e) => Err(e),
        };
        if r.is_err() {
            self.conns[i] = None;
            Metrics::inc(&self.metrics.node_errors);
        }
        r
    }

    /// Insert a batch of rows, each routed to its rendezvous owner and
    /// shipped in one per-node `insert_batch` sub-request.  Rows owned
    /// by a failed node are skipped (their `ids` slots stay `None`)
    /// and the outcome is flagged degraded; the call only errors when
    /// the input itself is invalid or **every** involved node failed.
    pub fn insert_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
    ) -> crate::Result<ClusterInsert> {
        let vecs: Vec<SparseVec> = rows
            .into_iter()
            .map(|r| SparseVec::new(dim, r))
            .collect::<crate::Result<_>>()?;
        let n = self.nodes.len();
        let mut per_node: Vec<Vec<usize>> = (0..n).map(|_| Vec::new()).collect();
        for (slot, v) in vecs.iter().enumerate() {
            per_node[rendezvous(&self.node_keys, row_key(v))].push(slot);
        }
        let mut vecs: Vec<Option<SparseVec>> = vecs.into_iter().map(Some).collect();
        let mut out = ClusterInsert {
            ids: (0..vecs.len()).map(|_| None).collect(),
            inserted: 0,
            degraded: false,
            failed_nodes: Vec::new(),
        };
        let mut answered = 0usize;
        let mut involved = 0usize;
        for (node, slots) in per_node.iter().enumerate() {
            if slots.is_empty() {
                continue;
            }
            involved += 1;
            let batch: Vec<SparseVec> =
                slots.iter().filter_map(|&s| vecs[s].take()).collect();
            match self.try_node(node, |c| c.insert_batch_vecs(batch)) {
                Ok(ids) if ids.len() == slots.len() => {
                    answered += 1;
                    for (&slot, id) in slots.iter().zip(ids) {
                        out.ids[slot] = Some((self.nodes[node].id.clone(), id));
                        out.inserted += 1;
                    }
                }
                Ok(_) => {
                    // wrong id count is a node fault, not an input fault
                    self.conns[node] = None;
                    Metrics::inc(&self.metrics.node_errors);
                    out.degraded = true;
                    out.failed_nodes.push(self.nodes[node].id.clone());
                }
                Err(_) => {
                    out.degraded = true;
                    out.failed_nodes.push(self.nodes[node].id.clone());
                }
            }
        }
        if involved > 0 && answered == 0 {
            return Err(crate::Error::Protocol(format!(
                "all {involved} involved cluster nodes failed the insert"
            )));
        }
        Ok(out)
    }

    /// Top-k queries for a batch of rows: every node answers for its
    /// slice of the corpus, and the per-row partial lists are merged
    /// under the cluster total order.  A failed node is skipped and
    /// the outcome flagged degraded; only all nodes failing is an
    /// error.
    pub fn query_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
        topk: usize,
    ) -> crate::Result<ClusterQuery> {
        // validate input once up front — input faults are the
        // caller's, never a degraded merge
        let _ = BlockingClient::vecs(dim, rows.clone())?;
        let nrows = rows.len();
        let mut out = ClusterQuery {
            results: (0..nrows).map(|_| Vec::new()).collect(),
            degraded: false,
            failed_nodes: Vec::new(),
        };
        let mut answered = 0usize;
        for node in 0..self.nodes.len() {
            let rows = rows.clone();
            match self.try_node(node, |c| c.query_batch(dim, rows, topk)) {
                Ok(results) if results.len() == nrows => {
                    answered += 1;
                    for (row, ns) in results.into_iter().enumerate() {
                        out.results[row].extend(ns.into_iter().map(|n| {
                            ClusterNeighbor {
                                node: self.nodes[node].id.clone(),
                                id: n.id,
                                score: n.score,
                            }
                        }));
                    }
                }
                Ok(_) => {
                    self.conns[node] = None;
                    Metrics::inc(&self.metrics.node_errors);
                    out.degraded = true;
                    out.failed_nodes.push(self.nodes[node].id.clone());
                }
                Err(_) => {
                    out.degraded = true;
                    out.failed_nodes.push(self.nodes[node].id.clone());
                }
            }
        }
        if answered == 0 {
            return Err(crate::Error::Protocol(format!(
                "all {} cluster nodes failed the query",
                self.nodes.len()
            )));
        }
        for merged in &mut out.results {
            sort_cluster_neighbors(merged);
            merged.truncate(topk);
        }
        Ok(out)
    }

    /// Top-k query for one row (a one-row [`ClusterClient::query_batch`]).
    pub fn query(
        &mut self,
        dim: u32,
        indices: Vec<u32>,
        topk: usize,
    ) -> crate::Result<(Vec<ClusterNeighbor>, bool, Vec<String>)> {
        let mut q = self.query_batch(dim, vec![indices], topk)?;
        match q.results.pop() {
            Some(ns) if q.results.is_empty() => Ok((ns, q.degraded, q.failed_nodes)),
            _ => Err(crate::Error::Protocol(
                "cluster query returned wrong row count".into(),
            )),
        }
    }

    /// Fetch node `i`'s durable image (snapshot + WAL tail) for
    /// bootstrapping a fresh member.  A replicate fault is a hard
    /// error — there is nothing to degrade to — but still counts in
    /// `node_errors` and drops the connection like any other failure.
    pub fn replicate_from(&mut self, i: usize) -> crate::Result<(Vec<u8>, Vec<u8>)> {
        self.try_node(i, BlockingClient::replicate)
    }
}

/// Cumulative progress of a [`load_jsonl`] bulk ingest.
#[derive(Clone, Copy, Debug)]
pub struct LoadReport {
    /// Vector rows inserted so far.
    pub rows: u64,
    /// `insert_batch` round-trips issued so far.
    pub batches: u64,
    /// Wall-clock seconds elapsed.
    pub secs: f64,
}

impl LoadReport {
    /// Ingest throughput in rows per second (0 before the clock moves).
    pub fn rows_per_sec(&self) -> f64 {
        if self.secs > 0.0 {
            self.rows as f64 / self.secs
        } else {
            0.0
        }
    }
}

/// Stream a JSONL vector file — one `{"dim":D,"indices":[...]}` object
/// per line, blank lines skipped — into a running server through
/// `insert_batch` round-trips of up to `batch_size` rows.  `progress`
/// is called after every round-trip with cumulative counts (the CLI
/// prints a throughput line from it).  Ingest is sequential over one
/// connection; a bad line or a rejected batch aborts with an error
/// naming the offending line.
pub fn load_jsonl(
    addr: &str,
    path: &std::path::Path,
    batch_size: usize,
    progress: impl FnMut(&LoadReport),
) -> crate::Result<LoadReport> {
    load_jsonl_with(addr, path, batch_size, false, progress)
}

/// Same as [`load_jsonl`], but negotiates `bin1` first: every batch is
/// sketched and packed **client-side** and shipped as one
/// `insert_packed` frame, so the server's ingest work per row is a
/// checksum verification plus a copy into the packed arena.  Results
/// are identical to the JSON path — the client's hasher is rebuilt
/// from the parameters the server advertised at negotiation.
pub fn load_jsonl_binary(
    addr: &str,
    path: &std::path::Path,
    batch_size: usize,
    progress: impl FnMut(&LoadReport),
) -> crate::Result<LoadReport> {
    load_jsonl_with(addr, path, batch_size, true, progress)
}

fn load_jsonl_with(
    addr: &str,
    path: &std::path::Path,
    batch_size: usize,
    binary: bool,
    mut progress: impl FnMut(&LoadReport),
) -> crate::Result<LoadReport> {
    if batch_size == 0 {
        return Err(crate::Error::Invalid("batch size must be > 0".into()));
    }
    if batch_size > protocol::MAX_WIRE_BATCH {
        return Err(crate::Error::Invalid(format!(
            "batch size {batch_size} exceeds the wire cap of {} rows per \
             request",
            protocol::MAX_WIRE_BATCH
        )));
    }
    let file = std::fs::File::open(path)?;
    let reader = BufReader::new(file);
    let mut client = BlockingClient::connect(addr)?;
    if binary {
        client.binary()?;
    }
    let t0 = Instant::now();
    let mut report = LoadReport {
        rows: 0,
        batches: 0,
        secs: 0.0,
    };
    let mut pending: Vec<SparseVec> = Vec::with_capacity(batch_size);
    let mut first_line = 0usize; // 1-based line number of pending[0]
    let mut flush = |pending: &mut Vec<SparseVec>,
                     report: &mut LoadReport,
                     client: &mut BlockingClient,
                     first_line: usize|
     -> crate::Result<()> {
        if pending.is_empty() {
            return Ok(());
        }
        let n = pending.len();
        let ids = client
            .insert_batch_vecs(std::mem::take(pending))
            .map_err(|e| {
                crate::Error::Protocol(format!(
                    "batch starting at line {first_line} rejected: {e}"
                ))
            })?;
        if ids.len() != n {
            return Err(crate::Error::Protocol(format!(
                "insert returned {} ids for {n} rows",
                ids.len()
            )));
        }
        report.rows += n as u64;
        report.batches += 1;
        report.secs = t0.elapsed().as_secs_f64();
        Ok(())
    };
    for (idx, line) in reader.lines().enumerate() {
        let lineno = idx + 1;
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(&line)
            .map_err(crate::Error::from)
            .and_then(|j| SparseVec::from_json(&j))
            .map_err(|e| {
                crate::Error::Invalid(format!("{}:{lineno}: {e}", path.display()))
            })?;
        if pending.is_empty() {
            first_line = lineno;
        }
        pending.push(parsed);
        if pending.len() == batch_size {
            flush(&mut pending, &mut report, &mut client, first_line)?;
            progress(&report);
        }
    }
    if !pending.is_empty() {
        flush(&mut pending, &mut report, &mut client, first_line)?;
        progress(&report);
    }
    report.secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Stream a JSONL vector file into a **cluster**: rows are read in
/// `batch_size` chunks and each chunk goes through
/// [`ClusterClient::insert_batch`], which splits it into per-node
/// sub-batches by rendezvous routing.  Rows skipped by degraded
/// inserts are *not* counted in the report's `rows`; `progress` sees
/// cumulative inserted counts.  Errors only on bad input or when a
/// whole chunk finds every involved node dead.
pub fn load_jsonl_cluster(
    cfg: ClusterConfig,
    path: &std::path::Path,
    batch_size: usize,
    mut progress: impl FnMut(&LoadReport),
) -> crate::Result<LoadReport> {
    if batch_size == 0 {
        return Err(crate::Error::Invalid("batch size must be > 0".into()));
    }
    if batch_size > protocol::MAX_WIRE_BATCH {
        return Err(crate::Error::Invalid(format!(
            "batch size {batch_size} exceeds the wire cap of {} rows per \
             request",
            protocol::MAX_WIRE_BATCH
        )));
    }
    let file = std::fs::File::open(path)?;
    let reader = BufReader::new(file);
    let mut client = ClusterClient::connect(cfg)?;
    let t0 = Instant::now();
    let mut report = LoadReport {
        rows: 0,
        batches: 0,
        secs: 0.0,
    };
    let mut pending: Vec<Vec<u32>> = Vec::with_capacity(batch_size);
    let mut dim: u32 = 0;
    let mut first_line = 0usize;
    let mut flush = |pending: &mut Vec<Vec<u32>>,
                     report: &mut LoadReport,
                     client: &mut ClusterClient,
                     dim: u32,
                     first_line: usize|
     -> crate::Result<()> {
        if pending.is_empty() {
            return Ok(());
        }
        let out = client
            .insert_batch(dim, std::mem::take(pending))
            .map_err(|e| {
                crate::Error::Protocol(format!(
                    "batch starting at line {first_line} rejected: {e}"
                ))
            })?;
        report.rows += out.inserted;
        report.batches += 1;
        report.secs = t0.elapsed().as_secs_f64();
        Ok(())
    };
    for (idx, line) in reader.lines().enumerate() {
        let lineno = idx + 1;
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(&line)
            .map_err(crate::Error::from)
            .and_then(|j| SparseVec::from_json(&j))
            .map_err(|e| {
                crate::Error::Invalid(format!("{}:{lineno}: {e}", path.display()))
            })?;
        if pending.is_empty() {
            first_line = lineno;
            dim = parsed.dim();
        }
        pending.push(parsed.indices().to_vec());
        if pending.len() == batch_size {
            flush(&mut pending, &mut report, &mut client, dim, first_line)?;
            progress(&report);
        }
    }
    if !pending.is_empty() {
        flush(&mut pending, &mut report, &mut client, dim, first_line)?;
        progress(&report);
    }
    report.secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn load_report_throughput() {
        let r = LoadReport {
            rows: 100,
            batches: 2,
            secs: 4.0,
        };
        assert_eq!(r.rows_per_sec(), 25.0);
        let r = LoadReport {
            rows: 0,
            batches: 0,
            secs: 0.0,
        };
        assert_eq!(r.rows_per_sec(), 0.0);
    }

    #[test]
    fn cluster_config_parses_and_validates() {
        let j = Json::parse(
            r#"{"timeout_ms": 250, "nodes": [
                {"id": "a", "addr": "127.0.0.1:7878"},
                {"id": "b", "addr": "127.0.0.1:7879"}]}"#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_json(&j).unwrap();
        assert_eq!(cfg.timeout_ms, 250);
        assert_eq!(cfg.nodes.len(), 2);
        assert_eq!(cfg.nodes[1].id, "b");

        // timeout defaults when omitted
        let j = Json::parse(r#"{"nodes": [{"id": "a", "addr": "x:1"}]}"#).unwrap();
        assert_eq!(
            ClusterConfig::from_json(&j).unwrap().timeout_ms,
            ClusterConfig::DEFAULT_TIMEOUT_MS
        );

        // rejected: empty node list, duplicate ids, empty id
        for bad in [
            r#"{"nodes": []}"#,
            r#"{"nodes": [{"id": "a", "addr": "x:1"}, {"id": "a", "addr": "x:2"}]}"#,
            r#"{"nodes": [{"id": "", "addr": "x:1"}]}"#,
        ] {
            assert!(
                ClusterConfig::from_json(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn rendezvous_routing_is_deterministic_and_covers_all_nodes() {
        let node_keys: Vec<u64> =
            ["a", "b", "c", "d"].iter().map(|s| fnv1a64(s.as_bytes())).collect();
        let mut owned = vec![0u32; node_keys.len()];
        for key in 0..4096u64 {
            let first = rendezvous(&node_keys, mix64(key));
            // pure function of (node keys, row key)
            assert_eq!(rendezvous(&node_keys, mix64(key)), first);
            owned[first] += 1;
        }
        // 4096 keys over 4 nodes: every node owns a meaningful share
        for (i, &n) in owned.iter().enumerate() {
            assert!(n > 512, "node {i} owns only {n} of 4096 keys");
        }
    }

    #[test]
    fn rendezvous_only_moves_keys_the_new_node_wins() {
        // growing the topology must never move a key between two
        // pre-existing nodes — that is the point of rendezvous hashing
        let three: Vec<u64> =
            ["a", "b", "c"].iter().map(|s| fnv1a64(s.as_bytes())).collect();
        let four: Vec<u64> =
            ["a", "b", "c", "d"].iter().map(|s| fnv1a64(s.as_bytes())).collect();
        let mut moved_to_new = 0u32;
        for key in 0..2048u64 {
            let before = rendezvous(&three, mix64(key));
            let after = rendezvous(&four, mix64(key));
            if before != after {
                assert_eq!(after, 3, "key moved between pre-existing nodes");
                moved_to_new += 1;
            }
        }
        // the new node won roughly a quarter of the keyspace
        assert!(moved_to_new > 256, "new node won only {moved_to_new} keys");
    }

    #[test]
    fn row_key_depends_on_content() {
        let v1 = SparseVec::new(64, vec![1, 5, 9]).unwrap();
        let v2 = SparseVec::new(64, vec![1, 5, 9]).unwrap();
        let v3 = SparseVec::new(64, vec![1, 5, 10]).unwrap();
        let v4 = SparseVec::new(128, vec![1, 5, 9]).unwrap();
        assert_eq!(row_key(&v1), row_key(&v2));
        assert_ne!(row_key(&v1), row_key(&v3));
        assert_ne!(row_key(&v1), row_key(&v4), "dim is part of the key");
    }

    #[test]
    fn cluster_merge_order_is_total_and_deterministic() {
        let n = |node: &str, id: u64, score: f64| ClusterNeighbor {
            node: node.into(),
            id,
            score,
        };
        let mut xs = vec![
            n("b", 7, 0.5),
            n("a", 7, 0.5),  // same score+id: node id breaks the tie
            n("a", 3, 0.5),  // same score: lower id first
            n("c", 99, 0.9), // higher score first
            n("a", 1, 0.1),
        ];
        sort_cluster_neighbors(&mut xs);
        assert_eq!(
            xs,
            vec![
                n("c", 99, 0.9),
                n("a", 3, 0.5),
                n("a", 7, 0.5),
                n("b", 7, 0.5),
                n("a", 1, 0.1),
            ]
        );
    }
}
