//! Binary wire framing (`bin1`) for the serving protocol.
//!
//! JSON-lines (see [`super::protocol`]) stays the default dialect; a
//! client that sends `{"op":"hello","proto":"bin1"}` as its first line
//! switches the connection to length-prefixed binary frames:
//!
//! ```text
//! | len: u32 LE | crc: u32 LE | op: u8 | payload: (len - 1) bytes |
//! ```
//!
//! `len` counts the op byte plus the payload (so a bare op frame has
//! `len = 1`; `len = 0` is malformed), and `crc` is the FNV-1a32
//! checksum of the op byte followed by the payload (see
//! [`crate::util::fnv`]).  All multi-byte integers on the wire are
//! little-endian.  The frame body is bounded by [`MAX_FRAME_BYTES`] so
//! a corrupt or hostile length prefix cannot make the server buffer
//! unbounded memory.
//!
//! The payoff is the ingest path: a binary `insert_packed` frame
//! carries [`crate::sketch::pack_row`] output byte-for-byte, so the
//! server verifies the checksum and copies words straight into the
//! packed arena — no JSON parse, no re-sketch, no per-lane widening.
//!
//! ## Error recovery
//!
//! [`FrameError`] distinguishes faults that leave the stream **synced**
//! (the full declared body was consumed, so the next byte starts the
//! next frame: bad checksum, unknown op, malformed payload) from faults
//! where the byte position is unknowable or the peer is gone (truncated
//! stream, oversized declared length, I/O).  Servers answer synced
//! faults with one [`BinResponse::Err`] frame and keep the connection;
//! unsynced faults close it.  Both increment the `frame_errors` metric.
//!
//! The operator-facing byte-layout reference is the "Binary framing"
//! section of `docs/PROTOCOL.md`; this module is the codec it
//! describes.

use crate::server::protocol::{WireNeighbor, MAX_WIRE_BATCH};
use crate::sketch::SparseVec;
use crate::util::fnv::{fnv1a32_more, FNV32_INIT};
use std::fmt;
use std::io::{self, Read, Write};

/// The protocol name clients put in the hello line (`"proto":"bin1"`)
/// and servers echo back when the switch is accepted.
pub const PROTO_NAME: &str = "bin1";

/// Hard cap on one frame body (op byte + payload).  Large enough for a
/// [`MAX_WIRE_BATCH`]-row packed batch at any supported width; small
/// enough that a corrupt length prefix cannot balloon the read buffer.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Request op codes (client → server).  Kept in a distinct numeric
/// range from response ops so a desynced peer cannot mistake one for
/// the other.
pub mod op {
    /// Liveness check; empty payload.
    pub const PING: u8 = 0x01;
    /// Sketch one sparse vector (stateless).
    pub const SKETCH: u8 = 0x02;
    /// Sketch many sparse vectors in one frame (stateless).
    pub const SKETCH_BATCH: u8 = 0x03;
    /// Ingest pre-packed sketch rows (the zero-copy path).
    pub const INSERT_PACKED: u8 = 0x04;
    /// Top-k near neighbors for many query vectors in one frame.
    pub const QUERY_BATCH: u8 = 0x05;
    /// Delete a stored id.
    pub const DELETE: u8 = 0x06;
    /// Estimate J between two stored ids.
    pub const ESTIMATE: u8 = 0x07;
    /// Fetch recent (or pinned) request traces.
    pub const TRACE: u8 = 0x08;
    /// Fetch the Prometheus text exposition.
    pub const METRICS: u8 = 0x09;
    /// Export the node's durable image (snapshot + WAL tail) for a
    /// joining cluster peer; empty payload.
    pub const REPLICATE: u8 = 0x0A;
    /// Failure reply; payload is the UTF-8 error message.
    pub const R_ERR: u8 = 0x80;
    /// Ping reply; empty payload.
    pub const R_PONG: u8 = 0x81;
    /// Sketch reply: K lanes.
    pub const R_SKETCH: u8 = 0x82;
    /// Batched sketch reply.
    pub const R_SKETCH_BATCH: u8 = 0x83;
    /// Insert reply: assigned ids.
    pub const R_IDS: u8 = 0x84;
    /// Batched query reply: per-row neighbor lists.
    pub const R_RESULTS: u8 = 0x85;
    /// Delete reply: the removed id.
    pub const R_DELETED: u8 = 0x86;
    /// Estimate reply: Ĵ.
    pub const R_ESTIMATE: u8 = 0x87;
    /// Trace reply: per-stage span breakdowns, newest first.
    pub const R_TRACE: u8 = 0x88;
    /// Metrics reply: UTF-8 Prometheus exposition text.
    pub const R_METRICS: u8 = 0x89;
    /// Replicate reply: `snap_len:u64 | snapshot bytes | WAL bytes`
    /// (the WAL stream is the remainder of the payload).
    pub const R_REPLICATE: u8 = 0x8A;
}

/// Everything that can go wrong reading, writing, or decoding a frame.
#[derive(Debug)]
pub enum FrameError {
    /// The stream ended mid-header or mid-body.
    Truncated,
    /// A length prefix larger than [`MAX_FRAME_BYTES`]; the body was
    /// not read, so the stream position is unusable afterwards.
    Oversized {
        /// The declared body length.
        len: usize,
    },
    /// The body checksum did not match the header.
    BadChecksum {
        /// The checksum the header declared.
        want: u32,
        /// The checksum computed over the received body.
        got: u32,
    },
    /// An op byte this codec does not know.
    UnknownOp(u8),
    /// The payload did not decode under its op's layout.
    Malformed(String),
    /// Transport failure.
    Io(io::Error),
}

impl FrameError {
    /// True iff the fault left the stream positioned at the next frame
    /// boundary (the full declared body was consumed), so the server
    /// may answer with one error frame and keep reading.  False means
    /// the byte position is unknowable or the peer is gone: close.
    pub fn stream_synced(&self) -> bool {
        matches!(
            self,
            FrameError::BadChecksum { .. }
                | FrameError::UnknownOp(_)
                | FrameError::Malformed(_)
        )
    }
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Truncated => write!(f, "frame truncated: stream ended mid-frame"),
            FrameError::Oversized { len } => write!(
                f,
                "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
            ),
            FrameError::BadChecksum { want, got } => write!(
                f,
                "frame checksum mismatch: header says {want:#010x}, body hashes to {got:#010x}"
            ),
            FrameError::UnknownOp(op) => write!(f, "unknown frame op {op:#04x}"),
            FrameError::Malformed(msg) => write!(f, "malformed frame payload: {msg}"),
            FrameError::Io(e) => write!(f, "frame io error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> Self {
        FrameError::Io(e)
    }
}

impl From<FrameError> for crate::Error {
    fn from(e: FrameError) -> Self {
        match e {
            FrameError::Io(io) => crate::Error::Io(io),
            other => crate::Error::Protocol(other.to_string()),
        }
    }
}

/// Reads `len | crc | op | payload` frames off a byte stream.
#[derive(Debug)]
pub struct FrameReader<R> {
    inner: R,
}

impl<R: Read> FrameReader<R> {
    /// Wrap a readable transport (callers hand in their own
    /// `BufReader` if the transport benefits from one).
    pub fn new(inner: R) -> Self {
        FrameReader { inner }
    }

    /// Read one frame.  `Ok(None)` is a clean end-of-stream at a frame
    /// boundary; EOF anywhere inside a frame is
    /// [`FrameError::Truncated`].  On [`FrameError::BadChecksum`] the
    /// full body was consumed, so the caller may answer and keep
    /// reading from the same stream.
    pub fn read_frame(&mut self) -> Result<Option<(u8, Vec<u8>)>, FrameError> {
        let mut hdr = [0u8; 8];
        let mut filled = 0;
        while filled < hdr.len() {
            match self.inner.read(&mut hdr[filled..]) {
                Ok(0) if filled == 0 => return Ok(None),
                Ok(0) => return Err(FrameError::Truncated),
                Ok(n) => filled += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(FrameError::Io(e)),
            }
        }
        let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
        let want = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]);
        if len == 0 {
            // no body was declared, so the stream stays synced
            return Err(FrameError::Malformed("zero-length frame".into()));
        }
        if len > MAX_FRAME_BYTES {
            return Err(FrameError::Oversized { len });
        }
        let mut body = vec![0u8; len];
        self.inner.read_exact(&mut body).map_err(|e| {
            if e.kind() == io::ErrorKind::UnexpectedEof {
                FrameError::Truncated
            } else {
                FrameError::Io(e)
            }
        })?;
        let got = fnv1a32_more(FNV32_INIT, &body);
        if got != want {
            return Err(FrameError::BadChecksum { want, got });
        }
        let payload = body.split_off(1);
        Ok(Some((body[0], payload)))
    }
}

/// Writes `len | crc | op | payload` frames onto a byte stream, one
/// `write_all` + flush per frame.
#[derive(Debug)]
pub struct FrameWriter<W> {
    inner: W,
}

impl<W: Write> FrameWriter<W> {
    /// Wrap a writable transport.
    pub fn new(inner: W) -> Self {
        FrameWriter { inner }
    }

    /// Frame and send `op` + `payload`, flushing afterwards.
    pub fn write_frame(&mut self, op: u8, payload: &[u8]) -> Result<(), FrameError> {
        let len = 1 + payload.len();
        if len > MAX_FRAME_BYTES {
            return Err(FrameError::Oversized { len });
        }
        let crc = fnv1a32_more(fnv1a32_more(FNV32_INIT, &[op]), payload);
        let mut buf = Vec::with_capacity(8 + len);
        buf.extend_from_slice(&(len as u32).to_le_bytes());
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.push(op);
        buf.extend_from_slice(payload);
        self.inner.write_all(&buf)?;
        self.inner.flush()?;
        Ok(())
    }
}

// ---- payload cursor -------------------------------------------------

/// Bounds-checked little-endian reader over a decoded payload.  Every
/// multi-byte read verifies the remaining length first, so a hostile
/// count field fails with [`FrameError::Malformed`] instead of an
/// allocation blow-up or a panic.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn need(&self, n: usize) -> Result<(), FrameError> {
        if self.buf.len() - self.pos < n {
            return Err(FrameError::Malformed(format!(
                "payload ends early: need {n} more bytes at offset {}",
                self.pos
            )));
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        self.need(1)?;
        let b = self.buf[self.pos];
        self.pos += 1;
        Ok(b)
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        self.need(4)?;
        let b = &self.buf[self.pos..self.pos + 4];
        self.pos += 4;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        self.need(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(u64::from_le_bytes(b))
    }

    fn f64(&mut self) -> Result<f64, FrameError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// All remaining bytes (used by ops whose tail is one blob).
    fn rest(&mut self) -> &'a [u8] {
        let r = &self.buf[self.pos..];
        self.pos = self.buf.len();
        r
    }

    /// Decode must consume the payload exactly; trailing garbage means
    /// the peer and this codec disagree about the layout.
    fn finish(self) -> Result<(), FrameError> {
        if self.pos != self.buf.len() {
            return Err(FrameError::Malformed(format!(
                "{} trailing bytes after payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Batch counts share [`MAX_WIRE_BATCH`] with the JSON dialect; zero
/// rows is legal at the codec layer (the dispatch layer owns the
/// empty-batch policy, mirroring JSON's `vecs_field`).
fn batch_count(c: &mut Cursor<'_>, what: &str) -> Result<usize, FrameError> {
    let n = c.u32()? as usize;
    if n > MAX_WIRE_BATCH {
        return Err(FrameError::Malformed(format!(
            "{what} with {n} rows exceeds the {MAX_WIRE_BATCH}-row cap"
        )));
    }
    Ok(n)
}

fn put_vec(out: &mut Vec<u8>, v: &SparseVec) {
    put_u32(out, v.dim());
    put_u32(out, v.nnz() as u32);
    for &i in v.indices() {
        put_u32(out, i);
    }
}

fn take_vec(c: &mut Cursor<'_>) -> Result<SparseVec, FrameError> {
    let dim = c.u32()?;
    let nnz = c.u32()? as usize;
    c.need(nnz * 4)?;
    let indices = (0..nnz).map(|_| c.u32()).collect::<Result<Vec<_>, _>>()?;
    SparseVec::new(dim, indices).map_err(|e| FrameError::Malformed(e.to_string()))
}

fn put_lanes(out: &mut Vec<u8>, lanes: &[u32]) {
    put_u32(out, lanes.len() as u32);
    for &v in lanes {
        put_u32(out, v);
    }
}

fn take_lanes(c: &mut Cursor<'_>) -> Result<Vec<u32>, FrameError> {
    let k = c.u32()? as usize;
    c.need(k * 4)?;
    (0..k).map(|_| c.u32()).collect()
}

// ---- requests -------------------------------------------------------

/// Client → server binary requests.  The deliberate subset of the JSON
/// [`super::protocol::Request`] surface that benefits from framing:
/// batch ingest/query plus the cheap singletons a loader, health
/// check, or observability poller needs (`trace`/`metrics` are carried
/// so a bin1 loadgen can introspect without reconnecting).  Everything
/// else (save, stats, query_above, raw insert_batch) stays on JSON
/// lines — negotiation is per-connection, so a client opens a second
/// JSON connection for those.
#[derive(Clone, Debug, PartialEq)]
pub enum BinRequest {
    /// Liveness check.
    Ping,
    /// Sketch one vector (stateless).
    Sketch(SparseVec),
    /// Sketch many vectors in one frame (stateless).
    SketchBatch(Vec<SparseVec>),
    /// Ingest pre-packed rows: each row is exactly `words_per_row`
    /// words of [`crate::sketch::pack_row`] output.
    InsertPacked {
        /// Words per packed row (must match the server's K·b).
        words_per_row: usize,
        /// The rows, in id-assignment order.
        rows: Vec<Vec<u64>>,
    },
    /// Top-k near neighbors for many query vectors.
    QueryBatch {
        /// The query vectors, in response order.
        vecs: Vec<SparseVec>,
        /// Result bound per row.
        topk: usize,
    },
    /// Delete a stored id.
    Delete(u64),
    /// Estimate J between two stored ids.
    Estimate(u64, u64),
    /// Fetch up to `n` recent (or pinned-slow) request traces.
    Trace {
        /// Maximum traces to return (newest first).
        n: usize,
        /// Return the pinned slow-trace FIFO instead of the ring.
        pinned: bool,
    },
    /// Fetch the Prometheus text exposition.
    Metrics,
    /// Export the node's durable image for a joining cluster peer.
    Replicate,
}

impl BinRequest {
    /// Serialize to `(op, payload)` for [`FrameWriter::write_frame`].
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut p = Vec::new();
        let op = match self {
            BinRequest::Ping => op::PING,
            BinRequest::Sketch(v) => {
                put_vec(&mut p, v);
                op::SKETCH
            }
            BinRequest::SketchBatch(vs) => {
                put_u32(&mut p, vs.len() as u32);
                for v in vs {
                    put_vec(&mut p, v);
                }
                op::SKETCH_BATCH
            }
            BinRequest::InsertPacked {
                words_per_row,
                rows,
            } => {
                put_u32(&mut p, rows.len() as u32);
                put_u32(&mut p, *words_per_row as u32);
                for row in rows {
                    debug_assert_eq!(row.len(), *words_per_row);
                    for &w in row {
                        put_u64(&mut p, w);
                    }
                }
                op::INSERT_PACKED
            }
            BinRequest::QueryBatch { vecs, topk } => {
                put_u32(&mut p, vecs.len() as u32);
                put_u32(&mut p, *topk as u32);
                for v in vecs {
                    put_vec(&mut p, v);
                }
                op::QUERY_BATCH
            }
            BinRequest::Delete(id) => {
                put_u64(&mut p, *id);
                op::DELETE
            }
            BinRequest::Estimate(a, b) => {
                put_u64(&mut p, *a);
                put_u64(&mut p, *b);
                op::ESTIMATE
            }
            BinRequest::Trace { n, pinned } => {
                put_u32(&mut p, *n as u32);
                p.push(u8::from(*pinned));
                op::TRACE
            }
            BinRequest::Metrics => op::METRICS,
            BinRequest::Replicate => op::REPLICATE,
        };
        (op, p)
    }

    /// Decode a received frame (server side).
    pub fn decode(op: u8, payload: &[u8]) -> Result<Self, FrameError> {
        let mut c = Cursor::new(payload);
        let req = match op {
            op::PING => BinRequest::Ping,
            op::SKETCH => BinRequest::Sketch(take_vec(&mut c)?),
            op::SKETCH_BATCH => {
                let n = batch_count(&mut c, "sketch_batch")?;
                BinRequest::SketchBatch(
                    (0..n).map(|_| take_vec(&mut c)).collect::<Result<_, _>>()?,
                )
            }
            op::INSERT_PACKED => {
                let n = batch_count(&mut c, "insert_packed")?;
                let wpr = c.u32()? as usize;
                if n > 0 && wpr == 0 {
                    return Err(FrameError::Malformed(
                        "insert_packed with zero words per row".into(),
                    ));
                }
                c.need(n * wpr * 8)?;
                let rows = (0..n)
                    .map(|_| (0..wpr).map(|_| c.u64()).collect())
                    .collect::<Result<_, _>>()?;
                BinRequest::InsertPacked {
                    words_per_row: wpr,
                    rows,
                }
            }
            op::QUERY_BATCH => {
                let n = batch_count(&mut c, "query_batch")?;
                let topk = c.u32()? as usize;
                BinRequest::QueryBatch {
                    vecs: (0..n).map(|_| take_vec(&mut c)).collect::<Result<_, _>>()?,
                    topk,
                }
            }
            op::DELETE => BinRequest::Delete(c.u64()?),
            op::ESTIMATE => BinRequest::Estimate(c.u64()?, c.u64()?),
            op::TRACE => BinRequest::Trace {
                n: c.u32()? as usize,
                pinned: c.u8()? != 0,
            },
            op::METRICS => BinRequest::Metrics,
            op::REPLICATE => BinRequest::Replicate,
            other => return Err(FrameError::UnknownOp(other)),
        };
        c.finish()?;
        Ok(req)
    }
}

// ---- responses ------------------------------------------------------

/// Server → client binary responses, one per request frame, in request
/// order.
#[derive(Clone, Debug, PartialEq)]
pub enum BinResponse {
    /// Failure; the payload is the UTF-8 error message.
    Err(String),
    /// Ping reply.
    Pong,
    /// Sketch result: K lanes.
    Sketch(Vec<u32>),
    /// Batched sketch result, in request order.
    SketchBatch(Vec<Vec<u32>>),
    /// Insert result: assigned (consecutive) ids.
    Ids(Vec<u64>),
    /// Batched query result: per-row scored neighbors, best first.
    Results(Vec<Vec<WireNeighbor>>),
    /// Delete result: the removed id.
    Deleted(u64),
    /// Estimate result: Ĵ.
    Estimate(f64),
    /// Trace result: per-stage span breakdowns, newest first.
    Trace(Vec<crate::obs::Trace>),
    /// Metrics result: the UTF-8 Prometheus exposition text.
    Metrics(String),
    /// Replicate result: the node's durable image for a joining peer.
    Replicate {
        /// Raw snapshot bytes (a complete `CMHSNAP*` image).
        snapshot: Vec<u8>,
        /// Raw WAL-tail bytes (a whole, well-formed record sequence).
        wal: Vec<u8>,
    },
}

impl BinResponse {
    /// Serialize to `(op, payload)` for [`FrameWriter::write_frame`].
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut p = Vec::new();
        let op = match self {
            BinResponse::Err(msg) => {
                p.extend_from_slice(msg.as_bytes());
                op::R_ERR
            }
            BinResponse::Pong => op::R_PONG,
            BinResponse::Sketch(lanes) => {
                put_lanes(&mut p, lanes);
                op::R_SKETCH
            }
            BinResponse::SketchBatch(rows) => {
                put_u32(&mut p, rows.len() as u32);
                for lanes in rows {
                    put_lanes(&mut p, lanes);
                }
                op::R_SKETCH_BATCH
            }
            BinResponse::Ids(ids) => {
                put_u32(&mut p, ids.len() as u32);
                for &id in ids {
                    put_u64(&mut p, id);
                }
                op::R_IDS
            }
            BinResponse::Results(rows) => {
                put_u32(&mut p, rows.len() as u32);
                for ns in rows {
                    put_u32(&mut p, ns.len() as u32);
                    for n in ns {
                        put_u64(&mut p, n.id);
                        put_f64(&mut p, n.score);
                    }
                }
                op::R_RESULTS
            }
            BinResponse::Deleted(id) => {
                put_u64(&mut p, *id);
                op::R_DELETED
            }
            BinResponse::Estimate(jhat) => {
                put_f64(&mut p, *jhat);
                op::R_ESTIMATE
            }
            BinResponse::Trace(traces) => {
                put_u32(&mut p, traces.len() as u32);
                for t in traces {
                    put_u64(&mut p, t.seq);
                    p.push(t.op as u8);
                    put_u32(&mut p, t.items);
                    p.push(u8::from(t.slow));
                    put_u64(&mut p, t.total_us);
                    for &us in &t.stages_us {
                        put_u64(&mut p, us);
                    }
                }
                op::R_TRACE
            }
            BinResponse::Metrics(text) => {
                p.extend_from_slice(text.as_bytes());
                op::R_METRICS
            }
            BinResponse::Replicate { snapshot, wal } => {
                put_u64(&mut p, snapshot.len() as u64);
                p.extend_from_slice(snapshot);
                p.extend_from_slice(wal);
                op::R_REPLICATE
            }
        };
        (op, p)
    }

    /// Decode a received frame (client side).
    pub fn decode(op: u8, payload: &[u8]) -> Result<Self, FrameError> {
        let mut c = Cursor::new(payload);
        let resp = match op {
            op::R_ERR => BinResponse::Err(
                String::from_utf8(c.rest().to_vec())
                    .map_err(|_| FrameError::Malformed("error message is not UTF-8".into()))?,
            ),
            op::R_PONG => BinResponse::Pong,
            op::R_SKETCH => BinResponse::Sketch(take_lanes(&mut c)?),
            op::R_SKETCH_BATCH => {
                let n = batch_count(&mut c, "sketch_batch reply")?;
                BinResponse::SketchBatch(
                    (0..n)
                        .map(|_| take_lanes(&mut c))
                        .collect::<Result<_, _>>()?,
                )
            }
            op::R_IDS => {
                let n = batch_count(&mut c, "ids reply")?;
                c.need(n * 8)?;
                BinResponse::Ids((0..n).map(|_| c.u64()).collect::<Result<_, _>>()?)
            }
            op::R_RESULTS => {
                let n = batch_count(&mut c, "results reply")?;
                let rows = (0..n)
                    .map(|_| -> Result<Vec<WireNeighbor>, FrameError> {
                        let m = c.u32()? as usize;
                        c.need(m * 16)?;
                        (0..m)
                            .map(|_| {
                                Ok(WireNeighbor {
                                    id: c.u64()?,
                                    score: c.f64()?,
                                })
                            })
                            .collect()
                    })
                    .collect::<Result<_, _>>()?;
                BinResponse::Results(rows)
            }
            op::R_DELETED => BinResponse::Deleted(c.u64()?),
            op::R_ESTIMATE => BinResponse::Estimate(c.f64()?),
            op::R_TRACE => {
                let n = batch_count(&mut c, "trace reply")?;
                // fixed-size trace record: seq(8) + op(1) + items(4) +
                // slow(1) + total(8) + stages(7×8)
                c.need(n * (22 + crate::obs::NUM_STAGES * 8))?;
                let traces = (0..n)
                    .map(|_| -> Result<crate::obs::Trace, FrameError> {
                        let seq = c.u64()?;
                        let op_byte = c.u8()?;
                        let op = crate::obs::OpKind::from_index(op_byte).ok_or_else(|| {
                            FrameError::Malformed(format!("unknown trace op index {op_byte}"))
                        })?;
                        let items = c.u32()?;
                        let slow = c.u8()? != 0;
                        let total_us = c.u64()?;
                        let mut stages_us = [0u64; crate::obs::NUM_STAGES];
                        for us in &mut stages_us {
                            *us = c.u64()?;
                        }
                        Ok(crate::obs::Trace {
                            seq,
                            op,
                            items,
                            total_us,
                            slow,
                            stages_us,
                        })
                    })
                    .collect::<Result<_, _>>()?;
                BinResponse::Trace(traces)
            }
            op::R_METRICS => BinResponse::Metrics(
                String::from_utf8(c.rest().to_vec())
                    .map_err(|_| FrameError::Malformed("metrics text is not UTF-8".into()))?,
            ),
            op::R_REPLICATE => {
                // snap_len must fit the payload it was declared in; a
                // count past the frame's own end is corruption, not a
                // bigger allocation.
                let declared = c.u64()?;
                let snap_len = usize::try_from(declared).map_err(|_| {
                    FrameError::Malformed(format!(
                        "replicate snapshot length {declared} overflows"
                    ))
                })?;
                c.need(snap_len)?;
                let rest = c.rest();
                BinResponse::Replicate {
                    snapshot: rest[..snap_len].to_vec(),
                    wal: rest[snap_len..].to_vec(),
                }
            }
            other => return Err(FrameError::UnknownOp(other)),
        };
        c.finish()?;
        Ok(resp)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use std::io::Cursor as IoCursor;

    fn vec_of(dim: u32, idx: &[u32]) -> SparseVec {
        SparseVec::new(dim, idx.to_vec()).unwrap()
    }

    fn roundtrip_req(req: BinRequest) -> BinRequest {
        let (op, payload) = req.encode();
        BinRequest::decode(op, &payload).unwrap()
    }

    fn roundtrip_resp(resp: BinResponse) -> BinResponse {
        let (op, payload) = resp.encode();
        BinResponse::decode(op, &payload).unwrap()
    }

    #[test]
    fn every_request_roundtrips() {
        for req in [
            BinRequest::Ping,
            BinRequest::Sketch(vec_of(64, &[1, 5, 63])),
            BinRequest::SketchBatch(vec![vec_of(64, &[0]), vec_of(64, &[])]),
            BinRequest::InsertPacked {
                words_per_row: 2,
                rows: vec![vec![u64::MAX, 7], vec![0, 1]],
            },
            BinRequest::QueryBatch {
                vecs: vec![vec_of(32, &[3, 4])],
                topk: 5,
            },
            BinRequest::Delete(u64::MAX),
            BinRequest::Estimate(3, 9),
            BinRequest::Trace {
                n: 16,
                pinned: true,
            },
            BinRequest::Trace {
                n: 0,
                pinned: false,
            },
            BinRequest::Metrics,
            BinRequest::Replicate,
        ] {
            assert_eq!(roundtrip_req(req.clone()), req);
        }
    }

    #[test]
    fn every_response_roundtrips() {
        for resp in [
            BinResponse::Err("busy: retry later".into()),
            BinResponse::Pong,
            BinResponse::Sketch(vec![1, 2, u32::MAX]),
            BinResponse::SketchBatch(vec![vec![7], vec![]]),
            BinResponse::Ids(vec![0, u64::MAX]),
            BinResponse::Results(vec![
                vec![WireNeighbor { id: 3, score: 0.75 }],
                vec![],
            ]),
            BinResponse::Deleted(12),
            BinResponse::Estimate(0.4921875),
            BinResponse::Trace(vec![
                crate::obs::Trace {
                    seq: 41,
                    op: crate::obs::OpKind::QueryBatch,
                    items: 128,
                    total_us: 15_000,
                    slow: true,
                    stages_us: [10, 0, 0, 40, 9_000, 5_000, 50],
                },
                crate::obs::Trace {
                    seq: 42,
                    op: crate::obs::OpKind::Ping,
                    items: 1,
                    total_us: 3,
                    slow: false,
                    stages_us: [0; crate::obs::NUM_STAGES],
                },
            ]),
            BinResponse::Trace(vec![]),
            BinResponse::Metrics("# TYPE cminhash_requests_total counter\n".into()),
            BinResponse::Replicate {
                snapshot: vec![0x43, 0x4D, 0x48, 0x00, 0xFF],
                wal: vec![1, 2, 3],
            },
            BinResponse::Replicate {
                snapshot: vec![],
                wal: vec![],
            },
        ] {
            assert_eq!(roundtrip_resp(resp.clone()), resp);
        }
    }

    #[test]
    fn replicate_replies_with_oversized_snap_len_are_malformed() {
        // snap_len claims more bytes than the payload carries
        let mut p = Vec::new();
        put_u64(&mut p, 100);
        p.extend_from_slice(&[0u8; 10]);
        match BinResponse::decode(op::R_REPLICATE, &p) {
            Err(FrameError::Malformed(msg)) => assert!(msg.contains("ends early"), "{msg}"),
            other => panic!("{other:?}"),
        }
        // and a u64 that can't even fit in usize on any target
        let mut p = Vec::new();
        put_u64(&mut p, u64::MAX);
        assert!(BinResponse::decode(op::R_REPLICATE, &p).is_err());
    }

    #[test]
    fn trace_replies_with_unknown_op_indices_are_malformed() {
        let (opc, mut payload) = BinResponse::Trace(vec![crate::obs::Trace {
            seq: 1,
            op: crate::obs::OpKind::Query,
            items: 1,
            total_us: 5,
            slow: false,
            stages_us: [0; crate::obs::NUM_STAGES],
        }])
        .encode();
        payload[4 + 8] = 0xEE; // corrupt the op index (count:u32 then seq:u64)
        match BinResponse::decode(opc, &payload) {
            Err(FrameError::Malformed(msg)) => assert!(msg.contains("op index"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn zero_row_batches_roundtrip_at_the_codec_layer() {
        // empty-batch policy belongs to dispatch, not the codec
        assert_eq!(
            roundtrip_req(BinRequest::SketchBatch(vec![])),
            BinRequest::SketchBatch(vec![])
        );
        let req = BinRequest::InsertPacked {
            words_per_row: 4,
            rows: vec![],
        };
        assert_eq!(roundtrip_req(req.clone()), req);
        let req = BinRequest::QueryBatch {
            vecs: vec![],
            topk: 1,
        };
        assert_eq!(roundtrip_req(req.clone()), req);
    }

    #[test]
    fn over_cap_batches_are_rejected_on_decode() {
        let mut p = Vec::new();
        put_u32(&mut p, (MAX_WIRE_BATCH + 1) as u32);
        put_u32(&mut p, 1); // words_per_row
        match BinRequest::decode(op::INSERT_PACKED, &p) {
            Err(FrameError::Malformed(msg)) => assert!(msg.contains("cap"), "{msg}"),
            other => panic!("{other:?}"),
        }
        let mut p = Vec::new();
        put_u32(&mut p, (MAX_WIRE_BATCH + 1) as u32);
        put_u32(&mut p, 3); // topk
        assert!(BinRequest::decode(op::QUERY_BATCH, &p).is_err());
    }

    #[test]
    fn hostile_counts_fail_without_allocating() {
        // nnz claims 4 billion indices but the payload is 12 bytes
        let mut p = Vec::new();
        put_u32(&mut p, 64); // dim
        put_u32(&mut p, u32::MAX); // nnz
        put_u32(&mut p, 1);
        match BinRequest::decode(op::SKETCH, &p) {
            Err(FrameError::Malformed(msg)) => assert!(msg.contains("ends early"), "{msg}"),
            other => panic!("{other:?}"),
        }
        // insert_packed claiming more words than the payload holds
        let mut p = Vec::new();
        put_u32(&mut p, 8); // rows
        put_u32(&mut p, 1 << 20); // words per row
        match BinRequest::decode(op::INSERT_PACKED, &p) {
            Err(FrameError::Malformed(_)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_are_malformed() {
        let (opc, mut payload) = BinRequest::Delete(7).encode();
        payload.push(0xAA);
        match BinRequest::decode(opc, &payload) {
            Err(FrameError::Malformed(msg)) => assert!(msg.contains("trailing"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_ops_are_rejected() {
        assert!(matches!(
            BinRequest::decode(0x7F, &[]),
            Err(FrameError::UnknownOp(0x7F))
        ));
        // a request op arriving where a response is expected is unknown
        assert!(matches!(
            BinResponse::decode(op::PING, &[]),
            Err(FrameError::UnknownOp(_))
        ));
    }

    #[test]
    fn frame_reader_writer_roundtrip() {
        let mut buf = Vec::new();
        {
            let mut w = FrameWriter::new(&mut buf);
            w.write_frame(op::PING, &[]).unwrap();
            w.write_frame(op::DELETE, &7u64.to_le_bytes()).unwrap();
        }
        let mut r = FrameReader::new(IoCursor::new(buf));
        assert_eq!(r.read_frame().unwrap(), Some((op::PING, vec![])));
        let (opc, payload) = r.read_frame().unwrap().unwrap();
        assert_eq!(opc, op::DELETE);
        assert_eq!(BinRequest::decode(opc, &payload).unwrap(), BinRequest::Delete(7));
        // clean EOF at a frame boundary
        assert!(r.read_frame().unwrap().is_none());
    }

    #[test]
    fn frame_layout_is_pinned() {
        // ping: len=1, crc=fnv1a32([0x01]), op=0x01, no payload
        let mut buf = Vec::new();
        FrameWriter::new(&mut buf).write_frame(op::PING, &[]).unwrap();
        let crc = fnv1a32_more(FNV32_INIT, &[op::PING]);
        let mut want = vec![1, 0, 0, 0];
        want.extend_from_slice(&crc.to_le_bytes());
        want.push(op::PING);
        assert_eq!(buf, want);
    }

    #[test]
    fn corrupt_body_is_a_synced_checksum_error() {
        let mut buf = Vec::new();
        {
            let mut w = FrameWriter::new(&mut buf);
            w.write_frame(op::DELETE, &7u64.to_le_bytes()).unwrap();
            w.write_frame(op::PING, &[]).unwrap();
        }
        buf[10] ^= 0xFF; // flip a body byte of the first frame
        let mut r = FrameReader::new(IoCursor::new(buf));
        match r.read_frame() {
            Err(e @ FrameError::BadChecksum { .. }) => assert!(e.stream_synced()),
            other => panic!("{other:?}"),
        }
        // the reader consumed the whole corrupt body: next frame is intact
        assert_eq!(r.read_frame().unwrap(), Some((op::PING, vec![])));
    }

    #[test]
    fn truncated_and_oversized_frames_close_the_stream() {
        // header declares 100 bytes, stream carries 3
        let mut buf = Vec::new();
        buf.extend_from_slice(&100u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]);
        let mut r = FrameReader::new(IoCursor::new(buf));
        match r.read_frame() {
            Err(e @ FrameError::Truncated) => assert!(!e.stream_synced()),
            other => panic!("{other:?}"),
        }
        // partial header
        let mut r = FrameReader::new(IoCursor::new(vec![9u8, 0, 0]));
        assert!(matches!(r.read_frame(), Err(FrameError::Truncated)));
        // oversized declared length never allocates the body
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        let mut r = FrameReader::new(IoCursor::new(buf));
        match r.read_frame() {
            Err(e @ FrameError::Oversized { .. }) => assert!(!e.stream_synced()),
            other => panic!("{other:?}"),
        }
        // zero-length frame is malformed but synced
        let mut buf = Vec::new();
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        let mut r = FrameReader::new(IoCursor::new(buf));
        match r.read_frame() {
            Err(e @ FrameError::Malformed(_)) => assert!(e.stream_synced()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn frame_errors_convert_into_protocol_errors() {
        let e: crate::Error = FrameError::UnknownOp(0x55).into();
        assert!(matches!(e, crate::Error::Protocol(_)), "{e}");
        let e: crate::Error =
            FrameError::Io(io::Error::new(io::ErrorKind::BrokenPipe, "gone")).into();
        assert!(matches!(e, crate::Error::Io(_)), "{e}");
    }
}
