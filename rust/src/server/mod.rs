//! TCP server speaking the JSON-line protocol over a **bounded worker
//! pool**, plus a small blocking client used by examples, benches and
//! tests, and a JSONL bulk loader streaming through `insert_batch`.
//!
//! Connection admission: `server.max_connections` worker threads are
//! spawned up front; the accept loop tracks how many are serving via a
//! shared counter and hands accepted sockets over a rendezvous
//! channel.  A connection arriving while **every** worker is serving
//! is turned away with a clean `busy` protocol error line instead of
//! spawning an unbounded OS thread; while any worker is free the
//! handoff blocks for at most the instant it takes that worker to
//! park, so connection bursts are never spuriously rejected.  The
//! accept loop never dies on transient `accept()` failures
//! (`ECONNABORTED`, `EMFILE` under fd pressure, interrupts): it logs,
//! counts them in `accept_errors`, backs off briefly and keeps
//! listening; only a listener-is-gone class error (`EBADF`/`EINVAL`)
//! stops it.

pub mod protocol;

use crate::coordinator::Coordinator;
use crate::metrics::Metrics;
use crate::sketch::SparseVec;
use crate::util::json::Json;
use protocol::{Request, Response, WireNeighbor};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A running server (accept loop + fixed pool of connection workers).
pub struct Server {
    addr: SocketAddr,
}

impl Server {
    /// Bind `addr` (may be port 0), spawn the
    /// `server.max_connections`-sized worker pool and the accept loop.
    /// Returns once the listener is live.
    pub fn spawn(svc: Arc<Coordinator>, addr: &str) -> crate::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let max_conns = svc.config().server.max_connections;
        // `active` counts sockets handed to the pool whose connections
        // have not finished.  The accept loop is the only incrementer
        // (before the handoff) and each worker decrements exactly once
        // per connection (drop guard), so `active == max_conns` is a
        // precise "every worker is serving" signal.
        let active = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        // Rendezvous handoff: the accept loop only sends after proving
        // `active < max_conns`, which guarantees some worker is parked
        // in (or headed for) `recv`, so the blocking send completes
        // immediately.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(0);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for i in 0..max_conns {
            let rx = conn_rx.clone();
            let svc = svc.clone();
            let active = active.clone();
            std::thread::Builder::new()
                .name(format!("conn-worker-{i}"))
                .spawn(move || loop {
                    // Hold the receiver lock only while parked: the
                    // guard drops as soon as `recv` hands us a socket,
                    // letting the next idle worker park itself.
                    let socket = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match socket {
                        Ok(s) => {
                            let _release = ActiveGuard(&active);
                            // Contain panics: a worker that dies takes a
                            // pool slot with it forever (and a fully dead
                            // pool wedges the accept loop), so one bad
                            // request path must only cost its own
                            // connection — as thread-per-connection did.
                            let svc = svc.clone();
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(move || {
                                    let _ = handle_conn(svc, s);
                                }),
                            );
                        }
                        // Accept loop gone: the pool drains and exits.
                        Err(_) => break,
                    }
                })
                .map_err(crate::Error::Io)?;
        }
        std::thread::Builder::new()
            .name("accept-loop".into())
            .spawn(move || accept_loop(&listener, &conn_tx, &active, &svc, max_conns))
            .map_err(crate::Error::Io)?;
        Ok(Server { addr: local })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block this thread forever (the accept loop runs in background).
    pub fn join_forever(&self) -> ! {
        loop {
            std::thread::park();
        }
    }
}

/// Decrements the active-connection counter when a worker finishes a
/// connection, even if `handle_conn` unwinds.
struct ActiveGuard<'a>(&'a std::sync::atomic::AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, std::sync::atomic::Ordering::Release);
    }
}

fn accept_loop(
    listener: &TcpListener,
    conn_tx: &mpsc::SyncSender<TcpStream>,
    active: &std::sync::atomic::AtomicUsize,
    svc: &Arc<Coordinator>,
    max_connections: usize,
) {
    use std::sync::atomic::Ordering;
    for conn in listener.incoming() {
        match conn {
            Ok(socket) => {
                if active.load(Ordering::Acquire) >= max_connections {
                    // Every worker is serving a connection: turn the
                    // overflow client away with a protocol-level error
                    // instead of queueing it invisibly or spawning an
                    // unbounded thread.
                    Metrics::inc(&svc.metrics().busy_rejections);
                    busy_reject(socket, max_connections);
                    continue;
                }
                // A slot is free, so a worker is parked in (or headed
                // for) `recv`; increment first so the worker's paired
                // decrement can never underflow the counter.
                active.fetch_add(1, Ordering::AcqRel);
                if conn_tx.send(socket).is_err() {
                    // Pool gone (shutdown): stop accepting.
                    active.fetch_sub(1, Ordering::Release);
                    break;
                }
            }
            Err(e) if accept_error_is_fatal(&e) => {
                eprintln!("accept-loop: fatal accept error, stopping listener: {e}");
                break;
            }
            Err(e) => {
                // Transient (ECONNABORTED, EINTR, EMFILE/ENFILE fd
                // pressure…): the listener is still valid, so dying
                // here would silently stop the server accepting
                // forever.  Log, count, back off a breath, continue.
                Metrics::inc(&svc.metrics().accept_errors);
                eprintln!("accept-loop: transient accept error (continuing): {e}");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Whether an `accept()` error means the listener itself is unusable.
/// `accept(2)` on a healthy listener only fails transiently (aborted
/// handshakes, signal interrupts, fd exhaustion that later clears);
/// `EBADF`/`EINVAL` mean the listening socket is gone or was never
/// valid, which no amount of retrying fixes.
fn accept_error_is_fatal(e: &std::io::Error) -> bool {
    const EBADF: i32 = 9;
    const EINVAL: i32 = 22;
    matches!(e.raw_os_error(), Some(EBADF) | Some(EINVAL))
        || e.kind() == std::io::ErrorKind::InvalidInput
}

/// Send one `busy` error line to an overflow connection and close it.
fn busy_reject(mut socket: TcpStream, max_connections: usize) {
    let mut line = Response::err(&crate::Error::Busy { max_connections })
        .to_json()
        .to_string();
    line.push('\n');
    let _ = socket.write_all(line.as_bytes());
    // Dropping the socket closes the connection.
}

fn handle_conn(svc: Arc<Coordinator>, socket: TcpStream) -> crate::Result<()> {
    socket.set_nodelay(true)?;
    let mut writer = socket.try_clone()?;
    let reader = BufReader::new(socket);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Json::parse(&line) {
            Ok(j) => match Request::from_json(&j) {
                Ok(req) => dispatch(&svc, req),
                Err(e) => {
                    Metrics::inc(&svc.metrics().errors);
                    Response::err(&e)
                }
            },
            Err(e) => {
                Metrics::inc(&svc.metrics().errors);
                Response::err(&crate::Error::Protocol(e.to_string()))
            }
        };
        let mut out = resp.to_json().to_string();
        out.push('\n');
        writer.write_all(out.as_bytes())?;
    }
    Ok(())
}

fn wire_neighbors(ns: Vec<crate::index::Neighbor>) -> Vec<WireNeighbor> {
    ns.into_iter()
        .map(|n| WireNeighbor {
            id: n.id,
            score: n.score,
        })
        .collect()
}

fn dispatch(svc: &Arc<Coordinator>, req: Request) -> Response {
    let result: crate::Result<Response> = (|| {
        Ok(match req {
            Request::Ping => Response::Pong,
            Request::Sketch { vec } => Response::Sketch {
                sketch: svc.sketch(vec)?,
            },
            Request::SketchBatch { vecs } => Response::SketchBatch {
                sketches: svc.sketch_many(vecs)?,
            },
            Request::Insert { vec } => {
                let (id, sketch) = svc.insert(vec)?;
                Response::Insert { id, sketch }
            }
            Request::InsertBatch { vecs } => Response::InsertBatch {
                ids: svc
                    .insert_many(vecs)?
                    .into_iter()
                    .map(|(id, _)| id)
                    .collect(),
            },
            Request::Delete { id } => {
                svc.delete(id)?;
                Response::Deleted { id }
            }
            Request::Save => Response::Saved {
                persisted_bytes: svc.save()?,
            },
            Request::Estimate { a, b } => Response::Estimate {
                jhat: svc.estimate_ids(a, b)?,
            },
            Request::EstimateVecs { v, w } => Response::Estimate {
                jhat: svc.estimate_vecs(v, w)?,
            },
            Request::Query { vec, topk } => Response::Query {
                neighbors: wire_neighbors(svc.query(vec, topk)?),
            },
            Request::QueryBatch { vecs, topk } => Response::QueryBatch {
                results: svc
                    .query_many(vecs, topk)?
                    .into_iter()
                    .map(wire_neighbors)
                    .collect(),
            },
            Request::QueryAbove { vec, threshold } => Response::Query {
                neighbors: wire_neighbors(svc.query_above(vec, threshold)?),
            },
            Request::Stats => {
                let (metrics, store) = svc.stats();
                Response::Stats {
                    scheme: svc.config().sketch.scheme,
                    metrics,
                    store,
                }
            }
        })
    })();
    match result {
        Ok(r) => r,
        Err(e) => {
            Metrics::inc(&svc.metrics().errors);
            Response::err(&e)
        }
    }
}

/// A minimal blocking client for examples/benches/tests.
pub struct BlockingClient {
    reader: BufReader<TcpStream>,
}

impl BlockingClient {
    /// Connect to a running server.
    pub fn connect(addr: &str) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(BlockingClient {
            reader: BufReader::new(stream),
        })
    }

    /// Send one request and read one response.
    pub fn call(&mut self, req: &Request) -> crate::Result<Response> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        Response::from_json(&Json::parse(&resp)?)
    }

    /// Send one request and return the raw JSON response line
    /// (used for `stats`).
    pub fn call_raw(&mut self, req: &Request) -> crate::Result<Json> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        Ok(Json::parse(&resp)?)
    }

    fn vecs(dim: u32, rows: Vec<Vec<u32>>) -> crate::Result<Vec<SparseVec>> {
        rows.into_iter().map(|r| SparseVec::new(dim, r)).collect()
    }

    /// Convenience: sketch a sparse vector.
    pub fn sketch(&mut self, dim: u32, indices: Vec<u32>) -> crate::Result<Vec<u32>> {
        let vec = SparseVec::new(dim, indices)?;
        match self.call(&Request::Sketch { vec })? {
            Response::Sketch { sketch } => Ok(sketch),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: sketch many vectors in one round-trip.
    pub fn sketch_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
    ) -> crate::Result<Vec<Vec<u32>>> {
        let vecs = Self::vecs(dim, rows)?;
        match self.call(&Request::SketchBatch { vecs })? {
            Response::SketchBatch { sketches } => Ok(sketches),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: insert a sparse vector.
    pub fn insert(&mut self, dim: u32, indices: Vec<u32>) -> crate::Result<u64> {
        let vec = SparseVec::new(dim, indices)?;
        match self.call(&Request::Insert { vec })? {
            Response::Insert { id, .. } => Ok(id),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: insert many vectors as one unit; returns the
    /// assigned (consecutive) ids in row order.
    pub fn insert_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
    ) -> crate::Result<Vec<u64>> {
        let vecs = Self::vecs(dim, rows)?;
        match self.call(&Request::InsertBatch { vecs })? {
            Response::InsertBatch { ids } => Ok(ids),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: delete a stored id.
    pub fn delete(&mut self, id: u64) -> crate::Result<()> {
        match self.call(&Request::Delete { id })? {
            Response::Deleted { .. } => Ok(()),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: top-k query.
    pub fn query(
        &mut self,
        dim: u32,
        indices: Vec<u32>,
        topk: usize,
    ) -> crate::Result<Vec<WireNeighbor>> {
        let vec = SparseVec::new(dim, indices)?;
        match self.call(&Request::Query { vec, topk })? {
            Response::Query { neighbors } => Ok(neighbors),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: top-k queries for many vectors in one round-trip;
    /// one neighbor list per row, in row order.
    pub fn query_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
        topk: usize,
    ) -> crate::Result<Vec<Vec<WireNeighbor>>> {
        let vecs = Self::vecs(dim, rows)?;
        match self.call(&Request::QueryBatch { vecs, topk })? {
            Response::QueryBatch { results } => Ok(results),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }
}

/// Cumulative progress of a [`load_jsonl`] bulk ingest.
#[derive(Clone, Copy, Debug)]
pub struct LoadReport {
    /// Vector rows inserted so far.
    pub rows: u64,
    /// `insert_batch` round-trips issued so far.
    pub batches: u64,
    /// Wall-clock seconds elapsed.
    pub secs: f64,
}

impl LoadReport {
    /// Ingest throughput in rows per second (0 before the clock moves).
    pub fn rows_per_sec(&self) -> f64 {
        if self.secs > 0.0 {
            self.rows as f64 / self.secs
        } else {
            0.0
        }
    }
}

/// Stream a JSONL vector file — one `{"dim":D,"indices":[...]}` object
/// per line, blank lines skipped — into a running server through
/// `insert_batch` round-trips of up to `batch_size` rows.  `progress`
/// is called after every round-trip with cumulative counts (the CLI
/// prints a throughput line from it).  Ingest is sequential over one
/// connection; a bad line or a rejected batch aborts with an error
/// naming the offending line.
pub fn load_jsonl(
    addr: &str,
    path: &std::path::Path,
    batch_size: usize,
    mut progress: impl FnMut(&LoadReport),
) -> crate::Result<LoadReport> {
    if batch_size == 0 {
        return Err(crate::Error::Invalid("batch size must be > 0".into()));
    }
    if batch_size > protocol::MAX_WIRE_BATCH {
        return Err(crate::Error::Invalid(format!(
            "batch size {batch_size} exceeds the wire cap of {} rows per \
             request",
            protocol::MAX_WIRE_BATCH
        )));
    }
    let file = std::fs::File::open(path)?;
    let reader = BufReader::new(file);
    let mut client = BlockingClient::connect(addr)?;
    let t0 = Instant::now();
    let mut report = LoadReport {
        rows: 0,
        batches: 0,
        secs: 0.0,
    };
    let mut pending: Vec<SparseVec> = Vec::with_capacity(batch_size);
    let mut first_line = 0usize; // 1-based line number of pending[0]
    let mut flush = |pending: &mut Vec<SparseVec>,
                     report: &mut LoadReport,
                     client: &mut BlockingClient,
                     first_line: usize|
     -> crate::Result<()> {
        if pending.is_empty() {
            return Ok(());
        }
        let n = pending.len();
        match client.call(&Request::InsertBatch {
            vecs: std::mem::take(pending),
        })? {
            Response::InsertBatch { ids } => {
                if ids.len() != n {
                    return Err(crate::Error::Protocol(format!(
                        "insert_batch returned {} ids for {n} rows",
                        ids.len()
                    )));
                }
            }
            Response::Err { error } => {
                return Err(crate::Error::Protocol(format!(
                    "batch starting at line {first_line} rejected: {error}"
                )));
            }
            other => {
                return Err(crate::Error::Protocol(format!(
                    "unexpected response {other:?}"
                )));
            }
        }
        report.rows += n as u64;
        report.batches += 1;
        report.secs = t0.elapsed().as_secs_f64();
        Ok(())
    };
    for (idx, line) in reader.lines().enumerate() {
        let lineno = idx + 1;
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(&line)
            .map_err(crate::Error::from)
            .and_then(|j| SparseVec::from_json(&j))
            .map_err(|e| {
                crate::Error::Invalid(format!("{}:{lineno}: {e}", path.display()))
            })?;
        if pending.is_empty() {
            first_line = lineno;
        }
        pending.push(parsed);
        if pending.len() == batch_size {
            flush(&mut pending, &mut report, &mut client, first_line)?;
            progress(&report);
        }
    }
    if !pending.is_empty() {
        flush(&mut pending, &mut report, &mut client, first_line)?;
        progress(&report);
    }
    report.secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_error_classification() {
        // Transient: the loop must survive these (the old code died on
        // the first one and stopped listening forever).
        for e in [
            std::io::Error::new(std::io::ErrorKind::ConnectionAborted, "ECONNABORTED"),
            std::io::Error::new(std::io::ErrorKind::Interrupted, "EINTR"),
            std::io::Error::from_raw_os_error(24), // EMFILE
            std::io::Error::from_raw_os_error(23), // ENFILE
        ] {
            assert!(!accept_error_is_fatal(&e), "{e} must be survivable");
        }
        // Fatal: the listener fd itself is unusable.
        for e in [
            std::io::Error::from_raw_os_error(9),  // EBADF
            std::io::Error::from_raw_os_error(22), // EINVAL
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "bad listener"),
        ] {
            assert!(accept_error_is_fatal(&e), "{e} must stop the loop");
        }
    }

    #[test]
    fn load_report_throughput() {
        let r = LoadReport {
            rows: 100,
            batches: 2,
            secs: 4.0,
        };
        assert_eq!(r.rows_per_sec(), 25.0);
        let r = LoadReport {
            rows: 0,
            batches: 0,
            secs: 0.0,
        };
        assert_eq!(r.rows_per_sec(), 0.0);
    }
}
