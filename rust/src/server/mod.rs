//! TCP server speaking the JSON-line protocol (thread-per-connection),
//! plus a small blocking client used by examples, benches and tests.

pub mod protocol;

use crate::coordinator::Coordinator;
use crate::metrics::Metrics;
use crate::util::json::Json;
use protocol::{Request, Response, WireNeighbor};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;

/// A running server (listener thread + per-connection threads).
pub struct Server {
    addr: SocketAddr,
}

impl Server {
    /// Bind `addr` (may be port 0) and start accepting in background
    /// threads.  Returns once the listener is live.
    pub fn spawn(svc: Arc<Coordinator>, addr: &str) -> crate::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        std::thread::Builder::new()
            .name("accept-loop".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    match conn {
                        Ok(socket) => {
                            let svc = svc.clone();
                            let _ = std::thread::Builder::new()
                                .name("conn".into())
                                .spawn(move || {
                                    let _ = handle_conn(svc, socket);
                                });
                        }
                        Err(_) => break,
                    }
                }
            })
            .map_err(crate::Error::Io)?;
        Ok(Server { addr: local })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block this thread forever (the accept loop runs in background).
    pub fn join_forever(&self) -> ! {
        loop {
            std::thread::park();
        }
    }
}

fn handle_conn(svc: Arc<Coordinator>, socket: TcpStream) -> crate::Result<()> {
    socket.set_nodelay(true)?;
    let mut writer = socket.try_clone()?;
    let reader = BufReader::new(socket);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Json::parse(&line) {
            Ok(j) => match Request::from_json(&j) {
                Ok(req) => dispatch(&svc, req),
                Err(e) => {
                    Metrics::inc(&svc.metrics().errors);
                    Response::err(&e)
                }
            },
            Err(e) => {
                Metrics::inc(&svc.metrics().errors);
                Response::err(&crate::Error::Protocol(e.to_string()))
            }
        };
        let mut out = resp.to_json().to_string();
        out.push('\n');
        writer.write_all(out.as_bytes())?;
    }
    Ok(())
}

fn dispatch(svc: &Arc<Coordinator>, req: Request) -> Response {
    let result: crate::Result<Response> = (|| {
        Ok(match req {
            Request::Ping => Response::Pong,
            Request::Sketch { vec } => Response::Sketch {
                sketch: svc.sketch(vec)?,
            },
            Request::Insert { vec } => {
                let (id, sketch) = svc.insert(vec)?;
                Response::Insert { id, sketch }
            }
            Request::Delete { id } => {
                svc.delete(id)?;
                Response::Deleted { id }
            }
            Request::Save => Response::Saved {
                persisted_bytes: svc.save()?,
            },
            Request::Estimate { a, b } => Response::Estimate {
                jhat: svc.estimate_ids(a, b)?,
            },
            Request::EstimateVecs { v, w } => Response::Estimate {
                jhat: svc.estimate_vecs(v, w)?,
            },
            Request::Query { vec, topk } => Response::Query {
                neighbors: svc
                    .query(vec, topk)?
                    .into_iter()
                    .map(|n| WireNeighbor {
                        id: n.id,
                        score: n.score,
                    })
                    .collect(),
            },
            Request::QueryAbove { vec, threshold } => Response::Query {
                neighbors: svc
                    .query_above(vec, threshold)?
                    .into_iter()
                    .map(|n| WireNeighbor {
                        id: n.id,
                        score: n.score,
                    })
                    .collect(),
            },
            Request::Stats => {
                let (metrics, store) = svc.stats();
                Response::Stats { metrics, store }
            }
        })
    })();
    match result {
        Ok(r) => r,
        Err(e) => {
            Metrics::inc(&svc.metrics().errors);
            Response::err(&e)
        }
    }
}

/// A minimal blocking client for examples/benches/tests.
pub struct BlockingClient {
    reader: BufReader<TcpStream>,
}

impl BlockingClient {
    /// Connect to a running server.
    pub fn connect(addr: &str) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(BlockingClient {
            reader: BufReader::new(stream),
        })
    }

    /// Send one request and read one response.
    pub fn call(&mut self, req: &Request) -> crate::Result<Response> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        Response::from_json(&Json::parse(&resp)?)
    }

    /// Send one request and return the raw JSON response line
    /// (used for `stats`).
    pub fn call_raw(&mut self, req: &Request) -> crate::Result<Json> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        Ok(Json::parse(&resp)?)
    }

    /// Convenience: sketch a sparse vector.
    pub fn sketch(&mut self, dim: u32, indices: Vec<u32>) -> crate::Result<Vec<u32>> {
        let vec = crate::sketch::SparseVec::new(dim, indices)?;
        match self.call(&Request::Sketch { vec })? {
            Response::Sketch { sketch } => Ok(sketch),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: insert a sparse vector.
    pub fn insert(&mut self, dim: u32, indices: Vec<u32>) -> crate::Result<u64> {
        let vec = crate::sketch::SparseVec::new(dim, indices)?;
        match self.call(&Request::Insert { vec })? {
            Response::Insert { id, .. } => Ok(id),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: delete a stored id.
    pub fn delete(&mut self, id: u64) -> crate::Result<()> {
        match self.call(&Request::Delete { id })? {
            Response::Deleted { .. } => Ok(()),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Convenience: top-k query.
    pub fn query(
        &mut self,
        dim: u32,
        indices: Vec<u32>,
        topk: usize,
    ) -> crate::Result<Vec<WireNeighbor>> {
        let vec = crate::sketch::SparseVec::new(dim, indices)?;
        match self.call(&Request::Query { vec, topk })? {
            Response::Query { neighbors } => Ok(neighbors),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Err(crate::Error::Protocol(format!(
                "unexpected response {other:?}"
            ))),
        }
    }
}
