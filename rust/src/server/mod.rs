//! TCP server speaking the JSON-line protocol over a **bounded worker
//! pool**.  The client-side half — the blocking single-node client,
//! the JSONL bulk loaders, and the cluster client that spreads a
//! corpus over several of these servers — lives in [`client`].
//!
//! Every connection starts on JSON lines; a client may send one
//! `{"op":"hello","proto":"bin1"}` line to switch the rest of the
//! stream to the length-prefixed binary framing in [`frame`] (unknown
//! `proto` values answer `{"ok":true,"proto":"jsonl"}` and stay on
//! JSON, so probing an old server is always safe).  The binary dialect
//! shares [`dispatch`] with JSON — identical corpora produce identical
//! results on either framing — and adds `insert_packed`, which carries
//! [`crate::sketch::pack_row`] output byte-for-byte so ingest becomes
//! a checksum-verified copy into the packed arena.
//!
//! Connection admission: `server.max_connections` worker threads are
//! spawned up front; the accept loop tracks how many are serving via a
//! shared counter and hands accepted sockets over a rendezvous
//! channel.  A connection arriving while **every** worker is serving
//! is turned away with a clean `busy` protocol error line instead of
//! spawning an unbounded OS thread; while any worker is free the
//! handoff blocks for at most the instant it takes that worker to
//! park, so connection bursts are never spuriously rejected.  The
//! accept loop never dies on transient `accept()` failures
//! (`ECONNABORTED`, `EMFILE` under fd pressure, interrupts): it logs,
//! counts them in `accept_errors`, backs off briefly and keeps
//! listening; only a listener-is-gone class error (`EBADF`/`EINVAL`)
//! stops it.

pub mod client;
pub mod frame;
pub mod protocol;

pub use client::{
    load_jsonl, load_jsonl_binary, load_jsonl_cluster, BlockingClient, ClusterClient,
    ClusterConfig, ClusterInsert, ClusterNeighbor, ClusterNode, ClusterQuery, LoadReport,
};

use crate::coordinator::Coordinator;
use crate::metrics::Metrics;
use crate::obs::{add_stage_us, stage, OpKind, RequestGuard, Stage};
use crate::util::json::Json;
use protocol::{Request, Response, WireNeighbor};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A running server (accept loop + fixed pool of connection workers).
pub struct Server {
    addr: SocketAddr,
}

impl Server {
    /// Bind `addr` (may be port 0), spawn the
    /// `server.max_connections`-sized worker pool and the accept loop.
    /// Returns once the listener is live.
    // The connection-queue mutex poisons only if a worker panicked
    // holding it; the pool is then unrecoverable — crash loudly.
    #[allow(clippy::disallowed_methods)]
    pub fn spawn(svc: Arc<Coordinator>, addr: &str) -> crate::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let max_conns = svc.config().server.max_connections;
        // `active` counts sockets handed to the pool whose connections
        // have not finished.  The accept loop is the only incrementer
        // (before the handoff) and each worker decrements exactly once
        // per connection (drop guard), so `active == max_conns` is a
        // precise "every worker is serving" signal.
        let active = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        // Rendezvous handoff: the accept loop only sends after proving
        // `active < max_conns`, which guarantees some worker is parked
        // in (or headed for) `recv`, so the blocking send completes
        // immediately.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(0);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for i in 0..max_conns {
            let rx = conn_rx.clone();
            let svc = svc.clone();
            let active = active.clone();
            std::thread::Builder::new()
                .name(format!("conn-worker-{i}"))
                .spawn(move || loop {
                    // Hold the receiver lock only while parked: the
                    // guard drops as soon as `recv` hands us a socket,
                    // letting the next idle worker park itself.
                    let socket = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match socket {
                        Ok(s) => {
                            let _release = ActiveGuard(&active);
                            // Contain panics: a worker that dies takes a
                            // pool slot with it forever (and a fully dead
                            // pool wedges the accept loop), so one bad
                            // request path must only cost its own
                            // connection — as thread-per-connection did.
                            let svc = svc.clone();
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(move || {
                                    let _ = handle_conn(svc, s);
                                }),
                            );
                        }
                        // Accept loop gone: the pool drains and exits.
                        Err(_) => break,
                    }
                })
                .map_err(crate::Error::Io)?;
        }
        std::thread::Builder::new()
            .name("accept-loop".into())
            .spawn(move || accept_loop(&listener, &conn_tx, &active, &svc, max_conns))
            .map_err(crate::Error::Io)?;
        Ok(Server { addr: local })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block this thread forever (the accept loop runs in background).
    pub fn join_forever(&self) -> ! {
        loop {
            std::thread::park();
        }
    }
}

/// Decrements the active-connection counter when a worker finishes a
/// connection, even if `handle_conn` unwinds.
struct ActiveGuard<'a>(&'a std::sync::atomic::AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, std::sync::atomic::Ordering::Release);
    }
}

fn accept_loop(
    listener: &TcpListener,
    conn_tx: &mpsc::SyncSender<TcpStream>,
    active: &std::sync::atomic::AtomicUsize,
    svc: &Arc<Coordinator>,
    max_connections: usize,
) {
    use std::sync::atomic::Ordering;
    for conn in listener.incoming() {
        match conn {
            Ok(socket) => {
                if active.load(Ordering::Acquire) >= max_connections {
                    // Every worker is serving a connection: turn the
                    // overflow client away with a protocol-level error
                    // instead of queueing it invisibly or spawning an
                    // unbounded thread.
                    Metrics::inc(&svc.metrics().busy_rejections);
                    busy_reject(socket, max_connections);
                    continue;
                }
                // A slot is free, so a worker is parked in (or headed
                // for) `recv`; increment first so the worker's paired
                // decrement can never underflow the counter.
                active.fetch_add(1, Ordering::AcqRel);
                if conn_tx.send(socket).is_err() {
                    // Pool gone (shutdown): stop accepting.
                    active.fetch_sub(1, Ordering::Release);
                    break;
                }
            }
            Err(e) if accept_error_is_fatal(&e) => {
                eprintln!("accept-loop: fatal accept error, stopping listener: {e}");
                break;
            }
            Err(e) => {
                // Transient (ECONNABORTED, EINTR, EMFILE/ENFILE fd
                // pressure…): the listener is still valid, so dying
                // here would silently stop the server accepting
                // forever.  Log, count, back off a breath, continue.
                Metrics::inc(&svc.metrics().accept_errors);
                eprintln!("accept-loop: transient accept error (continuing): {e}");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Whether an `accept()` error means the listener itself is unusable.
/// `accept(2)` on a healthy listener only fails transiently (aborted
/// handshakes, signal interrupts, fd exhaustion that later clears);
/// `EBADF`/`EINVAL` mean the listening socket is gone or was never
/// valid, which no amount of retrying fixes.
fn accept_error_is_fatal(e: &std::io::Error) -> bool {
    const EBADF: i32 = 9;
    const EINVAL: i32 = 22;
    matches!(e.raw_os_error(), Some(EBADF) | Some(EINVAL))
        || e.kind() == std::io::ErrorKind::InvalidInput
}

/// Send one `busy` error line to an overflow connection and close it.
fn busy_reject(mut socket: TcpStream, max_connections: usize) {
    let mut line = Response::err(&crate::Error::Busy { max_connections })
        .to_json()
        .to_string();
    line.push('\n');
    let _ = socket.write_all(line.as_bytes());
    // Dropping the socket closes the connection.
}

/// Serve one connection.  Starts on JSON lines; a successful `hello`
/// negotiation (see [`handle_hello`]) may hand the rest of the stream
/// to [`serve_binary`].  Lines are read as raw bytes so a client that
/// sends invalid UTF-8 gets one clean JSON error line instead of
/// killing the read loop; a final line without a trailing newline is
/// still processed.
///
/// Every successfully decoded request is traced: the clock starts when
/// its line arrives, the parse cost is credited to the `decode` stage,
/// inner layers record their own spans, serialization + socket write
/// are the `encode` stage, and the trace publishes only after the
/// response bytes are handed to the kernel — so `total_us` is what the
/// client actually waited, minus network.  Undecodable lines are
/// counted in `errors` but not traced (there is no op to label them
/// with).
fn handle_conn(svc: Arc<Coordinator>, socket: TcpStream) -> crate::Result<()> {
    socket.set_nodelay(true)?;
    let mut writer = socket.try_clone()?;
    let mut reader = BufReader::new(socket);
    let mut hello_done = false;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        if reader.read_until(b'\n', &mut buf)? == 0 {
            return Ok(()); // clean EOF at a line boundary
        }
        let t0 = Instant::now();
        let mut tracked: Option<(RequestGuard<'_>, u32)> = None;
        let resp = match std::str::from_utf8(&buf) {
            Err(_) => {
                Metrics::inc(&svc.metrics().errors);
                Response::err(&crate::Error::Protocol(
                    "request line is not valid UTF-8".into(),
                ))
            }
            Ok(line) => {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                match Json::parse(line) {
                    Ok(j) => {
                        let is_hello =
                            matches!(j.get_opt("op").map(|o| o.as_str()), Some(Ok("hello")));
                        if is_hello {
                            match handle_hello(&svc, &j, &mut hello_done, &mut writer)? {
                                HelloOutcome::SwitchToBinary => {
                                    return serve_binary(&svc, reader, writer);
                                }
                                HelloOutcome::StayJson => continue,
                            }
                        }
                        match Request::from_json(&j) {
                            Ok(req) => {
                                let guard = svc.obs().begin_at(op_kind(&req), t0);
                                add_stage_us(
                                    Stage::Decode,
                                    t0.elapsed().as_micros() as u64,
                                );
                                let items = item_count(&req);
                                let r = dispatch(&svc, req);
                                tracked = Some((guard, items));
                                r
                            }
                            Err(e) => {
                                Metrics::inc(&svc.metrics().errors);
                                Response::err(&e)
                            }
                        }
                    }
                    Err(e) => {
                        Metrics::inc(&svc.metrics().errors);
                        Response::err(&crate::Error::Protocol(e.to_string()))
                    }
                }
            }
        };
        {
            let _span = stage(Stage::Encode);
            let mut out = resp.to_json().to_string();
            out.push('\n');
            writer.write_all(out.as_bytes())?;
        }
        if let Some((mut guard, items)) = tracked {
            guard.finish(items);
        }
    }
}

/// The [`OpKind`] label for a decoded JSON request.
fn op_kind(req: &Request) -> OpKind {
    match req {
        Request::Ping => OpKind::Ping,
        Request::Sketch { .. } => OpKind::Sketch,
        Request::SketchBatch { .. } => OpKind::SketchBatch,
        Request::Insert { .. } => OpKind::Insert,
        Request::InsertBatch { .. } => OpKind::InsertBatch,
        Request::Delete { .. } => OpKind::Delete,
        Request::Save => OpKind::Save,
        Request::Estimate { .. } => OpKind::Estimate,
        Request::EstimateVecs { .. } => OpKind::EstimateVecs,
        Request::Query { .. } => OpKind::Query,
        Request::QueryBatch { .. } => OpKind::QueryBatch,
        Request::QueryAbove { .. } => OpKind::QueryAbove,
        Request::Stats => OpKind::Stats,
        Request::Trace { .. } => OpKind::Trace,
        Request::Metrics => OpKind::Metrics,
        Request::Replicate => OpKind::Replicate,
    }
}

/// Row count of a JSON request (1 for singleton ops), for the trace's
/// `items` field.
fn item_count(req: &Request) -> u32 {
    match req {
        Request::SketchBatch { vecs }
        | Request::InsertBatch { vecs }
        | Request::QueryBatch { vecs, .. } => vecs.len() as u32,
        _ => 1,
    }
}

/// What a `hello` line decided for the rest of the connection.
enum HelloOutcome {
    /// Negotiation succeeded: switch this connection to `bin1` frames.
    SwitchToBinary,
    /// Stay on JSON lines (fallback, repeat hello, or malformed hello).
    StayJson,
}

/// Answer one `{"op":"hello",...}` line.  `"proto":"bin1"` switches
/// the connection to binary frames and advertises the sketch
/// parameters (`scheme`/`dim`/`k`/`seed`/`bits`) a client needs to
/// build the identical hasher locally, plus `max_batch`; any other
/// proto answers `{"ok":true,"proto":"jsonl"}` and stays on JSON, so
/// new clients can probe old servers safely.  `hello_done` is only set
/// by a successful answer — a malformed hello (missing `proto`) leaves
/// the connection able to retry — and a second hello after it is a
/// protocol error.
fn handle_hello(
    svc: &Arc<Coordinator>,
    j: &Json,
    hello_done: &mut bool,
    writer: &mut TcpStream,
) -> crate::Result<HelloOutcome> {
    fn send(writer: &mut TcpStream, json: &Json) -> crate::Result<()> {
        let mut out = json.to_string();
        out.push('\n');
        writer.write_all(out.as_bytes())?;
        Ok(())
    }
    if *hello_done {
        Metrics::inc(&svc.metrics().errors);
        let e = crate::Error::Protocol("hello already negotiated on this connection".into());
        send(writer, &Response::err(&e).to_json())?;
        return Ok(HelloOutcome::StayJson);
    }
    let proto = match j.get("proto").and_then(|p| p.as_str()) {
        Ok(p) => p,
        Err(e) => {
            Metrics::inc(&svc.metrics().errors);
            send(writer, &Response::err(&e).to_json())?;
            return Ok(HelloOutcome::StayJson);
        }
    };
    if proto == frame::PROTO_NAME {
        let cfg = svc.config();
        send(
            writer,
            &Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("proto", Json::str(frame::PROTO_NAME)),
                ("scheme", Json::str(cfg.sketch.scheme.as_str())),
                ("dim", Json::Num(cfg.dim as f64)),
                ("k", Json::Num(cfg.num_hashes as f64)),
                ("seed", Json::Num(cfg.seed as f64)),
                ("bits", Json::Num(f64::from(cfg.sketch.bits))),
                ("max_batch", Json::Num(protocol::MAX_WIRE_BATCH as f64)),
            ]),
        )?;
        *hello_done = true;
        Ok(HelloOutcome::SwitchToBinary)
    } else {
        *hello_done = true;
        send(
            writer,
            &Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("proto", Json::str("jsonl")),
            ]),
        )?;
        Ok(HelloOutcome::StayJson)
    }
}

/// The binary half of a negotiated connection: one `bin1` frame in,
/// one frame out, until clean EOF.  Synced faults (bad checksum,
/// unknown op, malformed payload — the declared body was fully
/// consumed) get one error frame and the loop continues; a truncated
/// stream or I/O failure closes without a reply (the peer is gone);
/// an oversized length prefix answers then closes, because the stream
/// position is no longer trustworthy.  Every fault increments
/// `frame_errors`, keeping binary corruption distinguishable from
/// JSON-level `errors` in `stats`.
fn serve_binary(
    svc: &Arc<Coordinator>,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
) -> crate::Result<()> {
    let mut fr = frame::FrameReader::new(reader);
    let mut fw = frame::FrameWriter::new(writer);
    loop {
        let read = fr.read_frame();
        // Trace clock starts once the frame is fully off the wire
        // (mirroring the JSON path, whose clock starts after its line
        // is read), so blocking in `read_frame` between requests never
        // counts against a request.
        let t0 = Instant::now();
        match read {
            Ok(None) => return Ok(()),
            Ok(Some((op, payload))) => {
                let mut tracked: Option<(RequestGuard<'_>, u32)> = None;
                let resp = match frame::BinRequest::decode(op, &payload) {
                    Ok(req) => {
                        let guard = svc.obs().begin_at(bin_op_kind(&req), t0);
                        add_stage_us(Stage::Decode, t0.elapsed().as_micros() as u64);
                        let items = bin_item_count(&req);
                        let r = dispatch_binary(svc, req);
                        tracked = Some((guard, items));
                        r
                    }
                    Err(e) => {
                        Metrics::inc(&svc.metrics().frame_errors);
                        frame::BinResponse::Err(e.to_string())
                    }
                };
                {
                    let _span = stage(Stage::Encode);
                    let (rop, rpay) = resp.encode();
                    fw.write_frame(rop, &rpay).map_err(crate::Error::from)?;
                }
                if let Some((mut guard, items)) = tracked {
                    guard.finish(items);
                }
            }
            Err(e) => {
                Metrics::inc(&svc.metrics().frame_errors);
                if matches!(e, frame::FrameError::Truncated | frame::FrameError::Io(_)) {
                    return Err(e.into());
                }
                let (rop, rpay) = frame::BinResponse::Err(e.to_string()).encode();
                fw.write_frame(rop, &rpay).map_err(crate::Error::from)?;
                if !e.stream_synced() {
                    return Err(e.into());
                }
            }
        }
    }
}

fn wire_neighbors(ns: Vec<crate::index::Neighbor>) -> Vec<WireNeighbor> {
    ns.into_iter()
        .map(|n| WireNeighbor {
            id: n.id,
            score: n.score,
        })
        .collect()
}

fn dispatch(svc: &Arc<Coordinator>, req: Request) -> Response {
    let result: crate::Result<Response> = (|| {
        Ok(match req {
            Request::Ping => Response::Pong,
            Request::Sketch { vec } => Response::Sketch {
                sketch: svc.sketch(vec)?,
            },
            Request::SketchBatch { vecs } => Response::SketchBatch {
                sketches: svc.sketch_many(vecs)?,
            },
            Request::Insert { vec } => {
                let (id, sketch) = svc.insert(vec)?;
                Response::Insert { id, sketch }
            }
            Request::InsertBatch { vecs } => Response::InsertBatch {
                ids: svc
                    .insert_many(vecs)?
                    .into_iter()
                    .map(|(id, _)| id)
                    .collect(),
            },
            Request::Delete { id } => {
                svc.delete(id)?;
                Response::Deleted { id }
            }
            Request::Save => Response::Saved {
                persisted_bytes: svc.save()?,
            },
            Request::Estimate { a, b } => Response::Estimate {
                jhat: svc.estimate_ids(a, b)?,
            },
            Request::EstimateVecs { v, w } => Response::Estimate {
                jhat: svc.estimate_vecs(v, w)?,
            },
            Request::Query { vec, topk } => Response::Query {
                neighbors: wire_neighbors(svc.query(vec, topk)?),
            },
            Request::QueryBatch { vecs, topk } => Response::QueryBatch {
                results: svc
                    .query_many(vecs, topk)?
                    .into_iter()
                    .map(wire_neighbors)
                    .collect(),
            },
            Request::QueryAbove { vec, threshold } => Response::Query {
                neighbors: wire_neighbors(svc.query_above(vec, threshold)?),
            },
            Request::Stats => {
                let (metrics, store) = svc.stats();
                Response::Stats {
                    scheme: svc.config().sketch.scheme,
                    metrics,
                    store,
                    ops: svc.obs().op_counts(),
                }
            }
            Request::Trace { n, pinned } => {
                // Cap replies at the shared wire-batch row limit so a huge
                // trace ring can never produce a bin1 reply the reference
                // client's own batch-count guard would reject.
                let n = n.min(protocol::MAX_WIRE_BATCH);
                Response::Trace {
                    traces: if pinned {
                        svc.obs().pinned(n)
                    } else {
                        svc.obs().recent(n)
                    },
                }
            }
            Request::Metrics => {
                let (metrics, store) = svc.stats();
                Response::Metrics {
                    text: crate::obs::prom::render(
                        svc.config().sketch.scheme,
                        &metrics,
                        &store,
                        &svc.obs().op_counts(),
                    ),
                }
            }
            Request::Replicate => {
                let (snapshot, wal) = svc.replicate_export()?;
                Response::Replicate { snapshot, wal }
            }
        })
    })();
    match result {
        Ok(r) => r,
        Err(e) => {
            Metrics::inc(&svc.metrics().errors);
            Response::err(&e)
        }
    }
}

/// Map an internal JSON-dialect response onto its binary twin.  Both
/// dialects share [`dispatch`], so results (and error strings) are
/// identical no matter which framing carried the request.
fn bin_of(resp: Response) -> frame::BinResponse {
    use frame::BinResponse as B;
    match resp {
        Response::Err { error } => B::Err(error),
        Response::Pong => B::Pong,
        Response::Sketch { sketch } => B::Sketch(sketch),
        Response::SketchBatch { sketches } => B::SketchBatch(sketches),
        Response::Deleted { id } => B::Deleted(id),
        Response::Estimate { jhat } => B::Estimate(jhat),
        Response::QueryBatch { results } => B::Results(results),
        Response::Trace { traces } => B::Trace(traces),
        Response::Metrics { text } => B::Metrics(text),
        Response::Replicate { snapshot, wal } => B::Replicate { snapshot, wal },
        // the remaining variants have no binary request that produces
        // them; reaching this arm is a server-side dispatch bug
        other => B::Err(format!("unexpected internal response {other:?}")),
    }
}

/// The [`OpKind`] label for a decoded binary request.
fn bin_op_kind(req: &frame::BinRequest) -> OpKind {
    use frame::BinRequest as B;
    match req {
        B::Ping => OpKind::Ping,
        B::Sketch(_) => OpKind::Sketch,
        B::SketchBatch(_) => OpKind::SketchBatch,
        B::InsertPacked { .. } => OpKind::InsertPacked,
        B::QueryBatch { .. } => OpKind::QueryBatch,
        B::Delete(_) => OpKind::Delete,
        B::Estimate(..) => OpKind::Estimate,
        B::Trace { .. } => OpKind::Trace,
        B::Metrics => OpKind::Metrics,
        B::Replicate => OpKind::Replicate,
    }
}

/// Row count of a binary request (1 for singleton ops).
fn bin_item_count(req: &frame::BinRequest) -> u32 {
    use frame::BinRequest as B;
    match req {
        B::SketchBatch(vecs) => vecs.len() as u32,
        B::InsertPacked { rows, .. } => rows.len() as u32,
        B::QueryBatch { vecs, .. } => vecs.len() as u32,
        _ => 1,
    }
}

/// Execute one decoded binary request.  Everything with a JSON twin is
/// converted and routed through [`dispatch`] (sharing its semantics
/// and error accounting); `insert_packed` — binary-only — goes straight
/// to [`Coordinator::insert_packed_many`], the zero-copy path.  Batch
/// emptiness is policed here to mirror the JSON parser's empty-`vecs`
/// rejection, since the frame codec deliberately lets zero-row batches
/// roundtrip.
fn dispatch_binary(svc: &Arc<Coordinator>, req: frame::BinRequest) -> frame::BinResponse {
    use frame::BinRequest as B;
    let reject_empty = |what: &str| {
        Metrics::inc(&svc.metrics().errors);
        frame::BinResponse::Err(
            crate::Error::Protocol(format!("{what} with zero rows")).to_string(),
        )
    };
    match req {
        B::Ping => bin_of(dispatch(svc, Request::Ping)),
        B::Sketch(vec) => bin_of(dispatch(svc, Request::Sketch { vec })),
        B::SketchBatch(vecs) if vecs.is_empty() => reject_empty("sketch_batch"),
        B::SketchBatch(vecs) => bin_of(dispatch(svc, Request::SketchBatch { vecs })),
        B::QueryBatch { vecs, .. } if vecs.is_empty() => reject_empty("query_batch"),
        B::QueryBatch { vecs, topk } => {
            bin_of(dispatch(svc, Request::QueryBatch { vecs, topk }))
        }
        B::Delete(id) => bin_of(dispatch(svc, Request::Delete { id })),
        B::Estimate(a, b) => bin_of(dispatch(svc, Request::Estimate { a, b })),
        B::Trace { n, pinned } => bin_of(dispatch(svc, Request::Trace { n, pinned })),
        B::Metrics => bin_of(dispatch(svc, Request::Metrics)),
        B::Replicate => bin_of(dispatch(svc, Request::Replicate)),
        B::InsertPacked { rows, .. } => match svc.insert_packed_many(rows) {
            Ok(ids) => frame::BinResponse::Ids(ids),
            Err(e) => {
                Metrics::inc(&svc.metrics().errors);
                frame::BinResponse::Err(e.to_string())
            }
        },
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn accept_error_classification() {
        // Transient: the loop must survive these (the old code died on
        // the first one and stopped listening forever).
        for e in [
            std::io::Error::new(std::io::ErrorKind::ConnectionAborted, "ECONNABORTED"),
            std::io::Error::new(std::io::ErrorKind::Interrupted, "EINTR"),
            std::io::Error::from_raw_os_error(24), // EMFILE
            std::io::Error::from_raw_os_error(23), // ENFILE
        ] {
            assert!(!accept_error_is_fatal(&e), "{e} must be survivable");
        }
        // Fatal: the listener fd itself is unusable.
        for e in [
            std::io::Error::from_raw_os_error(9),  // EBADF
            std::io::Error::from_raw_os_error(22), // EINVAL
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "bad listener"),
        ] {
            assert!(accept_error_is_fatal(&e), "{e} must stop the loop");
        }
    }

    #[test]
    fn bin_of_maps_shared_variants() {
        assert_eq!(bin_of(Response::Pong), frame::BinResponse::Pong);
        assert_eq!(
            bin_of(Response::Sketch { sketch: vec![3] }),
            frame::BinResponse::Sketch(vec![3])
        );
        assert_eq!(
            bin_of(Response::Deleted { id: 9 }),
            frame::BinResponse::Deleted(9)
        );
        assert_eq!(
            bin_of(Response::Err {
                error: "nope".into()
            }),
            frame::BinResponse::Err("nope".into())
        );
        // a variant with no binary twin surfaces as an error frame,
        // not a panic
        match bin_of(Response::Saved { persisted_bytes: 1 }) {
            frame::BinResponse::Err(msg) => assert!(msg.contains("unexpected"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }

}
