//! TCP server speaking the JSON-line protocol over a **bounded worker
//! pool**, plus a small blocking client used by examples, benches and
//! tests, and a JSONL bulk loader streaming through `insert_batch`.
//!
//! Every connection starts on JSON lines; a client may send one
//! `{"op":"hello","proto":"bin1"}` line to switch the rest of the
//! stream to the length-prefixed binary framing in [`frame`] (unknown
//! `proto` values answer `{"ok":true,"proto":"jsonl"}` and stay on
//! JSON, so probing an old server is always safe).  The binary dialect
//! shares [`dispatch`] with JSON — identical corpora produce identical
//! results on either framing — and adds `insert_packed`, which carries
//! [`crate::sketch::pack_row`] output byte-for-byte so ingest becomes
//! a checksum-verified copy into the packed arena.
//!
//! Connection admission: `server.max_connections` worker threads are
//! spawned up front; the accept loop tracks how many are serving via a
//! shared counter and hands accepted sockets over a rendezvous
//! channel.  A connection arriving while **every** worker is serving
//! is turned away with a clean `busy` protocol error line instead of
//! spawning an unbounded OS thread; while any worker is free the
//! handoff blocks for at most the instant it takes that worker to
//! park, so connection bursts are never spuriously rejected.  The
//! accept loop never dies on transient `accept()` failures
//! (`ECONNABORTED`, `EMFILE` under fd pressure, interrupts): it logs,
//! counts them in `accept_errors`, backs off briefly and keeps
//! listening; only a listener-is-gone class error (`EBADF`/`EINVAL`)
//! stops it.

pub mod frame;
pub mod protocol;

use crate::coordinator::Coordinator;
use crate::metrics::Metrics;
use crate::obs::{add_stage_us, stage, OpKind, RequestGuard, Stage};
use crate::sketch::SparseVec;
use crate::util::json::Json;
use protocol::{Request, Response, WireNeighbor};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A running server (accept loop + fixed pool of connection workers).
pub struct Server {
    addr: SocketAddr,
}

impl Server {
    /// Bind `addr` (may be port 0), spawn the
    /// `server.max_connections`-sized worker pool and the accept loop.
    /// Returns once the listener is live.
    // The connection-queue mutex poisons only if a worker panicked
    // holding it; the pool is then unrecoverable — crash loudly.
    #[allow(clippy::disallowed_methods)]
    pub fn spawn(svc: Arc<Coordinator>, addr: &str) -> crate::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let max_conns = svc.config().server.max_connections;
        // `active` counts sockets handed to the pool whose connections
        // have not finished.  The accept loop is the only incrementer
        // (before the handoff) and each worker decrements exactly once
        // per connection (drop guard), so `active == max_conns` is a
        // precise "every worker is serving" signal.
        let active = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        // Rendezvous handoff: the accept loop only sends after proving
        // `active < max_conns`, which guarantees some worker is parked
        // in (or headed for) `recv`, so the blocking send completes
        // immediately.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(0);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for i in 0..max_conns {
            let rx = conn_rx.clone();
            let svc = svc.clone();
            let active = active.clone();
            std::thread::Builder::new()
                .name(format!("conn-worker-{i}"))
                .spawn(move || loop {
                    // Hold the receiver lock only while parked: the
                    // guard drops as soon as `recv` hands us a socket,
                    // letting the next idle worker park itself.
                    let socket = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match socket {
                        Ok(s) => {
                            let _release = ActiveGuard(&active);
                            // Contain panics: a worker that dies takes a
                            // pool slot with it forever (and a fully dead
                            // pool wedges the accept loop), so one bad
                            // request path must only cost its own
                            // connection — as thread-per-connection did.
                            let svc = svc.clone();
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(move || {
                                    let _ = handle_conn(svc, s);
                                }),
                            );
                        }
                        // Accept loop gone: the pool drains and exits.
                        Err(_) => break,
                    }
                })
                .map_err(crate::Error::Io)?;
        }
        std::thread::Builder::new()
            .name("accept-loop".into())
            .spawn(move || accept_loop(&listener, &conn_tx, &active, &svc, max_conns))
            .map_err(crate::Error::Io)?;
        Ok(Server { addr: local })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block this thread forever (the accept loop runs in background).
    pub fn join_forever(&self) -> ! {
        loop {
            std::thread::park();
        }
    }
}

/// Decrements the active-connection counter when a worker finishes a
/// connection, even if `handle_conn` unwinds.
struct ActiveGuard<'a>(&'a std::sync::atomic::AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, std::sync::atomic::Ordering::Release);
    }
}

fn accept_loop(
    listener: &TcpListener,
    conn_tx: &mpsc::SyncSender<TcpStream>,
    active: &std::sync::atomic::AtomicUsize,
    svc: &Arc<Coordinator>,
    max_connections: usize,
) {
    use std::sync::atomic::Ordering;
    for conn in listener.incoming() {
        match conn {
            Ok(socket) => {
                if active.load(Ordering::Acquire) >= max_connections {
                    // Every worker is serving a connection: turn the
                    // overflow client away with a protocol-level error
                    // instead of queueing it invisibly or spawning an
                    // unbounded thread.
                    Metrics::inc(&svc.metrics().busy_rejections);
                    busy_reject(socket, max_connections);
                    continue;
                }
                // A slot is free, so a worker is parked in (or headed
                // for) `recv`; increment first so the worker's paired
                // decrement can never underflow the counter.
                active.fetch_add(1, Ordering::AcqRel);
                if conn_tx.send(socket).is_err() {
                    // Pool gone (shutdown): stop accepting.
                    active.fetch_sub(1, Ordering::Release);
                    break;
                }
            }
            Err(e) if accept_error_is_fatal(&e) => {
                eprintln!("accept-loop: fatal accept error, stopping listener: {e}");
                break;
            }
            Err(e) => {
                // Transient (ECONNABORTED, EINTR, EMFILE/ENFILE fd
                // pressure…): the listener is still valid, so dying
                // here would silently stop the server accepting
                // forever.  Log, count, back off a breath, continue.
                Metrics::inc(&svc.metrics().accept_errors);
                eprintln!("accept-loop: transient accept error (continuing): {e}");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Whether an `accept()` error means the listener itself is unusable.
/// `accept(2)` on a healthy listener only fails transiently (aborted
/// handshakes, signal interrupts, fd exhaustion that later clears);
/// `EBADF`/`EINVAL` mean the listening socket is gone or was never
/// valid, which no amount of retrying fixes.
fn accept_error_is_fatal(e: &std::io::Error) -> bool {
    const EBADF: i32 = 9;
    const EINVAL: i32 = 22;
    matches!(e.raw_os_error(), Some(EBADF) | Some(EINVAL))
        || e.kind() == std::io::ErrorKind::InvalidInput
}

/// Send one `busy` error line to an overflow connection and close it.
fn busy_reject(mut socket: TcpStream, max_connections: usize) {
    let mut line = Response::err(&crate::Error::Busy { max_connections })
        .to_json()
        .to_string();
    line.push('\n');
    let _ = socket.write_all(line.as_bytes());
    // Dropping the socket closes the connection.
}

/// Serve one connection.  Starts on JSON lines; a successful `hello`
/// negotiation (see [`handle_hello`]) may hand the rest of the stream
/// to [`serve_binary`].  Lines are read as raw bytes so a client that
/// sends invalid UTF-8 gets one clean JSON error line instead of
/// killing the read loop; a final line without a trailing newline is
/// still processed.
///
/// Every successfully decoded request is traced: the clock starts when
/// its line arrives, the parse cost is credited to the `decode` stage,
/// inner layers record their own spans, serialization + socket write
/// are the `encode` stage, and the trace publishes only after the
/// response bytes are handed to the kernel — so `total_us` is what the
/// client actually waited, minus network.  Undecodable lines are
/// counted in `errors` but not traced (there is no op to label them
/// with).
fn handle_conn(svc: Arc<Coordinator>, socket: TcpStream) -> crate::Result<()> {
    socket.set_nodelay(true)?;
    let mut writer = socket.try_clone()?;
    let mut reader = BufReader::new(socket);
    let mut hello_done = false;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        if reader.read_until(b'\n', &mut buf)? == 0 {
            return Ok(()); // clean EOF at a line boundary
        }
        let t0 = Instant::now();
        let mut tracked: Option<(RequestGuard<'_>, u32)> = None;
        let resp = match std::str::from_utf8(&buf) {
            Err(_) => {
                Metrics::inc(&svc.metrics().errors);
                Response::err(&crate::Error::Protocol(
                    "request line is not valid UTF-8".into(),
                ))
            }
            Ok(line) => {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                match Json::parse(line) {
                    Ok(j) => {
                        let is_hello =
                            matches!(j.get_opt("op").map(|o| o.as_str()), Some(Ok("hello")));
                        if is_hello {
                            match handle_hello(&svc, &j, &mut hello_done, &mut writer)? {
                                HelloOutcome::SwitchToBinary => {
                                    return serve_binary(&svc, reader, writer);
                                }
                                HelloOutcome::StayJson => continue,
                            }
                        }
                        match Request::from_json(&j) {
                            Ok(req) => {
                                let guard = svc.obs().begin_at(op_kind(&req), t0);
                                add_stage_us(
                                    Stage::Decode,
                                    t0.elapsed().as_micros() as u64,
                                );
                                let items = item_count(&req);
                                let r = dispatch(&svc, req);
                                tracked = Some((guard, items));
                                r
                            }
                            Err(e) => {
                                Metrics::inc(&svc.metrics().errors);
                                Response::err(&e)
                            }
                        }
                    }
                    Err(e) => {
                        Metrics::inc(&svc.metrics().errors);
                        Response::err(&crate::Error::Protocol(e.to_string()))
                    }
                }
            }
        };
        {
            let _span = stage(Stage::Encode);
            let mut out = resp.to_json().to_string();
            out.push('\n');
            writer.write_all(out.as_bytes())?;
        }
        if let Some((mut guard, items)) = tracked {
            guard.finish(items);
        }
    }
}

/// The [`OpKind`] label for a decoded JSON request.
fn op_kind(req: &Request) -> OpKind {
    match req {
        Request::Ping => OpKind::Ping,
        Request::Sketch { .. } => OpKind::Sketch,
        Request::SketchBatch { .. } => OpKind::SketchBatch,
        Request::Insert { .. } => OpKind::Insert,
        Request::InsertBatch { .. } => OpKind::InsertBatch,
        Request::Delete { .. } => OpKind::Delete,
        Request::Save => OpKind::Save,
        Request::Estimate { .. } => OpKind::Estimate,
        Request::EstimateVecs { .. } => OpKind::EstimateVecs,
        Request::Query { .. } => OpKind::Query,
        Request::QueryBatch { .. } => OpKind::QueryBatch,
        Request::QueryAbove { .. } => OpKind::QueryAbove,
        Request::Stats => OpKind::Stats,
        Request::Trace { .. } => OpKind::Trace,
        Request::Metrics => OpKind::Metrics,
    }
}

/// Row count of a JSON request (1 for singleton ops), for the trace's
/// `items` field.
fn item_count(req: &Request) -> u32 {
    match req {
        Request::SketchBatch { vecs }
        | Request::InsertBatch { vecs }
        | Request::QueryBatch { vecs, .. } => vecs.len() as u32,
        _ => 1,
    }
}

/// What a `hello` line decided for the rest of the connection.
enum HelloOutcome {
    /// Negotiation succeeded: switch this connection to `bin1` frames.
    SwitchToBinary,
    /// Stay on JSON lines (fallback, repeat hello, or malformed hello).
    StayJson,
}

/// Answer one `{"op":"hello",...}` line.  `"proto":"bin1"` switches
/// the connection to binary frames and advertises the sketch
/// parameters (`scheme`/`dim`/`k`/`seed`/`bits`) a client needs to
/// build the identical hasher locally, plus `max_batch`; any other
/// proto answers `{"ok":true,"proto":"jsonl"}` and stays on JSON, so
/// new clients can probe old servers safely.  `hello_done` is only set
/// by a successful answer — a malformed hello (missing `proto`) leaves
/// the connection able to retry — and a second hello after it is a
/// protocol error.
fn handle_hello(
    svc: &Arc<Coordinator>,
    j: &Json,
    hello_done: &mut bool,
    writer: &mut TcpStream,
) -> crate::Result<HelloOutcome> {
    fn send(writer: &mut TcpStream, json: &Json) -> crate::Result<()> {
        let mut out = json.to_string();
        out.push('\n');
        writer.write_all(out.as_bytes())?;
        Ok(())
    }
    if *hello_done {
        Metrics::inc(&svc.metrics().errors);
        let e = crate::Error::Protocol("hello already negotiated on this connection".into());
        send(writer, &Response::err(&e).to_json())?;
        return Ok(HelloOutcome::StayJson);
    }
    let proto = match j.get("proto").and_then(|p| p.as_str()) {
        Ok(p) => p,
        Err(e) => {
            Metrics::inc(&svc.metrics().errors);
            send(writer, &Response::err(&e).to_json())?;
            return Ok(HelloOutcome::StayJson);
        }
    };
    if proto == frame::PROTO_NAME {
        let cfg = svc.config();
        send(
            writer,
            &Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("proto", Json::str(frame::PROTO_NAME)),
                ("scheme", Json::str(cfg.sketch.scheme.as_str())),
                ("dim", Json::Num(cfg.dim as f64)),
                ("k", Json::Num(cfg.num_hashes as f64)),
                ("seed", Json::Num(cfg.seed as f64)),
                ("bits", Json::Num(f64::from(cfg.sketch.bits))),
                ("max_batch", Json::Num(protocol::MAX_WIRE_BATCH as f64)),
            ]),
        )?;
        *hello_done = true;
        Ok(HelloOutcome::SwitchToBinary)
    } else {
        *hello_done = true;
        send(
            writer,
            &Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("proto", Json::str("jsonl")),
            ]),
        )?;
        Ok(HelloOutcome::StayJson)
    }
}

/// The binary half of a negotiated connection: one `bin1` frame in,
/// one frame out, until clean EOF.  Synced faults (bad checksum,
/// unknown op, malformed payload — the declared body was fully
/// consumed) get one error frame and the loop continues; a truncated
/// stream or I/O failure closes without a reply (the peer is gone);
/// an oversized length prefix answers then closes, because the stream
/// position is no longer trustworthy.  Every fault increments
/// `frame_errors`, keeping binary corruption distinguishable from
/// JSON-level `errors` in `stats`.
fn serve_binary(
    svc: &Arc<Coordinator>,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
) -> crate::Result<()> {
    let mut fr = frame::FrameReader::new(reader);
    let mut fw = frame::FrameWriter::new(writer);
    loop {
        let read = fr.read_frame();
        // Trace clock starts once the frame is fully off the wire
        // (mirroring the JSON path, whose clock starts after its line
        // is read), so blocking in `read_frame` between requests never
        // counts against a request.
        let t0 = Instant::now();
        match read {
            Ok(None) => return Ok(()),
            Ok(Some((op, payload))) => {
                let mut tracked: Option<(RequestGuard<'_>, u32)> = None;
                let resp = match frame::BinRequest::decode(op, &payload) {
                    Ok(req) => {
                        let guard = svc.obs().begin_at(bin_op_kind(&req), t0);
                        add_stage_us(Stage::Decode, t0.elapsed().as_micros() as u64);
                        let items = bin_item_count(&req);
                        let r = dispatch_binary(svc, req);
                        tracked = Some((guard, items));
                        r
                    }
                    Err(e) => {
                        Metrics::inc(&svc.metrics().frame_errors);
                        frame::BinResponse::Err(e.to_string())
                    }
                };
                {
                    let _span = stage(Stage::Encode);
                    let (rop, rpay) = resp.encode();
                    fw.write_frame(rop, &rpay).map_err(crate::Error::from)?;
                }
                if let Some((mut guard, items)) = tracked {
                    guard.finish(items);
                }
            }
            Err(e) => {
                Metrics::inc(&svc.metrics().frame_errors);
                if matches!(e, frame::FrameError::Truncated | frame::FrameError::Io(_)) {
                    return Err(e.into());
                }
                let (rop, rpay) = frame::BinResponse::Err(e.to_string()).encode();
                fw.write_frame(rop, &rpay).map_err(crate::Error::from)?;
                if !e.stream_synced() {
                    return Err(e.into());
                }
            }
        }
    }
}

fn wire_neighbors(ns: Vec<crate::index::Neighbor>) -> Vec<WireNeighbor> {
    ns.into_iter()
        .map(|n| WireNeighbor {
            id: n.id,
            score: n.score,
        })
        .collect()
}

fn dispatch(svc: &Arc<Coordinator>, req: Request) -> Response {
    let result: crate::Result<Response> = (|| {
        Ok(match req {
            Request::Ping => Response::Pong,
            Request::Sketch { vec } => Response::Sketch {
                sketch: svc.sketch(vec)?,
            },
            Request::SketchBatch { vecs } => Response::SketchBatch {
                sketches: svc.sketch_many(vecs)?,
            },
            Request::Insert { vec } => {
                let (id, sketch) = svc.insert(vec)?;
                Response::Insert { id, sketch }
            }
            Request::InsertBatch { vecs } => Response::InsertBatch {
                ids: svc
                    .insert_many(vecs)?
                    .into_iter()
                    .map(|(id, _)| id)
                    .collect(),
            },
            Request::Delete { id } => {
                svc.delete(id)?;
                Response::Deleted { id }
            }
            Request::Save => Response::Saved {
                persisted_bytes: svc.save()?,
            },
            Request::Estimate { a, b } => Response::Estimate {
                jhat: svc.estimate_ids(a, b)?,
            },
            Request::EstimateVecs { v, w } => Response::Estimate {
                jhat: svc.estimate_vecs(v, w)?,
            },
            Request::Query { vec, topk } => Response::Query {
                neighbors: wire_neighbors(svc.query(vec, topk)?),
            },
            Request::QueryBatch { vecs, topk } => Response::QueryBatch {
                results: svc
                    .query_many(vecs, topk)?
                    .into_iter()
                    .map(wire_neighbors)
                    .collect(),
            },
            Request::QueryAbove { vec, threshold } => Response::Query {
                neighbors: wire_neighbors(svc.query_above(vec, threshold)?),
            },
            Request::Stats => {
                let (metrics, store) = svc.stats();
                Response::Stats {
                    scheme: svc.config().sketch.scheme,
                    metrics,
                    store,
                    ops: svc.obs().op_counts(),
                }
            }
            Request::Trace { n, pinned } => {
                // Cap replies at the shared wire-batch row limit so a huge
                // trace ring can never produce a bin1 reply the reference
                // client's own batch-count guard would reject.
                let n = n.min(protocol::MAX_WIRE_BATCH);
                Response::Trace {
                    traces: if pinned {
                        svc.obs().pinned(n)
                    } else {
                        svc.obs().recent(n)
                    },
                }
            }
            Request::Metrics => {
                let (metrics, store) = svc.stats();
                Response::Metrics {
                    text: crate::obs::prom::render(
                        svc.config().sketch.scheme,
                        &metrics,
                        &store,
                        &svc.obs().op_counts(),
                    ),
                }
            }
        })
    })();
    match result {
        Ok(r) => r,
        Err(e) => {
            Metrics::inc(&svc.metrics().errors);
            Response::err(&e)
        }
    }
}

/// Map an internal JSON-dialect response onto its binary twin.  Both
/// dialects share [`dispatch`], so results (and error strings) are
/// identical no matter which framing carried the request.
fn bin_of(resp: Response) -> frame::BinResponse {
    use frame::BinResponse as B;
    match resp {
        Response::Err { error } => B::Err(error),
        Response::Pong => B::Pong,
        Response::Sketch { sketch } => B::Sketch(sketch),
        Response::SketchBatch { sketches } => B::SketchBatch(sketches),
        Response::Deleted { id } => B::Deleted(id),
        Response::Estimate { jhat } => B::Estimate(jhat),
        Response::QueryBatch { results } => B::Results(results),
        Response::Trace { traces } => B::Trace(traces),
        Response::Metrics { text } => B::Metrics(text),
        // the remaining variants have no binary request that produces
        // them; reaching this arm is a server-side dispatch bug
        other => B::Err(format!("unexpected internal response {other:?}")),
    }
}

/// The [`OpKind`] label for a decoded binary request.
fn bin_op_kind(req: &frame::BinRequest) -> OpKind {
    use frame::BinRequest as B;
    match req {
        B::Ping => OpKind::Ping,
        B::Sketch(_) => OpKind::Sketch,
        B::SketchBatch(_) => OpKind::SketchBatch,
        B::InsertPacked { .. } => OpKind::InsertPacked,
        B::QueryBatch { .. } => OpKind::QueryBatch,
        B::Delete(_) => OpKind::Delete,
        B::Estimate(..) => OpKind::Estimate,
        B::Trace { .. } => OpKind::Trace,
        B::Metrics => OpKind::Metrics,
    }
}

/// Row count of a binary request (1 for singleton ops).
fn bin_item_count(req: &frame::BinRequest) -> u32 {
    use frame::BinRequest as B;
    match req {
        B::SketchBatch(vecs) => vecs.len() as u32,
        B::InsertPacked { rows, .. } => rows.len() as u32,
        B::QueryBatch { vecs, .. } => vecs.len() as u32,
        _ => 1,
    }
}

/// Execute one decoded binary request.  Everything with a JSON twin is
/// converted and routed through [`dispatch`] (sharing its semantics
/// and error accounting); `insert_packed` — binary-only — goes straight
/// to [`Coordinator::insert_packed_many`], the zero-copy path.  Batch
/// emptiness is policed here to mirror the JSON parser's empty-`vecs`
/// rejection, since the frame codec deliberately lets zero-row batches
/// roundtrip.
fn dispatch_binary(svc: &Arc<Coordinator>, req: frame::BinRequest) -> frame::BinResponse {
    use frame::BinRequest as B;
    let reject_empty = |what: &str| {
        Metrics::inc(&svc.metrics().errors);
        frame::BinResponse::Err(
            crate::Error::Protocol(format!("{what} with zero rows")).to_string(),
        )
    };
    match req {
        B::Ping => bin_of(dispatch(svc, Request::Ping)),
        B::Sketch(vec) => bin_of(dispatch(svc, Request::Sketch { vec })),
        B::SketchBatch(vecs) if vecs.is_empty() => reject_empty("sketch_batch"),
        B::SketchBatch(vecs) => bin_of(dispatch(svc, Request::SketchBatch { vecs })),
        B::QueryBatch { vecs, .. } if vecs.is_empty() => reject_empty("query_batch"),
        B::QueryBatch { vecs, topk } => {
            bin_of(dispatch(svc, Request::QueryBatch { vecs, topk }))
        }
        B::Delete(id) => bin_of(dispatch(svc, Request::Delete { id })),
        B::Estimate(a, b) => bin_of(dispatch(svc, Request::Estimate { a, b })),
        B::Trace { n, pinned } => bin_of(dispatch(svc, Request::Trace { n, pinned })),
        B::Metrics => bin_of(dispatch(svc, Request::Metrics)),
        B::InsertPacked { rows, .. } => match svc.insert_packed_many(rows) {
            Ok(ids) => frame::BinResponse::Ids(ids),
            Err(e) => {
                Metrics::inc(&svc.metrics().errors);
                frame::BinResponse::Err(e.to_string())
            }
        },
    }
}

/// Everything a binary-mode client needs to sketch locally: a hasher
/// rebuilt from the server's advertised scheme/dim/K/seed (schemes are
/// deterministic, so lanes match the server bit-for-bit — the same
/// guarantee offline sketching jobs rely on) plus the packing
/// geometry.
struct BinInfo {
    hasher: Arc<dyn crate::sketch::Sketcher>,
    dim: u32,
    k: usize,
    bits: u8,
}

impl BinInfo {
    /// Sketch + mask + pack one vector exactly as the server would
    /// have on a JSON insert.
    fn pack(&self, v: &SparseVec) -> crate::Result<Vec<u64>> {
        if v.dim() != self.dim {
            return Err(crate::Error::ShapeMismatch {
                what: "vector dim",
                expected: self.dim as usize,
                got: v.dim() as usize,
            });
        }
        if v.nnz() == 0 {
            return Err(crate::Error::Invalid("empty vector".into()));
        }
        let full = self.hasher.sketch_sparse(v.indices());
        let mut out = vec![0u64; crate::sketch::packed_words(self.k, self.bits)];
        crate::sketch::pack_row(&full, self.bits, &mut out);
        Ok(out)
    }
}

/// A minimal blocking client for examples/benches/tests.  Speaks JSON
/// lines by default; [`BlockingClient::binary`] negotiates `bin1` and
/// reroutes the conveniences through binary frames — inserts are
/// sketched **client-side** with the hasher the server advertised and
/// shipped as packed rows (the zero-copy ingest path).
pub struct BlockingClient {
    reader: BufReader<TcpStream>,
    bin: Option<BinInfo>,
}

impl BlockingClient {
    /// Connect to a running server (JSON-lines mode).
    pub fn connect(addr: &str) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(BlockingClient {
            reader: BufReader::new(stream),
            bin: None,
        })
    }

    /// Negotiate `bin1` framing on this connection and build the local
    /// hasher from the parameters the server advertised.  Errors if
    /// the server declines (it stays on JSON and the connection
    /// remains usable) or if negotiation already happened.
    pub fn binary(&mut self) -> crate::Result<()> {
        if self.bin.is_some() {
            return Err(crate::Error::Invalid(
                "connection is already in binary mode".into(),
            ));
        }
        let hello = Json::obj(vec![
            ("op", Json::str("hello")),
            ("proto", Json::str(frame::PROTO_NAME)),
        ]);
        let mut line = hello.to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        let j = Json::parse(&resp)?;
        if !j.get("ok")?.as_bool()? {
            return Err(crate::Error::Protocol(j.get("error")?.as_str()?.to_string()));
        }
        let proto = j.get("proto")?.as_str()?;
        if proto != frame::PROTO_NAME {
            return Err(crate::Error::Protocol(format!(
                "server declined binary mode (answered proto {proto:?})"
            )));
        }
        let scheme = crate::sketch::SketchScheme::parse(j.get("scheme")?.as_str()?)?;
        let dim = j.get("dim")?.as_u32()?;
        let k = j.get("k")?.as_usize()?;
        let seed = j.get("seed")?.as_u64()?;
        let bits = u8::try_from(j.get("bits")?.as_u32()?)
            .map_err(|_| crate::Error::Protocol("advertised bits out of range".into()))?;
        crate::sketch::check_sketch_bits(bits)?;
        let hasher = scheme.build(dim as usize, k, seed)?;
        self.bin = Some(BinInfo {
            hasher,
            dim,
            k,
            bits,
        });
        Ok(())
    }

    /// True once [`BlockingClient::binary`] has negotiated `bin1`.
    pub fn is_binary(&self) -> bool {
        self.bin.is_some()
    }

    /// Guard for the raw JSON entry points after a `bin1` switch.
    fn reject_json_mode(&self) -> crate::Result<()> {
        if self.bin.is_some() {
            return Err(crate::Error::Invalid(
                "connection negotiated bin1; raw JSON ops are unavailable (open \
                 a second JSON connection for save/stats)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Send one request and read one response (JSON mode only).
    pub fn call(&mut self, req: &Request) -> crate::Result<Response> {
        self.reject_json_mode()?;
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        Response::from_json(&Json::parse(&resp)?)
    }

    /// Send one request and return the raw JSON response line
    /// (used for `stats`; JSON mode only).
    pub fn call_raw(&mut self, req: &Request) -> crate::Result<Json> {
        self.reject_json_mode()?;
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.reader.get_mut().write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(crate::Error::Shutdown);
        }
        Ok(Json::parse(&resp)?)
    }

    /// Send one binary request frame and read one response frame.
    fn bin_call(&mut self, req: &frame::BinRequest) -> crate::Result<frame::BinResponse> {
        debug_assert!(self.bin.is_some());
        let (op, payload) = req.encode();
        frame::FrameWriter::new(self.reader.get_mut())
            .write_frame(op, &payload)
            .map_err(crate::Error::from)?;
        match frame::FrameReader::new(&mut self.reader)
            .read_frame()
            .map_err(crate::Error::from)?
        {
            None => Err(crate::Error::Shutdown),
            Some((op, payload)) => {
                frame::BinResponse::decode(op, &payload).map_err(crate::Error::from)
            }
        }
    }

    fn vecs(dim: u32, rows: Vec<Vec<u32>>) -> crate::Result<Vec<SparseVec>> {
        rows.into_iter().map(|r| SparseVec::new(dim, r)).collect()
    }

    fn unexpected<T>(resp: impl std::fmt::Debug) -> crate::Result<T> {
        Err(crate::Error::Protocol(format!(
            "unexpected response {resp:?}"
        )))
    }

    /// Convenience: liveness check (either mode).
    pub fn ping(&mut self) -> crate::Result<()> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Ping)? {
                frame::BinResponse::Pong => Ok(()),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: sketch a sparse vector.
    pub fn sketch(&mut self, dim: u32, indices: Vec<u32>) -> crate::Result<Vec<u32>> {
        let vec = SparseVec::new(dim, indices)?;
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Sketch(vec))? {
                frame::BinResponse::Sketch(lanes) => Ok(lanes),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Sketch { vec })? {
            Response::Sketch { sketch } => Ok(sketch),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: sketch many vectors in one round-trip.
    pub fn sketch_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
    ) -> crate::Result<Vec<Vec<u32>>> {
        let vecs = Self::vecs(dim, rows)?;
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::SketchBatch(vecs))? {
                frame::BinResponse::SketchBatch(sketches) => Ok(sketches),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::SketchBatch { vecs })? {
            Response::SketchBatch { sketches } => Ok(sketches),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: insert a sparse vector.  In binary mode the row is
    /// sketched and packed locally, then shipped as a one-row
    /// `insert_packed` frame.
    // `expect("checked")` follows the `self.bin.is_some()` test above it.
    #[allow(clippy::disallowed_methods)]
    pub fn insert(&mut self, dim: u32, indices: Vec<u32>) -> crate::Result<u64> {
        let vec = SparseVec::new(dim, indices)?;
        if self.bin.is_some() {
            let row = self.bin.as_ref().expect("checked").pack(&vec)?;
            let mut ids = self.insert_packed(vec![row])?;
            return match ids.pop() {
                Some(id) if ids.is_empty() => Ok(id),
                _ => Self::unexpected("insert_packed id count != 1"),
            };
        }
        match self.call(&Request::Insert { vec })? {
            Response::Insert { id, .. } => Ok(id),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: insert many vectors as one unit; returns the
    /// assigned (consecutive) ids in row order.
    pub fn insert_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
    ) -> crate::Result<Vec<u64>> {
        self.insert_batch_vecs(Self::vecs(dim, rows)?)
    }

    /// Insert pre-validated vectors as one unit.  JSON mode sends
    /// `insert_batch` (the server sketches); binary mode sketches and
    /// packs every row locally and ships one `insert_packed` frame.
    // `expect("checked")` follows the `self.bin.is_some()` test above it.
    #[allow(clippy::disallowed_methods)]
    pub fn insert_batch_vecs(&mut self, vecs: Vec<SparseVec>) -> crate::Result<Vec<u64>> {
        if self.bin.is_some() {
            let bin = self.bin.as_ref().expect("checked");
            let rows = vecs
                .iter()
                .map(|v| bin.pack(v))
                .collect::<crate::Result<Vec<_>>>()?;
            return self.insert_packed(rows);
        }
        match self.call(&Request::InsertBatch { vecs })? {
            Response::InsertBatch { ids } => Ok(ids),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Ship pre-packed sketch rows ([`crate::sketch::pack_row`] output
    /// at the server's K and b, e.g. from an offline sketching job)
    /// down the zero-copy ingest path.  Binary mode only.
    pub fn insert_packed(&mut self, rows: Vec<Vec<u64>>) -> crate::Result<Vec<u64>> {
        if self.bin.is_none() {
            return Err(crate::Error::Invalid(
                "insert_packed requires binary mode (call binary() first)".into(),
            ));
        }
        let words_per_row = rows.first().map_or(0, Vec::len);
        match self.bin_call(&frame::BinRequest::InsertPacked {
            words_per_row,
            rows,
        })? {
            frame::BinResponse::Ids(ids) => Ok(ids),
            frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: delete a stored id.
    pub fn delete(&mut self, id: u64) -> crate::Result<()> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Delete(id))? {
                frame::BinResponse::Deleted(_) => Ok(()),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Delete { id })? {
            Response::Deleted { .. } => Ok(()),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: estimate Ĵ between two stored ids (either mode).
    pub fn estimate(&mut self, a: u64, b: u64) -> crate::Result<f64> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Estimate(a, b))? {
                frame::BinResponse::Estimate(jhat) => Ok(jhat),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Estimate { a, b })? {
            Response::Estimate { jhat } => Ok(jhat),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: top-k query (a one-row `query_batch` in binary
    /// mode — binary keeps the batch surface only).
    pub fn query(
        &mut self,
        dim: u32,
        indices: Vec<u32>,
        topk: usize,
    ) -> crate::Result<Vec<WireNeighbor>> {
        let vec = SparseVec::new(dim, indices)?;
        if self.bin.is_some() {
            let mut results = match self.bin_call(&frame::BinRequest::QueryBatch {
                vecs: vec![vec],
                topk,
            })? {
                frame::BinResponse::Results(results) => results,
                frame::BinResponse::Err(error) => {
                    return Err(crate::Error::Protocol(error))
                }
                other => return Self::unexpected(other),
            };
            return match results.pop() {
                Some(ns) if results.is_empty() => Ok(ns),
                _ => Self::unexpected("query result row count != 1"),
            };
        }
        match self.call(&Request::Query { vec, topk })? {
            Response::Query { neighbors } => Ok(neighbors),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: fetch up to `n` recent request traces, newest
    /// first — or the pinned slow-trace FIFO when `pinned` is true
    /// (either mode).
    pub fn trace(
        &mut self,
        n: usize,
        pinned: bool,
    ) -> crate::Result<Vec<crate::obs::Trace>> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Trace { n, pinned })? {
                frame::BinResponse::Trace(traces) => Ok(traces),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Trace { n, pinned })? {
            Response::Trace { traces } => Ok(traces),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: fetch the server's Prometheus text exposition
    /// (either mode).
    pub fn metrics_text(&mut self) -> crate::Result<String> {
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::Metrics)? {
                frame::BinResponse::Metrics(text) => Ok(text),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::Metrics)? {
            Response::Metrics { text } => Ok(text),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }

    /// Convenience: top-k queries for many vectors in one round-trip;
    /// one neighbor list per row, in row order.
    pub fn query_batch(
        &mut self,
        dim: u32,
        rows: Vec<Vec<u32>>,
        topk: usize,
    ) -> crate::Result<Vec<Vec<WireNeighbor>>> {
        let vecs = Self::vecs(dim, rows)?;
        if self.bin.is_some() {
            return match self.bin_call(&frame::BinRequest::QueryBatch { vecs, topk })? {
                frame::BinResponse::Results(results) => Ok(results),
                frame::BinResponse::Err(error) => Err(crate::Error::Protocol(error)),
                other => Self::unexpected(other),
            };
        }
        match self.call(&Request::QueryBatch { vecs, topk })? {
            Response::QueryBatch { results } => Ok(results),
            Response::Err { error } => Err(crate::Error::Protocol(error)),
            other => Self::unexpected(other),
        }
    }
}

/// Cumulative progress of a [`load_jsonl`] bulk ingest.
#[derive(Clone, Copy, Debug)]
pub struct LoadReport {
    /// Vector rows inserted so far.
    pub rows: u64,
    /// `insert_batch` round-trips issued so far.
    pub batches: u64,
    /// Wall-clock seconds elapsed.
    pub secs: f64,
}

impl LoadReport {
    /// Ingest throughput in rows per second (0 before the clock moves).
    pub fn rows_per_sec(&self) -> f64 {
        if self.secs > 0.0 {
            self.rows as f64 / self.secs
        } else {
            0.0
        }
    }
}

/// Stream a JSONL vector file — one `{"dim":D,"indices":[...]}` object
/// per line, blank lines skipped — into a running server through
/// `insert_batch` round-trips of up to `batch_size` rows.  `progress`
/// is called after every round-trip with cumulative counts (the CLI
/// prints a throughput line from it).  Ingest is sequential over one
/// connection; a bad line or a rejected batch aborts with an error
/// naming the offending line.
pub fn load_jsonl(
    addr: &str,
    path: &std::path::Path,
    batch_size: usize,
    progress: impl FnMut(&LoadReport),
) -> crate::Result<LoadReport> {
    load_jsonl_with(addr, path, batch_size, false, progress)
}

/// Same as [`load_jsonl`], but negotiates `bin1` first: every batch is
/// sketched and packed **client-side** and shipped as one
/// `insert_packed` frame, so the server's ingest work per row is a
/// checksum verification plus a copy into the packed arena.  Results
/// are identical to the JSON path — the client's hasher is rebuilt
/// from the parameters the server advertised at negotiation.
pub fn load_jsonl_binary(
    addr: &str,
    path: &std::path::Path,
    batch_size: usize,
    progress: impl FnMut(&LoadReport),
) -> crate::Result<LoadReport> {
    load_jsonl_with(addr, path, batch_size, true, progress)
}

fn load_jsonl_with(
    addr: &str,
    path: &std::path::Path,
    batch_size: usize,
    binary: bool,
    mut progress: impl FnMut(&LoadReport),
) -> crate::Result<LoadReport> {
    if batch_size == 0 {
        return Err(crate::Error::Invalid("batch size must be > 0".into()));
    }
    if batch_size > protocol::MAX_WIRE_BATCH {
        return Err(crate::Error::Invalid(format!(
            "batch size {batch_size} exceeds the wire cap of {} rows per \
             request",
            protocol::MAX_WIRE_BATCH
        )));
    }
    let file = std::fs::File::open(path)?;
    let reader = BufReader::new(file);
    let mut client = BlockingClient::connect(addr)?;
    if binary {
        client.binary()?;
    }
    let t0 = Instant::now();
    let mut report = LoadReport {
        rows: 0,
        batches: 0,
        secs: 0.0,
    };
    let mut pending: Vec<SparseVec> = Vec::with_capacity(batch_size);
    let mut first_line = 0usize; // 1-based line number of pending[0]
    let mut flush = |pending: &mut Vec<SparseVec>,
                     report: &mut LoadReport,
                     client: &mut BlockingClient,
                     first_line: usize|
     -> crate::Result<()> {
        if pending.is_empty() {
            return Ok(());
        }
        let n = pending.len();
        let ids = client
            .insert_batch_vecs(std::mem::take(pending))
            .map_err(|e| {
                crate::Error::Protocol(format!(
                    "batch starting at line {first_line} rejected: {e}"
                ))
            })?;
        if ids.len() != n {
            return Err(crate::Error::Protocol(format!(
                "insert returned {} ids for {n} rows",
                ids.len()
            )));
        }
        report.rows += n as u64;
        report.batches += 1;
        report.secs = t0.elapsed().as_secs_f64();
        Ok(())
    };
    for (idx, line) in reader.lines().enumerate() {
        let lineno = idx + 1;
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(&line)
            .map_err(crate::Error::from)
            .and_then(|j| SparseVec::from_json(&j))
            .map_err(|e| {
                crate::Error::Invalid(format!("{}:{lineno}: {e}", path.display()))
            })?;
        if pending.is_empty() {
            first_line = lineno;
        }
        pending.push(parsed);
        if pending.len() == batch_size {
            flush(&mut pending, &mut report, &mut client, first_line)?;
            progress(&report);
        }
    }
    if !pending.is_empty() {
        flush(&mut pending, &mut report, &mut client, first_line)?;
        progress(&report);
    }
    report.secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn accept_error_classification() {
        // Transient: the loop must survive these (the old code died on
        // the first one and stopped listening forever).
        for e in [
            std::io::Error::new(std::io::ErrorKind::ConnectionAborted, "ECONNABORTED"),
            std::io::Error::new(std::io::ErrorKind::Interrupted, "EINTR"),
            std::io::Error::from_raw_os_error(24), // EMFILE
            std::io::Error::from_raw_os_error(23), // ENFILE
        ] {
            assert!(!accept_error_is_fatal(&e), "{e} must be survivable");
        }
        // Fatal: the listener fd itself is unusable.
        for e in [
            std::io::Error::from_raw_os_error(9),  // EBADF
            std::io::Error::from_raw_os_error(22), // EINVAL
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "bad listener"),
        ] {
            assert!(accept_error_is_fatal(&e), "{e} must stop the loop");
        }
    }

    #[test]
    fn bin_of_maps_shared_variants() {
        assert_eq!(bin_of(Response::Pong), frame::BinResponse::Pong);
        assert_eq!(
            bin_of(Response::Sketch { sketch: vec![3] }),
            frame::BinResponse::Sketch(vec![3])
        );
        assert_eq!(
            bin_of(Response::Deleted { id: 9 }),
            frame::BinResponse::Deleted(9)
        );
        assert_eq!(
            bin_of(Response::Err {
                error: "nope".into()
            }),
            frame::BinResponse::Err("nope".into())
        );
        // a variant with no binary twin surfaces as an error frame,
        // not a panic
        match bin_of(Response::Saved { persisted_bytes: 1 }) {
            frame::BinResponse::Err(msg) => assert!(msg.contains("unexpected"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn load_report_throughput() {
        let r = LoadReport {
            rows: 100,
            batches: 2,
            secs: 4.0,
        };
        assert_eq!(r.rows_per_sec(), 25.0);
        let r = LoadReport {
            rows: 0,
            batches: 0,
            secs: 0.0,
        };
        assert_eq!(r.rows_per_sec(), 0.0);
    }
}
