//! JSON-line wire protocol.
//!
//! One JSON object per line in each direction.  Requests are tagged by
//! `"op"`; responses carry `"ok": true` plus op-specific fields, or
//! `"ok": false` with `"error"`.
//!
//! ```text
//! → {"op":"sketch","vec":{"dim":1024,"indices":[3,17,900]}}
//! ← {"ok":true,"sketch":[...]}
//! → {"op":"insert","vec":{...}}
//! ← {"ok":true,"id":7,"sketch":[...]}
//! → {"op":"delete","id":7}
//! ← {"ok":true,"deleted":7}
//! → {"op":"estimate","a":7,"b":9}
//! ← {"ok":true,"jhat":0.4921875}
//! → {"op":"query","vec":{...},"topk":5}
//! ← {"ok":true,"neighbors":[{"id":7,"score":0.98}, ...]}
//! → {"op":"save"}
//! ← {"ok":true,"saved":true,"persisted_bytes":123456}
//! → {"op":"stats"}      → {"op":"ping"}
//! ```

use crate::metrics::MetricsSnapshot;
use crate::sketch::SparseVec;
use crate::store::StoreStats;
use crate::util::json::Json;

/// Client → server requests.
#[derive(Clone, Debug)]
pub enum Request {
    /// Liveness check.
    Ping,
    /// Sketch a vector (stateless).
    Sketch {
        /// The vector.
        vec: SparseVec,
    },
    /// Sketch + store + index; returns the new id.
    Insert {
        /// The vector.
        vec: SparseVec,
    },
    /// Delete a stored id from the store and index.
    Delete {
        /// The id to delete.
        id: u64,
    },
    /// Estimate J between two stored ids.
    Estimate {
        /// First id.
        a: u64,
        /// Second id.
        b: u64,
    },
    /// Estimate J between two inline vectors.
    EstimateVecs {
        /// First vector.
        v: SparseVec,
        /// Second vector.
        w: SparseVec,
    },
    /// Top-k near neighbors among inserted items.
    Query {
        /// The query vector.
        vec: SparseVec,
        /// Result bound.
        topk: usize,
    },
    /// All neighbors with Ĵ ≥ threshold.
    QueryAbove {
        /// The query vector.
        vec: SparseVec,
        /// Similarity threshold.
        threshold: f64,
    },
    /// Fold the WAL into a fresh snapshot on disk.
    Save,
    /// Metrics snapshot.
    Stats,
}

impl Request {
    /// Parse a request line.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let op = j.get("op")?.as_str()?;
        Ok(match op {
            "ping" => Request::Ping,
            "sketch" => Request::Sketch {
                vec: SparseVec::from_json(j.get("vec")?)?,
            },
            "insert" => Request::Insert {
                vec: SparseVec::from_json(j.get("vec")?)?,
            },
            "delete" => Request::Delete {
                id: j.get("id")?.as_u64()?,
            },
            "estimate" => Request::Estimate {
                a: j.get("a")?.as_u64()?,
                b: j.get("b")?.as_u64()?,
            },
            "estimate_vecs" => Request::EstimateVecs {
                v: SparseVec::from_json(j.get("v")?)?,
                w: SparseVec::from_json(j.get("w")?)?,
            },
            "query" => Request::Query {
                vec: SparseVec::from_json(j.get("vec")?)?,
                topk: j.get("topk")?.as_usize()?,
            },
            "query_above" => Request::QueryAbove {
                vec: SparseVec::from_json(j.get("vec")?)?,
                threshold: j.get("threshold")?.as_f64()?,
            },
            "save" => Request::Save,
            "stats" => Request::Stats,
            other => {
                return Err(crate::Error::Protocol(format!("unknown op {other:?}")))
            }
        })
    }

    /// Serialize (used by the client side).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::obj(vec![("op", Json::str("ping"))]),
            Request::Sketch { vec } => Json::obj(vec![
                ("op", Json::str("sketch")),
                ("vec", vec.to_json()),
            ]),
            Request::Insert { vec } => Json::obj(vec![
                ("op", Json::str("insert")),
                ("vec", vec.to_json()),
            ]),
            Request::Delete { id } => Json::obj(vec![
                ("op", Json::str("delete")),
                ("id", Json::Num(*id as f64)),
            ]),
            Request::Estimate { a, b } => Json::obj(vec![
                ("op", Json::str("estimate")),
                ("a", Json::Num(*a as f64)),
                ("b", Json::Num(*b as f64)),
            ]),
            Request::EstimateVecs { v, w } => Json::obj(vec![
                ("op", Json::str("estimate_vecs")),
                ("v", v.to_json()),
                ("w", w.to_json()),
            ]),
            Request::Query { vec, topk } => Json::obj(vec![
                ("op", Json::str("query")),
                ("vec", vec.to_json()),
                ("topk", Json::Num(*topk as f64)),
            ]),
            Request::QueryAbove { vec, threshold } => Json::obj(vec![
                ("op", Json::str("query_above")),
                ("vec", vec.to_json()),
                ("threshold", Json::Num(*threshold)),
            ]),
            Request::Save => Json::obj(vec![("op", Json::str("save"))]),
            Request::Stats => Json::obj(vec![("op", Json::str("stats"))]),
        }
    }
}

/// One scored neighbor on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireNeighbor {
    /// Item id.
    pub id: u64,
    /// Estimated Jaccard.
    pub score: f64,
}

/// Server → client responses.
// Stats inlines the full metrics snapshot; responses are serialized
// immediately, never stored in bulk, so the size skew is harmless.
#[allow(clippy::large_enum_variant)]
#[derive(Clone, Debug)]
pub enum Response {
    /// Failure.
    Err {
        /// Human-readable error.
        error: String,
    },
    /// Ping reply.
    Pong,
    /// Sketch result.
    Sketch {
        /// K hash values.
        sketch: Vec<u32>,
    },
    /// Insert result.
    Insert {
        /// Assigned id.
        id: u64,
        /// K hash values.
        sketch: Vec<u32>,
    },
    /// Delete result.
    Deleted {
        /// The removed id.
        id: u64,
    },
    /// Save (snapshot compaction) result.
    Saved {
        /// Bytes on disk after compaction.
        persisted_bytes: u64,
    },
    /// Estimate result.
    Estimate {
        /// Ĵ.
        jhat: f64,
    },
    /// Query result.
    Query {
        /// Scored neighbors, best first.
        neighbors: Vec<WireNeighbor>,
    },
    /// Stats result.
    Stats {
        /// Metrics snapshot.
        metrics: MetricsSnapshot,
        /// Store occupancy + durability.
        store: StoreStats,
    },
}

impl Response {
    /// Build an error response.
    pub fn err(e: &crate::Error) -> Self {
        Response::Err {
            error: e.to_string(),
        }
    }

    /// Serialize one response line.
    pub fn to_json(&self) -> Json {
        match self {
            Response::Err { error } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(error)),
            ]),
            Response::Pong => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("pong", Json::Bool(true)),
            ]),
            Response::Sketch { sketch } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("sketch", Json::from_u32s(sketch)),
            ]),
            Response::Insert { id, sketch } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("id", Json::Num(*id as f64)),
                ("sketch", Json::from_u32s(sketch)),
            ]),
            Response::Deleted { id } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("deleted", Json::Num(*id as f64)),
            ]),
            Response::Saved { persisted_bytes } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("saved", Json::Bool(true)),
                ("persisted_bytes", Json::Num(*persisted_bytes as f64)),
            ]),
            Response::Estimate { jhat } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("jhat", Json::Num(*jhat)),
            ]),
            Response::Query { neighbors } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "neighbors",
                    Json::Arr(
                        neighbors
                            .iter()
                            .map(|n| {
                                Json::obj(vec![
                                    ("id", Json::Num(n.id as f64)),
                                    ("score", Json::Num(n.score)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Stats { metrics, store } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("metrics", metrics.to_json()),
                ("stored", Json::Num(store.stored as f64)),
                (
                    "shards",
                    Json::Arr(
                        store
                            .shards
                            .iter()
                            .map(|&n| Json::Num(n as f64))
                            .collect(),
                    ),
                ),
                ("persisted_bytes", Json::Num(store.persisted_bytes as f64)),
            ]),
        }
    }

    /// Parse a response line (client side).
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        if !j.get("ok")?.as_bool()? {
            return Ok(Response::Err {
                error: j.get("error")?.as_str()?.to_string(),
            });
        }
        if j.get_opt("pong").is_some() {
            return Ok(Response::Pong);
        }
        if let Some(id) = j.get_opt("deleted") {
            return Ok(Response::Deleted { id: id.as_u64()? });
        }
        if j.get_opt("saved").is_some() {
            return Ok(Response::Saved {
                persisted_bytes: j.get("persisted_bytes")?.as_u64()?,
            });
        }
        if let Some(id) = j.get_opt("id") {
            return Ok(Response::Insert {
                id: id.as_u64()?,
                sketch: j.get("sketch")?.as_u32_vec()?,
            });
        }
        if let Some(s) = j.get_opt("sketch") {
            return Ok(Response::Sketch {
                sketch: s.as_u32_vec()?,
            });
        }
        if let Some(v) = j.get_opt("jhat") {
            return Ok(Response::Estimate {
                jhat: v.as_f64()?,
            });
        }
        if let Some(ns) = j.get_opt("neighbors") {
            return Ok(Response::Query {
                neighbors: ns
                    .as_arr()?
                    .iter()
                    .map(|n| {
                        Ok(WireNeighbor {
                            id: n.get("id")?.as_u64()?,
                            score: n.get("score")?.as_f64()?,
                        })
                    })
                    .collect::<crate::Result<_>>()?,
            });
        }
        if j.get_opt("metrics").is_some() {
            // Clients mostly print stats verbatim; re-parsing the full
            // snapshot is not needed, so surface a protocol error if a
            // client tries to decode it structurally.
            return Err(crate::Error::Protocol(
                "stats responses are consumed as raw JSON".into(),
            ));
        }
        Err(crate::Error::Protocol("unrecognized response".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let line = r#"{"op":"sketch","vec":{"dim":16,"indices":[1,5]}}"#;
        let req = Request::from_json(&Json::parse(line).unwrap()).unwrap();
        match &req {
            Request::Sketch { vec } => {
                assert_eq!(vec.dim(), 16);
                assert_eq!(vec.indices(), &[1, 5]);
            }
            _ => panic!("wrong op"),
        }
        let back = req.to_json().to_string();
        assert!(back.contains(r#""op":"sketch""#));
        // parse what we serialized
        Request::from_json(&Json::parse(&back).unwrap()).unwrap();
    }

    #[test]
    fn all_ops_parse() {
        for line in [
            r#"{"op":"ping"}"#,
            r#"{"op":"insert","vec":{"dim":4,"indices":[]}}"#,
            r#"{"op":"delete","id":7}"#,
            r#"{"op":"save"}"#,
            r#"{"op":"estimate","a":1,"b":2}"#,
            r#"{"op":"estimate_vecs","v":{"dim":4,"indices":[0]},"w":{"dim":4,"indices":[1]}}"#,
            r#"{"op":"query","vec":{"dim":4,"indices":[0]},"topk":3}"#,
            r#"{"op":"query_above","vec":{"dim":4,"indices":[0]},"threshold":0.5}"#,
            r#"{"op":"stats"}"#,
        ] {
            Request::from_json(&Json::parse(line).unwrap())
                .unwrap_or_else(|e| panic!("{line}: {e}"));
        }
    }

    #[test]
    fn unknown_op_rejected() {
        let j = Json::parse(r#"{"op":"drop_tables"}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
    }

    #[test]
    fn delete_and_save_roundtrip() {
        let req = Request::Delete { id: 12 };
        let line = req.to_json().to_string();
        match Request::from_json(&Json::parse(&line).unwrap()).unwrap() {
            Request::Delete { id } => assert_eq!(id, 12),
            other => panic!("{other:?}"),
        }
        let r = Response::Deleted { id: 12 }.to_json().to_string();
        match Response::from_json(&Json::parse(&r).unwrap()).unwrap() {
            Response::Deleted { id } => assert_eq!(id, 12),
            other => panic!("{other:?}"),
        }
        let r = Response::Saved {
            persisted_bytes: 4096,
        }
        .to_json()
        .to_string();
        match Response::from_json(&Json::parse(&r).unwrap()).unwrap() {
            Response::Saved { persisted_bytes } => assert_eq!(persisted_bytes, 4096),
            other => panic!("{other:?}"),
        }
        // a delete op with no id is a protocol error
        assert!(Request::from_json(&Json::parse(r#"{"op":"delete"}"#).unwrap()).is_err());
    }

    #[test]
    fn stats_response_carries_shard_occupancy() {
        let r = Response::Stats {
            metrics: crate::metrics::Metrics::default().snapshot(),
            store: crate::store::StoreStats {
                stored: 5,
                shards: vec![2, 3],
                persisted_bytes: 77,
            },
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("stored").unwrap().as_u64().unwrap(), 5);
        assert_eq!(j.get("persisted_bytes").unwrap().as_u64().unwrap(), 77);
        assert_eq!(
            j.get("shards").unwrap().as_u32_vec().unwrap(),
            vec![2u32, 3]
        );
    }

    #[test]
    fn response_roundtrips() {
        let r = Response::Estimate { jhat: 0.5 };
        let s = r.to_json().to_string();
        assert!(s.contains(r#""ok":true"#));
        match Response::from_json(&Json::parse(&s).unwrap()).unwrap() {
            Response::Estimate { jhat } => assert_eq!(jhat, 0.5),
            other => panic!("{other:?}"),
        }
        let e = Response::err(&crate::Error::Shutdown).to_json().to_string();
        assert!(e.contains(r#""ok":false"#));
        match Response::from_json(&Json::parse(&e).unwrap()).unwrap() {
            Response::Err { error } => assert!(error.contains("shut down")),
            other => panic!("{other:?}"),
        }
        let q = Response::Query {
            neighbors: vec![WireNeighbor { id: 3, score: 0.75 }],
        };
        let s = q.to_json().to_string();
        match Response::from_json(&Json::parse(&s).unwrap()).unwrap() {
            Response::Query { neighbors } => {
                assert_eq!(neighbors, vec![WireNeighbor { id: 3, score: 0.75 }])
            }
            other => panic!("{other:?}"),
        }
    }
}
