//! JSON-line wire protocol.
//!
//! One JSON object per line in each direction.  Requests are tagged by
//! `"op"`; responses carry `"ok": true` plus op-specific fields, or
//! `"ok": false` with `"error"`.
//!
//! ```text
//! → {"op":"sketch","vec":{"dim":1024,"indices":[3,17,900]}}
//! ← {"ok":true,"sketch":[...]}
//! → {"op":"insert","vec":{...}}
//! ← {"ok":true,"id":7,"sketch":[...]}
//! → {"op":"delete","id":7}
//! ← {"ok":true,"deleted":7}
//! → {"op":"estimate","a":7,"b":9}
//! ← {"ok":true,"jhat":0.4921875}
//! → {"op":"query","vec":{...},"topk":5}
//! ← {"ok":true,"neighbors":[{"id":7,"score":0.98}, ...]}
//! → {"op":"save"}
//! ← {"ok":true,"saved":true,"persisted_bytes":123456}
//! → {"op":"stats"}      → {"op":"ping"}
//! ```
//!
//! `stats` responses lead with `"scheme"` — the active
//! [`SketchScheme`]'s canonical name — and `"bits"`, the stored sketch
//! width (32 = full lanes, < 32 = the packed b-bit plane, with
//! `"sketch_bytes"` the truthful resident bytes per stored sketch), so
//! clients can check that their offline sketches are comparable with
//! the server's before mixing them.  The complete operator-facing reference for every op
//! (including error classes and `busy` semantics) is
//! `docs/PROTOCOL.md`; this module is the codec it describes.
//!
//! **Batch ops** carry many vectors per request line and return one
//! response line per batch — the bulk-ingest path that amortizes the
//! round-trip and lets the engine see full batches.  A batch is
//! all-or-nothing: any bad row fails the whole request and mutates
//! nothing.  An `N = 1` batch returns exactly the singleton op's
//! values, and one line carries at most [`MAX_WIRE_BATCH`] rows.
//!
//! ```text
//! → {"op":"sketch_batch","vecs":[{...},{...}]}
//! ← {"ok":true,"sketches":[[...],[...]]}
//! → {"op":"insert_batch","vecs":[{...},{...}]}
//! ← {"ok":true,"ids":[7,8]}
//! → {"op":"query_batch","vecs":[{...},{...}],"topk":5}
//! ← {"ok":true,"results":[[{"id":7,"score":0.98},...],[...]]}
//! ```
//!
//! `insert_batch` deliberately returns **ids only**: bulk ingest is
//! its use-case, and echoing K hash values per row back at a client
//! that discards them would dominate the response bytes.  Clients
//! that want the sketches use `sketch_batch` (stateless) instead.

use crate::metrics::MetricsSnapshot;
use crate::sketch::{SketchScheme, SparseVec};
use crate::store::StoreStats;
use crate::util::json::Json;

/// Client → server requests.
#[derive(Clone, Debug)]
pub enum Request {
    /// Liveness check.
    Ping,
    /// Sketch a vector (stateless).
    Sketch {
        /// The vector.
        vec: SparseVec,
    },
    /// Sketch many vectors in one round-trip (stateless).
    SketchBatch {
        /// The vectors, in response order.
        vecs: Vec<SparseVec>,
    },
    /// Sketch + store + index; returns the new id.
    Insert {
        /// The vector.
        vec: SparseVec,
    },
    /// Sketch + store + index many vectors as one unit; returns
    /// consecutive new ids.
    InsertBatch {
        /// The vectors, in id-assignment order.
        vecs: Vec<SparseVec>,
    },
    /// Delete a stored id from the store and index.
    Delete {
        /// The id to delete.
        id: u64,
    },
    /// Estimate J between two stored ids.
    Estimate {
        /// First id.
        a: u64,
        /// Second id.
        b: u64,
    },
    /// Estimate J between two inline vectors.
    EstimateVecs {
        /// First vector.
        v: SparseVec,
        /// Second vector.
        w: SparseVec,
    },
    /// Top-k near neighbors among inserted items.
    Query {
        /// The query vector.
        vec: SparseVec,
        /// Result bound.
        topk: usize,
    },
    /// Top-k near neighbors for many query vectors in one round-trip.
    QueryBatch {
        /// The query vectors, in response order.
        vecs: Vec<SparseVec>,
        /// Result bound per row.
        topk: usize,
    },
    /// All neighbors with Ĵ ≥ threshold.
    QueryAbove {
        /// The query vector.
        vec: SparseVec,
        /// Similarity threshold.
        threshold: f64,
    },
    /// Fold the WAL into a fresh snapshot on disk.
    Save,
    /// Metrics snapshot.
    Stats,
    /// Recent (or pinned-slow) request traces with per-stage spans.
    Trace {
        /// Maximum traces to return (newest first).
        n: usize,
        /// Return the pinned slow-trace FIFO instead of the ring.
        pinned: bool,
    },
    /// Prometheus text exposition of the full metrics surface.
    Metrics,
    /// Export this node's durable image (snapshot + WAL tail) so a
    /// fresh cluster peer can bootstrap from it.
    Replicate,
}

/// Upper bound on rows per batch op.  One request line must not be
/// able to buffer unbounded memory or park an unbounded row count in
/// front of the batch pump (that would defeat the connection-level
/// admission control); clients ingesting more rows send more batches.
pub const MAX_WIRE_BATCH: usize = 8_192;

/// Parse the `"vecs"` array of a batch op.  An empty batch is a
/// protocol error — it could only ever return nothing and usually
/// signals a client-side bug — and an oversized one is rejected
/// before any row is parsed (see [`MAX_WIRE_BATCH`]).
fn vecs_field(j: &Json) -> crate::Result<Vec<SparseVec>> {
    let arr = j.get("vecs")?.as_arr()?;
    if arr.is_empty() {
        return Err(crate::Error::Protocol(
            "batch op with empty \"vecs\"".into(),
        ));
    }
    if arr.len() > MAX_WIRE_BATCH {
        return Err(crate::Error::Protocol(format!(
            "batch op with {} rows exceeds the {MAX_WIRE_BATCH}-row cap; \
             split the request into smaller batches",
            arr.len()
        )));
    }
    arr.iter().map(SparseVec::from_json).collect()
}

impl Request {
    /// Parse a request line.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let op = j.get("op")?.as_str()?;
        Ok(match op {
            "ping" => Request::Ping,
            "sketch" => Request::Sketch {
                vec: SparseVec::from_json(j.get("vec")?)?,
            },
            "sketch_batch" => Request::SketchBatch {
                vecs: vecs_field(j)?,
            },
            "insert" => Request::Insert {
                vec: SparseVec::from_json(j.get("vec")?)?,
            },
            "insert_batch" => Request::InsertBatch {
                vecs: vecs_field(j)?,
            },
            "delete" => Request::Delete {
                id: j.get("id")?.as_u64()?,
            },
            "estimate" => Request::Estimate {
                a: j.get("a")?.as_u64()?,
                b: j.get("b")?.as_u64()?,
            },
            "estimate_vecs" => Request::EstimateVecs {
                v: SparseVec::from_json(j.get("v")?)?,
                w: SparseVec::from_json(j.get("w")?)?,
            },
            "query" => Request::Query {
                vec: SparseVec::from_json(j.get("vec")?)?,
                topk: j.get("topk")?.as_usize()?,
            },
            "query_batch" => Request::QueryBatch {
                vecs: vecs_field(j)?,
                topk: j.get("topk")?.as_usize()?,
            },
            "query_above" => Request::QueryAbove {
                vec: SparseVec::from_json(j.get("vec")?)?,
                threshold: j.get("threshold")?.as_f64()?,
            },
            "save" => Request::Save,
            "stats" => Request::Stats,
            "trace" => Request::Trace {
                n: match j.get_opt("n") {
                    Some(v) => v.as_usize()?,
                    None => 16,
                },
                pinned: match j.get_opt("pinned") {
                    Some(v) => v.as_bool()?,
                    None => false,
                },
            },
            "metrics" => Request::Metrics,
            "replicate" => Request::Replicate,
            other => {
                return Err(crate::Error::Protocol(format!("unknown op {other:?}")))
            }
        })
    }

    /// Serialize (used by the client side).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::obj(vec![("op", Json::str("ping"))]),
            Request::Sketch { vec } => Json::obj(vec![
                ("op", Json::str("sketch")),
                ("vec", vec.to_json()),
            ]),
            Request::SketchBatch { vecs } => Json::obj(vec![
                ("op", Json::str("sketch_batch")),
                ("vecs", Json::Arr(vecs.iter().map(|v| v.to_json()).collect())),
            ]),
            Request::Insert { vec } => Json::obj(vec![
                ("op", Json::str("insert")),
                ("vec", vec.to_json()),
            ]),
            Request::InsertBatch { vecs } => Json::obj(vec![
                ("op", Json::str("insert_batch")),
                ("vecs", Json::Arr(vecs.iter().map(|v| v.to_json()).collect())),
            ]),
            Request::Delete { id } => Json::obj(vec![
                ("op", Json::str("delete")),
                ("id", Json::Num(*id as f64)),
            ]),
            Request::Estimate { a, b } => Json::obj(vec![
                ("op", Json::str("estimate")),
                ("a", Json::Num(*a as f64)),
                ("b", Json::Num(*b as f64)),
            ]),
            Request::EstimateVecs { v, w } => Json::obj(vec![
                ("op", Json::str("estimate_vecs")),
                ("v", v.to_json()),
                ("w", w.to_json()),
            ]),
            Request::Query { vec, topk } => Json::obj(vec![
                ("op", Json::str("query")),
                ("vec", vec.to_json()),
                ("topk", Json::Num(*topk as f64)),
            ]),
            Request::QueryBatch { vecs, topk } => Json::obj(vec![
                ("op", Json::str("query_batch")),
                ("vecs", Json::Arr(vecs.iter().map(|v| v.to_json()).collect())),
                ("topk", Json::Num(*topk as f64)),
            ]),
            Request::QueryAbove { vec, threshold } => Json::obj(vec![
                ("op", Json::str("query_above")),
                ("vec", vec.to_json()),
                ("threshold", Json::Num(*threshold)),
            ]),
            Request::Save => Json::obj(vec![("op", Json::str("save"))]),
            Request::Stats => Json::obj(vec![("op", Json::str("stats"))]),
            Request::Trace { n, pinned } => Json::obj(vec![
                ("op", Json::str("trace")),
                ("n", Json::Num(*n as f64)),
                ("pinned", Json::Bool(*pinned)),
            ]),
            Request::Metrics => Json::obj(vec![("op", Json::str("metrics"))]),
            Request::Replicate => Json::obj(vec![("op", Json::str("replicate"))]),
        }
    }
}

/// Hex alphabet for the replicate byte streams on the JSON wire.
const HEX: &[u8; 16] = b"0123456789abcdef";

/// Lowercase-hex encode a replicate byte stream (JSON is a text
/// protocol; the binary wire ships these bytes raw instead).
fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[usize::from(b >> 4)] as char);
        s.push(HEX[usize::from(b & 0xf)] as char);
    }
    s
}

/// Inverse of [`hex_encode`]; a stray digit or odd length is a
/// protocol error (the stream's own CRCs are checked later, at apply).
fn hex_decode(s: &str) -> crate::Result<Vec<u8>> {
    fn nib(c: u8) -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    }
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return Err(crate::Error::Protocol(
            "odd-length hex stream in replicate response".into(),
        ));
    }
    b.chunks_exact(2)
        .map(|p| match (nib(p[0]), nib(p[1])) {
            (Some(h), Some(l)) => Ok((h << 4) | l),
            _ => Err(crate::Error::Protocol(
                "bad hex digit in replicate response".into(),
            )),
        })
        .collect()
}

/// One scored neighbor on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireNeighbor {
    /// Item id.
    pub id: u64,
    /// Estimated Jaccard.
    pub score: f64,
}

/// Server → client responses.
// Stats inlines the full metrics snapshot; responses are serialized
// immediately, never stored in bulk, so the size skew is harmless.
#[allow(clippy::large_enum_variant)]
#[derive(Clone, Debug)]
pub enum Response {
    /// Failure.
    Err {
        /// Human-readable error.
        error: String,
    },
    /// Ping reply.
    Pong,
    /// Sketch result.
    Sketch {
        /// K hash values.
        sketch: Vec<u32>,
    },
    /// Batched sketch result, one sketch per request row.
    SketchBatch {
        /// K hash values per row, in request order.
        sketches: Vec<Vec<u32>>,
    },
    /// Insert result.
    Insert {
        /// Assigned id.
        id: u64,
        /// K hash values.
        sketch: Vec<u32>,
    },
    /// Batched insert result: ids only, in request order (bulk ingest
    /// discards sketches; use `sketch_batch` to obtain them).
    InsertBatch {
        /// Assigned (consecutive) ids.
        ids: Vec<u64>,
    },
    /// Delete result.
    Deleted {
        /// The removed id.
        id: u64,
    },
    /// Save (snapshot compaction) result.
    Saved {
        /// Bytes on disk after compaction.
        persisted_bytes: u64,
    },
    /// Estimate result.
    Estimate {
        /// Ĵ.
        jhat: f64,
    },
    /// Query result.
    Query {
        /// Scored neighbors, best first.
        neighbors: Vec<WireNeighbor>,
    },
    /// Batched query result, one neighbor list per request row.
    QueryBatch {
        /// Per-row scored neighbors, best first, in request order.
        results: Vec<Vec<WireNeighbor>>,
    },
    /// Stats result.
    Stats {
        /// The active sketch scheme (serialized as its canonical name,
        /// e.g. `"scheme":"cmh"`) — clients use it to check that their
        /// offline sketches are comparable with the server's.
        scheme: SketchScheme,
        /// Metrics snapshot.
        metrics: MetricsSnapshot,
        /// Store occupancy + durability.
        store: StoreStats,
        /// Per-op request counters (every op, zeros included).
        ops: Vec<(&'static str, u64)>,
    },
    /// Trace result: recent (or pinned) request traces, newest first.
    Trace {
        /// The traces, each with its per-stage span breakdown.
        traces: Vec<crate::obs::Trace>,
    },
    /// Prometheus text exposition.
    Metrics {
        /// The rendered exposition (text format 0.0.4).
        text: String,
    },
    /// Replicate result: the node's durable image for a joining peer.
    Replicate {
        /// Raw snapshot bytes (a complete `CMHSNAP*` image).
        snapshot: Vec<u8>,
        /// Raw WAL-tail bytes (a whole, well-formed record sequence).
        wal: Vec<u8>,
    },
}

/// Serialize one neighbor list (shared by `query` and `query_batch`).
fn neighbors_json(ns: &[WireNeighbor]) -> Json {
    Json::Arr(
        ns.iter()
            .map(|n| {
                Json::obj(vec![
                    ("id", Json::Num(n.id as f64)),
                    ("score", Json::Num(n.score)),
                ])
            })
            .collect(),
    )
}

/// Parse one neighbor list (shared by `query` and `query_batch`).
fn neighbors_from_json(j: &Json) -> crate::Result<Vec<WireNeighbor>> {
    j.as_arr()?
        .iter()
        .map(|n| {
            Ok(WireNeighbor {
                id: n.get("id")?.as_u64()?,
                score: n.get("score")?.as_f64()?,
            })
        })
        .collect()
}

impl Response {
    /// Build an error response.
    pub fn err(e: &crate::Error) -> Self {
        Response::Err {
            error: e.to_string(),
        }
    }

    /// Serialize one response line.
    pub fn to_json(&self) -> Json {
        match self {
            Response::Err { error } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(error)),
            ]),
            Response::Pong => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("pong", Json::Bool(true)),
            ]),
            Response::Sketch { sketch } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("sketch", Json::from_u32s(sketch)),
            ]),
            Response::SketchBatch { sketches } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "sketches",
                    Json::Arr(sketches.iter().map(|s| Json::from_u32s(s)).collect()),
                ),
            ]),
            Response::Insert { id, sketch } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("id", Json::Num(*id as f64)),
                ("sketch", Json::from_u32s(sketch)),
            ]),
            Response::InsertBatch { ids } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "ids",
                    Json::Arr(ids.iter().map(|&id| Json::Num(id as f64)).collect()),
                ),
            ]),
            Response::Deleted { id } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("deleted", Json::Num(*id as f64)),
            ]),
            Response::Saved { persisted_bytes } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("saved", Json::Bool(true)),
                ("persisted_bytes", Json::Num(*persisted_bytes as f64)),
            ]),
            Response::Estimate { jhat } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("jhat", Json::Num(*jhat)),
            ]),
            Response::Query { neighbors } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("neighbors", neighbors_json(neighbors)),
            ]),
            Response::QueryBatch { results } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "results",
                    Json::Arr(results.iter().map(|ns| neighbors_json(ns)).collect()),
                ),
            ]),
            Response::Stats {
                scheme,
                metrics,
                store,
                ops,
            } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("scheme", Json::str(scheme.as_str())),
                ("bits", Json::Num(f64::from(store.bits))),
                ("sketch_bytes", Json::Num(store.sketch_bytes as f64)),
                ("metrics", metrics.to_json()),
                (
                    "requests",
                    Json::obj(
                        ops.iter()
                            .map(|&(op, n)| (op, Json::Num(n as f64)))
                            .collect(),
                    ),
                ),
                ("stored", Json::Num(store.stored as f64)),
                (
                    "shards",
                    Json::Arr(
                        store
                            .shards
                            .iter()
                            .map(|&n| Json::Num(n as f64))
                            .collect(),
                    ),
                ),
                (
                    "shard_ops",
                    Json::Arr(
                        store
                            .shard_ops
                            .iter()
                            .map(|o| {
                                Json::obj(vec![
                                    ("inserts", Json::Num(o.inserts as f64)),
                                    ("deletes", Json::Num(o.deletes as f64)),
                                    ("queries", Json::Num(o.queries as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("band_buckets", Json::Num(store.band_buckets as f64)),
                ("band_max_bucket", Json::Num(store.band_max_bucket as f64)),
                ("candidates", Json::Num(store.candidates as f64)),
                ("persisted_bytes", Json::Num(store.persisted_bytes as f64)),
                (
                    "wal_appended_bytes",
                    Json::Num(store.wal_appended_bytes as f64),
                ),
                ("fsync_latency", store.fsync.to_json()),
            ]),
            Response::Trace { traces } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "traces",
                    Json::Arr(traces.iter().map(|t| t.to_json()).collect()),
                ),
            ]),
            Response::Metrics { text } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("text", Json::str(text)),
            ]),
            Response::Replicate { snapshot, wal } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("snapshot_hex", Json::Str(hex_encode(snapshot))),
                ("wal_hex", Json::Str(hex_encode(wal))),
            ]),
        }
    }

    /// Parse a response line (client side).
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        if !j.get("ok")?.as_bool()? {
            return Ok(Response::Err {
                error: j.get("error")?.as_str()?.to_string(),
            });
        }
        if j.get_opt("pong").is_some() {
            return Ok(Response::Pong);
        }
        if let Some(id) = j.get_opt("deleted") {
            return Ok(Response::Deleted { id: id.as_u64()? });
        }
        if j.get_opt("saved").is_some() {
            return Ok(Response::Saved {
                persisted_bytes: j.get("persisted_bytes")?.as_u64()?,
            });
        }
        if let Some(s) = j.get_opt("snapshot_hex") {
            return Ok(Response::Replicate {
                snapshot: hex_decode(s.as_str()?)?,
                wal: hex_decode(j.get("wal_hex")?.as_str()?)?,
            });
        }
        if let Some(ids) = j.get_opt("ids") {
            return Ok(Response::InsertBatch {
                ids: ids
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_u64())
                    .collect::<crate::Result<_>>()?,
            });
        }
        if let Some(s) = j.get_opt("sketches") {
            return Ok(Response::SketchBatch {
                sketches: s
                    .as_arr()?
                    .iter()
                    .map(|s| s.as_u32_vec())
                    .collect::<crate::Result<_>>()?,
            });
        }
        if let Some(rs) = j.get_opt("results") {
            return Ok(Response::QueryBatch {
                results: rs
                    .as_arr()?
                    .iter()
                    .map(neighbors_from_json)
                    .collect::<crate::Result<_>>()?,
            });
        }
        if let Some(id) = j.get_opt("id") {
            return Ok(Response::Insert {
                id: id.as_u64()?,
                sketch: j.get("sketch")?.as_u32_vec()?,
            });
        }
        if let Some(s) = j.get_opt("sketch") {
            return Ok(Response::Sketch {
                sketch: s.as_u32_vec()?,
            });
        }
        if let Some(v) = j.get_opt("jhat") {
            return Ok(Response::Estimate {
                jhat: v.as_f64()?,
            });
        }
        if let Some(ns) = j.get_opt("neighbors") {
            return Ok(Response::Query {
                neighbors: neighbors_from_json(ns)?,
            });
        }
        if let Some(ts) = j.get_opt("traces") {
            return Ok(Response::Trace {
                traces: ts
                    .as_arr()?
                    .iter()
                    .map(crate::obs::Trace::from_json)
                    .collect::<crate::Result<_>>()?,
            });
        }
        if let Some(t) = j.get_opt("text") {
            return Ok(Response::Metrics {
                text: t.as_str()?.to_string(),
            });
        }
        if j.get_opt("metrics").is_some() {
            // Clients mostly print stats verbatim; re-parsing the full
            // snapshot is not needed, so surface a protocol error if a
            // client tries to decode it structurally.
            return Err(crate::Error::Protocol(
                "stats responses are consumed as raw JSON".into(),
            ));
        }
        Err(crate::Error::Protocol("unrecognized response".into()))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let line = r#"{"op":"sketch","vec":{"dim":16,"indices":[1,5]}}"#;
        let req = Request::from_json(&Json::parse(line).unwrap()).unwrap();
        match &req {
            Request::Sketch { vec } => {
                assert_eq!(vec.dim(), 16);
                assert_eq!(vec.indices(), &[1, 5]);
            }
            _ => panic!("wrong op"),
        }
        let back = req.to_json().to_string();
        assert!(back.contains(r#""op":"sketch""#));
        // parse what we serialized
        Request::from_json(&Json::parse(&back).unwrap()).unwrap();
    }

    #[test]
    fn all_ops_parse() {
        for line in [
            r#"{"op":"ping"}"#,
            r#"{"op":"insert","vec":{"dim":4,"indices":[]}}"#,
            r#"{"op":"delete","id":7}"#,
            r#"{"op":"save"}"#,
            r#"{"op":"estimate","a":1,"b":2}"#,
            r#"{"op":"estimate_vecs","v":{"dim":4,"indices":[0]},"w":{"dim":4,"indices":[1]}}"#,
            r#"{"op":"query","vec":{"dim":4,"indices":[0]},"topk":3}"#,
            r#"{"op":"query_above","vec":{"dim":4,"indices":[0]},"threshold":0.5}"#,
            r#"{"op":"sketch_batch","vecs":[{"dim":4,"indices":[0]}]}"#,
            r#"{"op":"insert_batch","vecs":[{"dim":4,"indices":[0]},{"dim":4,"indices":[1]}]}"#,
            r#"{"op":"query_batch","vecs":[{"dim":4,"indices":[0]}],"topk":3}"#,
            r#"{"op":"stats"}"#,
            r#"{"op":"trace"}"#,
            r#"{"op":"trace","n":5,"pinned":true}"#,
            r#"{"op":"metrics"}"#,
            r#"{"op":"replicate"}"#,
        ] {
            Request::from_json(&Json::parse(line).unwrap())
                .unwrap_or_else(|e| panic!("{line}: {e}"));
        }
    }

    #[test]
    fn batch_ops_roundtrip() {
        let vecs = vec![
            SparseVec::new(16, vec![1, 5]).unwrap(),
            SparseVec::new(16, vec![2]).unwrap(),
        ];
        // requests
        for req in [
            Request::SketchBatch { vecs: vecs.clone() },
            Request::InsertBatch { vecs: vecs.clone() },
            Request::QueryBatch {
                vecs: vecs.clone(),
                topk: 4,
            },
        ] {
            let line = req.to_json().to_string();
            let back = Request::from_json(&Json::parse(&line).unwrap()).unwrap();
            match (&req, &back) {
                (Request::SketchBatch { vecs: a }, Request::SketchBatch { vecs: b })
                | (Request::InsertBatch { vecs: a }, Request::InsertBatch { vecs: b }) => {
                    assert_eq!(a, b)
                }
                (
                    Request::QueryBatch { vecs: a, topk: ta },
                    Request::QueryBatch { vecs: b, topk: tb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ta, tb);
                }
                other => panic!("{other:?}"),
            }
        }
        // responses
        let r = Response::SketchBatch {
            sketches: vec![vec![1, 2], vec![3, 4]],
        };
        match Response::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap() {
            Response::SketchBatch { sketches } => {
                assert_eq!(sketches, vec![vec![1, 2], vec![3, 4]])
            }
            other => panic!("{other:?}"),
        }
        let r = Response::InsertBatch { ids: vec![7, 8] };
        match Response::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap() {
            Response::InsertBatch { ids } => assert_eq!(ids, vec![7, 8]),
            other => panic!("{other:?}"),
        }
        let r = Response::QueryBatch {
            results: vec![vec![WireNeighbor { id: 3, score: 0.5 }], vec![]],
        };
        match Response::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap() {
            Response::QueryBatch { results } => {
                assert_eq!(results.len(), 2);
                assert_eq!(results[0], vec![WireNeighbor { id: 3, score: 0.5 }]);
                assert!(results[1].is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn empty_batch_is_a_protocol_error() {
        for op in ["sketch_batch", "insert_batch"] {
            let j = Json::parse(&format!(r#"{{"op":"{op}","vecs":[]}}"#)).unwrap();
            assert!(Request::from_json(&j).is_err(), "{op} with no vecs");
        }
        let j = Json::parse(r#"{"op":"query_batch","vecs":[],"topk":3}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
        // missing vecs key entirely
        let j = Json::parse(r#"{"op":"sketch_batch"}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
    }

    #[test]
    fn oversized_batch_is_a_protocol_error() {
        let row = SparseVec::new(8, vec![1]).unwrap().to_json();
        let at_cap = Json::obj(vec![
            ("op", Json::str("sketch_batch")),
            ("vecs", Json::Arr(vec![row.clone(); MAX_WIRE_BATCH])),
        ]);
        assert!(Request::from_json(&at_cap).is_ok(), "cap itself is allowed");
        let over = Json::obj(vec![
            ("op", Json::str("insert_batch")),
            ("vecs", Json::Arr(vec![row; MAX_WIRE_BATCH + 1])),
        ]);
        match Request::from_json(&over) {
            Err(crate::Error::Protocol(msg)) => {
                assert!(msg.contains("cap"), "{msg}")
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_op_rejected() {
        let j = Json::parse(r#"{"op":"drop_tables"}"#).unwrap();
        assert!(Request::from_json(&j).is_err());
    }

    #[test]
    fn delete_and_save_roundtrip() {
        let req = Request::Delete { id: 12 };
        let line = req.to_json().to_string();
        match Request::from_json(&Json::parse(&line).unwrap()).unwrap() {
            Request::Delete { id } => assert_eq!(id, 12),
            other => panic!("{other:?}"),
        }
        let r = Response::Deleted { id: 12 }.to_json().to_string();
        match Response::from_json(&Json::parse(&r).unwrap()).unwrap() {
            Response::Deleted { id } => assert_eq!(id, 12),
            other => panic!("{other:?}"),
        }
        let r = Response::Saved {
            persisted_bytes: 4096,
        }
        .to_json()
        .to_string();
        match Response::from_json(&Json::parse(&r).unwrap()).unwrap() {
            Response::Saved { persisted_bytes } => assert_eq!(persisted_bytes, 4096),
            other => panic!("{other:?}"),
        }
        // a delete op with no id is a protocol error
        assert!(Request::from_json(&Json::parse(r#"{"op":"delete"}"#).unwrap()).is_err());
    }

    #[test]
    fn stats_response_carries_scheme_width_and_shard_occupancy() {
        let r = Response::Stats {
            scheme: SketchScheme::Coph,
            metrics: crate::metrics::Metrics::default().snapshot(),
            store: crate::store::StoreStats {
                stored: 5,
                shards: vec![2, 3],
                persisted_bytes: 77,
                bits: 8,
                sketch_bytes: 16,
                wal_appended_bytes: 900,
                fsync: crate::metrics::LatencySnapshot::default(),
                shard_ops: vec![
                    crate::store::ShardOps {
                        inserts: 4,
                        deletes: 1,
                        queries: 6,
                    },
                    crate::store::ShardOps {
                        inserts: 3,
                        deletes: 0,
                        queries: 6,
                    },
                ],
                band_buckets: 12,
                band_max_bucket: 3,
                candidates: 42,
            },
            ops: vec![("ping", 1), ("query", 6)],
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("scheme").unwrap().as_str().unwrap(), "coph");
        assert_eq!(j.get("bits").unwrap().as_u64().unwrap(), 8);
        assert_eq!(j.get("sketch_bytes").unwrap().as_u64().unwrap(), 16);
        assert_eq!(j.get("stored").unwrap().as_u64().unwrap(), 5);
        assert_eq!(j.get("persisted_bytes").unwrap().as_u64().unwrap(), 77);
        assert_eq!(
            j.get("shards").unwrap().as_u32_vec().unwrap(),
            vec![2u32, 3]
        );
        // the observability extensions ride the same response
        assert_eq!(j.get("wal_appended_bytes").unwrap().as_u64().unwrap(), 900);
        assert_eq!(j.get("band_buckets").unwrap().as_u64().unwrap(), 12);
        assert_eq!(j.get("band_max_bucket").unwrap().as_u64().unwrap(), 3);
        assert_eq!(j.get("candidates").unwrap().as_u64().unwrap(), 42);
        let shard_ops = j.get("shard_ops").unwrap().as_arr().unwrap();
        assert_eq!(shard_ops.len(), 2);
        assert_eq!(shard_ops[0].get("inserts").unwrap().as_u64().unwrap(), 4);
        assert_eq!(shard_ops[1].get("queries").unwrap().as_u64().unwrap(), 6);
        let reqs = j.get("requests").unwrap();
        assert_eq!(reqs.get("ping").unwrap().as_u64().unwrap(), 1);
        assert_eq!(reqs.get("query").unwrap().as_u64().unwrap(), 6);
        assert_eq!(
            j.get("fsync_latency").unwrap().get("count").unwrap().as_u64().unwrap(),
            0
        );
    }

    #[test]
    fn trace_and_metrics_responses_roundtrip() {
        let t = crate::obs::Trace {
            seq: 9,
            op: crate::obs::OpKind::Query,
            items: 2,
            total_us: 1500,
            slow: false,
            stages_us: [10, 900, 0, 40, 300, 200, 50],
        };
        let r = Response::Trace {
            traces: vec![t.clone()],
        };
        match Response::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap() {
            Response::Trace { traces } => assert_eq!(traces, vec![t]),
            other => panic!("{other:?}"),
        }
        let r = Response::Metrics {
            text: "# TYPE cminhash_uptime_seconds gauge\n".into(),
        };
        match Response::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap() {
            Response::Metrics { text } => {
                assert!(text.contains("cminhash_uptime_seconds"))
            }
            other => panic!("{other:?}"),
        }
        // trace request defaults: n=16, pinned=false
        match Request::from_json(&Json::parse(r#"{"op":"trace"}"#).unwrap()).unwrap() {
            Request::Trace { n, pinned } => {
                assert_eq!(n, 16);
                assert!(!pinned);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn replicate_roundtrips_and_rejects_bad_hex() {
        // request
        let line = Request::Replicate.to_json().to_string();
        assert!(matches!(
            Request::from_json(&Json::parse(&line).unwrap()).unwrap(),
            Request::Replicate
        ));
        // response: arbitrary byte streams survive the hex round-trip
        let r = Response::Replicate {
            snapshot: vec![0x00, 0xff, 0x41, 0x9a],
            wal: vec![],
        };
        match Response::from_json(&Json::parse(&r.to_json().to_string()).unwrap())
            .unwrap()
        {
            Response::Replicate { snapshot, wal } => {
                assert_eq!(snapshot, vec![0x00, 0xff, 0x41, 0x9a]);
                assert!(wal.is_empty());
            }
            other => panic!("{other:?}"),
        }
        // odd length and stray digits are protocol errors
        for bad in [
            r#"{"ok":true,"snapshot_hex":"abc","wal_hex":""}"#,
            r#"{"ok":true,"snapshot_hex":"zz","wal_hex":""}"#,
            r#"{"ok":true,"snapshot_hex":"","wal_hex":"0g"}"#,
        ] {
            assert!(
                Response::from_json(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
        // a replicate response must carry both streams
        let half = r#"{"ok":true,"snapshot_hex":""}"#;
        assert!(Response::from_json(&Json::parse(half).unwrap()).is_err());
    }

    #[test]
    fn response_roundtrips() {
        let r = Response::Estimate { jhat: 0.5 };
        let s = r.to_json().to_string();
        assert!(s.contains(r#""ok":true"#));
        match Response::from_json(&Json::parse(&s).unwrap()).unwrap() {
            Response::Estimate { jhat } => assert_eq!(jhat, 0.5),
            other => panic!("{other:?}"),
        }
        let e = Response::err(&crate::Error::Shutdown).to_json().to_string();
        assert!(e.contains(r#""ok":false"#));
        match Response::from_json(&Json::parse(&e).unwrap()).unwrap() {
            Response::Err { error } => assert!(error.contains("shut down")),
            other => panic!("{other:?}"),
        }
        let q = Response::Query {
            neighbors: vec![WireNeighbor { id: 3, score: 0.75 }],
        };
        let s = q.to_json().to_string();
        match Response::from_json(&Json::parse(&s).unwrap()).unwrap() {
            Response::Query { neighbors } => {
                assert_eq!(neighbors, vec![WireNeighbor { id: 3, score: 0.75 }])
            }
            other => panic!("{other:?}"),
        }
    }
}
