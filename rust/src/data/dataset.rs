//! `BinaryDataset`: a named collection of sparse binary rows with
//! save/load (JSON) and summary statistics.

use crate::sketch::SparseVec;
use crate::util::json::Json;
use std::path::Path;

/// A binary dataset: n rows of dimension D.
#[derive(Clone, Debug)]
pub struct BinaryDataset {
    name: String,
    dim: u32,
    rows: Vec<SparseVec>,
}

/// Summary statistics used by `cminhash dataset --stats` and DESIGN.md.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DatasetStats {
    /// Number of rows.
    pub n: usize,
    /// Dimensionality.
    pub dim: u32,
    /// Mean nonzeros per row.
    pub mean_nnz: f64,
    /// Min nonzeros.
    pub min_nnz: usize,
    /// Max nonzeros.
    pub max_nnz: usize,
    /// Mean pairwise Jaccard over a bounded sample of pairs.
    pub mean_jaccard: f64,
}

impl BinaryDataset {
    /// Assemble a dataset (all rows must share `dim`).
    pub fn new(name: &str, dim: u32, rows: Vec<SparseVec>) -> Self {
        for r in &rows {
            assert_eq!(r.dim(), dim, "row dim mismatch in dataset {name}");
        }
        BinaryDataset {
            name: name.to_string(),
            dim,
            rows,
        }
    }

    /// Dataset name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Dimensionality D.
    pub fn dim(&self) -> u32 {
        self.dim
    }

    /// Rows view.
    pub fn rows(&self) -> &[SparseVec] {
        &self.rows
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True iff there are no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// JSON form: `{"name": ..., "dim": D, "rows": [[idx...], ...]}`
    /// (rows store indices only; `dim` is shared).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("dim", Json::Num(f64::from(self.dim))),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::from_u32s(r.indices()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse the JSON form (validates every row).
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let name = j.get("name")?.as_str()?.to_string();
        let dim = j.get("dim")?.as_u32()?;
        let rows = j
            .get("rows")?
            .as_arr()?
            .iter()
            .map(|r| SparseVec::new(dim, r.as_u32_vec()?))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(BinaryDataset { name, dim, rows })
    }

    /// Save as JSON.
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load from JSON.
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Summary statistics (pairwise Jaccard sampled on ≤ `max_pairs`).
    pub fn stats(&self, max_pairs: usize) -> DatasetStats {
        let n = self.rows.len();
        let nnzs: Vec<usize> = self.rows.iter().map(|r| r.nnz()).collect();
        let mean_nnz = nnzs.iter().sum::<usize>() as f64 / n.max(1) as f64;
        let mut mean_j = 0.0;
        let mut pairs = 0usize;
        'outer: for i in 0..n {
            for jx in (i + 1)..n {
                mean_j += self.rows[i].jaccard(&self.rows[jx]);
                pairs += 1;
                if pairs >= max_pairs {
                    break 'outer;
                }
            }
        }
        DatasetStats {
            n,
            dim: self.dim,
            mean_nnz,
            min_nnz: nnzs.iter().copied().min().unwrap_or(0),
            max_nnz: nnzs.iter().copied().max().unwrap_or(0),
            mean_jaccard: if pairs == 0 { 0.0 } else { mean_j / pairs as f64 },
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;

    fn tiny() -> BinaryDataset {
        BinaryDataset::new(
            "tiny",
            8,
            vec![
                SparseVec::new(8, vec![0, 1]).unwrap(),
                SparseVec::new(8, vec![1, 2]).unwrap(),
                SparseVec::new(8, vec![5]).unwrap(),
            ],
        )
    }

    #[test]
    fn stats_are_sane() {
        let s = tiny().stats(100);
        assert_eq!(s.n, 3);
        assert_eq!(s.dim, 8);
        assert!((s.mean_nnz - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min_nnz, 1);
        assert_eq!(s.max_nnz, 2);
        assert!(s.mean_jaccard > 0.0 && s.mean_jaccard < 1.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = TempDir::new().unwrap();
        let p = dir.path().join("ds.json");
        let ds = tiny();
        ds.save(&p).unwrap();
        let back = BinaryDataset::load(&p).unwrap();
        assert_eq!(back.name(), "tiny");
        assert_eq!(back.rows(), ds.rows());
        assert_eq!(back.dim(), 8);
    }

    #[test]
    fn from_json_validates_rows() {
        let bad = Json::parse(r#"{"name":"x","dim":4,"rows":[[9]]}"#).unwrap();
        assert!(BinaryDataset::from_json(&bad).is_err());
    }

    #[test]
    #[should_panic(expected = "row dim mismatch")]
    fn mismatched_rows_panic() {
        BinaryDataset::new("bad", 8, vec![SparseVec::new(9, vec![0]).unwrap()]);
    }
}
