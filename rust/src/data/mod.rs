//! Datasets and workloads: everything the evaluation section needs.
//!
//! The paper's §4.2 uses NIPS/BBC (text) and MNIST/CIFAR (images); none
//! are available in this offline image, so the corpus generators
//! ([`zipf_corpus`], [`image_corpus`]) produce synthetic stand-ins
//! that preserve the property the experiment
//! measures (see DESIGN.md "Substitutions"): text-like corpora have
//! Zipf-distributed token sets with mild locational structure, while
//! image-like corpora have strongly *contiguous* nonzero patterns —
//! exactly what makes C-MinHash-(0, π) degrade in Figure 7.

mod corpora;
mod dataset;
mod structured;
mod workload;

pub use corpora::{image_corpus, near_duplicate_corpus, zipf_corpus, CorpusKind};
pub use dataset::{BinaryDataset, DatasetStats};
pub use structured::{structured_pair, PairPattern};
pub use workload::{TraceItem, Workload, WorkloadSpec};
