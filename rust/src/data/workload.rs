//! Serving workload traces for the e2e benchmarks: Poisson arrivals of
//! sketch/query requests over a corpus, mirroring how a dedup or ANN
//! service would be driven in production.

use super::dataset::BinaryDataset;
use crate::sketch::SparseVec;
use crate::util::rng::Rng;

/// Parameters of a synthetic request trace.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    /// Total number of requests.
    pub n_requests: usize,
    /// Mean arrival rate (requests/second) for the Poisson process.
    pub rate_per_sec: f64,
    /// Fraction of requests that are similarity queries (the rest are
    /// sketch-and-insert).
    pub query_fraction: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            n_requests: 1000,
            rate_per_sec: 2000.0,
            query_fraction: 0.2,
            seed: 0,
        }
    }
}

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct TraceItem {
    /// Arrival offset from trace start, in microseconds.
    pub at_us: u64,
    /// The vector to sketch / query with.
    pub vec: SparseVec,
    /// True for a similarity query, false for sketch-and-insert.
    pub is_query: bool,
}

/// A generated trace.
#[derive(Clone, Debug)]
pub struct Workload {
    items: Vec<TraceItem>,
}

impl Workload {
    /// Draw a trace over the rows of `corpus` (cycled, with queries
    /// drawn uniformly among previously inserted rows).
    pub fn generate(corpus: &BinaryDataset, spec: WorkloadSpec) -> Self {
        assert!(!corpus.is_empty(), "empty corpus");
        assert!(spec.rate_per_sec > 0.0);
        let mut rng = Rng::seed_from_u64(spec.seed);
        let mut t_us = 0f64;
        let mean_gap_us = 1e6 / spec.rate_per_sec;
        let mut items = Vec::with_capacity(spec.n_requests);
        for i in 0..spec.n_requests {
            // Exponential inter-arrival.
            let u: f64 = rng.next_f64().max(1e-12);
            t_us += -u.ln() * mean_gap_us;
            let is_query = rng.bool_with(spec.query_fraction.clamp(0.0, 1.0));
            let row = corpus.rows()[i % corpus.len()].clone();
            items.push(TraceItem {
                at_us: t_us as u64,
                vec: row,
                is_query,
            });
        }
        Workload { items }
    }

    /// Trace items, ordered by arrival time.
    pub fn items(&self) -> &[TraceItem] {
        &self.items
    }

    /// Total trace duration in microseconds.
    pub fn duration_us(&self) -> u64 {
        self.items.last().map(|i| i.at_us).unwrap_or(0)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::data::zipf_corpus;

    #[test]
    fn trace_is_ordered_and_rate_is_close() {
        let corpus = zipf_corpus("t", 16, 256, 10, 20, 1.1, 0);
        let spec = WorkloadSpec {
            n_requests: 2000,
            rate_per_sec: 1000.0,
            query_fraction: 0.25,
            seed: 1,
        };
        let w = Workload::generate(&corpus, spec);
        assert_eq!(w.items().len(), 2000);
        assert!(w.items().windows(2).all(|p| p[0].at_us <= p[1].at_us));
        // Expected duration ~ 2 seconds; allow generous slack.
        let dur_s = w.duration_us() as f64 / 1e6;
        assert!(dur_s > 1.0 && dur_s < 4.0, "duration {dur_s}s");
        let queries = w.items().iter().filter(|i| i.is_query).count();
        assert!(queries > 300 && queries < 700, "queries {queries}");
    }

    #[test]
    fn deterministic_given_seed() {
        let corpus = zipf_corpus("t", 4, 128, 5, 10, 1.1, 0);
        let spec = WorkloadSpec::default();
        let a = Workload::generate(&corpus, spec);
        let b = Workload::generate(&corpus, spec);
        assert_eq!(a.items().len(), b.items().len());
        assert!(a
            .items()
            .iter()
            .zip(b.items())
            .all(|(x, y)| x.at_us == y.at_us && x.is_query == y.is_query));
    }
}
