//! Synthetic corpora standing in for the paper's §4.2 datasets.
//!
//! | paper dataset | stand-in | preserved property |
//! |---|---|---|
//! | NIPS full papers | [`zipf_corpus`] (D=8k vocab) | Zipf token marginals, mild structure |
//! | BBC News | [`zipf_corpus`] (D=4k vocab, shorter docs) | same, sparser |
//! | MNIST | [`image_corpus`] (28×28 strokes) | strong contiguous pixel structure |
//! | CIFAR | [`image_corpus`] (32×32 blobs) | same, denser |
//!
//! The Figure 7 claim is qualitative: (σ,π) ≤ MH everywhere, and (0,π)
//! degrades most on *structured* (image-like) data.  Both generators are
//! deterministic given a seed.

use super::dataset::BinaryDataset;
use crate::sketch::SparseVec;
use crate::util::rng::Rng;

/// Which §4.2 stand-in to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    /// NIPS-like: large vocab, long documents.
    TextNips,
    /// BBC-like: smaller vocab, shorter documents.
    TextBbc,
    /// MNIST-like: 28×28 binary strokes.
    ImageMnist,
    /// CIFAR-like: 32×32 binary blobs.
    ImageCifar,
}

impl CorpusKind {
    /// Default corpus for this kind (sizes chosen so the all-pairs MAE
    /// protocol stays fast while the (f, a) spread matches the regime).
    pub fn generate(self, n_docs: usize, seed: u64) -> BinaryDataset {
        match self {
            CorpusKind::TextNips => zipf_corpus("nips-like", n_docs, 8192, 150, 400, 1.1, seed),
            CorpusKind::TextBbc => zipf_corpus("bbc-like", n_docs, 4096, 60, 180, 1.2, seed),
            CorpusKind::ImageMnist => image_corpus("mnist-like", n_docs, 28, 3, 6, seed),
            CorpusKind::ImageCifar => image_corpus("cifar-like", n_docs, 32, 6, 10, seed),
        }
    }

    /// All four kinds in the paper's Figure 7 order.
    pub fn all() -> [CorpusKind; 4] {
        [
            CorpusKind::TextNips,
            CorpusKind::TextBbc,
            CorpusKind::ImageMnist,
            CorpusKind::ImageCifar,
        ]
    }

    /// Display name used in figures/CSV.
    pub fn name(self) -> &'static str {
        match self {
            CorpusKind::TextNips => "nips-like",
            CorpusKind::TextBbc => "bbc-like",
            CorpusKind::ImageMnist => "mnist-like",
            CorpusKind::ImageCifar => "cifar-like",
        }
    }
}

/// Text-like corpus: each document draws `len ~ U[min_len, max_len]`
/// tokens from a Zipf(s) distribution over a `vocab`-sized vocabulary
/// (binary bag-of-words).  Shared head tokens create realistic overlap.
// Generated token ids are drawn modulo `vocab`, so `SparseVec::new`
// cannot reject them.
#[allow(clippy::disallowed_methods)]
pub fn zipf_corpus(
    name: &str,
    n_docs: usize,
    vocab: u32,
    min_len: usize,
    max_len: usize,
    s: f64,
    seed: u64,
) -> BinaryDataset {
    assert!(min_len <= max_len && max_len as u64 <= vocab as u64);
    let mut rng = Rng::seed_from_u64(seed);
    // Inverse-CDF table for the Zipf marginal.
    let weights: Vec<f64> = (1..=vocab as usize).map(|r| 1.0 / (r as f64).powf(s)).collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(vocab as usize);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    let mut rows = Vec::with_capacity(n_docs);
    for _ in 0..n_docs {
        let len = rng.range_usize(min_len, max_len + 1);
        let mut tokens = Vec::with_capacity(len * 2);
        while tokens.len() < len {
            let u: f64 = rng.next_f64();
            let tok = cdf.partition_point(|&c| c < u) as u32;
            tokens.push(tok.min(vocab - 1));
            tokens.sort_unstable();
            tokens.dedup();
        }
        rows.push(SparseVec::new(vocab, tokens).expect("tokens in range"));
    }
    BinaryDataset::new(name, vocab, rows)
}

/// Image-like corpus: `side × side` binary images made of a few
/// axis-aligned strokes/blobs — heavily *contiguous* nonzero structure
/// in the flattened vector, the regime where C-MinHash-(0, π) suffers.
// Stroke pixels are clamped to the `side × side` grid before flattening.
#[allow(clippy::disallowed_methods)]
pub fn image_corpus(
    name: &str,
    n_images: usize,
    side: u32,
    min_strokes: usize,
    max_strokes: usize,
    seed: u64,
) -> BinaryDataset {
    let d = side * side;
    let mut rng = Rng::seed_from_u64(seed);
    let mut rows = Vec::with_capacity(n_images);
    for _ in 0..n_images {
        let mut pix = Vec::new();
        let strokes = rng.range_usize(min_strokes, max_strokes + 1);
        for _ in 0..strokes {
            // a rectangle blob
            let w = rng.range_u32(2, side.max(3) / 2 + 1);
            let h = rng.range_u32(2, side.max(3) / 2 + 1);
            let x0 = rng.range_u32(0, side - w + 1);
            let y0 = rng.range_u32(0, side - h + 1);
            for y in y0..y0 + h {
                for x in x0..x0 + w {
                    pix.push(y * side + x);
                }
            }
        }
        rows.push(SparseVec::new(d, pix).expect("pixels in range"));
    }
    BinaryDataset::new(name, d, rows)
}

/// Corpus of near-duplicate families: `families` seed documents, each
/// with `copies` mutated near-duplicates (used by the ANN example and
/// index recall tests, mirroring MinHash's dedup application).
// Mutations substitute ids below `dim`, so every index stays in range.
#[allow(clippy::disallowed_methods)]
pub fn near_duplicate_corpus(
    n_families: usize,
    copies: usize,
    dim: u32,
    doc_len: usize,
    mutate: usize,
    seed: u64,
) -> BinaryDataset {
    let mut rng = Rng::seed_from_u64(seed);
    let mut rows = Vec::with_capacity(n_families * copies);
    for _ in 0..n_families {
        let mut base = Vec::with_capacity(doc_len);
        while base.len() < doc_len {
            base.push(rng.range_u32(0, dim));
            base.sort_unstable();
            base.dedup();
        }
        for _ in 0..copies {
            let mut doc = base.clone();
            for _ in 0..mutate {
                let pos = rng.range_usize(0, doc.len());
                doc[pos] = rng.range_u32(0, dim);
            }
            rows.push(SparseVec::new(dim, doc).expect("in range"));
        }
    }
    BinaryDataset::new("near-dup", dim, rows)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn zipf_corpus_shapes_and_determinism() {
        let c1 = zipf_corpus("t", 20, 512, 20, 60, 1.1, 5);
        let c2 = zipf_corpus("t", 20, 512, 20, 60, 1.1, 5);
        assert_eq!(c1.rows().len(), 20);
        assert_eq!(c1.dim(), 512);
        for (a, b) in c1.rows().iter().zip(c2.rows()) {
            assert_eq!(a, b);
        }
        for r in c1.rows() {
            assert!(r.nnz() >= 20 && r.nnz() <= 60);
        }
    }

    #[test]
    fn zipf_head_tokens_are_common() {
        let c = zipf_corpus("t", 50, 1024, 40, 80, 1.3, 1);
        let head_hits = c.rows().iter().filter(|r| r.indices().contains(&0)).count();
        let tail_hits = c
            .rows()
            .iter()
            .filter(|r| r.indices().contains(&1000))
            .count();
        assert!(head_hits > tail_hits, "head {head_hits} vs tail {tail_hits}");
    }

    #[test]
    fn image_corpus_is_contiguous_ish() {
        let c = image_corpus("i", 30, 28, 3, 6, 2);
        assert_eq!(c.dim(), 784);
        // Contiguity proxy: mean gap between consecutive nonzeros is far
        // below the unstructured expectation D/f.
        let mut mean_gap = 0.0;
        let mut n = 0usize;
        for r in c.rows() {
            let idx = r.indices();
            for w in idx.windows(2) {
                mean_gap += (w[1] - w[0]) as f64;
                n += 1;
            }
        }
        mean_gap /= n as f64;
        assert!(mean_gap < 8.0, "images not contiguous: mean gap {mean_gap}");
    }

    #[test]
    fn near_duplicates_are_similar_within_family() {
        let c = near_duplicate_corpus(3, 4, 4096, 100, 5, 7);
        assert_eq!(c.rows().len(), 12);
        let fam0 = &c.rows()[0..4];
        let cross = c.rows()[0].jaccard(&c.rows()[8]);
        let within = fam0[0].jaccard(&fam0[1]);
        assert!(within > 0.7, "within-family J = {within}");
        assert!(cross < 0.2, "cross-family J = {cross}");
    }
}
