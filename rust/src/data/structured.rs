//! §4.1's structured data pairs: concrete (v, w) vectors whose location
//! vector follows a prescribed pattern, for the Figure 6 simulation.

use crate::sketch::SparseVec;
use crate::theory::LocationVector;
use crate::util::rng::Rng;

/// Locational structure of a (D, f, a) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairPattern {
    /// The paper's §4.1 pattern: a “O”s, then f−a “×”s, then D−f “−”s.
    Contiguous,
    /// Occupied slots spread evenly over the circle.
    Interleaved,
    /// Uniformly random placement (what σ produces on average).
    Random(u64),
}

/// Build a (v, w) pair with the requested location structure.
pub fn structured_pair(d: usize, f: usize, a: usize, pattern: PairPattern) -> (SparseVec, SparseVec) {
    let x = match pattern {
        PairPattern::Contiguous => LocationVector::contiguous(d, f, a),
        PairPattern::Interleaved => LocationVector::interleaved(d, f, a),
        PairPattern::Random(seed) => {
            let mut syms = LocationVector::contiguous(d, f, a).symbols().to_vec();
            let mut rng = Rng::seed_from_u64(seed);
            rng.shuffle(&mut syms);
            LocationVector::from_symbols(syms)
        }
    };
    x.realize()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn pair_has_requested_overlap() {
        for pat in [
            PairPattern::Contiguous,
            PairPattern::Interleaved,
            PairPattern::Random(3),
        ] {
            let (v, w) = structured_pair(128, 40, 15, pat);
            assert_eq!(v.overlap(&w), (15, 40), "{pat:?}");
            assert_eq!(v.dim(), 128);
        }
    }

    #[test]
    fn contiguous_pattern_is_front_loaded() {
        let (v, w) = structured_pair(100, 20, 10, PairPattern::Contiguous);
        assert!(v.indices().iter().all(|&i| i < 20));
        assert!(w.indices().iter().all(|&i| i < 20));
    }

    #[test]
    fn random_pattern_is_seeded() {
        let p1 = structured_pair(64, 20, 5, PairPattern::Random(9));
        let p2 = structured_pair(64, 20, 5, PairPattern::Random(9));
        let p3 = structured_pair(64, 20, 5, PairPattern::Random(10));
        assert_eq!(p1, p2);
        assert_ne!(p1, p3);
    }
}
