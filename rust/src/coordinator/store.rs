//! Sketch store: id-keyed append-only storage of computed sketches.

use std::collections::HashMap;

/// Append-only sketch storage with monotonically increasing ids.
#[derive(Debug, Default)]
pub struct SketchStore {
    next_id: u64,
    sketches: HashMap<u64, Vec<u32>>,
}

impl SketchStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a sketch, returning its fresh id.
    pub fn insert(&mut self, sketch: Vec<u32>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.sketches.insert(id, sketch);
        id
    }

    /// Fetch a sketch by id.
    pub fn get(&self, id: u64) -> Option<&[u32]> {
        self.sketches.get(&id).map(|s| s.as_slice())
    }

    /// Number of stored sketches.
    pub fn len(&self) -> usize {
        self.sketches.len()
    }

    /// True iff empty.
    pub fn is_empty(&self) -> bool {
        self.sketches.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_monotone() {
        let mut s = SketchStore::new();
        let a = s.insert(vec![1]);
        let b = s.insert(vec![2]);
        assert!(b > a);
        assert_eq!(s.get(a), Some([1u32].as_slice()));
        assert_eq!(s.get(b), Some([2u32].as_slice()));
        assert_eq!(s.get(999), None);
        assert_eq!(s.len(), 2);
    }
}
