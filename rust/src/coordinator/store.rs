//! Sketch store: a standalone, single-shard id-keyed sketch map with
//! monotonically increasing fresh ids, deletion, and explicit-id
//! re-insert — the same storage contract the sharded store
//! (`crate::store`) implements, which keeps its sketches inside each
//! shard's `BandingIndex` rather than composing this type.  Useful on
//! its own for embedding a flat sketch table without LSH postings.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Id-keyed sketch storage with monotonically increasing fresh ids.
#[derive(Debug, Default)]
pub struct SketchStore {
    next_id: u64,
    sketches: HashMap<u64, Vec<u32>>,
}

impl SketchStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a sketch, returning its fresh id.
    pub fn insert(&mut self, sketch: Vec<u32>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.sketches.insert(id, sketch);
        id
    }

    /// Insert under a caller-chosen id (recovery / re-insert after
    /// delete).  Keeps the fresh-id counter ahead of every explicit
    /// id.  Returns `false` (and leaves the store unchanged) if the id
    /// is already occupied.
    pub fn insert_with_id(&mut self, id: u64, sketch: Vec<u32>) -> bool {
        match self.sketches.entry(id) {
            Entry::Occupied(_) => false,
            Entry::Vacant(slot) => {
                slot.insert(sketch);
                self.next_id = self.next_id.max(id.saturating_add(1));
                true
            }
        }
    }

    /// Remove a sketch, returning it if present.  Ids handed out by
    /// [`SketchStore::insert`] are never reused after removal.
    pub fn remove(&mut self, id: u64) -> Option<Vec<u32>> {
        self.sketches.remove(&id)
    }

    /// Fetch a sketch by id.
    pub fn get(&self, id: u64) -> Option<&[u32]> {
        self.sketches.get(&id).map(|s| s.as_slice())
    }

    /// Number of stored sketches.
    pub fn len(&self) -> usize {
        self.sketches.len()
    }

    /// True iff empty.
    pub fn is_empty(&self) -> bool {
        self.sketches.is_empty()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_monotone() {
        let mut s = SketchStore::new();
        let a = s.insert(vec![1]);
        let b = s.insert(vec![2]);
        assert!(b > a);
        assert_eq!(s.get(a), Some([1u32].as_slice()));
        assert_eq!(s.get(b), Some([2u32].as_slice()));
        assert_eq!(s.get(999), None);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn remove_and_reinsert() {
        let mut s = SketchStore::new();
        let a = s.insert(vec![1]);
        assert_eq!(s.remove(a), Some(vec![1]));
        assert_eq!(s.remove(a), None, "double remove is a no-op");
        assert!(s.is_empty());
        // fresh ids are never reused after a delete
        let b = s.insert(vec![2]);
        assert!(b > a);
        // explicit-id re-insert works; occupied ids are rejected
        assert!(s.insert_with_id(a, vec![3]));
        assert!(!s.insert_with_id(b, vec![9]));
        assert_eq!(s.get(a), Some([3u32].as_slice()));
        assert_eq!(s.get(b), Some([2u32].as_slice()));
    }

    #[test]
    fn insert_with_id_advances_fresh_ids() {
        let mut s = SketchStore::new();
        assert!(s.insert_with_id(100, vec![7]));
        let fresh = s.insert(vec![8]);
        assert!(fresh > 100, "fresh id {fresh} must skip past explicit ids");
    }
}
