//! The dynamic batching state machine — pure, deterministic, and unit
//! tested independently of tokio.
//!
//! Semantics (vLLM-router style):
//! * requests accumulate in arrival order;
//! * the batch flushes as soon as `max_batch` items are queued
//!   ([`FlushReason::Full`]);
//! * otherwise a deadline of `max_delay` from the *oldest* queued item
//!   forces a partial flush ([`FlushReason::Deadline`]) — bounding the
//!   queueing latency any request can pay;
//! * `drain` flushes whatever is left (shutdown path).

use std::time::{Duration, Instant};

/// Why a batch was emitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// `max_batch` items were queued.
    Full,
    /// The oldest item hit the latency deadline.
    Deadline,
    /// Explicit drain (shutdown).
    Drain,
}

/// Generic dynamic batcher over items of type `T`.
#[derive(Debug)]
pub struct Batcher<T> {
    max_batch: usize,
    max_delay: Duration,
    items: Vec<T>,
    oldest_at: Option<Instant>,
}

impl<T> Batcher<T> {
    /// Create with a size bound and a latency bound.
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        assert!(max_batch > 0);
        Batcher {
            max_batch,
            max_delay,
            items: Vec::with_capacity(max_batch),
            oldest_at: None,
        }
    }

    /// Number of queued items.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True iff nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Push an item at time `now`; returns a full batch if the size
    /// bound was reached.
    pub fn push(&mut self, item: T, now: Instant) -> Option<(Vec<T>, FlushReason)> {
        if self.items.is_empty() {
            self.oldest_at = Some(now);
        }
        self.items.push(item);
        if self.items.len() >= self.max_batch {
            Some((self.take(), FlushReason::Full))
        } else {
            None
        }
    }

    /// The instant at which the current partial batch must flush, if
    /// any.
    pub fn deadline(&self) -> Option<Instant> {
        self.oldest_at.map(|t| t + self.max_delay)
    }

    /// Flush if `now` has passed the deadline.
    pub fn poll_deadline(&mut self, now: Instant) -> Option<(Vec<T>, FlushReason)> {
        match self.deadline() {
            Some(d) if now >= d && !self.items.is_empty() => {
                Some((self.take(), FlushReason::Deadline))
            }
            _ => None,
        }
    }

    /// Unconditionally flush (shutdown).
    pub fn drain(&mut self) -> Option<(Vec<T>, FlushReason)> {
        if self.items.is_empty() {
            None
        } else {
            Some((self.take(), FlushReason::Drain))
        }
    }

    fn take(&mut self) -> Vec<T> {
        self.oldest_at = None;
        std::mem::replace(&mut self.items, Vec::with_capacity(self.max_batch))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(3, Duration::from_millis(10));
        let now = t0();
        assert!(b.push(1, now).is_none());
        assert!(b.push(2, now).is_none());
        let (batch, why) = b.push(3, now).unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
        assert_eq!(why, FlushReason::Full);
        assert!(b.is_empty());
        assert!(b.deadline().is_none());
    }

    #[test]
    fn deadline_from_oldest_item() {
        let mut b = Batcher::new(10, Duration::from_millis(5));
        let now = t0();
        b.push(1, now);
        b.push(2, now + Duration::from_millis(3));
        let d = b.deadline().unwrap();
        assert_eq!(d, now + Duration::from_millis(5), "anchored to oldest");
        assert!(b.poll_deadline(now + Duration::from_millis(4)).is_none());
        let (batch, why) = b.poll_deadline(now + Duration::from_millis(5)).unwrap();
        assert_eq!(batch, vec![1, 2]);
        assert_eq!(why, FlushReason::Deadline);
    }

    #[test]
    fn deadline_resets_after_flush() {
        let mut b = Batcher::new(2, Duration::from_millis(5));
        let now = t0();
        b.push(1, now);
        b.push(2, now); // flushed full
        assert!(b.deadline().is_none());
        b.push(3, now + Duration::from_millis(100));
        assert_eq!(
            b.deadline().unwrap(),
            now + Duration::from_millis(105),
            "new epoch anchored to new oldest"
        );
    }

    #[test]
    fn drain_returns_leftovers_once() {
        let mut b = Batcher::new(10, Duration::from_millis(5));
        assert!(b.drain().is_none());
        b.push('a', t0());
        let (batch, why) = b.drain().unwrap();
        assert_eq!(batch, vec!['a']);
        assert_eq!(why, FlushReason::Drain);
        assert!(b.drain().is_none());
    }

    #[test]
    fn preserves_arrival_order() {
        let mut b = Batcher::new(100, Duration::from_millis(1));
        let now = t0();
        for i in 0..50 {
            b.push(i, now);
        }
        let (batch, _) = b.poll_deadline(now + Duration::from_millis(2)).unwrap();
        assert_eq!(batch, (0..50).collect::<Vec<_>>());
    }
}
