//! L3 coordinator — the serving-system contribution.
//!
//! ```text
//! clients ─▶ Coordinator::sketch/insert/estimate/query
//!                 │ (sketch requests)
//!                 ▼
//!           dynamic batcher (max_batch | max_delay)
//!                 │ padded fixed-shape batches
//!                 ▼
//!           EngineBackend: XLA artifacts (PJRT thread)  — or —
//!                          pure-Rust hashers (fallback)
//!                 │
//!                 ▼
//!           sketch store ─▶ LSH banding index
//! ```
//!
//! The batcher state machine ([`Batcher`]) is pure and unit tested;
//! [`Coordinator`] wires it to the thread-per-connection server.

mod batcher;
mod service;
mod store;

pub use batcher::{Batcher, FlushReason};
pub use service::{Coordinator, EngineBackend};
pub use store::SketchStore;
