//! L3 coordinator — the serving-system contribution.
//!
//! ```text
//! clients ─▶ Coordinator::sketch/insert/delete/estimate/query/save
//!            (+ sketch_many/insert_many/query_many batch units)
//!                 │ (sketch requests)
//!                 ▼
//!           dynamic batcher (max_batch | max_delay)
//!                 │ padded fixed-shape batches
//!                 ▼
//!           EngineBackend: XLA artifacts (PJRT thread)  — or —
//!                          pure-Rust hashers (fallback)
//!                 │
//!                 ▼
//!           sharded sketch store (crate::store): WAL + snapshot
//!           durability, per-shard banding indexes, parallel query
//!           fan-out, one lock acquisition per shard per batch
//! ```
//!
//! The batcher state machine ([`Batcher`]) is pure and unit tested;
//! [`Coordinator`] wires it to the server's bounded connection pool.
//! [`SketchStore`] is a standalone single-shard storage primitive
//! with the same delete/re-insert contract; the sharded store itself
//! keeps sketches inside each shard's
//! [`BandingIndex`](crate::index::BandingIndex).

mod batcher;
mod service;
mod store;

pub use batcher::{Batcher, FlushReason};
pub use service::{Coordinator, EngineBackend};
pub use store::SketchStore;
