//! The coordinator service: dynamic batching in front of an engine,
//! plus sketch store, LSH index and metrics.
//!
//! Threading model (the offline build has no async runtime, and none is
//! needed): the server runs thread-per-connection; every connection
//! thread calls the blocking [`Coordinator`] API; sketch requests cross
//! one channel into the **batch pump thread**, which groups them up to
//! the artifact batch size or the latency deadline and executes on the
//! backend; responses travel back over per-request rendezvous channels.

use crate::config::{EngineKind, ServeConfig};
use crate::coordinator::batcher::Batcher;
use crate::index::{IndexConfig, Neighbor};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::runtime::{EngineHandle, HostTensor};
use crate::sketch::{CMinHasher, Perm, Role, Sketcher, SparseVec};
use crate::store::{resolve_shards, PersistentIndex, StoreStats};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which compute backend the coordinator drives.
// One long-lived value per service; the Xla/Rust size gap is irrelevant.
#[allow(clippy::large_enum_variant)]
pub enum EngineBackend {
    /// AOT XLA artifacts via the PJRT engine thread.  The *sparse*
    /// (gather-kernel) variant is preferred when every row in a batch
    /// has ≤ `f_max` nonzeros (§Perf: ~10× over the dense kernel);
    /// the dense variant is the fallback for heavier rows.
    Xla {
        /// Engine handle.
        handle: EngineHandle,
        /// Dense variant `(name, batch)` if present.
        dense: Option<(String, usize)>,
        /// Sparse variant ladder `(name, batch, f_max)`, ascending by
        /// batch size; a partial batch routes to the smallest fit.
        sparse: Vec<(String, usize, usize)>,
        /// σ as i32 (dense artifact input).
        sigma: Vec<i32>,
        /// σ⁻¹ as i32 (sparse artifact input).
        inv_sigma: Vec<i32>,
        /// π doubled (dense artifact input).
        pi2: Vec<i32>,
        /// π tripled with sentinel tail (sparse artifact input).
        pi3: Vec<i32>,
    },
    /// Pure-Rust fallback.
    Rust {
        /// The hasher.
        hasher: Arc<dyn Sketcher>,
    },
}

struct SketchJob {
    vec: SparseVec,
    resp: mpsc::SyncSender<crate::Result<Vec<u32>>>,
}

/// The L3 coordinator.
pub struct Coordinator {
    cfg: ServeConfig,
    tx: mpsc::Sender<SketchJob>,
    store: PersistentIndex,
    metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Build the backend, open (and, with persistence configured,
    /// recover) the sharded sketch store, spawn the batch pump thread,
    /// return the service.
    pub fn start(cfg: ServeConfig) -> crate::Result<Arc<Self>> {
        cfg.validate()?;
        let backend = Self::build_backend(&cfg)?;
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = mpsc::channel::<SketchJob>();
        let store = PersistentIndex::open(
            cfg.num_hashes,
            IndexConfig {
                bands: cfg.index.bands,
                rows_per_band: cfg.index.rows_per_band,
            },
            resolve_shards(cfg.store.shards),
            cfg.store.persist_dir.as_deref(),
        )?;
        let svc = Arc::new(Coordinator {
            cfg: cfg.clone(),
            tx,
            store,
            metrics: metrics.clone(),
        });
        let pump_metrics = metrics;
        let (dim, k) = (cfg.dim, cfg.num_hashes);
        let (max_batch, max_delay, policy) = (
            cfg.batch.max_batch,
            Duration::from_micros(cfg.batch.max_delay_us),
            cfg.batch.policy,
        );
        std::thread::Builder::new()
            .name("batch-pump".into())
            .spawn(move || {
                batch_pump(
                    rx,
                    backend,
                    dim,
                    k,
                    max_batch,
                    max_delay,
                    policy,
                    pump_metrics,
                )
            })
            .map_err(crate::Error::Io)?;
        Ok(svc)
    }

    fn build_backend(cfg: &ServeConfig) -> crate::Result<EngineBackend> {
        match cfg.engine {
            EngineKind::Rust => Ok(EngineBackend::Rust {
                hasher: Arc::new(CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed)),
            }),
            EngineKind::Xla => {
                let handle = EngineHandle::spawn(&cfg.artifacts_dir)?;
                let dense = handle.manifest().sketch_variant_for(cfg.dim, cfg.num_hashes);
                let sparse = handle
                    .manifest()
                    .sparse_sketch_variants_for(cfg.dim, cfg.num_hashes);
                if dense.is_none() && sparse.is_empty() {
                    return Err(crate::Error::UnknownArtifact(format!(
                        "no cminhash artifact for D={} K={} (re-run `make artifacts` \
                         with a matching variant)",
                        cfg.dim, cfg.num_hashes
                    )));
                }
                let sigma = Perm::generate(cfg.dim, cfg.seed, Role::Sigma);
                let pi = Perm::generate(cfg.dim, cfg.seed, Role::Pi);
                Ok(EngineBackend::Xla {
                    handle,
                    dense,
                    sparse,
                    sigma: sigma.values_i32(),
                    inv_sigma: sigma.inverse().values_i32(),
                    pi2: pi.doubled_i32(),
                    pi3: pi.tripled_sentinel_i32(),
                })
            }
        }
    }

    /// Service configuration.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Metrics handle.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    fn check_dim(&self, v: &SparseVec) -> crate::Result<()> {
        if v.dim() as usize != self.cfg.dim {
            return Err(crate::Error::ShapeMismatch {
                what: "vector dim",
                expected: self.cfg.dim,
                got: v.dim() as usize,
            });
        }
        Ok(())
    }

    /// Sketch one vector through the batched engine (blocks until the
    /// batch executes).
    pub fn sketch(&self, v: SparseVec) -> crate::Result<Vec<u32>> {
        self.check_dim(&v)?;
        let start = Instant::now();
        let (resp, rx) = mpsc::sync_channel(1);
        self.tx
            .send(SketchJob { vec: v, resp })
            .map_err(|_| crate::Error::Shutdown)?;
        let out = rx.recv().map_err(|_| crate::Error::Shutdown)??;
        self.metrics
            .sketch_latency
            .record(start.elapsed().as_micros() as u64);
        Metrics::inc(&self.metrics.sketches);
        Ok(out)
    }

    /// Sketch, store, and index a vector; returns `(id, sketch)`.
    /// With persistence configured the insert is WAL-logged before
    /// this returns.
    pub fn insert(&self, v: SparseVec) -> crate::Result<(u64, Vec<u32>)> {
        let sk = self.sketch(v)?;
        let id = self.store.insert(sk.clone())?;
        Ok((id, sk))
    }

    /// Delete a stored id (error on unknown ids); the deletion is
    /// WAL-logged and the id never resurfaces in query results.
    pub fn delete(&self, id: u64) -> crate::Result<()> {
        self.store.delete(id)?;
        Metrics::inc(&self.metrics.deletes);
        Ok(())
    }

    /// Estimate J between two stored sketches.
    pub fn estimate_ids(&self, a: u64, b: u64) -> crate::Result<f64> {
        let jhat = self.store.estimate(a, b)?;
        Metrics::inc(&self.metrics.estimates);
        Ok(jhat)
    }

    /// Estimate J between two raw vectors (sketches both).
    pub fn estimate_vecs(&self, v: SparseVec, w: SparseVec) -> crate::Result<f64> {
        let sv = self.sketch(v)?;
        let sw = self.sketch(w)?;
        Metrics::inc(&self.metrics.estimates);
        Ok(crate::sketch::estimate(&sv, &sw))
    }

    /// Top-k near neighbors of a vector among inserted items, fanned
    /// out across the store's shards.  `topk == 0` is a client error
    /// (it could only ever return nothing).
    pub fn query(&self, v: SparseVec, topk: usize) -> crate::Result<Vec<Neighbor>> {
        if topk == 0 {
            return Err(crate::Error::Invalid("topk must be at least 1".into()));
        }
        let start = Instant::now();
        let sk = self.sketch(v)?;
        let out = self.store.query(&sk, topk)?;
        self.metrics
            .query_latency
            .record(start.elapsed().as_micros() as u64);
        Metrics::inc(&self.metrics.queries);
        Ok(out)
    }

    /// All inserted items with estimated J ≥ `threshold`.
    pub fn query_above(&self, v: SparseVec, threshold: f64) -> crate::Result<Vec<Neighbor>> {
        let sk = self.sketch(v)?;
        Metrics::inc(&self.metrics.queries);
        self.store.query_above(&sk, threshold)
    }

    /// Fold the WAL into a fresh snapshot; returns persisted bytes.
    /// Errors when the service runs without a persist directory.
    pub fn save(&self) -> crate::Result<u64> {
        self.store.compact()
    }

    /// Metrics + store occupancy/durability snapshot.
    pub fn stats(&self) -> (MetricsSnapshot, StoreStats) {
        (self.metrics.snapshot(), self.store.stats())
    }
}

/// The batch pump: collects jobs, flushes on size / policy, executes on
/// the backend, distributes per-row results.
///
/// `Eager` policy (default): batch whatever is queued the moment the
/// engine is free — continuous batching, no idle waiting (§Perf: cut
/// rust-engine mean latency ~3× vs deadline batching at equal
/// throughput).  `Deadline`: classic wait-up-to-`max_delay`.
#[allow(clippy::too_many_arguments)] // one private call site, plain plumbing
fn batch_pump(
    rx: mpsc::Receiver<SketchJob>,
    backend: EngineBackend,
    dim: usize,
    k: usize,
    max_batch: usize,
    max_delay: Duration,
    policy: crate::config::BatchPolicy,
    metrics: Arc<Metrics>,
) {
    // For the XLA backend the flush size is the artifact's fixed batch.
    let flush_size = match &backend {
        EngineBackend::Xla { dense, sparse, .. } => sparse
            .last()
            .map(|(_, b, _)| *b)
            .or_else(|| dense.as_ref().map(|(_, b)| *b))
            .unwrap_or(max_batch),
        EngineBackend::Rust { .. } => max_batch,
    };
    let eager = policy == crate::config::BatchPolicy::Eager;
    let mut batcher: Batcher<SketchJob> = Batcher::new(flush_size, max_delay);
    'outer: loop {
        // Block for the first job of the next batch.
        match rx.recv() {
            Ok(job) => {
                let mut flush = batcher.push(job, Instant::now());
                // Accumulate until full / policy says go.
                while flush.is_none() {
                    match rx.try_recv() {
                        Ok(job) => {
                            flush = batcher.push(job, Instant::now());
                        }
                        Err(mpsc::TryRecvError::Empty) => {
                            if eager {
                                // Engine is idle and nothing is queued:
                                // run what we have now.
                                flush = batcher.drain();
                            } else {
                                let deadline =
                                    batcher.deadline().expect("non-empty batcher");
                                let now = Instant::now();
                                if now >= deadline {
                                    flush = batcher.poll_deadline(now);
                                } else {
                                    match rx.recv_timeout(deadline - now) {
                                        Ok(job) => {
                                            flush = batcher.push(job, Instant::now());
                                        }
                                        Err(mpsc::RecvTimeoutError::Timeout) => {
                                            flush =
                                                batcher.poll_deadline(Instant::now());
                                        }
                                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                                            break 'outer;
                                        }
                                    }
                                }
                            }
                        }
                        Err(mpsc::TryRecvError::Disconnected) => break 'outer,
                    }
                }
                if let Some((batch, _reason)) = flush {
                    run_batch(&backend, dim, k, batch, &metrics);
                }
            }
            Err(_) => break 'outer,
        }
    }
    // Producers gone: run whatever is left.
    if let Some((batch, _)) = batcher.drain() {
        run_batch(&backend, dim, k, batch, &metrics);
    }
}

fn run_batch(
    backend: &EngineBackend,
    dim: usize,
    k: usize,
    batch: Vec<SketchJob>,
    metrics: &Metrics,
) {
    let start = Instant::now();
    let n = batch.len();
    // Counted up-front so a client that observes its response also
    // observes the batch in /stats (responses are sent below).
    Metrics::inc(&metrics.batches);
    match backend {
        EngineBackend::Rust { hasher } => {
            for job in batch {
                let sk = hasher.sketch_sparse(job.vec.indices());
                let _ = job.resp.send(Ok(sk));
            }
        }
        EngineBackend::Xla {
            handle,
            dense,
            sparse,
            sigma,
            inv_sigma,
            pi2,
            pi3,
        } => {
            // Route: sparse gather kernel when every row fits in F_max
            // (the common case), dense kernel otherwise.
            let max_nnz = batch.iter().map(|j| j.vec.nnz()).max().unwrap_or(0);
            // Smallest sparse variant that fits this batch and its rows.
            let pick = sparse
                .iter()
                .find(|(_, b, f)| n <= *b && max_nnz <= *f);
            let (variant, inputs) = if let Some((name, batch_b, f_max)) = pick {
                Metrics::inc(&metrics.sparse_batches);
                metrics
                    .pad_rows
                    .fetch_add((*batch_b - n) as u64, std::sync::atomic::Ordering::Relaxed);
                // Pack padded index rows; pad value 2*D hits pi3's
                // sentinel tail.
                let pad = 2 * dim as i32;
                let mut idx = vec![pad; batch_b * f_max];
                for (row, job) in batch.iter().enumerate() {
                    for (j, &i) in job.vec.indices().iter().enumerate() {
                        idx[row * f_max + j] = i as i32;
                    }
                }
                (
                    name.clone(),
                    vec![
                        HostTensor::I32(idx),
                        HostTensor::I32(inv_sigma.clone()),
                        HostTensor::I32(pi3.clone()),
                    ],
                )
            } else {
                match dense {
                    Some((name, batch_b)) => {
                        debug_assert!(n <= *batch_b);
                        metrics.pad_rows.fetch_add(
                            (*batch_b - n) as u64,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                        // Dense bits matrix; padding rows stay all-zero
                        // and their sentinel sketches are never
                        // delivered to anyone.
                        let mut bits = vec![0i32; batch_b * dim];
                        for (row, job) in batch.iter().enumerate() {
                            for &i in job.vec.indices() {
                                bits[row * dim + i as usize] = 1;
                            }
                        }
                        (
                            name.clone(),
                            vec![
                                HostTensor::I32(bits),
                                HostTensor::I32(sigma.clone()),
                                HostTensor::I32(pi2.clone()),
                            ],
                        )
                    }
                    None => {
                        let msg = format!(
                            "row with {max_nnz} nonzeros exceeds sparse F_max and no \
                             dense artifact is loaded"
                        );
                        Metrics::inc(&metrics.errors);
                        for job in batch {
                            let _ = job.resp.send(Err(crate::Error::Invalid(msg.clone())));
                        }
                        metrics
                            .batch_latency
                            .record(start.elapsed().as_micros() as u64);
                        return;
                    }
                }
            };
            match handle.execute(&variant, inputs) {
                Ok(outputs) => match outputs[0].as_i32() {
                    Ok(hashes) => {
                        for (row, job) in batch.into_iter().enumerate() {
                            let sk: Vec<u32> = hashes[row * k..(row + 1) * k]
                                .iter()
                                .map(|&v| v as u32)
                                .collect();
                            let _ = job.resp.send(Ok(sk));
                        }
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        for job in batch {
                            let _ = job.resp.send(Err(crate::Error::Xla(msg.clone())));
                        }
                    }
                },
                Err(e) => {
                    let msg = e.to_string();
                    Metrics::inc(&metrics.errors);
                    for job in batch {
                        let _ = job.resp.send(Err(crate::Error::Xla(msg.clone())));
                    }
                }
            }
        }
    }
    metrics
        .batch_latency
        .record(start.elapsed().as_micros() as u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rust_cfg() -> ServeConfig {
        ServeConfig {
            engine: EngineKind::Rust,
            dim: 512,
            num_hashes: 64,
            index: crate::config::IndexSettings {
                bands: 16,
                rows_per_band: 4,
            },
            batch: crate::config::BatchConfig {
                max_batch: 4,
                max_delay_us: 500,
                policy: crate::config::BatchPolicy::Eager,
            },
            ..ServeConfig::default()
        }
    }

    #[test]
    fn sketch_matches_direct_hasher() {
        let cfg = rust_cfg();
        let svc = Coordinator::start(cfg.clone()).unwrap();
        let hasher = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);
        let v = SparseVec::new(512, vec![1, 99, 300]).unwrap();
        let got = svc.sketch(v.clone()).unwrap();
        assert_eq!(got, hasher.sketch_sparse(v.indices()));
    }

    #[test]
    fn insert_then_query_finds_self() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, (0..50).collect()).unwrap();
        let (id, _) = svc.insert(v.clone()).unwrap();
        let hits = svc.query(v, 3).unwrap();
        assert_eq!(hits[0].id, id);
        assert_eq!(hits[0].score, 1.0);
    }

    #[test]
    fn estimate_ids_and_vecs_agree() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, (0..60).collect()).unwrap();
        let w = SparseVec::new(512, (30..90).collect()).unwrap();
        let (ia, _) = svc.insert(v.clone()).unwrap();
        let (ib, _) = svc.insert(w.clone()).unwrap();
        let by_id = svc.estimate_ids(ia, ib).unwrap();
        let by_vec = svc.estimate_vecs(v, w).unwrap();
        assert!((by_id - by_vec).abs() < 1e-12);
        assert!(svc.estimate_ids(ia, 999).is_err());
    }

    #[test]
    fn rejects_wrong_dimension() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let bad = SparseVec::new(100, vec![1]).unwrap();
        assert!(matches!(
            svc.sketch(bad.clone()),
            Err(crate::Error::ShapeMismatch { .. })
        ));
        // query paths surface the same clean error, not a panic
        assert!(matches!(
            svc.query(bad.clone(), 3),
            Err(crate::Error::ShapeMismatch { .. })
        ));
        assert!(matches!(
            svc.query_above(bad, 0.5),
            Err(crate::Error::ShapeMismatch { .. })
        ));
    }

    #[test]
    fn topk_zero_is_a_client_error() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, vec![1, 2, 3]).unwrap();
        match svc.query(v, 0) {
            Err(crate::Error::Invalid(msg)) => assert!(msg.contains("topk"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn delete_removes_from_queries_and_counts() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, (0..50).collect()).unwrap();
        let (id, _) = svc.insert(v.clone()).unwrap();
        svc.delete(id).unwrap();
        assert!(svc.delete(id).is_err(), "double delete is an error");
        assert!(svc.query(v, 3).unwrap().iter().all(|n| n.id != id));
        assert!(svc.estimate_ids(id, id).is_err());
        let (snap, store) = svc.stats();
        assert_eq!(snap.deletes, 1);
        assert_eq!(store.stored, 0);
        assert_eq!(store.shards.iter().sum::<usize>(), 0);
        assert_eq!(store.persisted_bytes, 0, "no persistence configured");
    }

    #[test]
    fn save_requires_persistence() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        assert!(svc.save().is_err());
    }

    #[test]
    fn concurrent_requests_batch_up() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let mut handles = Vec::new();
        for i in 0..32u32 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let v = SparseVec::new(512, vec![i, i + 100, i + 200]).unwrap();
                svc.sketch(v).unwrap()
            }));
        }
        for h in handles {
            let sk = h.join().unwrap();
            assert_eq!(sk.len(), 64);
        }
        let (snap, _) = svc.stats();
        assert_eq!(snap.sketches, 32);
        assert!(snap.batches >= 1);
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        // One request against max_batch=4 must still complete (deadline).
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let t = Instant::now();
        let v = SparseVec::new(512, vec![7]).unwrap();
        let sk = svc.sketch(v).unwrap();
        assert_eq!(sk.len(), 64);
        // Deadline is 500us; allow generous scheduling slack.
        assert!(t.elapsed() < Duration::from_millis(200));
    }
}
