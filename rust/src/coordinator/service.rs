//! The coordinator service: dynamic batching in front of an engine,
//! plus sketch store, LSH index and metrics.
//!
//! Threading model (the offline build has no async runtime, and none is
//! needed): the server runs a bounded pool of connection workers; every
//! worker calls the blocking [`Coordinator`] API; sketch requests cross
//! one channel into the **batch pump thread**, which groups them up to
//! the artifact batch size or the latency deadline and executes on the
//! backend; responses travel back over one channel per client batch
//! (a singleton request is a batch of one).

use crate::config::{EngineKind, ServeConfig};
use crate::coordinator::batcher::Batcher;
use crate::index::{IndexConfig, Neighbor};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::obs::{stage, Obs, Stage};
use crate::runtime::{EngineHandle, HostTensor};
use crate::sketch::{Perm, Role, SketchScheme, Sketcher, SparseVec};
use crate::store::{resolve_shards, PersistentIndex, StoreStats};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which compute backend the coordinator drives.
// One long-lived value per service; the Xla/Rust size gap is irrelevant.
#[allow(clippy::large_enum_variant)]
pub enum EngineBackend {
    /// AOT XLA artifacts via the PJRT engine thread.  The *sparse*
    /// (gather-kernel) variant is preferred when every row in a batch
    /// has ≤ `f_max` nonzeros (§Perf: ~10× over the dense kernel);
    /// the dense variant is the fallback for heavier rows.
    Xla {
        /// Engine handle.
        handle: EngineHandle,
        /// Dense variant `(name, batch)` if present.
        dense: Option<(String, usize)>,
        /// Sparse variant ladder `(name, batch, f_max)`, ascending by
        /// batch size; a partial batch routes to the smallest fit.
        sparse: Vec<(String, usize, usize)>,
        /// σ as i32 (dense artifact input).
        sigma: Vec<i32>,
        /// σ⁻¹ as i32 (sparse artifact input).
        inv_sigma: Vec<i32>,
        /// π doubled (dense artifact input).
        pi2: Vec<i32>,
        /// π tripled with sentinel tail (sparse artifact input).
        pi3: Vec<i32>,
    },
    /// Pure-Rust hashers — the path that supports every
    /// [`SketchScheme`], selected by `cfg.sketch.scheme`.
    Rust {
        /// The scheme-selected hasher.
        hasher: Arc<dyn Sketcher>,
    },
}

/// One row of a client batch queued for the pump.  `resp` is shared by
/// every row of the same client batch — **one channel per batch**, not
/// per row — and carries the row index so the client can reassemble
/// results in submission order even when the pump splits the rows
/// across engine batches.  The channel's capacity equals the batch
/// size, so the pump never blocks delivering results.
struct SketchJob {
    vec: SparseVec,
    row: usize,
    resp: mpsc::SyncSender<(usize, crate::Result<Vec<u32>>)>,
}

/// The L3 coordinator.
pub struct Coordinator {
    cfg: ServeConfig,
    tx: mpsc::Sender<SketchJob>,
    store: PersistentIndex,
    metrics: Arc<Metrics>,
    obs: Arc<Obs>,
}

impl Coordinator {
    /// Build the backend, open (and, with persistence configured,
    /// recover) the sharded sketch store, spawn the batch pump thread,
    /// return the service.
    pub fn start(cfg: ServeConfig) -> crate::Result<Arc<Self>> {
        cfg.validate()?;
        let backend = Self::build_backend(&cfg)?;
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = mpsc::channel::<SketchJob>();
        let store = PersistentIndex::open_with_bits(
            cfg.num_hashes,
            cfg.sketch.scheme,
            cfg.sketch.bits,
            IndexConfig {
                bands: cfg.index.bands,
                rows_per_band: cfg.index.rows_per_band,
            },
            resolve_shards(cfg.store.shards),
            cfg.store.persist_dir.as_deref(),
        )?;
        let obs = Arc::new(Obs::new(
            cfg.obs.trace_ring,
            cfg.obs.slow_threshold_us,
            cfg.obs.pinned,
        ));
        let svc = Arc::new(Coordinator {
            cfg: cfg.clone(),
            tx,
            store,
            metrics: metrics.clone(),
            obs,
        });
        let pump_metrics = metrics;
        let (dim, k) = (cfg.dim, cfg.num_hashes);
        let (max_batch, max_delay, policy) = (
            cfg.batch.max_batch,
            Duration::from_micros(cfg.batch.max_delay_us),
            cfg.batch.policy,
        );
        std::thread::Builder::new()
            .name("batch-pump".into())
            .spawn(move || {
                batch_pump(
                    rx,
                    backend,
                    dim,
                    k,
                    max_batch,
                    max_delay,
                    policy,
                    pump_metrics,
                )
            })
            .map_err(crate::Error::Io)?;
        Ok(svc)
    }

    fn build_backend(cfg: &ServeConfig) -> crate::Result<EngineBackend> {
        match cfg.engine {
            EngineKind::Rust => Ok(EngineBackend::Rust {
                hasher: cfg
                    .sketch
                    .scheme
                    .build(cfg.dim, cfg.num_hashes, cfg.seed)?,
            }),
            EngineKind::Xla => {
                // The AOT artifacts implement exactly one pipeline: the
                // C-MinHash-(σ, π) kernels.  Serving any other scheme
                // through them would produce sketches from the wrong
                // algorithm, so the mismatch is rejected up front.
                if cfg.sketch.scheme != SketchScheme::Cmh {
                    return Err(crate::Error::Invalid(format!(
                        "engine xla only implements the 'cmh' scheme (the \
                         compiled artifacts are C-MinHash-(σ, π) kernels); \
                         scheme '{}' needs --engine rust",
                        cfg.sketch.scheme
                    )));
                }
                let handle = EngineHandle::spawn(&cfg.artifacts_dir)?;
                let dense = handle.manifest().sketch_variant_for(cfg.dim, cfg.num_hashes);
                let sparse = handle
                    .manifest()
                    .sparse_sketch_variants_for(cfg.dim, cfg.num_hashes);
                if dense.is_none() && sparse.is_empty() {
                    return Err(crate::Error::UnknownArtifact(format!(
                        "no cminhash artifact for D={} K={} (re-run `make artifacts` \
                         with a matching variant)",
                        cfg.dim, cfg.num_hashes
                    )));
                }
                let sigma = Perm::generate(cfg.dim, cfg.seed, Role::Sigma);
                let pi = Perm::generate(cfg.dim, cfg.seed, Role::Pi);
                Ok(EngineBackend::Xla {
                    handle,
                    dense,
                    sparse,
                    sigma: sigma.values_i32(),
                    inv_sigma: sigma.inverse().values_i32(),
                    pi2: pi.doubled_i32(),
                    pi3: pi.tripled_sentinel_i32(),
                })
            }
        }
    }

    /// Service configuration.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Metrics handle.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Request-tracing observability plane (trace ring, per-op
    /// counters, slow-request pinning).
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Validate a request vector: the dimension must match the service
    /// and the vector must have at least one nonzero.  An empty vector
    /// has no minimum — its sketch would be the all-sentinel value,
    /// which collides in every slot with every other empty vector and
    /// fabricates Ĵ = 1.0 where exact Jaccard (eq. 1) gives 0 — so it
    /// is rejected at the boundary with a clean error.
    fn check_vec(&self, v: &SparseVec) -> crate::Result<()> {
        if v.dim() as usize != self.cfg.dim {
            return Err(crate::Error::ShapeMismatch {
                what: "vector dim",
                expected: self.cfg.dim,
                got: v.dim() as usize,
            });
        }
        if v.nnz() == 0 {
            return Err(crate::Error::Invalid(
                "empty vector (0 nonzeros): MinHash is undefined on the empty \
                 set and its sentinel sketch would spuriously estimate Ĵ = 1.0 \
                 against every other empty vector"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Sketch one vector through the batched engine (blocks until the
    /// batch executes).
    // One row in, one row out is the batcher contract (pinned by the
    // tests below); an empty reply is a bug worth crashing on.
    #[allow(clippy::disallowed_methods)]
    pub fn sketch(&self, v: SparseVec) -> crate::Result<Vec<u32>> {
        let mut out = self.sketch_many(vec![v])?;
        Ok(out.pop().expect("one row in, one row out"))
    }

    /// Sketch a whole client batch through the engine: every row is
    /// submitted to the batch pump **before** the first wait, so the
    /// rows coalesce into as few engine executions as the artifact
    /// batch size allows, and all results come back over one channel.
    /// Results are returned in submission order.  The batch is
    /// all-or-nothing: any row failing validation or execution fails
    /// the call.
    pub fn sketch_many(&self, vs: Vec<SparseVec>) -> crate::Result<Vec<Vec<u32>>> {
        if vs.is_empty() {
            return Err(crate::Error::Invalid("empty batch".into()));
        }
        for v in &vs {
            self.check_vec(v)?;
        }
        let n = vs.len();
        let start = Instant::now();
        // The whole submit→wait window is the request's "sketch" span:
        // queueing, pump batching, and engine execution all happen
        // while this thread blocks on the response channel.
        let _span = stage(Stage::Sketch);
        // Capacity n: the pump can deliver every row without blocking
        // even before this thread starts receiving.
        let (resp, rx) = mpsc::sync_channel(n);
        for (row, vec) in vs.into_iter().enumerate() {
            self.tx
                .send(SketchJob {
                    vec,
                    row,
                    resp: resp.clone(),
                })
                .map_err(|_| crate::Error::Shutdown)?;
        }
        drop(resp);
        let mut out: Vec<Vec<u32>> = vec![Vec::new(); n];
        for _ in 0..n {
            let (row, sk) = rx.recv().map_err(|_| crate::Error::Shutdown)?;
            out[row] = sk?;
        }
        self.metrics
            .sketch_latency
            .record(start.elapsed().as_micros() as u64);
        self.metrics
            .sketches
            .fetch_add(n as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }

    /// Sketch, store, and index a vector; returns `(id, sketch)`.
    /// With persistence configured the insert is WAL-logged before
    /// this returns.
    pub fn insert(&self, v: SparseVec) -> crate::Result<(u64, Vec<u32>)> {
        let sk = self.sketch(v)?;
        let id = self.store.insert(sk.clone())?;
        Ok((id, sk))
    }

    /// Sketch, store, and index a whole batch as a unit: one pass
    /// through the batch pump, one WAL append, one lock acquisition
    /// per store shard.  Returns `(id, sketch)` per row in submission
    /// order; ids are consecutive.
    pub fn insert_many(
        &self,
        vs: Vec<SparseVec>,
    ) -> crate::Result<Vec<(u64, Vec<u32>)>> {
        let sks = self.sketch_many(vs)?;
        let ids = self.store.insert_many(&sks)?;
        Ok(ids.into_iter().zip(sks).collect())
    }

    /// Store and index a batch of *already-packed* sketch rows — the
    /// binary wire's zero-copy ingest: the client (or an offline
    /// sketching job) ran the scheme's hasher and `pack_row` itself,
    /// so the bytes go straight into the packed arena with no
    /// sketching, no per-lane parse, and no repack.  Rows must be
    /// exactly [`crate::sketch::packed_words`]`(K, bits)` words with
    /// every padding bit past K·b zero (nonzero padding would corrupt
    /// popcount scoring for the row's whole lifetime, so it is
    /// rejected here at the boundary).  Returns fresh consecutive ids
    /// in row order.
    pub fn insert_packed_many(&self, rows: Vec<Vec<u64>>) -> crate::Result<Vec<u64>> {
        if rows.is_empty() {
            return Err(crate::Error::Invalid("empty batch".into()));
        }
        let k = self.cfg.num_hashes;
        let bits = self.cfg.sketch.bits;
        let wpr = crate::sketch::packed_words(k, bits);
        let used_in_last = k * bits as usize - (wpr - 1) * 64;
        for (row, words) in rows.iter().enumerate() {
            if words.len() != wpr {
                return Err(crate::Error::ShapeMismatch {
                    what: "packed row words",
                    expected: wpr,
                    got: words.len(),
                });
            }
            if used_in_last < 64 && (words[wpr - 1] >> used_in_last) != 0 {
                return Err(crate::Error::Invalid(format!(
                    "packed row {row} has nonzero padding bits past lane \
                     K={k} at bits={bits} (rows must come from pack_row, \
                     which zeroes the tail)"
                )));
            }
        }
        self.store.insert_packed_many(&rows)
    }

    /// Delete a stored id (error on unknown ids); the deletion is
    /// WAL-logged and the id never resurfaces in query results.
    pub fn delete(&self, id: u64) -> crate::Result<()> {
        self.store.delete(id)?;
        Metrics::inc(&self.metrics.deletes);
        Ok(())
    }

    /// Estimate J between two stored sketches.  With a packed store
    /// (`sketch.bits` < 32) the stored lanes are b bits wide and the
    /// estimate is the unbiased b-bit–corrected one; at the default
    /// full width it is the plain collision fraction.
    pub fn estimate_ids(&self, a: u64, b: u64) -> crate::Result<f64> {
        let start = Instant::now();
        let jhat = self.store.estimate(a, b)?;
        self.metrics
            .estimate_latency
            .record(start.elapsed().as_micros() as u64);
        Metrics::inc(&self.metrics.estimates);
        Ok(jhat)
    }

    /// Estimate J between two raw vectors (sketches both as one
    /// two-row batch through the pump).  Always full-width: inline
    /// vectors never touch the packed store, so nothing is truncated.
    pub fn estimate_vecs(&self, v: SparseVec, w: SparseVec) -> crate::Result<f64> {
        let start = Instant::now();
        let sks = self.sketch_many(vec![v, w])?;
        self.metrics
            .estimate_latency
            .record(start.elapsed().as_micros() as u64);
        Metrics::inc(&self.metrics.estimates);
        Ok(crate::sketch::estimate(&sks[0], &sks[1]))
    }

    /// Top-k near neighbors of a vector among inserted items, fanned
    /// out across the store's shards.  `topk == 0` is a client error
    /// (it could only ever return nothing).
    pub fn query(&self, v: SparseVec, topk: usize) -> crate::Result<Vec<Neighbor>> {
        if topk == 0 {
            return Err(crate::Error::Invalid("topk must be at least 1".into()));
        }
        let start = Instant::now();
        let sk = self.sketch(v)?;
        let out = self.store.query(&sk, topk)?;
        self.metrics
            .query_latency
            .record(start.elapsed().as_micros() as u64);
        Metrics::inc(&self.metrics.queries);
        Ok(out)
    }

    /// Top-k near neighbors for a whole batch of query vectors: one
    /// pass through the batch pump, one lock acquisition per store
    /// shard.  Returns one neighbor list per row, each identical to
    /// what the singleton [`Coordinator::query`] would return.
    pub fn query_many(
        &self,
        vs: Vec<SparseVec>,
        topk: usize,
    ) -> crate::Result<Vec<Vec<Neighbor>>> {
        if topk == 0 {
            return Err(crate::Error::Invalid("topk must be at least 1".into()));
        }
        let n = vs.len();
        let start = Instant::now();
        let sks = self.sketch_many(vs)?;
        let out = self.store.query_many(&sks, topk)?;
        self.metrics
            .query_latency
            .record(start.elapsed().as_micros() as u64);
        self.metrics
            .queries
            .fetch_add(n as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }

    /// All inserted items with estimated J ≥ `threshold`.
    pub fn query_above(&self, v: SparseVec, threshold: f64) -> crate::Result<Vec<Neighbor>> {
        let start = Instant::now();
        let sk = self.sketch(v)?;
        let out = self.store.query_above(&sk, threshold)?;
        // Same accounting as `query`: a latency sample per request, so
        // `stats` reflects threshold queries too.
        self.metrics
            .query_latency
            .record(start.elapsed().as_micros() as u64);
        Metrics::inc(&self.metrics.queries);
        Ok(out)
    }

    /// Fold the WAL into a fresh snapshot; returns persisted bytes.
    /// Errors when the service runs without a persist directory.
    pub fn save(&self) -> crate::Result<u64> {
        self.store.compact()
    }

    /// Export this node's durable image (snapshot bytes + WAL tail)
    /// for a joining cluster peer — the server half of the `replicate`
    /// wire op.  Errors without a persist directory.
    pub fn replicate_export(&self) -> crate::Result<(Vec<u8>, Vec<u8>)> {
        self.store.replicate_export()
    }

    /// Bootstrap this (fresh, empty) node from a peer's replicate
    /// image: both streams are validated end to end before anything is
    /// installed, and on a durable node the resulting directory is
    /// byte-identical to the peer's export.  Returns resident items.
    pub fn replicate_apply(&self, snapshot: &[u8], wal: &[u8]) -> crate::Result<u64> {
        self.store.replicate_apply(snapshot, wal)
    }

    /// Metrics + store occupancy/durability snapshot.
    pub fn stats(&self) -> (MetricsSnapshot, StoreStats) {
        (self.metrics.snapshot(), self.store.stats())
    }
}

/// The batch pump: collects jobs, flushes on size / policy, executes on
/// the backend, distributes per-row results.
///
/// `Eager` policy (default): batch whatever is queued the moment the
/// engine is free — continuous batching, no idle waiting (§Perf: cut
/// rust-engine mean latency ~3× vs deadline batching at equal
/// throughput).  `Deadline`: classic wait-up-to-`max_delay`.
#[allow(clippy::too_many_arguments)] // one private call site, plain plumbing
// `deadline().expect` runs only on the non-empty branch just tested.
#[allow(clippy::disallowed_methods)]
fn batch_pump(
    rx: mpsc::Receiver<SketchJob>,
    backend: EngineBackend,
    dim: usize,
    k: usize,
    max_batch: usize,
    max_delay: Duration,
    policy: crate::config::BatchPolicy,
    metrics: Arc<Metrics>,
) {
    // For the XLA backend the flush size is the artifact's fixed batch.
    let flush_size = match &backend {
        EngineBackend::Xla { dense, sparse, .. } => sparse
            .last()
            .map(|(_, b, _)| *b)
            .or_else(|| dense.as_ref().map(|(_, b)| *b))
            .unwrap_or(max_batch),
        EngineBackend::Rust { .. } => max_batch,
    };
    let eager = policy == crate::config::BatchPolicy::Eager;
    let mut batcher: Batcher<SketchJob> = Batcher::new(flush_size, max_delay);
    'outer: loop {
        // Block for the first job of the next batch.
        match rx.recv() {
            Ok(job) => {
                let mut flush = batcher.push(job, Instant::now());
                // Accumulate until full / policy says go.
                while flush.is_none() {
                    match rx.try_recv() {
                        Ok(job) => {
                            flush = batcher.push(job, Instant::now());
                        }
                        Err(mpsc::TryRecvError::Empty) => {
                            if eager {
                                // Engine is idle and nothing is queued:
                                // run what we have now.
                                flush = batcher.drain();
                            } else {
                                let deadline =
                                    batcher.deadline().expect("non-empty batcher");
                                let now = Instant::now();
                                if now >= deadline {
                                    flush = batcher.poll_deadline(now);
                                } else {
                                    match rx.recv_timeout(deadline - now) {
                                        Ok(job) => {
                                            flush = batcher.push(job, Instant::now());
                                        }
                                        Err(mpsc::RecvTimeoutError::Timeout) => {
                                            flush =
                                                batcher.poll_deadline(Instant::now());
                                        }
                                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                                            break 'outer;
                                        }
                                    }
                                }
                            }
                        }
                        Err(mpsc::TryRecvError::Disconnected) => break 'outer,
                    }
                }
                if let Some((batch, _reason)) = flush {
                    run_batch(&backend, dim, k, batch, &metrics);
                }
            }
            Err(_) => break 'outer,
        }
    }
    // Producers gone: run whatever is left.
    if let Some((batch, _)) = batcher.drain() {
        run_batch(&backend, dim, k, batch, &metrics);
    }
}

/// The largest batch the loaded artifact ladder can execute when the
/// heaviest row carries `max_nnz` nonzeros: the biggest batch
/// dimension among the sparse variants whose `F_max` fits the row,
/// plus the dense fallback (which fits any row).  `None` means no
/// loaded variant can hash such a row at all.
///
/// This is the invariant that kills the dense-fallback overflow: any
/// batch larger than the capacity is **split** before execution, so
/// the dense arm can never see more rows than its fixed batch
/// dimension (`batch_b - n` used to wrap and index out of bounds).
fn batch_capacity(
    dense: &Option<(String, usize)>,
    sparse: &[(String, usize, usize)],
    max_nnz: usize,
) -> Option<usize> {
    sparse
        .iter()
        .filter(|(_, _, f)| max_nnz <= *f)
        .map(|(_, b, _)| *b)
        .chain(dense.as_ref().map(|(_, b)| *b))
        .max()
}

fn fail_batch(batch: Vec<SketchJob>, msg: &str, metrics: &Metrics) {
    Metrics::inc(&metrics.errors);
    for job in batch {
        let _ = job
            .resp
            .send((job.row, Err(crate::Error::Invalid(msg.to_string()))));
    }
}

// The packed-capacity `expect` is guarded by the dense-variant match
// arm directly above it.
#[allow(clippy::disallowed_methods)]
fn run_batch(
    backend: &EngineBackend,
    dim: usize,
    k: usize,
    batch: Vec<SketchJob>,
    metrics: &Metrics,
) {
    let n = batch.len();
    match backend {
        EngineBackend::Rust { hasher } => {
            let start = Instant::now();
            Metrics::inc(&metrics.batches);
            for job in batch {
                let sk = hasher.sketch_sparse(job.vec.indices());
                let _ = job.resp.send((job.row, Ok(sk)));
            }
            metrics
                .batch_latency
                .record(start.elapsed().as_micros() as u64);
        }
        EngineBackend::Xla {
            handle,
            dense,
            sparse,
            sigma,
            inv_sigma,
            pi2,
            pi3,
        } => {
            let max_nnz = batch.iter().map(|j| j.vec.nnz()).max().unwrap_or(0);
            let Some(cap) = batch_capacity(dense, sparse, max_nnz) else {
                // Truthful cause: capacity is None only when the row
                // weight itself is unservable (batch *size* overflows
                // are split below, never errored).
                let f_ceiling = sparse.iter().map(|(_, _, f)| *f).max().unwrap_or(0);
                fail_batch(
                    batch,
                    &format!(
                        "row with {max_nnz} nonzeros exceeds every sparse \
                         variant's F_max ({f_ceiling}) and no dense artifact \
                         is loaded"
                    ),
                    metrics,
                );
                return;
            };
            if n > cap {
                // Oversized for every variant that can take these rows:
                // split into capacity-sized chunks.  Each chunk
                // re-routes independently, so chunks that dodge the
                // heavy rows may still take the fast sparse path.
                let mut rest = batch;
                while rest.len() > cap {
                    let tail = rest.split_off(cap);
                    run_batch(backend, dim, k, rest, metrics);
                    rest = tail;
                }
                run_batch(backend, dim, k, rest, metrics);
                return;
            }
            let start = Instant::now();
            Metrics::inc(&metrics.batches);
            // Route: sparse gather kernel when every row fits in F_max
            // (the common case), dense kernel otherwise.  Smallest
            // sparse variant that fits this batch and its rows wins.
            let pick = sparse
                .iter()
                .find(|(_, b, f)| n <= *b && max_nnz <= *f);
            let (variant, inputs) = if let Some((name, batch_b, f_max)) = pick {
                Metrics::inc(&metrics.sparse_batches);
                metrics
                    .pad_rows
                    .fetch_add((*batch_b - n) as u64, std::sync::atomic::Ordering::Relaxed);
                // Pack padded index rows; pad value 2*D hits pi3's
                // sentinel tail.
                let pad = 2 * dim as i32;
                let mut idx = vec![pad; batch_b * f_max];
                for (row, job) in batch.iter().enumerate() {
                    for (j, &i) in job.vec.indices().iter().enumerate() {
                        idx[row * f_max + j] = i as i32;
                    }
                }
                (
                    name.clone(),
                    vec![
                        HostTensor::I32(idx),
                        HostTensor::I32(inv_sigma.clone()),
                        HostTensor::I32(pi3.clone()),
                    ],
                )
            } else {
                // No sparse variant fits; the capacity invariant above
                // proves the dense fallback exists and fits n.
                let (name, batch_b) = dense
                    .as_ref()
                    .expect("capacity came from the dense variant");
                if n > *batch_b {
                    // Unreachable after the split; fail closed with a
                    // protocol error rather than writing out of bounds.
                    fail_batch(
                        batch,
                        &format!(
                            "internal routing bug: {n} rows reached the dense \
                             arm with batch capacity {batch_b}"
                        ),
                        metrics,
                    );
                    return;
                }
                metrics.pad_rows.fetch_add(
                    (*batch_b - n) as u64,
                    std::sync::atomic::Ordering::Relaxed,
                );
                // Dense bits matrix; padding rows stay all-zero
                // and their sentinel sketches are never
                // delivered to anyone.
                let mut bits = vec![0i32; batch_b * dim];
                for (row, job) in batch.iter().enumerate() {
                    for &i in job.vec.indices() {
                        bits[row * dim + i as usize] = 1;
                    }
                }
                (
                    name.clone(),
                    vec![
                        HostTensor::I32(bits),
                        HostTensor::I32(sigma.clone()),
                        HostTensor::I32(pi2.clone()),
                    ],
                )
            };
            match handle.execute(&variant, inputs) {
                Ok(outputs) => match outputs[0].as_i32() {
                    Ok(hashes) => {
                        for (row, job) in batch.into_iter().enumerate() {
                            let sk: Vec<u32> = hashes[row * k..(row + 1) * k]
                                .iter()
                                .map(|&v| v as u32)
                                .collect();
                            let _ = job.resp.send((job.row, Ok(sk)));
                        }
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        for job in batch {
                            let _ = job
                                .resp
                                .send((job.row, Err(crate::Error::Xla(msg.clone()))));
                        }
                    }
                },
                Err(e) => {
                    let msg = e.to_string();
                    Metrics::inc(&metrics.errors);
                    for job in batch {
                        let _ = job
                            .resp
                            .send((job.row, Err(crate::Error::Xla(msg.clone()))));
                    }
                }
            }
            metrics
                .batch_latency
                .record(start.elapsed().as_micros() as u64);
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::sketch::CMinHasher;

    fn rust_cfg() -> ServeConfig {
        ServeConfig {
            engine: EngineKind::Rust,
            dim: 512,
            num_hashes: 64,
            index: crate::config::IndexSettings {
                bands: 16,
                rows_per_band: 4,
            },
            batch: crate::config::BatchConfig {
                max_batch: 4,
                max_delay_us: 500,
                policy: crate::config::BatchPolicy::Eager,
            },
            ..ServeConfig::default()
        }
    }

    #[test]
    fn sketch_matches_direct_hasher() {
        let cfg = rust_cfg();
        let svc = Coordinator::start(cfg.clone()).unwrap();
        let hasher = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);
        let v = SparseVec::new(512, vec![1, 99, 300]).unwrap();
        let got = svc.sketch(v.clone()).unwrap();
        assert_eq!(got, hasher.sketch_sparse(v.indices()));
    }

    #[test]
    fn insert_then_query_finds_self() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, (0..50).collect()).unwrap();
        let (id, _) = svc.insert(v.clone()).unwrap();
        let hits = svc.query(v, 3).unwrap();
        assert_eq!(hits[0].id, id);
        assert_eq!(hits[0].score, 1.0);
    }

    #[test]
    fn estimate_ids_and_vecs_agree() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, (0..60).collect()).unwrap();
        let w = SparseVec::new(512, (30..90).collect()).unwrap();
        let (ia, _) = svc.insert(v.clone()).unwrap();
        let (ib, _) = svc.insert(w.clone()).unwrap();
        let by_id = svc.estimate_ids(ia, ib).unwrap();
        let by_vec = svc.estimate_vecs(v, w).unwrap();
        assert!((by_id - by_vec).abs() < 1e-12);
        assert!(svc.estimate_ids(ia, 999).is_err());
    }

    #[test]
    fn rejects_wrong_dimension() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let bad = SparseVec::new(100, vec![1]).unwrap();
        assert!(matches!(
            svc.sketch(bad.clone()),
            Err(crate::Error::ShapeMismatch { .. })
        ));
        // query paths surface the same clean error, not a panic
        assert!(matches!(
            svc.query(bad.clone(), 3),
            Err(crate::Error::ShapeMismatch { .. })
        ));
        assert!(matches!(
            svc.query_above(bad, 0.5),
            Err(crate::Error::ShapeMismatch { .. })
        ));
    }

    #[test]
    fn topk_zero_is_a_client_error() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, vec![1, 2, 3]).unwrap();
        match svc.query(v, 0) {
            Err(crate::Error::Invalid(msg)) => assert!(msg.contains("topk"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn delete_removes_from_queries_and_counts() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, (0..50).collect()).unwrap();
        let (id, _) = svc.insert(v.clone()).unwrap();
        svc.delete(id).unwrap();
        assert!(svc.delete(id).is_err(), "double delete is an error");
        assert!(svc.query(v, 3).unwrap().iter().all(|n| n.id != id));
        assert!(svc.estimate_ids(id, id).is_err());
        let (snap, store) = svc.stats();
        assert_eq!(snap.deletes, 1);
        assert_eq!(store.stored, 0);
        assert_eq!(store.shards.iter().sum::<usize>(), 0);
        assert_eq!(store.persisted_bytes, 0, "no persistence configured");
    }

    #[test]
    fn save_requires_persistence() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        assert!(svc.save().is_err());
    }

    #[test]
    fn concurrent_requests_batch_up() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let mut handles = Vec::new();
        for i in 0..32u32 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let v = SparseVec::new(512, vec![i, i + 100, i + 200]).unwrap();
                svc.sketch(v).unwrap()
            }));
        }
        for h in handles {
            let sk = h.join().unwrap();
            assert_eq!(sk.len(), 64);
        }
        let (snap, _) = svc.stats();
        assert_eq!(snap.sketches, 32);
        assert!(snap.batches >= 1);
    }

    #[test]
    fn sketch_many_matches_singletons_in_order() {
        let cfg = rust_cfg();
        let svc = Coordinator::start(cfg.clone()).unwrap();
        let hasher = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);
        // 13 rows > max_batch=4: the client batch spans several engine
        // batches and must still come back in submission order.
        let vs: Vec<SparseVec> = (0..13u32)
            .map(|i| SparseVec::new(512, vec![i, i + 40, i + 300]).unwrap())
            .collect();
        let got = svc.sketch_many(vs.clone()).unwrap();
        assert_eq!(got.len(), 13);
        for (row, v) in vs.iter().enumerate() {
            assert_eq!(
                got[row],
                hasher.sketch_sparse(v.indices()),
                "row {row} out of order or wrong"
            );
        }
        let (snap, _) = svc.stats();
        assert_eq!(snap.sketches, 13);
        assert!(snap.batches >= 4, "13 rows over max_batch=4 need >= 4 flushes");
    }

    #[test]
    fn insert_many_and_query_many_match_singleton_paths() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let single = Coordinator::start(rust_cfg()).unwrap();
        let vs: Vec<SparseVec> = (0..6u32)
            .map(|i| SparseVec::new(512, (i * 20..i * 20 + 50).collect()).unwrap())
            .collect();
        let batched = svc.insert_many(vs.clone()).unwrap();
        let singles: Vec<(u64, Vec<u32>)> = vs
            .iter()
            .map(|v| single.insert(v.clone()).unwrap())
            .collect();
        assert_eq!(batched, singles, "N-row batch == N singleton inserts");
        // batch query rows equal singleton query results
        let hits = svc.query_many(vs.clone(), 3).unwrap();
        assert_eq!(hits.len(), 6);
        for (row, v) in vs.iter().enumerate() {
            assert_eq!(hits[row], svc.query(v.clone(), 3).unwrap(), "row {row}");
            assert_eq!(hits[row][0].id, batched[row].0, "self is the top hit");
        }
        assert!(svc.query_many(vs, 0).is_err(), "topk=0 stays a client error");
        assert!(
            matches!(svc.sketch_many(vec![]), Err(crate::Error::Invalid(_))),
            "empty batch is a client error"
        );
    }

    #[test]
    fn empty_vectors_are_rejected_not_estimated_as_identical() {
        // Regression: two empty vectors used to sketch to the all-D
        // sentinel and estimate Ĵ = 1.0 against each other.
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let empty = SparseVec::new(512, vec![]).unwrap();
        let full = SparseVec::new(512, vec![1, 2, 3]).unwrap();
        for r in [
            svc.sketch(empty.clone()).err(),
            svc.insert(empty.clone()).err(),
            svc.query(empty.clone(), 3).err(),
            svc.query_above(empty.clone(), 0.5).err(),
            svc.estimate_vecs(empty.clone(), empty.clone()).err(),
            svc.estimate_vecs(full.clone(), empty.clone()).err(),
        ] {
            match r {
                Some(crate::Error::Invalid(msg)) => {
                    assert!(msg.contains("empty vector"), "{msg}")
                }
                other => panic!("expected Invalid(empty vector), got {other:?}"),
            }
        }
        // one empty row poisons a whole batch before submission
        assert!(svc
            .insert_many(vec![full.clone(), empty.clone()])
            .is_err());
        let (_, store) = svc.stats();
        assert_eq!(store.stored, 0, "nothing slipped into the store");
        // non-empty traffic still works afterwards
        assert_eq!(svc.sketch(full).unwrap().len(), 64);
    }

    #[test]
    fn query_above_records_latency_like_query() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, (0..50).collect()).unwrap();
        svc.insert(v.clone()).unwrap();
        svc.query(v.clone(), 3).unwrap();
        svc.query_above(v, 0.5).unwrap();
        let (snap, _) = svc.stats();
        assert_eq!(snap.queries, 2);
        assert_eq!(
            snap.query_latency.count, 2,
            "query_above must contribute a query_latency sample"
        );
    }

    #[test]
    fn estimates_record_latency_like_queries() {
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let v = SparseVec::new(512, (0..60).collect()).unwrap();
        let w = SparseVec::new(512, (30..90).collect()).unwrap();
        let (ia, _) = svc.insert(v.clone()).unwrap();
        let (ib, _) = svc.insert(w.clone()).unwrap();
        svc.estimate_ids(ia, ib).unwrap();
        svc.estimate_vecs(v, w).unwrap();
        let (snap, _) = svc.stats();
        assert_eq!(snap.estimates, 2);
        assert_eq!(
            snap.estimate_latency.count, 2,
            "both estimate paths must contribute an estimate_latency sample"
        );
        assert!(snap.uptime_s >= 0.0);
    }

    #[test]
    fn batch_capacity_prevents_dense_overflow() {
        // Regression for the dense-fallback overflow: a sparse ladder
        // with a large batch dimension and a smaller dense fallback.
        // The old pump flushed at the largest sparse batch (64) and let
        // a heavy-row batch fall into the dense arm, where
        // `dense_b - n` = 8 - 64 wrapped and indexed out of bounds.
        let dense = Some(("dense_b8".to_string(), 8usize));
        let sparse = vec![
            ("sparse_b16_f32".to_string(), 16usize, 32usize),
            ("sparse_b64_f16".to_string(), 64usize, 16usize),
        ];
        // Heavy rows (nnz 20 > both F_max=16; <= F_max=32): the b=16
        // sparse variant and the dense fallback can take them.
        assert_eq!(batch_capacity(&dense, &sparse, 20), Some(16));
        // Rows too heavy for every sparse variant: dense only -> any
        // batch larger than 8 must split, never execute.
        assert_eq!(batch_capacity(&dense, &sparse, 40), Some(8));
        // Light rows: the full 64-row sparse batch is usable.
        assert_eq!(batch_capacity(&dense, &sparse, 10), Some(64));
        // No dense artifact and rows overflow every F_max: unservable.
        assert_eq!(batch_capacity(&None, &sparse, 40), None);
        // No dense artifact but a sparse variant fits: capacity is its
        // batch size (old code errored here blaming nonzeros).
        assert_eq!(batch_capacity(&None, &sparse, 20), Some(16));
        // The invariant the split loop enforces: chunks of `cap` rows
        // can never exceed the batch dimension of the arm they route
        // to, so the `batch_b - n` pad computation cannot wrap.
        for nnz in [0usize, 10, 20, 40] {
            if let Some(cap) = batch_capacity(&dense, &sparse, nnz) {
                let fits = sparse
                    .iter()
                    .any(|(_, b, f)| cap <= *b && nnz <= *f)
                    || dense.as_ref().is_some_and(|(_, b)| cap <= *b);
                assert!(fits, "cap {cap} unservable for nnz {nnz}");
            }
        }
    }

    #[test]
    fn scheme_knob_selects_the_hasher() {
        // Every scheme serves end to end on the Rust engine, and the
        // served sketch equals the scheme's direct hasher output.
        let v = SparseVec::new(512, vec![1, 99, 300]).unwrap();
        for scheme in SketchScheme::ALL {
            let mut cfg = rust_cfg();
            cfg.sketch.scheme = scheme;
            let svc = Coordinator::start(cfg.clone()).unwrap();
            let direct = scheme
                .build(cfg.dim, cfg.num_hashes, cfg.seed)
                .unwrap()
                .sketch_sparse(v.indices());
            assert_eq!(svc.sketch(v.clone()).unwrap(), direct, "{scheme}");
        }
    }

    #[test]
    fn bits_knob_packs_the_store_end_to_end() {
        // `sketch.bits` < 32: sketch responses stay full-width (the
        // engine is untouched), the store keeps packed rows, queries
        // stay exact on self-probes, and stats report the width and
        // the truthful per-item footprint.
        let mut cfg = rust_cfg();
        cfg.sketch.bits = 8;
        let svc = Coordinator::start(cfg.clone()).unwrap();
        let hasher = CMinHasher::new(cfg.dim, cfg.num_hashes, cfg.seed);
        let v = SparseVec::new(512, (0..50).collect()).unwrap();
        let (id, sk) = svc.insert(v.clone()).unwrap();
        assert_eq!(
            sk,
            hasher.sketch_sparse(v.indices()),
            "insert echoes the full-width sketch"
        );
        let hits = svc.query(v.clone(), 3).unwrap();
        assert_eq!(hits[0].id, id);
        assert_eq!(hits[0].score, 1.0);
        assert_eq!(svc.estimate_ids(id, id).unwrap(), 1.0);
        let (_, store) = svc.stats();
        assert_eq!(store.bits, 8);
        assert_eq!(store.sketch_bytes, 64, "64 lanes × 8 bits = 64 bytes");
        // an unsupported width is rejected at startup, not at runtime
        let mut bad = rust_cfg();
        bad.sketch.bits = 5;
        assert!(Coordinator::start(bad).is_err());
    }

    #[test]
    fn insert_packed_many_matches_client_side_sketching() {
        use crate::sketch::{pack_row, packed_words};
        // A client that sketches + packs locally and ships words must
        // land in exactly the state server-side sketching produces —
        // at a packed width and at full width.
        let vs: Vec<SparseVec> = (0..5u32)
            .map(|i| SparseVec::new(512, (i * 20..i * 20 + 50).collect()).unwrap())
            .collect();
        for bits in [8u8, 32] {
            let mut cfg = rust_cfg();
            cfg.sketch.bits = bits;
            let server_side = Coordinator::start(cfg.clone()).unwrap();
            let client_side = Coordinator::start(cfg.clone()).unwrap();
            server_side.insert_many(vs.clone()).unwrap();
            let hasher = cfg
                .sketch
                .scheme
                .build(cfg.dim, cfg.num_hashes, cfg.seed)
                .unwrap();
            let wpr = packed_words(cfg.num_hashes, bits);
            let rows: Vec<Vec<u64>> = vs
                .iter()
                .map(|v| {
                    let mut row = vec![0u64; wpr];
                    pack_row(&hasher.sketch_sparse(v.indices()), bits, &mut row);
                    row
                })
                .collect();
            let ids = client_side.insert_packed_many(rows.clone()).unwrap();
            assert_eq!(ids, (0..5).collect::<Vec<u64>>(), "bits={bits}");
            for v in &vs {
                assert_eq!(
                    client_side.query(v.clone(), 3).unwrap(),
                    server_side.query(v.clone(), 3).unwrap(),
                    "bits={bits}"
                );
            }
            // boundary validation: empty batch, bad width, dirty padding
            assert!(client_side.insert_packed_many(vec![]).is_err());
            assert!(client_side
                .insert_packed_many(vec![vec![0u64; wpr + 1]])
                .is_err());
            if bits == 8 {
                let mut dirty = rows[0].clone();
                *dirty.last_mut().unwrap() |= 1u64 << 63; // K*8=512 bits fill 8 words exactly… use a width that has padding
                // 64 lanes × 8 bits = 512 bits = 8 words exactly: no
                // padding exists, so the high bit is a legal lane bit
                // and the row must be accepted.
                assert!(client_side.insert_packed_many(vec![dirty]).is_ok());
            }
        }
        // a width with real padding: K=64 at bits=1 → 64 bits, still
        // exact… use K from a custom config to get a ragged tail
        let mut cfg = rust_cfg();
        cfg.dim = 512;
        cfg.num_hashes = 48; // 48 lanes × 8 bits = 384 bits → 6 words, no tail
        cfg.sketch.bits = 2; // 48 × 2 = 96 bits → 2 words, 32 padding bits
        cfg.index.bands = 12;
        cfg.index.rows_per_band = 4;
        let svc = Coordinator::start(cfg).unwrap();
        let mut dirty = vec![0u64; 2];
        dirty[1] = 1u64 << 40; // inside the 32 padding bits
        match svc.insert_packed_many(vec![dirty]) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("padding"), "{msg}")
            }
            other => panic!("expected Invalid(padding), got {other:?}"),
        }
        let (_, store) = svc.stats();
        assert_eq!(store.stored, 0, "dirty row never landed");
    }

    #[test]
    fn xla_engine_rejects_non_cmh_schemes() {
        let mut cfg = rust_cfg();
        cfg.engine = EngineKind::Xla;
        cfg.sketch.scheme = SketchScheme::Coph;
        match Coordinator::start(cfg) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("cmh") && msg.contains("coph"), "{msg}")
            }
            Err(other) => panic!("expected Invalid, got {other:?}"),
            Ok(_) => panic!("xla + coph must be rejected"),
        }
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        // One request against max_batch=4 must still complete (deadline).
        let svc = Coordinator::start(rust_cfg()).unwrap();
        let t = Instant::now();
        let v = SparseVec::new(512, vec![7]).unwrap();
        let sk = svc.sketch(v).unwrap();
        assert_eq!(sk.len(), 64);
        // Deadline is 500us; allow generous scheduling slack.
        assert!(t.elapsed() < Duration::from_millis(200));
    }
}
