//! Serving configuration: JSON file + CLI overrides.
//!
//! (The offline build has no TOML parser; configs are JSON — see
//! `configs/serve.json` for the annotated default.)

use crate::sketch::SketchScheme;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Which engine computes sketches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT XLA artifacts via PJRT (the production path).
    Xla,
    /// Pure-Rust hashers (fallback / baseline).
    Rust,
}

impl EngineKind {
    /// Parse "xla" | "rust".
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "xla" => Ok(EngineKind::Xla),
            "rust" => Ok(EngineKind::Rust),
            other => Err(crate::Error::Invalid(format!(
                "unknown engine {other:?} (xla|rust)"
            ))),
        }
    }
}

/// When a partial batch is executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Continuous batching (vLLM-style): flush whatever is queued as
    /// soon as the engine is free and no more requests are immediately
    /// available.  Self-regulating: batch size ≈ arrivals per engine
    /// execution.  The default.
    Eager,
    /// Wait up to `max_delay_us` for the batch to fill (classic
    /// deadline batching).  Kept for the §Perf ablation.
    Deadline,
}

impl BatchPolicy {
    /// Parse "eager" | "deadline".
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "eager" => Ok(BatchPolicy::Eager),
            "deadline" => Ok(BatchPolicy::Deadline),
            other => Err(crate::Error::Invalid(format!(
                "unknown batch policy {other:?} (eager|deadline)"
            ))),
        }
    }
}

/// Dynamic batcher settings.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Flush when this many requests are queued (also the padding
    /// target for the XLA artifact's fixed batch dimension).
    pub max_batch: usize,
    /// Flush a partial batch after this many microseconds
    /// (only with [`BatchPolicy::Deadline`]).
    pub max_delay_us: u64,
    /// Partial-batch policy.
    pub policy: BatchPolicy,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 64,
            max_delay_us: 2_000,
            policy: BatchPolicy::Eager,
        }
    }
}

/// Sketching-scheme settings (which hasher the service runs and how
/// wide the stored sketches are).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SketchSettings {
    /// The minwise-hashing scheme: `classic | cmh | zero-pi | oph |
    /// coph | iuh` (see `docs/SCHEMES.md`).  Sketches from different schemes
    /// are not comparable, so the scheme is stamped into snapshots and
    /// reported by the `stats` wire op.
    pub scheme: SketchScheme,
    /// Bits stored per hash in the serving plane: one of
    /// `1|2|4|8|16|32`.  32 (the default) keeps full `u32` lanes and
    /// the exact pre-b-bit behavior; smaller widths pack rows into a
    /// contiguous bit-matrix (32/b× less resident memory per sketch),
    /// score queries with the word-level XOR+popcount kernel through
    /// the unbiased b-bit correction, and persist/WAL-log packed rows.
    /// Stamped into snapshots and reported by `stats` like the scheme
    /// (see `docs/SCHEMES.md` §Sketch width).
    pub bits: u8,
}

impl Default for SketchSettings {
    fn default() -> Self {
        SketchSettings {
            scheme: SketchScheme::Cmh,
            bits: 32,
        }
    }
}

/// LSH index settings.
#[derive(Clone, Copy, Debug)]
pub struct IndexSettings {
    /// Number of bands.
    pub bands: usize,
    /// Rows per band.
    pub rows_per_band: usize,
}

impl Default for IndexSettings {
    fn default() -> Self {
        IndexSettings {
            bands: 32,
            rows_per_band: 4,
        }
    }
}

/// Sharding + persistence settings for the sketch store subsystem.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreSettings {
    /// Number of index shards; 0 = auto (largest power of two ≤ the
    /// machine's cores, capped at 8).
    pub shards: usize,
    /// Durability directory for the snapshot + write-ahead log;
    /// `None` disables persistence (sketches die with the process).
    pub persist_dir: Option<PathBuf>,
}

/// TCP server settings (connection admission).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerSettings {
    /// Size of the connection worker pool: at most this many
    /// connections are served concurrently.  Connection number
    /// `max_connections + 1` receives a clean `busy` protocol error
    /// and is closed instead of spawning an unbounded OS thread.
    pub max_connections: usize,
}

impl Default for ServerSettings {
    fn default() -> Self {
        ServerSettings {
            max_connections: 256,
        }
    }
}

/// Observability settings (per-request tracing knobs; see
/// `docs/OBSERVABILITY.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ObsSettings {
    /// Trace ring capacity: the last N completed requests keep their
    /// per-stage spans for the `trace` wire op.  `0` disables trace
    /// capture entirely (per-op counters still count) — the bench
    /// baseline for the `obs_overhead` gate.
    pub trace_ring: usize,
    /// Requests whose total latency reaches this many microseconds are
    /// flagged `slow` and pinned past ring churn.
    pub slow_threshold_us: u64,
    /// How many slow traces stay pinned (FIFO eviction beyond this).
    pub pinned: usize,
}

impl Default for ObsSettings {
    fn default() -> Self {
        ObsSettings {
            trace_ring: 256,
            slow_threshold_us: 10_000,
            pinned: 32,
        }
    }
}

/// Top-level serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP listen address.
    pub addr: String,
    /// Directory containing `manifest.json` + HLO artifacts.
    pub artifacts_dir: PathBuf,
    /// Engine selection.
    pub engine: EngineKind,
    /// Data dimensionality D the service accepts.
    pub dim: usize,
    /// Sketch length K.
    pub num_hashes: usize,
    /// Seed for permutation generation — the *only* hashing state.
    pub seed: u64,
    /// Sketch-scheme selection.
    pub sketch: SketchSettings,
    /// Batching.
    pub batch: BatchConfig,
    /// Index.
    pub index: IndexSettings,
    /// Store sharding + persistence.
    pub store: StoreSettings,
    /// Server connection admission.
    pub server: ServerSettings,
    /// Observability (request tracing).
    pub obs: ObsSettings,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            artifacts_dir: PathBuf::from("artifacts"),
            // Rust is the default so a bare `cminhash serve` works on a
            // fresh clone; xla requires `make artifacts` (and, in this
            // offline build, the real PJRT bindings — see runtime::xla).
            engine: EngineKind::Rust,
            dim: 4096,
            num_hashes: 256,
            seed: 42,
            sketch: SketchSettings::default(),
            batch: BatchConfig::default(),
            index: IndexSettings::default(),
            store: StoreSettings::default(),
            server: ServerSettings::default(),
            obs: ObsSettings::default(),
        }
    }
}

impl ServeConfig {
    /// Load from a JSON file; missing keys fall back to defaults.
    pub fn from_file(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Build from parsed JSON (partial objects allowed).
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let mut cfg = ServeConfig::default();
        if let Some(v) = j.get_opt("addr") {
            cfg.addr = v.as_str()?.to_string();
        }
        if let Some(v) = j.get_opt("artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = j.get_opt("engine") {
            cfg.engine = EngineKind::parse(v.as_str()?)?;
        }
        if let Some(v) = j.get_opt("dim") {
            cfg.dim = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("num_hashes") {
            cfg.num_hashes = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("seed") {
            cfg.seed = v.as_u64()?;
        }
        if let Some(sk) = j.get_opt("sketch") {
            if let Some(v) = sk.get_opt("scheme") {
                cfg.sketch.scheme = SketchScheme::parse(v.as_str()?)?;
            }
            if let Some(v) = sk.get_opt("bits") {
                let raw = v.as_u64()?;
                cfg.sketch.bits = u8::try_from(raw).map_err(|_| {
                    crate::Error::Invalid(format!(
                        "sketch.bits = {raw} out of range (1|2|4|8|16|32)"
                    ))
                })?;
            }
        }
        if let Some(b) = j.get_opt("batch") {
            if let Some(v) = b.get_opt("max_batch") {
                cfg.batch.max_batch = v.as_usize()?;
            }
            if let Some(v) = b.get_opt("max_delay_us") {
                cfg.batch.max_delay_us = v.as_u64()?;
            }
            if let Some(v) = b.get_opt("policy") {
                cfg.batch.policy = BatchPolicy::parse(v.as_str()?)?;
            }
        }
        if let Some(ix) = j.get_opt("index") {
            if let Some(v) = ix.get_opt("bands") {
                cfg.index.bands = v.as_usize()?;
            }
            if let Some(v) = ix.get_opt("rows_per_band") {
                cfg.index.rows_per_band = v.as_usize()?;
            }
        }
        if let Some(st) = j.get_opt("store") {
            if let Some(v) = st.get_opt("shards") {
                cfg.store.shards = v.as_usize()?;
            }
            if let Some(v) = st.get_opt("persist_dir") {
                cfg.store.persist_dir = match v {
                    Json::Null => None,
                    other => Some(PathBuf::from(other.as_str()?)),
                };
            }
        }
        if let Some(sv) = j.get_opt("server") {
            if let Some(v) = sv.get_opt("max_connections") {
                cfg.server.max_connections = v.as_usize()?;
            }
        }
        if let Some(ob) = j.get_opt("obs") {
            if let Some(v) = ob.get_opt("trace_ring") {
                cfg.obs.trace_ring = v.as_usize()?;
            }
            if let Some(v) = ob.get_opt("slow_threshold_us") {
                cfg.obs.slow_threshold_us = v.as_u64()?;
            }
            if let Some(v) = ob.get_opt("pinned") {
                cfg.obs.pinned = v.as_usize()?;
            }
        }
        Ok(cfg)
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> crate::Result<()> {
        if self.num_hashes == 0 || self.num_hashes > self.dim {
            return Err(crate::Error::Invalid(format!(
                "need 1 <= K <= D, got K={}, D={}",
                self.num_hashes, self.dim
            )));
        }
        // Scheme-specific shape constraints (the OPH family needs K | D).
        self.sketch.scheme.validate(self.dim, self.num_hashes)?;
        // Storage-width constraint: lanes must tile u64 words.
        crate::sketch::check_sketch_bits(self.sketch.bits)?;
        if self.index.bands * self.index.rows_per_band > self.num_hashes {
            return Err(crate::Error::Invalid(format!(
                "bands({}) * rows({}) > K({})",
                self.index.bands, self.index.rows_per_band, self.num_hashes
            )));
        }
        if self.batch.max_batch == 0 {
            return Err(crate::Error::Invalid("max_batch must be > 0".into()));
        }
        if self.store.shards > 1024 {
            return Err(crate::Error::Invalid(format!(
                "store.shards = {} is absurd (max 1024)",
                self.store.shards
            )));
        }
        if self.server.max_connections == 0 {
            return Err(crate::Error::Invalid(
                "server.max_connections must be > 0".into(),
            ));
        }
        if self.server.max_connections > 16_384 {
            return Err(crate::Error::Invalid(format!(
                "server.max_connections = {} is absurd (max 16384; each \
                 connection holds one pool worker)",
                self.server.max_connections
            )));
        }
        if self.obs.trace_ring > 65_536 {
            return Err(crate::Error::Invalid(format!(
                "obs.trace_ring = {} is absurd (max 65536; each slot \
                 preallocates a trace)",
                self.obs.trace_ring
            )));
        }
        if self.obs.pinned > 4_096 {
            return Err(crate::Error::Invalid(format!(
                "obs.pinned = {} is absurd (max 4096)",
                self.obs.pinned
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;

    #[test]
    fn defaults_validate() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn json_partial_config_merges_with_defaults() {
        let dir = TempDir::new().unwrap();
        let p = dir.path().join("serve.json");
        std::fs::write(
            &p,
            r#"{
              "addr": "0.0.0.0:9000",
              "engine": "rust",
              "dim": 1024,
              "num_hashes": 128,
              "batch": {"max_batch": 8}
            }"#,
        )
        .unwrap();
        let c = ServeConfig::from_file(&p).unwrap();
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!(c.engine, EngineKind::Rust);
        assert_eq!(c.dim, 1024);
        assert_eq!(c.batch.max_batch, 8);
        assert_eq!(c.batch.max_delay_us, 2_000, "default preserved");
        c.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_k_and_bands() {
        let mut c = ServeConfig::default();
        c.num_hashes = c.dim + 1;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.index.bands = 1000;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.batch.max_batch = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn batch_policy_parse_and_config() {
        assert_eq!(BatchPolicy::parse("eager").unwrap(), BatchPolicy::Eager);
        assert_eq!(
            BatchPolicy::parse("deadline").unwrap(),
            BatchPolicy::Deadline
        );
        assert!(BatchPolicy::parse("yolo").is_err());
        let j = crate::util::json::Json::parse(
            r#"{"batch": {"policy": "deadline", "max_delay_us": 77}}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.batch.policy, BatchPolicy::Deadline);
        assert_eq!(c.batch.max_delay_us, 77);
    }

    #[test]
    fn store_settings_parse_and_default() {
        let c = ServeConfig::default();
        assert_eq!(c.store.shards, 0, "auto by default");
        assert!(c.store.persist_dir.is_none(), "in-memory by default");
        let j = crate::util::json::Json::parse(
            r#"{"store": {"shards": 4, "persist_dir": "data/sketches"}}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.store.shards, 4);
        assert_eq!(
            c.store.persist_dir,
            Some(PathBuf::from("data/sketches"))
        );
        // explicit null turns persistence off
        let j = crate::util::json::Json::parse(r#"{"store": {"persist_dir": null}}"#).unwrap();
        assert!(ServeConfig::from_json(&j).unwrap().store.persist_dir.is_none());
        // absurd shard counts are rejected
        let mut c = ServeConfig::default();
        c.store.shards = 100_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn server_settings_parse_and_validate() {
        let c = ServeConfig::default();
        assert_eq!(c.server.max_connections, 256, "pool default");
        let j = crate::util::json::Json::parse(r#"{"server": {"max_connections": 2}}"#)
            .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.server.max_connections, 2);
        c.validate().unwrap();
        let mut c = ServeConfig::default();
        c.server.max_connections = 0;
        assert!(c.validate().is_err(), "a zero-worker pool can serve nobody");
        c.server.max_connections = 1_000_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn sketch_bits_parse_and_validate() {
        let c = ServeConfig::default();
        assert_eq!(c.sketch.bits, 32, "full width is the default");
        let j = crate::util::json::Json::parse(r#"{"sketch": {"bits": 8}}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.sketch.bits, 8);
        c.validate().unwrap();
        // every supported width validates; anything else is rejected
        for bits in crate::sketch::SUPPORTED_BITS {
            let mut c = ServeConfig::default();
            c.sketch.bits = bits;
            c.validate().unwrap();
        }
        for bits in [0u8, 3, 7, 12, 24, 33] {
            let mut c = ServeConfig::default();
            c.sketch.bits = bits;
            assert!(c.validate().is_err(), "bits={bits}");
        }
        // out-of-range JSON values fail at parse time with a clean error
        let j =
            crate::util::json::Json::parse(r#"{"sketch": {"bits": 4096}}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn sketch_scheme_parse_and_validate() {
        let c = ServeConfig::default();
        assert_eq!(c.sketch.scheme, SketchScheme::Cmh, "cmh is the default");
        let j = crate::util::json::Json::parse(r#"{"sketch": {"scheme": "coph"}}"#)
            .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.sketch.scheme, SketchScheme::Coph);
        c.validate().unwrap();
        // unknown scheme names fail at parse time
        let j = crate::util::json::Json::parse(r#"{"sketch": {"scheme": "md5"}}"#)
            .unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
        // the OPH family's divisibility constraint is enforced
        let mut c = ServeConfig::default();
        c.sketch.scheme = SketchScheme::Oph;
        c.dim = 4096;
        c.num_hashes = 100; // 100 does not divide 4096
        c.index.bands = 10;
        c.index.rows_per_band = 10;
        match c.validate() {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("divide"), "{msg}")
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
        c.sketch.scheme = SketchScheme::Cmh;
        c.validate().unwrap();
    }

    #[test]
    fn obs_settings_parse_and_validate() {
        let c = ServeConfig::default();
        assert_eq!(c.obs.trace_ring, 256, "tracing on by default");
        assert_eq!(c.obs.slow_threshold_us, 10_000);
        assert_eq!(c.obs.pinned, 32);
        let j = crate::util::json::Json::parse(
            r#"{"obs": {"trace_ring": 0, "slow_threshold_us": 500, "pinned": 8}}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.obs.trace_ring, 0, "0 turns tracing off");
        assert_eq!(c.obs.slow_threshold_us, 500);
        assert_eq!(c.obs.pinned, 8);
        c.validate().unwrap();
        let mut c = ServeConfig::default();
        c.obs.trace_ring = 1_000_000;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.obs.pinned = 1_000_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("xla").unwrap(), EngineKind::Xla);
        assert_eq!(EngineKind::parse("rust").unwrap(), EngineKind::Rust);
        assert!(EngineKind::parse("gpu").is_err());
    }
}
