//! Regeneration of every figure in the paper's evaluation (§3–§4).
//!
//! Each `figN` function writes one CSV with the exact series the paper
//! plots; `cargo run --release -- figures --all` regenerates the full
//! evaluation, and the criterion benches time the underlying kernels.
//! EXPERIMENTS.md records paper-vs-measured for each.

use crate::data::CorpusKind;
use crate::sketch::{
    estimate, CMinHasher, ClassicMinHasher, Perm, Sketcher, ZeroPiHasher,
};
use crate::theory::{
    e_tilde, var_minhash, var_sigma_pi, var_zero_pi, variance_ratio, LocationVector,
};
use crate::util::rng::Rng;
use std::io::Write;
use std::path::Path;

fn write_csv(path: &Path, header: &str, rows: &[String]) -> crate::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

/// Figure 2: Var[Ĵ_{σ,π}] and Var[Ĵ_MH] versus J, D = 1000,
/// f ∈ {200, 500, 800}, K ∈ {500, 800}.
pub fn fig2(out_dir: &Path) -> crate::Result<()> {
    let d = 1000;
    let mut rows = Vec::new();
    for &k in &[500usize, 800] {
        for &f in &[200usize, 500, 800] {
            for a in (1..f).step_by((f / 50).max(1)) {
                let j = a as f64 / f as f64;
                rows.push(format!(
                    "{k},{f},{a},{j},{},{}",
                    var_sigma_pi(d, f, a, k),
                    var_minhash(j, k)
                ));
            }
        }
    }
    write_csv(
        &out_dir.join("fig2_variance_vs_j.csv"),
        "K,f,a,J,var_sigma_pi,var_minhash",
        &rows,
    )
}

/// Figure 3: Ẽ versus D for f = 10 and f = 30 (several a per panel),
/// with the J² asymptote.
pub fn fig3(out_dir: &Path) -> crate::Result<()> {
    let mut rows = Vec::new();
    for &(f, aa) in &[(10usize, [2usize, 5, 8]), (30, [5, 15, 25])] {
        for &a in &aa {
            let j2 = (a as f64 / f as f64).powi(2);
            let mut dd = f;
            while dd <= 5000 {
                rows.push(format!("{f},{a},{dd},{},{j2}", e_tilde(dd, f, a)));
                dd = (dd as f64 * 1.3).ceil() as usize;
            }
        }
    }
    write_csv(
        &out_dir.join("fig3_etilde_vs_d.csv"),
        "f,a,D,e_tilde,j_squared",
        &rows,
    )
}

/// Figure 4: variance ratio Var[Ĵ_MH]/Var[Ĵ_{σ,π}] versus J for
/// D = 1000, K = 800 — constant in a (Proposition 3.5).
pub fn fig4(out_dir: &Path) -> crate::Result<()> {
    let (d, k) = (1000usize, 800usize);
    let mut rows = Vec::new();
    for &f in &[200usize, 500, 800] {
        for a in (1..f).step_by((f / 40).max(1)) {
            let j = a as f64 / f as f64;
            if let Some(r) = variance_ratio(d, f, a, k) {
                rows.push(format!("{f},{a},{j},{r}"));
            }
        }
    }
    write_csv(&out_dir.join("fig4_ratio_vs_j.csv"), "f,a,J,ratio", &rows)
}

/// Figure 5: variance ratio versus f for D ∈ {500, 1000} and
/// K ∈ {100, 200, 400, 800} (a = f/2; Prop 3.5 makes the choice moot).
pub fn fig5(out_dir: &Path) -> crate::Result<()> {
    let mut rows = Vec::new();
    for &d in &[500usize, 1000] {
        for &k in &[100usize, 200, 400, 800] {
            if k > d {
                continue;
            }
            let mut f = 20usize;
            while f <= d {
                let a = (f / 2).max(1);
                if let Some(r) = variance_ratio(d, f, a, k) {
                    rows.push(format!("{d},{k},{f},{r}"));
                }
                f += (d / 25).max(10);
            }
        }
    }
    write_csv(&out_dir.join("fig5_ratio_vs_f.csv"), "D,K,f,ratio", &rows)
}

/// One empirical MSE measurement: `reps` draws of fresh (σ, π) (and, for
/// MinHash, K fresh permutations), estimating J of the fixed pair.
// Figure drivers are offline batch jobs: the permutation values are
// Fisher–Yates shuffles of 0..d (valid by construction) and an unknown
// method name is a caller bug — crashing beats emitting a bogus CSV.
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
fn empirical_mse(
    method: &str,
    x: &LocationVector,
    k: usize,
    reps: usize,
    seed: u64,
) -> f64 {
    let d = x.d();
    let (v, w) = x.realize();
    let truth = x.jaccard();
    let mut rng = Rng::seed_from_u64(seed);
    let mut sq = 0.0f64;
    let mut perm_vals: Vec<u32> = (0..d as u32).collect();
    for _ in 0..reps {
        let est = match method {
            "minhash" => {
                let rows: Vec<Perm> = (0..k)
                    .map(|_| {
                        rng.shuffle(&mut perm_vals);
                        Perm::from_values(perm_vals.clone()).unwrap()
                    })
                    .collect();
                let h = ClassicMinHasher::from_perms(&rows).unwrap();
                estimate(&h.sketch_sparse(v.indices()), &h.sketch_sparse(w.indices()))
            }
            "cminhash_0pi" => {
                rng.shuffle(&mut perm_vals);
                let pi = Perm::from_values(perm_vals.clone()).unwrap();
                let h = ZeroPiHasher::from_perm(k, &pi).unwrap();
                estimate(&h.sketch_sparse(v.indices()), &h.sketch_sparse(w.indices()))
            }
            "cminhash_sigma_pi" => {
                rng.shuffle(&mut perm_vals);
                let sigma = Perm::from_values(perm_vals.clone()).unwrap();
                rng.shuffle(&mut perm_vals);
                let pi = Perm::from_values(perm_vals.clone()).unwrap();
                let h = CMinHasher::from_perms(k, &sigma, &pi).unwrap();
                estimate(&h.sketch_sparse(v.indices()), &h.sketch_sparse(w.indices()))
            }
            other => panic!("unknown method {other}"),
        };
        sq += (est - truth) * (est - truth);
    }
    sq / reps as f64
}

/// Figure 6: empirical vs theoretical MSE on §4.1's structured pairs,
/// D = 128, several (f, a), K sweep, all three methods.
pub fn fig6(out_dir: &Path, reps: usize) -> crate::Result<()> {
    let d = 128usize;
    let mut rows = Vec::new();
    for &(f, a) in &[(32usize, 8usize), (32, 16), (64, 16), (64, 32), (96, 48)] {
        let x = LocationVector::contiguous(d, f, a);
        let j = x.jaccard();
        for &k in &[8usize, 16, 32, 64, 128] {
            let theo = [
                ("minhash", var_minhash(j, k)),
                ("cminhash_0pi", var_zero_pi(&x, k)),
                ("cminhash_sigma_pi", var_sigma_pi(d, f, a, k)),
            ];
            for (method, tvar) in theo {
                let emp = empirical_mse(method, &x, k, reps, 1234 + k as u64);
                rows.push(format!("{f},{a},{k},{method},{emp},{tvar}"));
            }
        }
    }
    write_csv(
        &out_dir.join("fig6_simulation.csv"),
        "f,a,K,method,empirical_mse,theoretical_var",
        &rows,
    )
}

/// Figure 7: all-pairs MAE versus K on the four §4.2 corpus stand-ins,
/// all three methods, `reps` independent repetitions.
pub fn fig7(out_dir: &Path, n_docs: usize, reps: usize) -> crate::Result<()> {
    let mut rows = Vec::new();
    for kind in CorpusKind::all() {
        let corpus = kind.generate(n_docs, 99);
        let d = corpus.dim() as usize;
        // Exact Jaccard ground truth once per corpus.
        let docs = corpus.rows();
        let mut truths = Vec::new();
        for i in 0..docs.len() {
            for j in (i + 1)..docs.len() {
                truths.push(docs[i].jaccard(&docs[j]));
            }
        }
        for &k in &[64usize, 128, 256, 512] {
            if k > d {
                continue;
            }
            for method in ["minhash", "cminhash_0pi", "cminhash_sigma_pi"] {
                let mut mae_acc = 0.0f64;
                for rep in 0..reps {
                    let seed = 1000 * rep as u64 + k as u64;
                    let sketcher: Box<dyn Sketcher> = match method {
                        "minhash" => Box::new(ClassicMinHasher::new(d, k, seed)),
                        "cminhash_0pi" => Box::new(ZeroPiHasher::new(d, k, seed)),
                        _ => Box::new(CMinHasher::new(d, k, seed)),
                    };
                    let sketches: Vec<Vec<u32>> = docs
                        .iter()
                        .map(|r| sketcher.sketch_sparse(r.indices()))
                        .collect();
                    let mut err = 0.0;
                    let mut t = 0usize;
                    for i in 0..docs.len() {
                        for j in (i + 1)..docs.len() {
                            err += (estimate(&sketches[i], &sketches[j]) - truths[t]).abs();
                            t += 1;
                        }
                    }
                    mae_acc += err / truths.len() as f64;
                }
                rows.push(format!(
                    "{},{d},{k},{method},{}",
                    kind.name(),
                    mae_acc / reps as f64
                ));
            }
        }
    }
    write_csv(
        &out_dir.join("fig7_real_data.csv"),
        "dataset,D,K,method,mae",
        &rows,
    )
}

/// Run one figure (2–7) or all of them.
pub fn run(fig: Option<u32>, out_dir: &Path, fast: bool) -> crate::Result<()> {
    let (reps6, docs7, reps7) = if fast { (300, 24, 2) } else { (2000, 48, 10) };
    let all = fig.is_none();
    let want = |n: u32| all || fig == Some(n);
    if want(2) {
        fig2(out_dir)?;
        println!("fig2 -> {}", out_dir.join("fig2_variance_vs_j.csv").display());
    }
    if want(3) {
        fig3(out_dir)?;
        println!("fig3 -> {}", out_dir.join("fig3_etilde_vs_d.csv").display());
    }
    if want(4) {
        fig4(out_dir)?;
        println!("fig4 -> {}", out_dir.join("fig4_ratio_vs_j.csv").display());
    }
    if want(5) {
        fig5(out_dir)?;
        println!("fig5 -> {}", out_dir.join("fig5_ratio_vs_f.csv").display());
    }
    if want(6) {
        fig6(out_dir, reps6)?;
        println!("fig6 -> {}", out_dir.join("fig6_simulation.csv").display());
    }
    if want(7) {
        fig7(out_dir, docs7, reps7)?;
        println!("fig7 -> {}", out_dir.join("fig7_real_data.csv").display());
    }
    Ok(())
}

/// Deterministic mini-workload used by tests: checks the qualitative
/// Figure 7 ordering (σ,π beats MinHash on average; 0,π hurts on
/// image-structured data) on a small corpus.
pub fn fig7_orderings(n_docs: usize, k: usize, reps: usize) -> (f64, f64, f64) {
    let corpus = CorpusKind::ImageMnist.generate(n_docs, 5);
    let d = corpus.dim() as usize;
    let docs = corpus.rows();
    let mut maes = [0.0f64; 3];
    for rep in 0..reps {
        let seed = rep as u64 * 31 + 1;
        let sketchers: [Box<dyn Sketcher>; 3] = [
            Box::new(ClassicMinHasher::new(d, k, seed)),
            Box::new(ZeroPiHasher::new(d, k, seed)),
            Box::new(CMinHasher::new(d, k, seed)),
        ];
        for (m, sk) in sketchers.iter().enumerate() {
            let sketches: Vec<Vec<u32>> =
                docs.iter().map(|r| sk.sketch_sparse(r.indices())).collect();
            let mut err = 0.0;
            let mut n = 0usize;
            for i in 0..docs.len() {
                for j in (i + 1)..docs.len() {
                    err += (estimate(&sketches[i], &sketches[j]) - docs[i].jaccard(&docs[j])).abs();
                    n += 1;
                }
            }
            maes[m] += err / n as f64;
        }
    }
    (
        maes[0] / reps as f64, // minhash
        maes[1] / reps as f64, // 0,pi
        maes[2] / reps as f64, // sigma,pi
    )
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;

    #[test]
    fn fig2_csv_has_expected_series() {
        let dir = TempDir::new().unwrap();
        fig2(dir.path()).unwrap();
        let text = std::fs::read_to_string(dir.path().join("fig2_variance_vs_j.csv")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() > 100);
        assert_eq!(lines[0], "K,f,a,J,var_sigma_pi,var_minhash");
        // every data row: var_sigma_pi < var_minhash (Thm 3.4)
        for l in &lines[1..] {
            let cols: Vec<f64> = l.split(',').map(|c| c.parse().unwrap()).collect();
            assert!(cols[4] < cols[5], "{l}");
        }
    }

    #[test]
    fn fig3_curves_increase_and_stay_below_j2() {
        let dir = TempDir::new().unwrap();
        fig3(dir.path()).unwrap();
        let text = std::fs::read_to_string(dir.path().join("fig3_etilde_vs_d.csv")).unwrap();
        for l in text.lines().skip(1) {
            let c: Vec<f64> = l.split(',').map(|x| x.parse().unwrap()).collect();
            assert!(c[3] < c[4] + 1e-12, "e_tilde >= J^2: {l}");
        }
    }

    #[test]
    fn fig7_qualitative_ordering() {
        let (mh, zero_pi, sigma_pi) = fig7_orderings(16, 128, 3);
        assert!(
            sigma_pi < mh,
            "C-MinHash-(σ,π) must beat MinHash: {sigma_pi} vs {mh}"
        );
        assert!(
            zero_pi > sigma_pi,
            "(0,π) must be worse than (σ,π) on structured images: {zero_pi} vs {sigma_pi}"
        );
    }
}
