//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the request path.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`) and
//! `Literal` wraps raw XLA pointers, so the engine lives on a dedicated
//! OS thread ([`EngineHandle::spawn`]) and speaks a plain-data protocol
//! ([`HostTensor`]) over channels; everything else in the process stays
//! `Send + Sync`.  This also gives the batcher a natural serialization
//! point: XLA CPU already parallelizes *inside* an execution.
//!
//! In the offline build the PJRT bindings are replaced by the in-tree
//! [`xla`] stub module, which compiles the same engine code but reports
//! a clear "not available" error at start-up; the pure-Rust engine
//! remains the fully supported path.

mod artifact;
mod engine;
pub mod xla;

pub use artifact::{ArtifactMeta, Manifest, TensorSpec};
pub use engine::{EngineHandle, HostTensor, XlaEngine};
