//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The production deployment links the real `xla` crate (PJRT CPU
//! client + `xla_extension`), which cannot be vendored into this
//! dependency-free offline build.  This module mirrors the exact
//! slice of its API that [`super::XlaEngine`] uses, so the engine code
//! compiles unchanged; every runtime entry point reports
//! [`Error::unavailable`] instead of executing.
//!
//! Behavioral contract:
//!
//! * [`PjRtClient::cpu`] fails first, so an `--engine xla` server
//!   start-up degrades into one clear error ("XLA runtime not
//!   available in this build") rather than a partial engine.
//! * The pure-Rust engine (`--engine rust`, [`crate::sketch`]) is the
//!   fully supported path and is bit-identical to the artifacts by
//!   construction (see `rust/tests/golden.rs`).
//! * The XLA integration tests (`runtime_roundtrip.rs`,
//!   `pipeline_consistency.rs`) gate on `artifacts/manifest.json` and
//!   self-skip when `make artifacts` has not produced it.
//!
//! Swapping the real crate back in is a one-line change: delete this
//! module, add the `xla` dependency, and drop the `use super::xla`
//! alias in `engine.rs`.

use std::fmt;

/// Error type mirroring `xla::Error`.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    /// The single error this stub ever produces.
    pub fn unavailable() -> Self {
        Error {
            msg: "XLA runtime not available in this build (offline stub); \
                  use the pure-Rust engine (`--engine rust`)"
                .to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Result alias mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}

impl NativeType for i32 {}
impl NativeType for f32 {}

/// Host-side tensor value (mirrors `xla::Literal`).
#[derive(Debug, Default)]
pub struct Literal {}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal {}
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable())
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (mirrors `xla::HloModuleProto`).
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Parse an HLO text file (as written by `python/compile/aot.py`).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// An XLA computation ready for compilation (mirrors
/// `xla::XlaComputation`).
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device-resident result buffer (mirrors `xla::PjRtBuffer`).
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    /// Transfer the buffer back to a host [`Literal`].
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Compiled executable (mirrors `xla::PjRtLoadedExecutable`).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device,
    /// per-output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// PJRT client handle (mirrors `xla::PjRtClient`).
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    /// Create the CPU client.  Always fails in the offline stub — and
    /// fails *first* in [`super::XlaEngine::load`], so nothing else in
    /// this module is ever reached at runtime.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    /// PJRT platform name (diagnostics).
    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_and_loud() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
        assert!(Literal::default().to_vec::<i32>().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn stub_error_converts_into_crate_error() {
        let e: crate::Error = Error::unavailable().into();
        assert!(matches!(e, crate::Error::Xla(_)));
        assert!(e.to_string().contains("xla"));
    }
}
