//! The XLA execution engine and its thread-safe handle.
//!
//! [`XlaEngine`] owns the PJRT CPU client and one compiled executable
//! per artifact (compiled eagerly at startup so the serving path never
//! pays compile latency).  [`EngineHandle::spawn`] moves the engine onto
//! a dedicated thread and exposes a `Send + Clone` request API over
//! channels, with [`HostTensor`] as the plain-data interchange type.

use super::artifact::{ArtifactMeta, Manifest};
use super::xla;
use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc as std_mpsc;

/// A host-side tensor crossing the engine-thread boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    /// Signed 32-bit tensor (bits, permutations, hashes).
    I32(Vec<i32>),
    /// 32-bit float tensor (estimates).
    F32(Vec<f32>),
}

impl HostTensor {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::I32(v) => v.len(),
            HostTensor::F32(v) => v.len(),
        }
    }

    /// True iff no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Unwrap as i32 data.
    pub fn as_i32(&self) -> crate::Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            HostTensor::F32(_) => Err(crate::Error::Invalid("expected i32 tensor".into())),
        }
    }

    /// Unwrap as f32 data.
    pub fn as_f32(&self) -> crate::Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            HostTensor::I32(_) => Err(crate::Error::Invalid("expected f32 tensor".into())),
        }
    }
}

/// The engine proper — **not** `Send`; lives on one thread.
pub struct XlaEngine {
    manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaEngine {
    /// Load the manifest and compile every artifact on the CPU PJRT
    /// client.
    pub fn load(artifacts_dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for name in manifest.artifacts.keys() {
            let path = manifest.hlo_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| crate::Error::Manifest("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(name.clone(), exe);
        }
        Ok(XlaEngine {
            manifest,
            client,
            executables,
        })
    }

    /// The manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn check_inputs(meta: &ArtifactMeta, inputs: &[HostTensor]) -> crate::Result<()> {
        if inputs.len() != meta.inputs.len() {
            return Err(crate::Error::ShapeMismatch {
                what: "input count",
                expected: meta.inputs.len(),
                got: inputs.len(),
            });
        }
        for (spec, t) in meta.inputs.iter().zip(inputs) {
            if t.len() != spec.elements() {
                return Err(crate::Error::ShapeMismatch {
                    what: "input elements",
                    expected: spec.elements(),
                    got: t.len(),
                });
            }
            let ok = matches!(
                (spec.dtype.as_str(), t),
                ("s32", HostTensor::I32(_)) | ("f32", HostTensor::F32(_))
            );
            if !ok {
                return Err(crate::Error::Invalid(format!(
                    "dtype mismatch for {}: manifest says {}",
                    spec.name, spec.dtype
                )));
            }
        }
        Ok(())
    }

    /// Execute `variant` with the given inputs; returns one tensor per
    /// manifest output.
    pub fn execute(&self, variant: &str, inputs: &[HostTensor]) -> crate::Result<Vec<HostTensor>> {
        let meta = self.manifest.get(variant)?;
        Self::check_inputs(meta, inputs)?;
        let exe = self
            .executables
            .get(variant)
            .ok_or_else(|| crate::Error::UnknownArtifact(variant.to_string()))?;
        let literals: Vec<xla::Literal> = meta
            .inputs
            .iter()
            .zip(inputs)
            .map(|(spec, t)| {
                let lit = match t {
                    HostTensor::I32(v) => xla::Literal::vec1(v),
                    HostTensor::F32(v) => xla::Literal::vec1(v),
                };
                lit.reshape(&spec.dims_i64()).map_err(crate::Error::from)
            })
            .collect::<crate::Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            return Err(crate::Error::Xla(format!(
                "expected {} outputs, got {}",
                meta.outputs.len(),
                parts.len()
            )));
        }
        meta.outputs
            .iter()
            .zip(parts)
            .map(|(spec, lit)| {
                let out = match spec.dtype.as_str() {
                    "s32" => HostTensor::I32(lit.to_vec::<i32>()?),
                    "f32" => HostTensor::F32(lit.to_vec::<f32>()?),
                    other => {
                        return Err(crate::Error::Manifest(format!(
                            "unsupported output dtype {other}"
                        )))
                    }
                };
                if out.len() != spec.elements() {
                    return Err(crate::Error::Xla(format!(
                        "output {} has {} elements, expected {}",
                        spec.name,
                        out.len(),
                        spec.elements()
                    )));
                }
                Ok(out)
            })
            .collect()
    }
}

enum EngineMsg {
    Execute {
        variant: String,
        inputs: Vec<HostTensor>,
        resp: std_mpsc::SyncSender<crate::Result<Vec<HostTensor>>>,
    },
    Shutdown,
}

/// Thread-safe handle to an [`XlaEngine`] running on its own thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: std_mpsc::Sender<EngineMsg>,
    manifest: std::sync::Arc<Manifest>,
}

impl EngineHandle {
    /// Spawn the engine thread; fails fast if artifacts cannot be
    /// loaded/compiled.
    pub fn spawn(artifacts_dir: &Path) -> crate::Result<Self> {
        let (tx, rx) = std_mpsc::channel::<EngineMsg>();
        let (ready_tx, ready_rx) = std_mpsc::channel::<crate::Result<Manifest>>();
        let dir = artifacts_dir.to_path_buf();
        std::thread::Builder::new()
            .name("xla-engine".into())
            .spawn(move || {
                let engine = match XlaEngine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.manifest().clone()));
                        e
                    }
                    Err(err) => {
                        let _ = ready_tx.send(Err(err));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        EngineMsg::Execute {
                            variant,
                            inputs,
                            resp,
                        } => {
                            let _ = resp.send(engine.execute(&variant, &inputs));
                        }
                        EngineMsg::Shutdown => break,
                    }
                }
            })
            .map_err(crate::Error::Io)?;
        let manifest = ready_rx
            .recv()
            .map_err(|_| crate::Error::Shutdown)??;
        Ok(EngineHandle {
            tx,
            manifest: std::sync::Arc::new(manifest),
        })
    }

    /// Manifest of the spawned engine.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute on the engine thread and wait for the result.
    pub fn execute(
        &self,
        variant: &str,
        inputs: Vec<HostTensor>,
    ) -> crate::Result<Vec<HostTensor>> {
        let (resp, rx) = std_mpsc::sync_channel(1);
        self.tx
            .send(EngineMsg::Execute {
                variant: variant.to_string(),
                inputs,
                resp,
            })
            .map_err(|_| crate::Error::Shutdown)?;
        rx.recv().map_err(|_| crate::Error::Shutdown)?
    }

    /// Ask the engine thread to exit once queued work drains.
    pub fn shutdown(&self) {
        let _ = self.tx.send(EngineMsg::Shutdown);
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::I32(vec![1, 2, 3]);
        assert_eq!(t.len(), 3);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
        let t = HostTensor::F32(vec![]);
        assert!(t.is_empty());
        assert!(t.as_f32().is_ok());
    }
    // Engine execution is covered by rust/tests/runtime_roundtrip.rs,
    // which needs real artifacts (`make artifacts`).
}
