//! `artifacts/manifest.json` parsing and shape bookkeeping.
//!
//! The manifest is written by `python/compile/aot.py` alongside the HLO
//! text files; it is the single source of truth for what the compiled
//! executables accept and return, and the runtime type-checks every
//! request against it.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor's shape/dtype as recorded by the AOT pipeline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Logical name ("bits", "sigma", "pi2", …).
    pub name: String,
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// "s32" or "f32".
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Dims as i64 (the `Literal::reshape` argument type).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// HLO text file name, relative to the artifacts dir.
    pub file: String,
    /// Input tensors, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensors, in tuple order.
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Format tag; this crate understands "hlo-text-v1".
    pub format: String,
    /// Artifact name → metadata.
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

fn tensor_from_json(j: &Json) -> crate::Result<TensorSpec> {
    Ok(TensorSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape: j.get("shape")?.as_usize_vec()?,
        dtype: j.get("dtype")?.as_str()?.to_string(),
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            crate::Error::Manifest(format!("cannot read {}: {e}", path.display()))
        })?;
        let j = Json::parse(&text)
            .map_err(|e| crate::Error::Manifest(format!("bad manifest: {e}")))?;
        let format = j.get("format")?.as_str()?.to_string();
        let mut artifacts = BTreeMap::new();
        if let Json::Obj(map) = j.get("artifacts")? {
            for (name, meta) in map {
                artifacts.insert(
                    name.clone(),
                    ArtifactMeta {
                        file: meta.get("file")?.as_str()?.to_string(),
                        inputs: meta
                            .get("inputs")?
                            .as_arr()?
                            .iter()
                            .map(tensor_from_json)
                            .collect::<crate::Result<_>>()?,
                        outputs: meta
                            .get("outputs")?
                            .as_arr()?
                            .iter()
                            .map(tensor_from_json)
                            .collect::<crate::Result<_>>()?,
                    },
                );
            }
        } else {
            return Err(crate::Error::Manifest("artifacts must be an object".into()));
        }
        let m = Manifest {
            format,
            artifacts,
            dir: dir.to_path_buf(),
        };
        if m.format != "hlo-text-v1" {
            return Err(crate::Error::Manifest(format!(
                "unsupported manifest format {:?}",
                m.format
            )));
        }
        for (name, meta) in &m.artifacts {
            if !dir.join(&meta.file).exists() {
                return Err(crate::Error::Manifest(format!(
                    "artifact file missing for {name}: {}",
                    meta.file
                )));
            }
        }
        Ok(m)
    }

    /// Metadata for `name`.
    pub fn get(&self, name: &str) -> crate::Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| crate::Error::UnknownArtifact(name.to_string()))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, name: &str) -> crate::Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// Find a σ,π sketch variant matching (D, K); returns
    /// `(name, batch_size)`.  Matches `cminhash_*` artifacts whose
    /// `bits` input is `[B, D]` and whose output is `[B, K]`.
    pub fn sketch_variant_for(&self, d: usize, k: usize) -> Option<(String, usize)> {
        for (name, meta) in &self.artifacts {
            if !name.starts_with("cminhash_") {
                continue;
            }
            let bits = meta.inputs.iter().find(|t| t.name == "bits")?;
            let out = meta.outputs.first()?;
            if bits.shape.len() == 2
                && bits.shape[1] == d
                && out.shape.len() == 2
                && out.shape[1] == k
            {
                return Some((name.clone(), bits.shape[0]));
            }
        }
        None
    }

    /// All *sparse* σ,π sketch variants matching (D, K), sorted by
    /// ascending batch size: `(name, batch_size, f_max)` each.  Matches
    /// `cminhashs_*` artifacts whose `indices` input is `[B, F]` and
    /// `inv_sigma` is `[D]`.  The ladder of batch sizes lets the
    /// coordinator route a partial batch to the smallest fitting
    /// executable instead of padding to the largest.
    pub fn sparse_sketch_variants_for(&self, d: usize, k: usize) -> Vec<(String, usize, usize)> {
        let mut out = Vec::new();
        for (name, meta) in &self.artifacts {
            if !name.starts_with("cminhashs_") {
                continue;
            }
            let (Some(idx), Some(inv), Some(o)) = (
                meta.inputs.iter().find(|t| t.name == "indices"),
                meta.inputs.iter().find(|t| t.name == "inv_sigma"),
                meta.outputs.first(),
            ) else {
                continue;
            };
            if idx.shape.len() == 2 && inv.shape == vec![d] && o.shape.len() == 2 && o.shape[1] == k
            {
                out.push((name.clone(), idx.shape[0], idx.shape[1]));
            }
        }
        out.sort_by_key(|(_, b, _)| *b);
        out
    }

    /// Find a pairwise estimator variant for sketches of length K:
    /// `(name, n, m)`.
    pub fn estimator_variant_for(&self, k: usize) -> Option<(String, usize, usize)> {
        for (name, meta) in &self.artifacts {
            if !name.starts_with("estimate_") {
                continue;
            }
            let h1 = meta.inputs.iter().find(|t| t.name == "h1")?;
            let h2 = meta.inputs.iter().find(|t| t.name == "h2")?;
            if h1.shape.len() == 2 && h1.shape[1] == k && h2.shape[1] == k {
                return Some((name.clone(), h1.shape[0], h2.shape[0]));
            }
        }
        None
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    const SAMPLE: &str = r#"{
      "format": "hlo-text-v1",
      "artifacts": {
        "cminhash_b8_d1024_k128": {
          "file": "cminhash_b8_d1024_k128.hlo.txt",
          "inputs": [
            {"name": "bits", "shape": [8, 1024], "dtype": "s32"},
            {"name": "sigma", "shape": [1024], "dtype": "s32"},
            {"name": "pi2", "shape": [2048], "dtype": "s32"}
          ],
          "outputs": [{"name": "hashes", "shape": [8, 128], "dtype": "s32"}]
        },
        "estimate_n8_m8_k128": {
          "file": "estimate_n8_m8_k128.hlo.txt",
          "inputs": [
            {"name": "h1", "shape": [8, 128], "dtype": "s32"},
            {"name": "h2", "shape": [8, 128], "dtype": "s32"}
          ],
          "outputs": [{"name": "jhat", "shape": [8, 8], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn load_and_lookup() {
        let dir = TempDir::new().unwrap();
        write_manifest(dir.path(), SAMPLE);
        std::fs::write(dir.path().join("cminhash_b8_d1024_k128.hlo.txt"), "x").unwrap();
        std::fs::write(dir.path().join("estimate_n8_m8_k128.hlo.txt"), "x").unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        let meta = m.get("cminhash_b8_d1024_k128").unwrap();
        assert_eq!(meta.inputs[0].elements(), 8 * 1024);
        assert_eq!(meta.inputs[0].dims_i64(), vec![8, 1024]);
        assert_eq!(
            m.sketch_variant_for(1024, 128),
            Some(("cminhash_b8_d1024_k128".into(), 8))
        );
        assert_eq!(m.sketch_variant_for(999, 128), None);
        assert_eq!(
            m.estimator_variant_for(128),
            Some(("estimate_n8_m8_k128".into(), 8, 8))
        );
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn missing_file_rejected() {
        let dir = TempDir::new().unwrap();
        write_manifest(dir.path(), SAMPLE);
        // no .hlo.txt files created
        assert!(Manifest::load(dir.path()).is_err());
    }

    #[test]
    fn bad_format_rejected() {
        let dir = TempDir::new().unwrap();
        write_manifest(
            dir.path(),
            r#"{"format": "v999", "artifacts": {}}"#,
        );
        assert!(Manifest::load(dir.path()).is_err());
    }
}
