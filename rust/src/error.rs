//! Crate-wide error type.
//!
//! A small hand-rolled enum (no `thiserror` to keep the dependency
//! surface minimal); everything converts into it with `?`.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the library.
#[derive(Debug)]
pub enum Error {
    /// Request shape does not match any loaded artifact variant.
    ShapeMismatch {
        /// Which quantity mismatched ("vector dim", "sketch", …).
        what: &'static str,
        /// The size the receiver requires.
        expected: usize,
        /// The size the request carried.
        got: usize,
    },
    /// Named artifact missing from the manifest / registry.
    UnknownArtifact(String),
    /// Invalid argument (dimension bounds, K > D, …).
    Invalid(String),
    /// Artifact manifest parse / consistency failure.
    Manifest(String),
    /// PJRT / XLA runtime failure.
    Xla(String),
    /// Server protocol violation (bad JSON, unknown op, …).
    Protocol(String),
    /// Connection pool saturated; the client should retry later.
    Busy {
        /// The configured connection cap that was hit.
        max_connections: usize,
    },
    /// Coordinator shut down / channel closed.
    Shutdown,
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ShapeMismatch {
                what,
                expected,
                got,
            } => write!(f, "shape mismatch for {what}: expected {expected}, got {got}"),
            Error::UnknownArtifact(name) => write!(f, "unknown artifact variant: {name}"),
            Error::Invalid(msg) => write!(f, "invalid argument: {msg}"),
            Error::Manifest(msg) => write!(f, "manifest error: {msg}"),
            Error::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            Error::Protocol(msg) => write!(f, "protocol error: {msg}"),
            Error::Busy { max_connections } => write!(
                f,
                "busy: all {max_connections} connection slots are in use; retry later"
            ),
            Error::Shutdown => write!(f, "coordinator is shut down"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::runtime::xla::Error> for Error {
    fn from(e: crate::runtime::xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}


#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = Error::ShapeMismatch {
            what: "bits",
            expected: 1024,
            got: 17,
        };
        assert!(e.to_string().contains("bits"));
        assert!(e.to_string().contains("1024"));
        let e = Error::UnknownArtifact("nope".into());
        assert!(e.to_string().contains("nope"));
    }

    #[test]
    fn busy_error_names_the_cap() {
        let e = Error::Busy {
            max_connections: 4,
        };
        let s = e.to_string();
        assert!(s.starts_with("busy"), "{s}");
        assert!(s.contains('4'), "{s}");
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
