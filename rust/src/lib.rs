//! # cminhash — a production C-MinHash sketching & similarity-search stack
//!
//! Reproduction of *“C-MinHash: Rigorously Reducing K Permutations to
//! Two”* (Xiaoyun Li & Ping Li, 2021) as a three-layer system:
//!
//! * **L1** — a Pallas kernel (Python, build time) computing all K
//!   circulant hashes of a batch; lowered to HLO text in `artifacts/`.
//! * **L2** — JAX sketch pipelines (Algorithm 1/2/3 + estimator graphs),
//!   also AOT-lowered.
//! * **L3** — this crate: a serving coordinator that loads the artifacts
//!   via PJRT ([`runtime`]), batches client requests ([`coordinator`]),
//!   serves sketches / estimates / near-neighbor queries ([`server`],
//!   [`index`]) out of a sharded, crash-recoverable sketch store
//!   ([`store`]), and ships five pluggable hashing schemes —
//!   classic MinHash, C-MinHash-(σ, π)/(0, π), OPH, and C-OPH,
//!   selected end to end via [`sketch::SketchScheme`] — with an
//!   optional packed b-bit storage plane (`sketch.bits`: 32/b× less
//!   sketch memory, XOR+popcount query scoring), plus exact paper
//!   theory ([`theory`]) and dataset generators ([`data`]).
//!
//! Python never runs on the request path: `make artifacts` is the only
//! Python invocation, and the binary is self-contained afterwards.
//!
//! ## Quick start
//!
//! ```no_run
//! use cminhash::sketch::{CMinHasher, Sketcher};
//! let hasher = CMinHasher::new(1024, 128, 42); // D, K, seed
//! let v: Vec<u32> = vec![3, 17, 900];          // sparse nonzero indices
//! let w: Vec<u32> = vec![3, 17, 901];
//! let hv = hasher.sketch_sparse(&v);
//! let hw = hasher.sketch_sparse(&w);
//! let j = cminhash::sketch::estimate(&hv, &hw);
//! assert!(j > 0.0 && j <= 1.0);
//! ```

#![deny(missing_docs)]
#![forbid(unsafe_code)]
// Panicking std APIs are outlawed on library paths (see clippy.toml);
// every deliberate exception carries an #[allow] naming its invariant.
#![warn(clippy::disallowed_methods, clippy::disallowed_macros)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod figures;
pub mod data;
pub mod error;
pub mod index;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod sketch;
pub mod store;
pub mod theory;
pub mod util;

pub use error::{Error, Result};
