//! Lemma 2.1 and Theorem 2.2: the location-dependent variance of
//! C-MinHash-(0, π).

use super::location::{LagCounts, LocationVector};

/// Lemma 2.1: Θ_Δ = E_π[𝟙_s 𝟙_t] for t − s = Δ, given the lag-Δ pair
/// counts of the (fixed) location vector:
///
/// Θ_Δ = (|𝓛₀| + (|𝓖₀| + |𝓛₂|)·J) / (f + |𝓖₀| + |𝓖₁|).
pub fn theta_delta(c: &LagCounts, f: usize, a: usize) -> f64 {
    if f == 0 {
        return 0.0;
    }
    let j = a as f64 / f as f64;
    (c.l0 as f64 + (c.g0 + c.l2) as f64 * j) / (f + c.g0 + c.g1) as f64
}

/// Theorem 2.2: Var[Ĵ_{0,π}] for a specific location vector and K.
///
/// Var = J/K + (2/K²)·Σ_{Δ=1}^{K−1} (K − Δ)·Θ_Δ − J²
/// (the paper indexes the sum by s = 2..K with Δ = K−s+1 and weight
/// s−1 = K−Δ; this is the same sum re-indexed).
///
/// Requires K ≤ D (the paper's standing assumption).
pub fn var_zero_pi(x: &LocationVector, k: usize) -> f64 {
    let (a, f, d) = (x.a(), x.f(), x.d());
    assert!((1..=d).contains(&k), "need 1 <= K <= D");
    if a == 0 || a == f {
        return 0.0; // J ∈ {0,1}: indicator is constant
    }
    let j = a as f64 / f as f64;
    let kf = k as f64;
    let mut cross = 0.0f64;
    for delta in 1..k {
        let c = x.counts_at_lag(delta);
        cross += (k - delta) as f64 * theta_delta(&c, f, a);
    }
    j / kf + 2.0 * cross / (kf * kf) - j * j
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::sketch::{Perm, Sketcher, ZeroPiHasher};
    use crate::theory::location::Symbol;
    use crate::util::rng::Rng;

    #[test]
    fn degenerate_j_has_zero_variance() {
        let x = LocationVector::contiguous(20, 5, 0);
        assert_eq!(var_zero_pi(&x, 10), 0.0);
        let x = LocationVector::contiguous(20, 5, 5);
        assert_eq!(var_zero_pi(&x, 10), 0.0);
    }

    #[test]
    fn k_equals_one_matches_minhash() {
        // A single hash has no cross terms: Var = J(1−J)/1.
        let x = LocationVector::contiguous(30, 12, 5);
        let j = x.jaccard();
        assert!((var_zero_pi(&x, 1) - j * (1.0 - j)).abs() < 1e-12);
    }

    /// Empirical Var[Ĵ_{0,π}] over random π for a fixed location vector —
    /// directly simulates Algorithm 2 and checks Theorem 2.2.
    fn empirical_var(x: &LocationVector, k: usize, reps: usize, seed: u64) -> f64 {
        let d = x.d();
        let (v, w) = x.realize();
        let mut rng = Rng::seed_from_u64(seed);
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..reps {
            let pi = Perm::from_values(rng.permutation(d)).unwrap();
            let h = ZeroPiHasher::from_perm(k, &pi).unwrap();
            let est = crate::sketch::estimate(
                &h.sketch_sparse(v.indices()),
                &h.sketch_sparse(w.indices()),
            );
            sum += est;
            sumsq += est * est;
        }
        let mean = sum / reps as f64;
        sumsq / reps as f64 - mean * mean
    }

    #[test]
    fn theorem_2_2_matches_simulation_contiguous() {
        let x = LocationVector::contiguous(64, 24, 9);
        let theo = var_zero_pi(&x, 32);
        let emp = empirical_var(&x, 32, 30_000, 1);
        // MC sd of a variance estimate at 30k reps is well under 5%.
        assert!(
            (theo - emp).abs() < 0.10 * theo.max(1e-4),
            "theory {theo} vs empirical {emp}"
        );
    }

    #[test]
    fn theorem_2_2_matches_simulation_interleaved() {
        let x = LocationVector::interleaved(64, 24, 9);
        let theo = var_zero_pi(&x, 32);
        let emp = empirical_var(&x, 32, 30_000, 2);
        assert!(
            (theo - emp).abs() < 0.10 * theo.max(1e-4),
            "theory {theo} vs empirical {emp}"
        );
    }

    #[test]
    fn location_dependence_is_real() {
        // The whole point of §2: different arrangements of the same
        // (D, f, a) give different Var[Ĵ_{0,π}].
        let xc = LocationVector::contiguous(64, 24, 9);
        let xi = LocationVector::interleaved(64, 24, 9);
        let vc = var_zero_pi(&xc, 32);
        let vi = var_zero_pi(&xi, 32);
        assert!((vc - vi).abs() > 1e-4, "contiguous {vc} vs interleaved {vi}");
    }

    #[test]
    fn theta_is_a_probability() {
        let x = LocationVector::contiguous(40, 15, 6);
        for delta in 1..20 {
            let th = theta_delta(&x.counts_at_lag(delta), x.f(), x.a());
            assert!((0.0..=1.0).contains(&th), "delta={delta} theta={th}");
        }
    }

    #[test]
    fn all_both_symbols_mean_theta_one() {
        // x = all "O": every hash collides, Θ = 1 for any Δ.
        let x = LocationVector::from_symbols(vec![Symbol::Both; 16]);
        let th = theta_delta(&x.counts_at_lag(3), x.f(), x.a());
        assert!((th - 1.0).abs() < 1e-12);
    }
}
