//! Theorem 3.1: Var[Ĵ_{σ,π}] — four independent evaluation paths.
//!
//! 1. [`e_tilde`] — the run-count decomposition (exact, O(min(f, D−f)),
//!    works at any D; see the module docs in `theory/mod.rs` for the
//!    derivation).  This is the production path used by Figures 2–5.
//! 2. [`e_tilde_enum`] — a literal implementation of the paper's
//!    two-step stars-and-bars enumeration (Appendix A.3, eq. 25),
//!    O((D−f)·a⁴): the cross-check that our decomposition and the
//!    paper's combinatorics agree.
//! 3. [`e_tilde_brute`] — full enumeration of all labeled circular
//!    arrangements (D ≤ ~12): the ground truth both of the above are
//!    tested against.
//! 4. [`e_tilde_mc`] — Monte Carlo over σ: used by tests and by users
//!    who want error bars at parameter ranges they do not trust.

use crate::util::rng::Rng;

use super::combinat::ln_choose;
use super::location::{LocationVector, Symbol};

/// Lemma 2.1's conditional expectation at Δ=1, as a function of the
/// lag-1 pair counts: g = (ℓ₀ + a(g₀+ℓ₂)/f) / (f+g₀+g₁).
#[inline]
fn g_value(l0: f64, l2: f64, g0: f64, g1: f64, f: f64, a: f64) -> f64 {
    (l0 + a * (g0 + l2) / f) / (f + g0 + g1)
}

/// Ẽ of Theorem 3.1 via the exact run-count decomposition.
///
/// Requires 0 < a < f ≤ D.  Exact for every D (validated against
/// [`e_tilde_brute`] and [`e_tilde_enum`] in the test-suite).
pub fn e_tilde(d: usize, f: usize, a: usize) -> f64 {
    assert!(a > 0 && a < f && f <= d, "need 0 < a < f <= D");
    let (df, ff, af) = (d as f64, f as f64, a as f64);
    if d == f {
        // No “−” symbols: |𝓖₀|=|𝓖₁|=|𝓛₂|=0 and |𝓛₀| ~ hyper;
        // Ẽ = E[ℓ₀]/f = a(a−1)/(f(f−1)) = J·(a−1)/(f−1)  (proof of Thm 3.4).
        return af * (af - 1.0) / (ff * (ff - 1.0));
    }
    // P(R = r) = (D/r)·C(D−f−1, r−1)·C(f−1, r−1) / C(D, D−f):
    // run-count law of the (D−f) “−”s on a labeled circle.
    let ln_denom = ln_choose(d, d - f);
    let mut total = 0.0f64;
    for r in 1..=f.min(d - f) {
        let rf = r as f64;
        let ln_p = df.ln() - rf.ln() + ln_choose(d - f - 1, r - 1) + ln_choose(f - 1, r - 1)
            - ln_denom;
        if ln_p == f64::NEG_INFINITY {
            continue;
        }
        // E[numerator | R=r]:
        //   E[ℓ₀|r] = (f−r)·a(a−1)/(f(f−1))       (f−r intra-gap pairs)
        //   E[g₀|r] = E[ℓ₂|r] = r·a/f             (gap ends, exchangeable)
        let e_l0 = (ff - rf) * af * (af - 1.0) / (ff * (ff - 1.0));
        let e_num = e_l0 + af * (2.0 * rf * af / ff) / ff;
        total += ln_p.exp() * e_num / (ff + rf);
    }
    total
}

/// Theorem 3.1: Var[Ĵ_{σ,π}] = J/K + (K−1)·Ẽ/K − J².
///
/// Exact for any (D, f, a, K) with K ≤ D; 0 when J ∈ {0, 1}.
pub fn var_sigma_pi(d: usize, f: usize, a: usize, k: usize) -> f64 {
    assert!((1..=d).contains(&k), "need 1 <= K <= D");
    assert!(f <= d && a <= f);
    if a == 0 || a == f {
        return 0.0;
    }
    let j = a as f64 / f as f64;
    let kf = k as f64;
    let e = e_tilde(d, f, a);
    // Mathematically >= 0; clamp the ~1e-18 float residue that appears
    // at exact-zero cases (e.g. D = f, a = 1, K = D).
    (j / kf + (kf - 1.0) * e / kf - j * j).max(0.0)
}

/// Literal implementation of the paper's Appendix A.3 enumeration
/// (eq. 25): step 1 places “×”s between “−”s (hypergeometric over
/// s = |𝒞₁|), step 2 throws “O”s into the four bin types (multivariate
/// stars-and-bars over n₁..n₄).  O((D−f)·a⁴) — use for cross-checks at
/// small/medium sizes, not for D = 1000 sweeps.
pub fn e_tilde_enum(d: usize, f: usize, a: usize) -> f64 {
    assert!(a > 0 && a < f && f <= d, "need 0 < a < f <= D");
    if d == f {
        return e_tilde(d, f, a);
    }
    let (ff, af) = (f as f64, a as f64);
    let ln_step1_denom = ln_choose(d - a - 1, d - f - 1);
    let ln_step2_denom = ln_choose(d - 1, a);
    let s_lo = (d as i64 - 2 * f as i64 + a as i64).max(0) as usize;
    let mut total = 0.0f64;
    for s in s_lo..=(d - f - 1) {
        // |𝒞₁| = s (−,− pairs), |𝒞₂| = |𝒞₃| = D−f−s, |𝒞₄| = f−a−(D−f−s).
        let c2 = d - f - s;
        if c2 > f - a {
            continue; // more occupied gaps than “×”s
        }
        let c4 = (f - a) - c2;
        let ln_ps = ln_choose(d - f, s) + ln_choose(f - a - 1, c2.wrapping_sub(1))
            - ln_step1_denom;
        let ln_ps = if c2 == 0 { f64::NEG_INFINITY } else { ln_ps };
        if ln_ps == f64::NEG_INFINITY {
            continue;
        }
        let ps = ln_ps.exp();
        for n1 in 0..=s.min(a) {
            for n2 in 0..=c2.min(a) {
                for n3 in 0..=c2.min(a) {
                    for n4 in 0..=c4.min(a) {
                        let m = n1 + n2 + n3 + n4;
                        if m == 0 || m > a {
                            continue;
                        }
                        let ln_w = ln_choose(s, n1)
                            + ln_choose(c2, n2)
                            + ln_choose(c2, n3)
                            + ln_choose(c4, n4)
                            + ln_choose(a - 1, m - 1)
                            - ln_step2_denom;
                        if ln_w == f64::NEG_INFINITY {
                            continue;
                        }
                        // Bin effects (Appendix A.3 step 2):
                        let l2 = (n1 + n3) as f64;
                        let g0 = (n1 + n2) as f64;
                        let g1 = (c2 - n2) as f64;
                        let l0 = (a - m) as f64;
                        total +=
                            ps * ln_w.exp() * g_value(l0, l2, g0, g1, ff, af);
                    }
                }
            }
        }
    }
    total
}

/// Ground truth for tiny D: enumerate every labeled circular arrangement
/// of the multiset {O^a, ×^{f−a}, −^{D−f}} and average g.  Cost
/// C(D,a)·C(D−a,f−a); keep D ≤ ~12.
pub fn e_tilde_brute(d: usize, f: usize, a: usize) -> f64 {
    assert!(a > 0 && a < f && f <= d && d <= 16, "brute force needs tiny D");
    let mut total = 0.0f64;
    let mut count = 0usize;
    // Iterate subsets for "O" positions, then "×" positions among the rest.
    let o_sets = combinations(d, a);
    for oset in &o_sets {
        let rest: Vec<usize> = (0..d).filter(|i| !oset.contains(i)).collect();
        for xidx in combinations(rest.len(), f - a) {
            let mut sym = vec![Symbol::Neither; d];
            for &i in oset {
                sym[i] = Symbol::Both;
            }
            for &t in &xidx {
                sym[rest[t]] = Symbol::One;
            }
            let x = LocationVector::from_symbols(sym);
            let c = x.counts_at_lag(1);
            total += g_value(
                c.l0 as f64,
                c.l2 as f64,
                c.g0 as f64,
                c.g1 as f64,
                f as f64,
                a as f64,
            );
            count += 1;
        }
    }
    total / count as f64
}

fn combinations(n: usize, k: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(k);
    fn rec(start: usize, n: usize, k: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if cur.len() == k {
            out.push(cur.clone());
            return;
        }
        for i in start..n {
            cur.push(i);
            rec(i + 1, n, k, cur, out);
            cur.pop();
        }
    }
    rec(0, n, k, &mut cur, &mut out);
    out
}

/// Monte-Carlo Ẽ: sample uniformly random circular arrangements
/// (i.e. random σ) and average Lemma 2.1's conditional expectation — a
/// Rao-Blackwellized estimator of Ẽ.
pub fn e_tilde_mc(d: usize, f: usize, a: usize, samples: usize, seed: u64) -> f64 {
    assert!(a > 0 && a < f && f <= d);
    let mut rng = Rng::seed_from_u64(seed);
    let mut sym: Vec<Symbol> = Vec::with_capacity(d);
    sym.extend(std::iter::repeat(Symbol::Both).take(a));
    sym.extend(std::iter::repeat(Symbol::One).take(f - a));
    sym.extend(std::iter::repeat(Symbol::Neither).take(d - f));
    let mut total = 0.0f64;
    for _ in 0..samples {
        rng.shuffle(&mut sym);
        let x = LocationVector::from_symbols(sym.clone());
        let c = x.counts_at_lag(1);
        total += g_value(
            c.l0 as f64,
            c.l2 as f64,
            c.g0 as f64,
            c.g1 as f64,
            f as f64,
            a as f64,
        );
    }
    total / samples as f64
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn runs_formula_matches_brute_force() {
        for (d, f, a) in [
            (8, 4, 2),
            (9, 5, 2),
            (10, 4, 3),
            (7, 6, 3),
            (10, 7, 5),
            (11, 3, 1),
            (12, 9, 4),
        ] {
            let brute = e_tilde_brute(d, f, a);
            let runs = e_tilde(d, f, a);
            assert!(
                (brute - runs).abs() < 1e-12,
                "D={d} f={f} a={a}: brute={brute} runs={runs}"
            );
        }
    }

    #[test]
    fn mc_agrees_with_exact() {
        let (d, f, a) = (200, 60, 20);
        let exact = e_tilde(d, f, a);
        let mc = e_tilde_mc(d, f, a, 60_000, 7);
        assert!(
            (exact - mc).abs() < 5e-3,
            "exact={exact} mc={mc}"
        );
    }

    #[test]
    fn d_equals_f_limit() {
        // Ẽ_{D=f} = J·(a−1)/(f−1) (proof of Theorem 3.4).
        let (f, a) = (20usize, 7usize);
        let want = (a as f64 / f as f64) * ((a - 1) as f64 / (f - 1) as f64);
        assert!((e_tilde(f, f, a) - want).abs() < 1e-14);
    }

    #[test]
    fn lemma_3_3_monotone_in_d() {
        // Ẽ_{D+1} > Ẽ_D for all D >= f; and Ẽ_D < J² (Thm 3.4).
        for (f, a) in [(10usize, 3usize), (30, 11), (6, 5)] {
            let j2 = (a as f64 / f as f64).powi(2);
            let mut prev = e_tilde(f, f, a);
            for d in (f + 1)..(f + 200) {
                let cur = e_tilde(d, f, a);
                assert!(cur > prev, "not increasing at D={d}, f={f}, a={a}");
                assert!(cur < j2, "Ẽ >= J² at D={d}");
                prev = cur;
            }
        }
    }

    #[test]
    fn converges_to_j_squared() {
        let (f, a) = (12usize, 5usize);
        let j2 = (a as f64 / f as f64).powi(2);
        let e = e_tilde(200_000, f, a);
        assert!((e - j2).abs() < 1e-3, "e={e} j2={j2}");
    }

    #[test]
    fn variance_nonnegative_and_below_minhash() {
        for (d, f, a, k) in [(128, 50, 20, 64), (1000, 800, 400, 800), (64, 64, 32, 64)] {
            let v = var_sigma_pi(d, f, a, k);
            let j = a as f64 / f as f64;
            assert!(v >= 0.0);
            assert!(v < j * (1.0 - j) / k as f64 + 1e-15);
        }
    }

    #[test]
    fn k_equals_one_matches_minhash_exactly() {
        // Single hash: no correlation terms at all.
        let (d, f, a) = (64usize, 20usize, 8usize);
        let j = a as f64 / f as f64;
        assert!((var_sigma_pi(d, f, a, 1) - j * (1.0 - j)).abs() < 1e-14);
    }
}
