//! Log-domain combinatorics for the exact variance formulas.
//!
//! Everything runs through ln-factorials so that D ~ 10³–10⁶ (the paper
//! plots up to D = 1000; the API tolerates far more) never overflows.
//! `ln_factorial` uses an exact cached table for small n and the
//! Stirling series for large n (abs error < 1e-12 for n ≥ 256).

use std::sync::OnceLock;

const TABLE_N: usize = 4096;

fn table() -> &'static [f64; TABLE_N] {
    static T: OnceLock<[f64; TABLE_N]> = OnceLock::new();
    T.get_or_init(|| {
        let mut t = [0.0f64; TABLE_N];
        for n in 2..TABLE_N {
            t[n] = t[n - 1] + (n as f64).ln();
        }
        t
    })
}

/// ln(n!) — exact (cumulative-sum table) for n < 4096, Stirling series
/// beyond.
pub fn ln_factorial(n: usize) -> f64 {
    if n < TABLE_N {
        return table()[n];
    }
    // Stirling: ln n! = n ln n − n + ½ln(2πn) + 1/(12n) − 1/(360n³) + …
    let x = n as f64;
    x * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI * x).ln() + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x * x * x)
}

/// ln C(n, k); `f64::NEG_INFINITY` when the coefficient is zero
/// (k > n), matching how vanishing terms drop out of the sums.
pub fn ln_choose(n: usize, k: usize) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// C(n, k) as f64 (may be +inf for astronomically large values; callers
/// only ever use *ratios*, which stay finite through the log domain).
pub fn choose(n: usize, k: usize) -> f64 {
    ln_choose(n, k).exp()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn factorial_small_exact() {
        assert_eq!(ln_factorial(0), 0.0);
        assert_eq!(ln_factorial(1), 0.0);
        assert!((ln_factorial(5) - 120f64.ln()).abs() < 1e-12);
        assert!((ln_factorial(10) - 3628800f64.ln()).abs() < 1e-10);
    }

    #[test]
    fn stirling_matches_table_at_boundary() {
        // Compare the Stirling branch against the exact recurrence just
        // past the table edge.
        let exact = ln_factorial(TABLE_N - 1) + (TABLE_N as f64).ln();
        let stirling = ln_factorial(TABLE_N);
        assert!(
            (exact - stirling).abs() < 1e-9,
            "boundary mismatch: {exact} vs {stirling}"
        );
    }

    #[test]
    fn choose_basics() {
        assert!((choose(5, 2) - 10.0).abs() < 1e-9);
        assert!((choose(10, 0) - 1.0).abs() < 1e-12);
        assert!((choose(10, 10) - 1.0).abs() < 1e-12);
        assert_eq!(choose(3, 4), 0.0);
    }

    #[test]
    fn pascal_identity_holds() {
        for n in 1..60usize {
            for k in 1..n {
                let lhs = choose(n, k);
                let rhs = choose(n - 1, k - 1) + choose(n - 1, k);
                assert!((lhs - rhs).abs() < 1e-6 * lhs.max(1.0), "n={n} k={k}");
            }
        }
    }
}
