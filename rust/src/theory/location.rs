//! Location vectors (Definition 2.1) and lag-Δ pair counts
//! (Definition 2.2) — the combinatorial skeleton of both variance
//! theorems.

use crate::sketch::SparseVec;

/// One entry of the location vector **x** (Definition 2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Symbol {
    /// “O”: v_i = w_i = 1 (intersection).
    Both,
    /// “×”: v_i + w_i = 1 (symmetric difference).
    One,
    /// “−”: v_i = w_i = 0.
    Neither,
}

/// Lag-Δ pair counts |𝓛₀|, |𝓛₁|, |𝓛₂|, |𝓖₀|, |𝓖₁| of Definition 2.2
/// (the ones Lemma 2.1 needs; the rest follow from the intrinsic
/// constraints, eq. 6).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LagCounts {
    /// (O, O) pairs at lag Δ.
    pub l0: usize,
    /// (O, ×) pairs.
    pub l1: usize,
    /// (O, −) pairs.
    pub l2: usize,
    /// (−, O) pairs.
    pub g0: usize,
    /// (−, ×) pairs.
    pub g1: usize,
}

/// The location vector of a data pair, with cached (a, f).
#[derive(Clone, Debug)]
pub struct LocationVector {
    symbols: Vec<Symbol>,
    a: usize,
    f: usize,
}

impl LocationVector {
    /// Build from two binary vectors of equal dimension.
    pub fn from_pair(v: &SparseVec, w: &SparseVec) -> crate::Result<Self> {
        if v.dim() != w.dim() {
            return Err(crate::Error::Invalid(format!(
                "dim mismatch {} vs {}",
                v.dim(),
                w.dim()
            )));
        }
        let d = v.dim() as usize;
        let mut symbols = vec![Symbol::Neither; d];
        for &i in v.indices() {
            symbols[i as usize] = Symbol::One;
        }
        for &i in w.indices() {
            symbols[i as usize] = match symbols[i as usize] {
                Symbol::One => Symbol::Both,
                _ => Symbol::One,
            };
        }
        Ok(Self::from_symbols(symbols))
    }

    /// Build directly from a symbol array.
    pub fn from_symbols(symbols: Vec<Symbol>) -> Self {
        let a = symbols.iter().filter(|s| **s == Symbol::Both).count();
        let f = a + symbols.iter().filter(|s| **s == Symbol::One).count();
        LocationVector { symbols, a, f }
    }

    /// The §4.1 synthetic pattern: a “O”s, then (f−a) “×”s, then
    /// (D−f) “−”s, sequentially.
    pub fn contiguous(d: usize, f: usize, a: usize) -> Self {
        assert!(a <= f && f <= d);
        let mut symbols = Vec::with_capacity(d);
        symbols.extend(std::iter::repeat(Symbol::Both).take(a));
        symbols.extend(std::iter::repeat(Symbol::One).take(f - a));
        symbols.extend(std::iter::repeat(Symbol::Neither).take(d - f));
        LocationVector { symbols, a, f }
    }

    /// An evenly-interleaved pattern (low-structure counterpart used by
    /// Fig. 6 to show the location-dependence of C-MinHash-(0, π)).
    pub fn interleaved(d: usize, f: usize, a: usize) -> Self {
        assert!(a <= f && f <= d);
        let mut symbols = vec![Symbol::Neither; d];
        // spread the f occupied slots uniformly, first a of them "Both"
        let mut placed = 0usize;
        for t in 0..f {
            let pos = (t * d) / f;
            let sym = if placed < a { Symbol::Both } else { Symbol::One };
            symbols[pos] = sym;
            placed += 1;
        }
        LocationVector::from_symbols(symbols)
    }

    /// Intersection size a.
    pub fn a(&self) -> usize {
        self.a
    }

    /// Union size f.
    pub fn f(&self) -> usize {
        self.f
    }

    /// Dimension D.
    pub fn d(&self) -> usize {
        self.symbols.len()
    }

    /// Jaccard similarity J = a/f (0 when f = 0).
    pub fn jaccard(&self) -> f64 {
        if self.f == 0 {
            0.0
        } else {
            self.a as f64 / self.f as f64
        }
    }

    /// Symbols view.
    pub fn symbols(&self) -> &[Symbol] {
        &self.symbols
    }

    /// Materialize a concrete (v, w) pair with this location vector.
    /// “×” positions alternate between v-only and w-only (the split does
    /// not affect any collision statistic — only x drives collisions).
    // Indices enumerate 0..d positions of this location vector, so
    // `SparseVec::new` cannot reject them.
    #[allow(clippy::disallowed_methods)]
    pub fn realize(&self) -> (SparseVec, SparseVec) {
        let d = self.d() as u32;
        let mut v = Vec::new();
        let mut w = Vec::new();
        let mut flip = false;
        for (i, s) in self.symbols.iter().enumerate() {
            match s {
                Symbol::Both => {
                    v.push(i as u32);
                    w.push(i as u32);
                }
                Symbol::One => {
                    if flip {
                        w.push(i as u32);
                    } else {
                        v.push(i as u32);
                    }
                    flip = !flip;
                }
                Symbol::Neither => {}
            }
        }
        (
            SparseVec::new(d, v).expect("indices in range"),
            SparseVec::new(d, w).expect("indices in range"),
        )
    }

    /// Lag-Δ pair counts over the circularly-wrapped vector
    /// (Definition 2.2 with Remark 2.1's wrap-around).
    pub fn counts_at_lag(&self, delta: usize) -> LagCounts {
        let d = self.symbols.len();
        debug_assert!((1..d).contains(&delta));
        let mut c = LagCounts::default();
        for i in 0..d {
            let j = if i + delta >= d { i + delta - d } else { i + delta };
            match (self.symbols[i], self.symbols[j]) {
                (Symbol::Both, Symbol::Both) => c.l0 += 1,
                (Symbol::Both, Symbol::One) => c.l1 += 1,
                (Symbol::Both, Symbol::Neither) => c.l2 += 1,
                (Symbol::Neither, Symbol::Both) => c.g0 += 1,
                (Symbol::Neither, Symbol::One) => c.g1 += 1,
                _ => {}
            }
        }
        c
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn from_pair_classifies_symbols() {
        let v = SparseVec::new(6, vec![0, 1, 2]).unwrap();
        let w = SparseVec::new(6, vec![1, 2, 3]).unwrap();
        let x = LocationVector::from_pair(&v, &w).unwrap();
        assert_eq!(x.a(), 2);
        assert_eq!(x.f(), 4);
        assert_eq!(x.symbols()[0], Symbol::One);
        assert_eq!(x.symbols()[1], Symbol::Both);
        assert_eq!(x.symbols()[4], Symbol::Neither);
    }

    #[test]
    fn intrinsic_constraints_hold_at_every_lag() {
        // eq. (6): the row/column sums of the pair-count matrix.
        let x = LocationVector::contiguous(40, 17, 6);
        let (a, f, d) = (x.a(), x.f(), x.d());
        for delta in 1..d.min(20) {
            let c = x.counts_at_lag(delta);
            assert_eq!(c.l0 + c.l1 + c.l2, a, "L row sum at delta={delta}");
            // |G0|+|G1|+|G2| = D−f  =>  G2 = D−f−g0−g1 must be >= 0
            assert!(c.g0 + c.g1 <= d - f);
            // |L0|+|G0|+|H0| = a  =>  h0 = a − l0 − g0 >= 0
            assert!(c.l0 + c.g0 <= a);
        }
    }

    #[test]
    fn realize_roundtrips_counts() {
        let x = LocationVector::contiguous(32, 10, 4);
        let (v, w) = x.realize();
        let (inter, union) = v.overlap(&w);
        assert_eq!(inter, 4);
        assert_eq!(union, 10);
        let x2 = LocationVector::from_pair(&v, &w).unwrap();
        assert_eq!(x2.symbols(), x.symbols());
    }

    #[test]
    fn contiguous_lag1_counts() {
        // O O O x x x - - - -  (D=10, f=6, a=3), circular.
        let x = LocationVector::contiguous(10, 6, 3);
        let c = x.counts_at_lag(1);
        assert_eq!(
            (c.l0, c.l1, c.l2, c.g0, c.g1),
            (2, 1, 0, 1, 0),
            "wrap-around pair is (−, O)"
        );
    }

    #[test]
    fn interleaved_has_requested_a_f() {
        let x = LocationVector::interleaved(50, 20, 7);
        assert_eq!((x.a(), x.f(), x.d()), (7, 20, 50));
    }
}
