//! Exact theory from the paper, used to regenerate Figures 2–6 and to
//! property-test the samplers.
//!
//! * [`var_minhash`] — classical MinHash variance J(1−J)/K (eq. 3).
//! * [`theta_delta`] / [`var_zero_pi`] — Lemma 2.1 + Theorem 2.2:
//!   the *location-dependent* variance of C-MinHash-(0, π).
//! * [`e_tilde`] / [`var_sigma_pi`] — Theorem 3.1: the variance of
//!   C-MinHash-(σ, π), evaluated **exactly in O(min(f, D−f)) for any
//!   D** via a run-count decomposition (below), instead of the paper's
//!   5-fold combinatorial sum.  Cross-checked against a literal
//!   enumeration ([`e_tilde_enum`]), a brute-force over all labeled
//!   arrangements ([`e_tilde_brute`]) and Monte Carlo
//!   ([`e_tilde_mc`]) in the test-suite.
//!
//! ## The run-count decomposition of Ẽ (Theorem 3.1)
//!
//! Ẽ = E_σ[g(ℓ₀, ℓ₂, g₀, g₁)] with
//! g = ℓ₀/(f+g₀+g₁) + a·(g₀+ℓ₂)/((f+g₀+g₁)·f) (Lemma 2.1 at Δ=1),
//! where the counts are lag-1 pair counts of a uniformly random circular
//! arrangement of a “O”s, (f−a) “×”s and (D−f) “−”s.  Observe:
//!
//! 1. g₀+g₁ = R, the number of maximal runs of “−” (each run's last “−”
//!    is followed by exactly one non-“−”), so the denominator only
//!    depends on R.
//! 2. Conditional on R = r, by exchangeability of the f non-“−” symbols
//!    over their positions: E[g₀|r] = E[ℓ₂|r] = r·a/f (a gap starts/ends
//!    with “O” w.p. a/f), and E[ℓ₀|r] = (f−r)·a(a−1)/(f(f−1)) (there are
//!    f−r intra-gap adjacencies, each “OO” w.p. a(a−1)/(f(f−1))).
//! 3. P(R=r) = (D/r)·C(D−f−1, r−1)·C(f−1, r−1) / C(D, D−f) — the classic
//!    labeled-circle run-count distribution.
//!
//! Hence Ẽ = Σ_r P(R=r)·[(f−r)·a(a−1)/(f(f−1)) + 2r·a²/f²] / (f+r),
//! which matches the paper's Theorem 3.1 expression term-for-term on
//! every case the enumeration can reach (see `rust/tests/theory_cross.rs`)
//! and reproduces the limits the paper proves: Ẽ_{D=f} = J·(a−1)/(f−1)
//! and Ẽ_D ↑ J² as D → ∞ (Lemma 3.3 / Theorem 3.4).

mod combinat;
mod location;
mod sigma_pi;
mod zero_pi;

pub use combinat::{choose, ln_choose, ln_factorial};
pub use location::{LagCounts, LocationVector, Symbol};
pub use sigma_pi::{e_tilde, e_tilde_brute, e_tilde_enum, e_tilde_mc, var_sigma_pi};
pub use zero_pi::{theta_delta, var_zero_pi};

/// Classical MinHash variance, eq. (3): Var[Ĵ_MH] = J(1−J)/K.
pub fn var_minhash(j: f64, k: usize) -> f64 {
    assert!(k >= 1);
    assert!((0.0..=1.0).contains(&j));
    j * (1.0 - j) / k as f64
}

/// Variance ratio Var[Ĵ_MH] / Var[Ĵ_{σ,π}] — the Figure 4/5 quantity.
/// Returns `None` when J ∈ {0, 1} (both variances are 0).
pub fn variance_ratio(d: usize, f: usize, a: usize, k: usize) -> Option<f64> {
    if a == 0 || a == f {
        return None;
    }
    let j = a as f64 / f as f64;
    Some(var_minhash(j, k) / var_sigma_pi(d, f, a, k))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn minhash_variance_basics() {
        assert_eq!(var_minhash(0.0, 10), 0.0);
        assert_eq!(var_minhash(1.0, 10), 0.0);
        assert!((var_minhash(0.5, 100) - 0.0025).abs() < 1e-15);
        // symmetric about 1/2
        assert!((var_minhash(0.3, 7) - var_minhash(0.7, 7)).abs() < 1e-15);
    }

    #[test]
    fn ratio_none_at_degenerate_j() {
        assert!(variance_ratio(100, 10, 0, 8).is_none());
        assert!(variance_ratio(100, 10, 10, 8).is_none());
        assert!(variance_ratio(100, 10, 5, 8).is_some());
    }

    #[test]
    fn theorem_3_4_uniform_superiority_grid() {
        // Var_{σ,π} < Var_MH strictly, for every feasible (D, f, a).
        for d in [10usize, 33, 64, 200, 1000] {
            for f in [2usize, 5, d / 3, d / 2, d - 1, d] {
                if f < 2 || f > d {
                    continue;
                }
                let k = 64.min(d);
                for a in 1..f {
                    let j = a as f64 / f as f64;
                    let vs = var_sigma_pi(d, f, a, k);
                    let vm = var_minhash(j, k);
                    assert!(
                        vs < vm + 1e-15,
                        "Thm 3.4 violated at D={d} f={f} a={a}: {vs} >= {vm}"
                    );
                    assert!(vs >= 0.0, "negative variance at D={d} f={f} a={a}");
                }
            }
        }
    }

    #[test]
    fn proposition_3_5_constant_ratio_in_a() {
        // For fixed (D, f, K) the ratio is the same for every 0 < a < f.
        let (d, f, k) = (500, 120, 256);
        let base = variance_ratio(d, f, 1, k).unwrap();
        for a in [2usize, 10, 60, 100, 119] {
            let r = variance_ratio(d, f, a, k).unwrap();
            // tolerance: the run-formula sums ~f ln/exp terms, so allow
            // accumulated float noise of ~1e-7 relative
            assert!(
                (r - base).abs() < 1e-7 * base,
                "Prop 3.5 violated at a={a}: {r} vs {base}"
            );
        }
    }

    #[test]
    fn proposition_3_2_symmetry_in_a() {
        // Var for (D, f, a) equals Var for (D, f, f−a).
        let (d, f, k) = (300, 80, 128);
        for a in 1..f {
            let v1 = var_sigma_pi(d, f, a, k);
            let v2 = var_sigma_pi(d, f, f - a, k);
            // ~1e-8 relative noise is expected: the run-formula goes
            // through exp(ln-choose) with exponents of O(D ln D).
            assert!(
                (v1 - v2).abs() < 1e-6 * v1.abs().max(1e-12),
                "Prop 3.2 violated at a={a}: {v1} vs {v2}"
            );
        }
    }

    #[test]
    fn ratio_improves_with_k_and_f() {
        // Figure 5's trends: ratio increases with K and with f.
        let d = 500;
        let r_k64 = variance_ratio(d, 200, 50, 64).unwrap();
        let r_k400 = variance_ratio(d, 200, 50, 400).unwrap();
        assert!(r_k400 > r_k64);
        let r_f50 = variance_ratio(d, 50, 10, 256).unwrap();
        let r_f400 = variance_ratio(d, 400, 10, 256).unwrap();
        assert!(r_f400 > r_f50);
    }
}
