//! One Permutation Hashing (OPH) and its circulant variant C-OPH.
//!
//! Classical MinHash (and C-MinHash) spend O(f·K) work per sketch: every
//! nonzero is looked at once *per hash*.  OPH (Li, Owen & Zhang, 2012)
//! instead permutes the universe **once**, splits the permuted axis into
//! K equal bins, and takes one minimum per bin — each nonzero touches
//! exactly one bin, so a sketch costs **O(f)** total.  Empty bins are
//! repaired by *optimal densification* (Shrivastava, 2017): each empty
//! bin copies the value of a uniformly re-hashed non-empty bin, which
//! preserves the unbiasedness of the collision estimator.
//!
//! C-OPH (Li & Li, arXiv:2111.09544) applies the C-MinHash idea to OPH:
//! an initial σ scatters the data into random bins (exactly the role σ
//! plays in C-MinHash-(σ, π)), and then **one** permutation of length
//! D/K — re-used across the K bins via circulant shifts (bin b
//! re-orders its local offsets with the shift-by-b rotation) — replaces
//! the in-bin ordering that OPH's full-length permutation provided.
//! The sketch stays O(f).
//!
//! Both hashers store, per bin, the *global* permuted position of the
//! bin's minimum (a value in `0..D`, sentinel `D` for a vector with no
//! nonzeros anywhere) — so slot values from different source bins can
//! never collide accidentally, and the sentinel/estimator conventions
//! match the circulant hashers ([`CMinHasher`](super::CMinHasher),
//! [`ZeroPiHasher`](super::ZeroPiHasher)).

use super::perm::{Perm, Role};
use super::Sketcher;

/// SplitMix64-style finalizer used as the 2-universal probe hash of
/// optimal densification: attempt `t` for empty bin `b` probes bin
/// `mix(seed, b, t) mod K`.  Both vectors of a pair share the hasher
/// (same seed), hence the same probe sequences — the property the
/// densification unbiasedness proof needs.
#[inline]
fn mix(seed: u64, bin: u64, attempt: u64) -> u64 {
    let mut z = seed
        ^ bin.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ attempt.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Optimal densification: every empty bin (slot still holding
/// `sentinel`) copies the pre-densification value of a non-empty bin
/// chosen by rehashing `(bin, attempt)` until an occupied bin is hit.
/// A sketch with *no* occupied bin (the all-zero vector) is left as
/// all-sentinel, matching every other hasher in this crate.
///
/// The probe loop is bounded: after `64·K` misses (probability ≈ 0 for
/// any vector with at least one nonzero) it falls back to the nearest
/// occupied bin to the right, so the function always terminates and
/// stays deterministic per `(seed, empty-pattern)`.
// The fallback scan runs only when at least one bin is occupied (the
// all-empty case returned earlier), so `expect` cannot fire.
#[allow(clippy::disallowed_methods)]
fn densify(out: &mut [u32], sentinel: u32, seed: u64) {
    // Fast path: dense-enough vectors (f ≫ K, the common serving case)
    // leave no bin empty — keep the advertised O(f) sketch cost
    // allocation-free for them.
    if !out.contains(&sentinel) {
        return;
    }
    let k = out.len();
    let occupied: Vec<bool> = out.iter().map(|&v| v != sentinel).collect();
    if occupied.iter().all(|&o| !o) {
        return;
    }
    let snapshot: Vec<u32> = out.to_vec();
    for b in 0..k {
        if occupied[b] {
            continue;
        }
        let mut src = None;
        for t in 1..=(64 * k as u64) {
            let cand = (mix(seed, b as u64, t) % k as u64) as usize;
            if occupied[cand] {
                src = Some(cand);
                break;
            }
        }
        let src = src.unwrap_or_else(|| {
            (1..k)
                .map(|step| (b + step) % k)
                .find(|&c| occupied[c])
                .expect("some bin is occupied")
        });
        out[b] = snapshot[src];
    }
}

/// One Permutation Hashing with optimal densification.
///
/// One permutation π of `0..D`; bin `b` of the sketch covers permuted
/// positions `[b·D/K, (b+1)·D/K)` and holds the smallest permuted
/// position of the vector's nonzeros that lands there (empty bins are
/// densified).  Requires `K | D` so every bin has the same width.
///
/// ```
/// use cminhash::sketch::{OphHasher, Sketcher};
/// let h = OphHasher::new(64, 16, 7).unwrap();        // D=64, K=16 bins
/// let sk = h.sketch_sparse(&[3, 17, 40, 63]);
/// assert_eq!(sk.len(), 16);
/// assert!(sk.iter().all(|&v| v < 64), "densified: no sentinel left");
/// ```
#[derive(Clone, Debug)]
pub struct OphHasher {
    d: usize,
    k: usize,
    /// Bin width m = D/K.
    m: usize,
    /// π as a value array: `pi[s]` is the permuted position of index s.
    pi: Vec<u32>,
    /// Densification probe seed.
    seed: u64,
}

impl OphHasher {
    /// Seeded constructor; errors unless `1 <= K <= D` and `K | D`.
    pub fn new(d: usize, k: usize, seed: u64) -> crate::Result<Self> {
        let pi = Perm::generate(d, seed, Role::Oph);
        Self::from_perm(k, &pi, seed)
    }

    /// Explicit binning permutation (length D); errors unless
    /// `1 <= K <= D` and `K | D`.
    pub fn from_perm(k: usize, pi: &Perm, densify_seed: u64) -> crate::Result<Self> {
        let d = pi.len();
        check_bins(d, k)?;
        Ok(OphHasher {
            d,
            k,
            m: d / k,
            pi: pi.values().to_vec(),
            seed: densify_seed,
        })
    }

    /// Bin width D/K.
    pub fn bin_width(&self) -> usize {
        self.m
    }
}

impl Sketcher for OphHasher {
    fn dim(&self) -> usize {
        self.d
    }

    fn num_hashes(&self) -> usize {
        self.k
    }

    fn sketch_sparse(&self, nonzeros: &[u32]) -> Vec<u32> {
        let mut out = vec![self.d as u32; self.k];
        for &s in nonzeros {
            debug_assert!((s as usize) < self.d);
            let p = self.pi[s as usize];
            let bin = p as usize / self.m;
            if p < out[bin] {
                out[bin] = p;
            }
        }
        densify(&mut out, self.d as u32, self.seed);
        out
    }
}

/// C-OPH (arXiv:2111.09544): One Permutation Hashing where one
/// circulant permutation of length **D/K** replaces the in-bin
/// ordering across all K bins.
///
/// Exactly like C-MinHash-(σ, π), an initial full-length permutation σ
/// first scatters the data (randomizing *which bin* every index lands
/// in — without it, deterministic binning makes the estimator biased
/// on structured data, the OPH analogue of the paper's Figure-7
/// degradation for C-MinHash-(0, π)).  The scattered axis is split
/// into K bins of width m = D/K; a nonzero landing in bin `b` at local
/// offset `j` gets the in-bin rank `π_m[(j − b) mod m]` — the
/// shift-by-`b` rotation of the **single** small permutation π_m —
/// and bin `b`'s slot keeps the global value `b·m + min rank` (empty
/// bins are densified).
///
/// Versus OPH, the length-D binning permutation's second job (in-bin
/// ordering) is done by a length-D/K array; versus C-MinHash, a
/// sketch costs **O(f)** instead of O(f·K).
///
/// ```
/// use cminhash::sketch::{CophHasher, Sketcher};
/// let h = CophHasher::new(64, 16, 7).unwrap();       // bin width 4
/// let sk = h.sketch_sparse(&[3, 17, 40, 63]);
/// assert_eq!(sk.len(), 16);
/// // slot values are global positions in 0..D (densified: no sentinel)
/// assert!(sk.iter().all(|&v| v < 64));
/// ```
#[derive(Clone, Debug)]
pub struct CophHasher {
    d: usize,
    k: usize,
    /// Bin width m = D/K (also the length of the circulant permutation).
    m: usize,
    /// σ stored as its inverse: nonzero s lands at `inv_sigma[s]`.
    inv_sigma: Vec<u32>,
    /// π_m ‖ π_m — doubled so shift-by-`b` is the contiguous window
    /// `pi2[j + m − (b mod m)]`, zero modular arithmetic (the same
    /// trick as [`CMinHasher`](super::CMinHasher)'s doubled π).
    pi2: Vec<u32>,
    /// Densification probe seed.
    seed: u64,
}

impl CophHasher {
    /// Seeded constructor (σ on the same stream as [`CMinHasher`]'s σ
    /// for the same seed, so ablations are paired); errors unless
    /// `1 <= K <= D` and `K | D`.
    ///
    /// [`CMinHasher`]: super::CMinHasher
    pub fn new(d: usize, k: usize, seed: u64) -> crate::Result<Self> {
        check_bins(d, k)?;
        let sigma = Perm::generate(d, seed, Role::Sigma);
        let pi = Perm::generate(d / k, seed, Role::Oph);
        Self::from_perms(k, &sigma, &pi, seed)
    }

    /// Explicit permutations: σ of length D, the circulant in-bin
    /// permutation of length D/K; errors unless `1 <= K <= D` and
    /// `K | D`.
    pub fn from_perms(
        k: usize,
        sigma: &Perm,
        pi: &Perm,
        densify_seed: u64,
    ) -> crate::Result<Self> {
        let d = sigma.len();
        check_bins(d, k)?;
        let m = d / k;
        if pi.len() != m {
            return Err(crate::Error::Invalid(format!(
                "C-OPH circulant permutation has length {}, need D/K = {m}",
                pi.len()
            )));
        }
        Ok(CophHasher {
            d,
            k,
            m,
            inv_sigma: sigma.inverse().values().to_vec(),
            pi2: pi.doubled(),
            seed: densify_seed,
        })
    }

    /// Bin width D/K (= the circulant permutation's length).
    pub fn bin_width(&self) -> usize {
        self.m
    }
}

impl Sketcher for CophHasher {
    fn dim(&self) -> usize {
        self.d
    }

    fn num_hashes(&self) -> usize {
        self.k
    }

    fn sketch_sparse(&self, nonzeros: &[u32]) -> Vec<u32> {
        let m = self.m;
        let mut out = vec![self.d as u32; self.k];
        for &s in nonzeros {
            debug_assert!((s as usize) < self.d);
            let q = self.inv_sigma[s as usize] as usize;
            let bin = q / m;
            let j = q % m;
            let sh = bin % m;
            // π_m[(j − bin) mod m] via the doubled array; j + m − sh is
            // always within 1..2m.
            let rank = self.pi2[j + m - sh];
            let global = (bin * m) as u32 + rank;
            if global < out[bin] {
                out[bin] = global;
            }
        }
        densify(&mut out, self.d as u32, self.seed);
        out
    }
}

/// Bin-shape validation for the OPH family — the single authority for
/// the equal-width-bin constraint, shared by the hasher constructors
/// and [`SketchScheme::validate`](super::SketchScheme::validate) so
/// the config/CLI path and direct construction give one diagnostic.
pub(super) fn check_bins(d: usize, k: usize) -> crate::Result<()> {
    if k == 0 || k > d {
        return Err(crate::Error::Invalid(format!(
            "need 1 <= K <= D, got K={k}, D={d}"
        )));
    }
    if d % k != 0 {
        return Err(crate::Error::Invalid(format!(
            "OPH/C-OPH need K to divide D so bins are equal-width, \
             got D={d}, K={k} (D mod K = {}); pick a K dividing D, or \
             another scheme",
            d % k
        )));
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::sketch::{estimate, SparseVec};

    #[test]
    fn bin_shape_validation() {
        assert!(OphHasher::new(64, 0, 1).is_err());
        assert!(OphHasher::new(64, 65, 1).is_err());
        assert!(OphHasher::new(64, 48, 1).is_err(), "48 does not divide 64");
        assert!(OphHasher::new(64, 64, 1).is_ok());
        assert!(CophHasher::new(64, 48, 1).is_err());
        assert!(CophHasher::new(64, 16, 1).is_ok());
        // explicit C-OPH circulant perm must be bin-width long
        let sigma = Perm::identity(64);
        let wrong = Perm::identity(5);
        assert!(CophHasher::from_perms(16, &sigma, &wrong, 0).is_err());
        let right = Perm::identity(4);
        assert!(CophHasher::from_perms(16, &sigma, &right, 0).is_ok());
    }

    #[test]
    fn oph_bins_hold_their_own_minima_before_densification() {
        // Identity permutation makes the binning transparent: bin b of
        // a full-width vector must hold exactly b*m.
        let d = 32;
        let k = 8; // m = 4
        let h = OphHasher::from_perm(k, &Perm::identity(d), 9).unwrap();
        let all: Vec<u32> = (0..d as u32).collect();
        let sk = h.sketch_sparse(&all);
        assert_eq!(sk, vec![0, 4, 8, 12, 16, 20, 24, 28]);
        // a single nonzero occupies one bin; the rest copy it
        let sk = h.sketch_sparse(&[9]);
        assert!(sk.iter().all(|&v| v == 9), "{sk:?}");
    }

    #[test]
    fn coph_identity_perms_make_ranks_transparent() {
        // Identity σ keeps s in place; identity π_m maps local offset j
        // in bin b to rank (j - b) mod m.  Over a full vector every
        // bin's min rank is 0, i.e. global b*m.
        let d = 32;
        let k = 8; // m = 4
        let h =
            CophHasher::from_perms(k, &Perm::identity(d), &Perm::identity(4), 9).unwrap();
        let all: Vec<u32> = (0..d as u32).collect();
        assert_eq!(h.sketch_sparse(&all), vec![0, 4, 8, 12, 16, 20, 24, 28]);
        // one nonzero s = 9: bin 2, j = 1, shift 2 -> rank (1-2) mod 4 = 3
        let sk = h.sketch_sparse(&[9]);
        assert!(sk.iter().all(|&v| v == 2 * 4 + 3), "{sk:?}");
    }

    #[test]
    fn coph_sigma_randomizes_binning() {
        // Regression: without σ, raw-index binning left structured
        // vectors in fixed bins and the estimator was measurably
        // biased on exactly the range-structured data the tests use.
        // With σ the bin a nonzero lands in must follow inv_sigma.
        let d = 32;
        let k = 8;
        let h = CophHasher::new(d, k, 3).unwrap();
        let sigma = Perm::generate(d, 3, Role::Sigma);
        let q = sigma.inverse().at(9) as usize;
        let sk = h.sketch_sparse(&[9]);
        // the single occupied bin is q/m, and densification copied its
        // value everywhere
        assert!(sk.iter().all(|&v| v == sk[q / 4]), "{sk:?}");
        assert_eq!(sk[q / 4] as usize / 4, q / 4, "value stays in its bin");
    }

    #[test]
    fn empty_vector_keeps_sentinels() {
        for h in [
            Box::new(OphHasher::new(32, 8, 1).unwrap()) as Box<dyn Sketcher>,
            Box::new(CophHasher::new(32, 8, 1).unwrap()),
        ] {
            assert!(h.sketch_sparse(&[]).iter().all(|&v| v == 32));
        }
    }

    #[test]
    fn sketches_are_deterministic_and_in_range() {
        let oph = OphHasher::new(256, 32, 11).unwrap();
        let coph = CophHasher::new(256, 32, 11).unwrap();
        let nz: Vec<u32> = vec![0, 7, 100, 200, 255];
        for h in [&oph as &dyn Sketcher, &coph] {
            let a = h.sketch_sparse(&nz);
            assert_eq!(a, h.sketch_sparse(&nz));
            assert!(a.iter().all(|&v| v < 256), "densified values in 0..D");
        }
    }

    #[test]
    fn densify_copies_only_from_occupied_bins() {
        let sentinel = 100;
        let mut out = vec![sentinel, 7, sentinel, 42, sentinel, sentinel];
        densify(&mut out, sentinel, 33);
        assert!(out.iter().all(|&v| v == 7 || v == 42), "{out:?}");
        assert_eq!(out[1], 7);
        assert_eq!(out[3], 42);
        // fully dense and fully empty are both no-ops
        let mut full = vec![1, 2, 3];
        densify(&mut full, 9, 0);
        assert_eq!(full, vec![1, 2, 3]);
        let mut empty = vec![9, 9];
        densify(&mut empty, 9, 0);
        assert_eq!(empty, vec![9, 9]);
    }

    #[test]
    fn estimates_track_exact_jaccard_on_average() {
        // Mean estimate over many seeds must approach the exact J for
        // both schemes (the densified estimator is unbiased).
        const K: usize = 16;
        let v = SparseVec::new(64, (0..24).collect()).unwrap();
        let w = SparseVec::new(64, (12..36).collect()).unwrap();
        let truth = v.jaccard(&w); // 12/36 = 1/3
        for build in [
            (|seed| Box::new(OphHasher::new(64, K, seed).unwrap()) as Box<dyn Sketcher>)
                as fn(u64) -> Box<dyn Sketcher>,
            |seed| Box::new(CophHasher::new(64, K, seed).unwrap()),
        ] {
            let trials = 300;
            let mut sum = 0.0;
            for seed in 0..trials {
                let h = build(seed);
                sum += estimate(
                    &h.sketch_sparse(v.indices()),
                    &h.sketch_sparse(w.indices()),
                );
            }
            let mean = sum / trials as f64;
            assert!(
                (mean - truth).abs() < 0.04,
                "mean {mean} vs truth {truth}"
            );
        }
    }
}
