//! Sparse binary vector type shared by the data layer and the hashers.

use crate::util::json::Json;

/// A D-dimensional binary vector stored as sorted unique nonzero
/// indices — the natural representation for the massive sparse data
/// MinHash targets (bag-of-words, shingles, pixels…).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SparseVec {
    dim: u32,
    indices: Vec<u32>,
}

impl SparseVec {
    /// Build from arbitrary indices (sorted + deduped; out-of-range
    /// rejected).
    pub fn new(dim: u32, mut indices: Vec<u32>) -> crate::Result<Self> {
        indices.sort_unstable();
        indices.dedup();
        if let Some(&last) = indices.last() {
            if last >= dim {
                return Err(crate::Error::Invalid(format!(
                    "index {last} out of range for dim {dim}"
                )));
            }
        }
        Ok(SparseVec { dim, indices })
    }

    /// Build from a dense 0/1 slice.
    pub fn from_dense(bits: &[u8]) -> Self {
        let indices = bits
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0)
            .map(|(i, _)| i as u32)
            .collect();
        SparseVec {
            dim: bits.len() as u32,
            indices,
        }
    }

    /// Dense 0/1 expansion.
    pub fn to_dense(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.dim as usize];
        for &i in &self.indices {
            out[i as usize] = 1;
        }
        out
    }

    /// Dense expansion as i32 (artifact input dtype).
    pub fn to_dense_i32(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.dim as usize];
        for &i in &self.indices {
            out[i as usize] = 1;
        }
        out
    }

    /// Dimensionality D.
    pub fn dim(&self) -> u32 {
        self.dim
    }

    /// Number of nonzeros f.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Sorted nonzero indices.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// Exact Jaccard similarity with another vector (eq. 1) via sorted
    /// merge — the ground truth every estimator is scored against.
    pub fn jaccard(&self, other: &SparseVec) -> f64 {
        let (mut i, mut j) = (0usize, 0usize);
        let (a, b) = (&self.indices, &other.indices);
        let mut inter = 0usize;
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    inter += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        let union = a.len() + b.len() - inter;
        if union == 0 {
            0.0
        } else {
            inter as f64 / union as f64
        }
    }

    /// JSON form: `{"dim": D, "indices": [...]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dim", Json::Num(f64::from(self.dim))),
            ("indices", Json::from_u32s(&self.indices)),
        ])
    }

    /// Parse the JSON form (validates ranges).
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        SparseVec::new(j.get("dim")?.as_u32()?, j.get("indices")?.as_u32_vec()?)
    }

    /// Intersection size a and union size f with another vector.
    pub fn overlap(&self, other: &SparseVec) -> (usize, usize) {
        let (mut i, mut j) = (0usize, 0usize);
        let (a, b) = (&self.indices, &other.indices);
        let mut inter = 0usize;
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    inter += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        (inter, a.len() + b.len() - inter)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn new_sorts_dedups_and_validates() {
        let v = SparseVec::new(10, vec![5, 1, 5, 3]).unwrap();
        assert_eq!(v.indices(), &[1, 3, 5]);
        assert!(SparseVec::new(4, vec![4]).is_err());
    }

    #[test]
    fn dense_roundtrip() {
        let bits = [0u8, 1, 0, 0, 1, 1];
        let v = SparseVec::from_dense(&bits);
        assert_eq!(v.to_dense(), bits.to_vec());
        assert_eq!(v.nnz(), 3);
    }

    #[test]
    fn json_roundtrip() {
        let v = SparseVec::new(100, vec![3, 50, 99]).unwrap();
        let j = v.to_json();
        let back = SparseVec::from_json(&j).unwrap();
        assert_eq!(back, v);
        // malformed rejected
        let bad = crate::util::json::Json::parse(r#"{"dim":4,"indices":[9]}"#).unwrap();
        assert!(SparseVec::from_json(&bad).is_err());
    }

    #[test]
    fn jaccard_matches_definition() {
        let v = SparseVec::new(16, vec![0, 1, 2, 3]).unwrap();
        let w = SparseVec::new(16, vec![2, 3, 4, 5]).unwrap();
        assert!((v.jaccard(&w) - 2.0 / 6.0).abs() < 1e-12);
        assert_eq!(v.overlap(&w), (2, 6));
        let empty = SparseVec::new(16, vec![]).unwrap();
        assert_eq!(empty.jaccard(&empty), 0.0);
        assert!((v.jaccard(&v) - 1.0).abs() < 1e-12);
    }
}
